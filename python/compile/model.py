"""Layer-2 JAX model: the conv layers / CNN forward pass that get
AOT-lowered to HLO artifacts, built on the Layer-1 Pallas kernels.

The CNN mirrors `coordinator::network::ConvNet` on the Rust side: a
stack of 3x3 valid convolutions with integer ReLU between layers (none
after the last). Weights are *arguments*, so the Rust runtime can feed
the exact tensors it used on the CGRA simulator and compare bit-exactly.
"""

import jax.numpy as jnp

from .kernels.conv_direct import conv2d_direct
from .kernels.conv_im2col import conv2d_im2col


def conv_layer(x, w, kind: str = "direct"):
    """One conv layer through the chosen Pallas kernel."""
    if kind == "direct":
        return conv2d_direct(x, w)
    if kind == "im2col":
        return conv2d_im2col(x, w)
    raise ValueError(f"unknown kernel kind {kind!r}")


def cnn_fwd(x, *weights, kind: str = "direct"):
    """Forward pass of the conv stack; ReLU after all but the last layer.

    Returns a 1-tuple (the AOT bridge lowers with return_tuple=True).
    """
    n = len(weights)
    for i, w in enumerate(weights):
        x = conv_layer(x, w, kind=kind)
        if i + 1 < n:
            x = jnp.maximum(x, 0)
    return (x,)


def conv_fwd(x, w, kind: str = "direct"):
    """Single conv layer entry point (1-tuple for the AOT bridge)."""
    return (conv_layer(x, w, kind=kind),)
