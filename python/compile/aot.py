"""AOT bridge: lower the Layer-2 JAX functions to HLO **text** artifacts
plus a JSON manifest the Rust runtime consumes.

HLO text (not serialized HloModuleProto) is the interchange format: the
image's xla_extension 0.5.1 rejects jax>=0.5's 64-bit-instruction-id
protos, while the text parser reassigns ids (see
/opt/xla-example/README.md). Python runs ONLY here — never on the Rust
request path.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import functools
import json
import os

import jax
from jax._src.lib import xla_client as xc
import jax.numpy as jnp

from .model import cnn_fwd, conv_fwd

# Conv artifact shapes: (C, K, OX, OY). Small ones verify cheaply; the
# baseline is the paper's Fig. 4 layer.
CONV_SHAPES = [
    (2, 3, 4, 5),
    (4, 4, 8, 8),
    (5, 17, 4, 3),
    (16, 16, 16, 16),
]

# CNN artifact: mirrors ConvNet::random(depth=3, c0=3, k=8, h=w=12).
CNN_SPEC = {"c0": 3, "k": 8, "h": 12, "w": 12, "depth": 3}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_conv(c, k, ox, oy, kind):
    fn = functools.partial(conv_fwd, kind=kind)
    return jax.jit(fn).lower(i32(c, ox + 2, oy + 2), i32(k, c, 3, 3))


def lower_cnn(spec, kind):
    args = [i32(spec["c0"], spec["h"], spec["w"])]
    c, h, w = spec["c0"], spec["h"], spec["w"]
    for _ in range(spec["depth"]):
        args.append(i32(spec["k"], c, 3, 3))
        c, h, w = spec["k"], h - 2, w - 2
    fn = functools.partial(cnn_fwd, kind=kind)
    return jax.jit(fn).lower(*args)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": 1, "artifacts": []}

    for c, k, ox, oy in CONV_SHAPES:
        for kind in ("direct", "im2col"):
            name = f"conv_{kind}_c{c}k{k}o{ox}x{oy}"
            path = f"{name}.hlo.txt"
            text = to_hlo_text(lower_conv(c, k, ox, oy, kind))
            with open(os.path.join(args.out, path), "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "file": path,
                    "kind": "conv",
                    "kernel": kind,
                    "c": c,
                    "k": k,
                    "ox": ox,
                    "oy": oy,
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    name = "cnn_direct"
    path = f"{name}.hlo.txt"
    text = to_hlo_text(lower_cnn(CNN_SPEC, "direct"))
    with open(os.path.join(args.out, path), "w") as f:
        f.write(text)
    manifest["artifacts"].append(
        {"name": name, "file": path, "kind": "cnn", "kernel": "direct", **CNN_SPEC}
    )
    print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
