"""Pure-jnp oracle for the convolution kernels.

This is the CORE correctness signal of the Python layer: both Pallas
kernels (direct and im2col) must match it bit-exactly, and the Rust side
verifies the CGRA simulator against the AOT artifact lowered from the
same functions.

All data is int32 with wrapping (two's-complement) semantics, matching
the paper's 32-bit integer kernels and the Rust simulator exactly.
"""

import jax.numpy as jnp


def conv2d_ref(x, w):
    """Direct 3x3 valid convolution, stride 1, groups 1.

    Args:
      x: int32[C, IH, IW]   input, CHW.
      w: int32[K, C, 3, 3]  weights.

    Returns:
      int32[K, OX, OY] with OX = IH-2, OY = IW-2.
    """
    c, ih, iw = x.shape
    k, cw, fy, fx = w.shape
    assert cw == c and fy == 3 and fx == 3, (x.shape, w.shape)
    ox, oy = ih - 2, iw - 2
    acc = jnp.zeros((k, ox, oy), jnp.int32)
    for dy in range(3):
        for dx in range(3):
            patch = x[:, dy : dy + ox, dx : dx + oy]  # [C, OX, OY]
            # [K, C] x [C, OX*OY] contraction in int32.
            taps = w[:, :, dy, dx]  # [K, C]
            acc = acc + jnp.einsum(
                "kc,cxy->kxy", taps, patch, preferred_element_type=jnp.int32
            )
    return acc


def relu_ref(x):
    """Integer ReLU."""
    return jnp.maximum(x, 0)


def cnn_ref(x, weights, relu_mask):
    """Reference forward pass of a conv stack.

    Args:
      x: int32[C0, H, W].
      weights: list of int32[K, C, 3, 3].
      relu_mask: list of bool, whether ReLU follows each layer.
    """
    for w, relu in zip(weights, relu_mask):
        x = conv2d_ref(x, w)
        if relu:
            x = relu_ref(x)
    return x
