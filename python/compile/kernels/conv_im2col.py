"""Layer-1 Pallas kernel: im2col + matmul (the IP/OP paradigm).

The paper's alternative implementation: trade extra memory and reorder
work for purely sequential access and a dense matrix multiply. On TPU
terms (DESIGN.md §Hardware-Adaptation) the patch matrix is staged into
VMEM and fed to an MXU-shaped contraction; on this CPU-only install the
kernel runs under `interpret=True` and the contraction is an int32
`jnp.dot` (integer convolutions don't use the bf16 MXU path anyway —
documented as part of the adaptation).

Grid: one program instance per K-tile of output channels (tile = 16,
mirroring the paper's 16-PE output-channel parallelism).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

K_TILE = 16


def _im2col(x, ox, oy):
    """Patch matrix [OX*OY, C*9] in (fy, fx, c) column order — the same
    HWC-friendly order as the Rust `conv::im2col_patch`."""
    c = x.shape[0]
    cols = []
    for dy in range(3):
        for dx in range(3):
            cols.append(x[:, dy : dy + ox, dx : dx + oy].reshape(c, ox * oy))
    # [9, C, P] -> [P, 9*C] with channel fastest within each tap.
    stacked = jnp.stack(cols, axis=0)
    return stacked.transpose(2, 0, 1).reshape(ox * oy, 9 * c)


def _kernel(x_ref, w_ref, o_ref, *, ox: int, oy: int):
    """One K-tile: build the patch matrix, contract with the tile's
    weight rows."""
    x = x_ref[...]  # [C, IH, IW]
    wm = w_ref[...]  # [K_TILE, 9*C] (padded rows for the last tile)
    patches = _im2col(x, ox, oy)  # [P, 9*C]
    out = jnp.dot(patches, wm.T, preferred_element_type=jnp.int32)  # [P, K_TILE]
    o_ref[...] = out.T.reshape(K_TILE, ox, oy)


def _weights_matrix(w):
    """KCFF weights -> im2col rows [(fy*3+fx)*C + c], padded to a
    multiple of K_TILE rows (matching the Rust `Weights::to_im2col_matrix`
    order and the idle-lane padding of the CGRA kernels)."""
    k, c = w.shape[0], w.shape[1]
    wm = w.transpose(0, 2, 3, 1).reshape(k, 9 * c)  # [(fy,fx,c)] order
    pad = (-k) % K_TILE
    if pad:
        wm = jnp.concatenate([wm, jnp.zeros((pad, 9 * c), jnp.int32)], axis=0)
    return wm


def conv2d_im2col(x, w):
    """Im2col convolution via the Pallas kernel.

    Args / returns as `ref.conv2d_ref` (int32, CHW in, KHW out).
    """
    c, ih, iw = x.shape
    k = w.shape[0]
    ox, oy = ih - 2, iw - 2
    wm = _weights_matrix(w)
    ktiles = wm.shape[0] // K_TILE
    kern = functools.partial(_kernel, ox=ox, oy=oy)
    out = pl.pallas_call(
        kern,
        grid=(ktiles,),
        in_specs=[
            pl.BlockSpec((c, ih, iw), lambda i: (0, 0, 0)),
            pl.BlockSpec((K_TILE, 9 * c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((K_TILE, ox, oy), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ktiles * K_TILE, ox, oy), jnp.int32),
        interpret=True,
    )(x, wm)
    return out[:k]


def buffer_words(c: int, ih: int, iw: int) -> int:
    """Estimated VMEM residency (words) of one grid step: input + patch
    matrix + weight tile + output tile."""
    ox, oy = ih - 2, iw - 2
    p = ox * oy
    return c * ih * iw + p * 9 * c + K_TILE * 9 * c + K_TILE * p
