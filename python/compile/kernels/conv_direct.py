"""Layer-1 Pallas kernel: direct convolution, weight-stationary (WP).

The TPU re-expression of the paper's winning mapping (DESIGN.md
§Hardware-Adaptation): instead of pinning one 3x3 tap per PE, the kernel
pins one output channel's full filter bank in VMEM while the spatial
extent streams through — the same "maximal weight reuse, CHW layout"
insight, tiled for a scratchpad + vector-unit machine rather than a 4x4
torus.

Grid: one program instance per output channel K. Per instance:
  - x block:  the whole CHW input  (C x IH x IW) resident in VMEM;
  - w block:  that channel's filters (1 x C x 3 x 3) — weight-stationary;
  - o block:  the channel's output plane (1 x OX x OY).

`interpret=True` is mandatory on this CPU-only install: real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
VMEM-footprint / MXU-utilization estimates for a real TPU are in
DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, ox: int, oy: int):
    """One output channel: accumulate the nine shifted tap products."""
    x = x_ref[...]  # [C, IH, IW] in VMEM
    w = w_ref[...]  # [1, C, 3, 3] stationary
    acc = jnp.zeros((ox, oy), jnp.int32)
    for dy in range(3):
        for dx in range(3):
            patch = x[:, dy : dy + ox, dx : dx + oy]  # [C, OX, OY]
            taps = w[0, :, dy, dx]  # [C]
            acc = acc + jnp.sum(patch * taps[:, None, None], axis=0, dtype=jnp.int32)
    o_ref[0, :, :] = acc


def conv2d_direct(x, w):
    """Direct convolution via the weight-stationary Pallas kernel.

    Args / returns as `ref.conv2d_ref` (int32, CHW in, KHW out).
    """
    c, ih, iw = x.shape
    k = w.shape[0]
    ox, oy = ih - 2, iw - 2
    kern = functools.partial(_kernel, ox=ox, oy=oy)
    return pl.pallas_call(
        kern,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((c, ih, iw), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, c, 3, 3), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ox, oy), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, ox, oy), jnp.int32),
        interpret=True,
    )(x, w)


def vmem_words(c: int, ih: int, iw: int) -> int:
    """Estimated VMEM residency (32-bit words) of one grid step — the
    number the real-TPU feasibility table in DESIGN.md §Perf reports."""
    ox, oy = ih - 2, iw - 2
    return c * ih * iw + c * 9 + ox * oy
