"""Layer-2 model shape/semantics tests + AOT lowering smoke tests."""

import numpy as np
import jax
import jax.numpy as jnp

from compile.aot import CNN_SPEC, i32, lower_cnn, lower_conv, to_hlo_text
from compile.kernels.ref import cnn_ref
from compile.model import cnn_fwd, conv_fwd


def rand(shape, mag, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-mag, mag + 1, size=shape, dtype=np.int64).astype(np.int32))


def test_cnn_fwd_matches_ref():
    spec = CNN_SPEC
    x = rand((spec["c0"], spec["h"], spec["w"]), 8, seed=1)
    ws, c = [], spec["c0"]
    for i in range(spec["depth"]):
        ws.append(rand((spec["k"], c, 3, 3), 4, seed=2 + i))
        c = spec["k"]
    (got,) = cnn_fwd(x, *ws)
    relu_mask = [True] * (spec["depth"] - 1) + [False]
    want = cnn_ref(x, ws, relu_mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_fwd_is_tupled():
    x = rand((2, 5, 5), 5, seed=3)
    w = rand((3, 2, 3, 3), 5, seed=4)
    out = conv_fwd(x, w)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (3, 3, 3)


def test_conv_lowering_emits_hlo_text():
    text = to_hlo_text(lower_conv(2, 3, 4, 5, "direct"))
    assert "HloModule" in text
    assert "s32" in text  # int32 computation throughout


def test_cnn_lowering_has_all_weight_params():
    text = to_hlo_text(lower_cnn(CNN_SPEC, "direct"))
    assert "HloModule" in text
    # 1 input + depth weight parameters.
    for i in range(CNN_SPEC["depth"] + 1):
        assert f"parameter({i})" in text


def test_lowered_conv_executes_like_eager():
    # Round-trip through XLA compilation (CPU) — the same computation the
    # Rust runtime executes from the artifact.
    lowered = lower_conv(2, 3, 4, 5, "im2col")
    compiled = lowered.compile()
    x = rand((2, 6, 7), 30, seed=7)
    w = rand((3, 2, 3, 3), 9, seed=8)
    (got,) = compiled(x, w)
    (want,) = conv_fwd(x, w, kind="im2col")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_i32_spec_helper():
    s = i32(2, 3)
    assert s.shape == (2, 3) and s.dtype == jnp.int32
