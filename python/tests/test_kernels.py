"""Pallas kernels vs the pure-jnp oracle — exact int32 equality,
including hypothesis sweeps over shapes and value ranges."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.conv_direct import conv2d_direct
from compile.kernels.conv_im2col import conv2d_im2col
from compile.kernels.ref import cnn_ref, conv2d_ref


def rand(shape, mag, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-mag, mag + 1, size=shape, dtype=np.int64).astype(np.int32))


KERNELS = [("direct", conv2d_direct), ("im2col", conv2d_im2col)]


@pytest.mark.parametrize("name,fn", KERNELS)
@pytest.mark.parametrize(
    "c,k,ox,oy",
    [(1, 1, 2, 2), (2, 3, 4, 5), (4, 4, 8, 8), (5, 17, 4, 3), (16, 16, 8, 8), (16, 2, 16, 16)],
)
def test_kernel_matches_ref(name, fn, c, k, ox, oy):
    x = rand((c, ox + 2, oy + 2), 50, seed=c * 131 + k * 17 + ox)
    w = rand((k, c, 3, 3), 9, seed=k * 7 + oy)
    got = fn(x, w)
    want = conv2d_ref(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want), err_msg=name)


@pytest.mark.parametrize("name,fn", KERNELS)
def test_kernel_wraps_like_int32(name, fn):
    # Large magnitudes force wraparound; the kernel must wrap identically
    # to the oracle (and to the Rust simulator's wrapping arithmetic).
    x = rand((3, 6, 6), 2**30, seed=1)
    w = rand((2, 3, 3, 3), 2**20, seed=2)
    got = np.asarray(fn(x, w))
    want = np.asarray(conv2d_ref(x, w))
    np.testing.assert_array_equal(got, want, err_msg=name)


@settings(max_examples=25, deadline=None)
@given(
    c=st.integers(1, 8),
    k=st.integers(1, 20),
    ox=st.integers(1, 10),
    oy=st.integers(1, 10),
    mag=st.sampled_from([1, 7, 100, 10_000]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_direct_vs_ref(c, k, ox, oy, mag, seed):
    x = rand((c, ox + 2, oy + 2), mag, seed)
    w = rand((k, c, 3, 3), mag, seed ^ 0x5EED)
    np.testing.assert_array_equal(
        np.asarray(conv2d_direct(x, w)), np.asarray(conv2d_ref(x, w))
    )


@settings(max_examples=25, deadline=None)
@given(
    c=st.integers(1, 8),
    k=st.integers(1, 20),
    ox=st.integers(1, 10),
    oy=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_im2col_vs_ref(c, k, ox, oy, seed):
    x = rand((c, ox + 2, oy + 2), 60, seed)
    w = rand((k, c, 3, 3), 9, seed ^ 0xABCD)
    np.testing.assert_array_equal(
        np.asarray(conv2d_im2col(x, w)), np.asarray(conv2d_ref(x, w))
    )


def test_kernels_agree_with_each_other():
    x = rand((6, 10, 9), 40, seed=11)
    w = rand((18, 6, 3, 3), 8, seed=12)
    np.testing.assert_array_equal(
        np.asarray(conv2d_direct(x, w)), np.asarray(conv2d_im2col(x, w))
    )


def test_cnn_ref_relu_chain():
    x = rand((3, 12, 12), 10, seed=3)
    ws = [rand((8, 3, 3, 3), 4, seed=4), rand((8, 8, 3, 3), 4, seed=5)]
    out = cnn_ref(x, ws, [True, False])
    assert out.shape == (8, 8, 8)
    # Intermediate ReLU: recomputing with clamped intermediate matches.
    mid = jnp.maximum(conv2d_ref(x, ws[0]), 0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(conv2d_ref(mid, ws[1])))
