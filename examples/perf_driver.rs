// Perf-profiling driver: run many WP launches in a tight loop.
use openedge_cgra::cgra::{Cgra, CgraConfig, Memory};
use openedge_cgra::conv::{random_input, random_weights, ConvShape};
use openedge_cgra::kernels::{run_mapping, Mapping};
use openedge_cgra::prop::Rng;

fn main() {
    let shape = ConvShape::baseline();
    let mut rng = Rng::new(1);
    let input = random_input(&shape, 10, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);
    let cgra = Cgra::new(CgraConfig::default()).unwrap();
    let _ = Memory::new(16, 4);
    for _ in 0..5 {
        let out = run_mapping(&cgra, Mapping::Wp, &shape, &input, &weights).unwrap();
        std::hint::black_box(out);
    }
}
