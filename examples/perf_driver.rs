// Perf-profiling driver: run many WP convolutions in a tight loop
// through one engine session (explicit tensors, so nothing is cached
// and every iteration is a full simulation).
use openedge_cgra::conv::{random_input, random_weights, ConvShape};
use openedge_cgra::engine::{ConvRequest, EngineBuilder};
use openedge_cgra::kernels::Mapping;
use openedge_cgra::prop::Rng;

fn main() {
    let shape = ConvShape::baseline();
    let mut rng = Rng::new(1);
    let input = random_input(&shape, 10, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);
    let engine = EngineBuilder::new().build().unwrap();
    let req = ConvRequest::with_data(shape, Mapping::Wp, input, weights);
    for _ in 0..5 {
        let out = engine.submit(&req).unwrap();
        std::hint::black_box(out);
    }
}
