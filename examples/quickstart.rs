//! Quickstart: run the paper's baseline convolution with the winning WP
//! mapping on the simulated OpenEdgeCGRA, check it bit-exactly against
//! the golden model, and print the paper's four metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use openedge_cgra::cgra::{Cgra, CgraConfig};
use openedge_cgra::conv::{conv2d, random_input, random_weights, ConvShape};
use openedge_cgra::energy::EnergyModel;
use openedge_cgra::kernels::{run_mapping, Mapping};
use openedge_cgra::metrics::MappingReport;
use openedge_cgra::prop::Rng;
use openedge_cgra::util::fmt::kib;

fn main() -> anyhow::Result<()> {
    // The paper's baseline layer: C = K = Ox = Oy = 16, 3x3 filter.
    let shape = ConvShape::baseline();
    let mut rng = Rng::new(2024);
    let input = random_input(&shape, 30, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);

    // The simulated HEEPsilon platform with calibrated timing.
    let cgra = Cgra::new(CgraConfig::default())?;

    // Direct convolution + weight parallelism (Fig. 1).
    let out = run_mapping(&cgra, Mapping::Wp, &shape, &input, &weights)?;

    // Bit-exact functional check against the golden model.
    let golden = conv2d(&shape, &input, &weights);
    assert_eq!(out.output.data, golden.data, "WP output mismatch");
    println!("functional check: CGRA output is bit-exact vs the golden conv ✔\n");

    // The paper's four metrics (§2.3).
    let report = MappingReport::from_outcome(&out, &EnergyModel::default());
    println!("layer    : {shape}");
    println!("mapping  : {} (the paper's winner)", report.mapping);
    println!("latency  : {} cycles ({:.3} ms @100 MHz)", report.latency_cycles, report.latency_ms);
    println!("energy   : {:.2} uJ  (avg power {:.2} mW)", report.energy_uj, report.avg_power_mw);
    println!("memory   : {}", kib(report.footprint_bytes));
    println!("perf     : {:.3} MAC/cycle  (paper: ~0.6)", report.mac_per_cycle);
    println!("util     : {:.1}% of PE slots active (paper: 78% in the main loop)",
        report.utilization * 100.0);
    Ok(())
}
