//! Quickstart: run the paper's baseline convolution through the
//! session-based `Engine`, check it bit-exactly against the golden
//! model, and print the paper's four metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use openedge_cgra::conv::{conv2d, random_input, random_weights, ConvShape};
use openedge_cgra::engine::{ConvRequest, EngineBuilder};
use openedge_cgra::kernels::Mapping;
use openedge_cgra::prop::Rng;
use openedge_cgra::util::fmt::kib;

fn main() -> anyhow::Result<()> {
    // The paper's baseline layer: C = K = Ox = Oy = 16, 3x3 filter.
    let shape = ConvShape::baseline();
    let mut rng = Rng::new(2024);
    let input = random_input(&shape, 30, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);

    // One session owns the simulated HEEPsilon platform (calibrated
    // timing), the energy model, the worker pool and the result caches.
    let engine = EngineBuilder::new().build()?;

    // Mapping::Auto picks the strategy per the paper's finding and
    // records the decision; explicit tensors keep the run uncached so
    // the functional check below exercises a real simulation.
    let req = ConvRequest::with_data(shape, Mapping::Auto, input.clone(), weights.clone());
    let res = engine.submit(&req)?;
    if let Some(d) = res.auto {
        println!("{d}");
    }

    // Bit-exact functional check against the golden model.
    let golden = conv2d(&shape, &input, &weights);
    assert_eq!(res.output.data, golden.data, "CGRA output mismatch");
    println!("functional check: CGRA output is bit-exact vs the golden conv ✔\n");

    // The paper's four metrics (§2.3).
    let report = &res.report;
    println!("layer    : {shape}");
    println!("mapping  : {} (the paper's winner)", report.mapping);
    println!("latency  : {} cycles ({:.3} ms @100 MHz)", report.latency_cycles, report.latency_ms);
    println!("energy   : {:.2} uJ  (avg power {:.2} mW)", report.energy_uj, report.avg_power_mw);
    println!("memory   : {}", kib(report.footprint_bytes));
    println!("perf     : {:.3} MAC/cycle  (paper: ~0.6)", report.mac_per_cycle);
    println!("util     : {:.1}% of PE slots active (paper: 78% in the main loop)",
        report.utilization * 100.0);

    // The same layer as a seeded request is cacheable: the second
    // submission is served from the engine's point cache.
    let seeded = ConvRequest::seeded(shape, Mapping::Wp, 2024);
    let first = engine.submit(&seeded)?;
    let second = engine.submit(&seeded)?;
    println!(
        "\ncache    : first seeded submit hit={}, repeat hit={}",
        first.cache_hit, second.cache_hit
    );
    assert!(!first.cache_hit && second.cache_hit);
    Ok(())
}
