//! Mapping explorer: compare all four CGRA mapping strategies and the
//! CPU baseline on a layer of your choice — the Figure 4 experiment as
//! a library-driven tool, batched over the engine's worker pool.
//!
//! ```sh
//! cargo run --release --example mapping_explorer -- [C] [K] [OX] [OY]
//! cargo run --release --example mapping_explorer -- 16 17 16 16   # K=17 imbalance
//! ```

use openedge_cgra::conv::{conv2d, random_input, random_weights, ConvShape};
use openedge_cgra::engine::{ConvRequest, EngineBuilder};
use openedge_cgra::kernels::Mapping;
use openedge_cgra::prop::Rng;
use openedge_cgra::util::fmt::{bar_chart, kib, Table};

fn main() -> anyhow::Result<()> {
    let args: Vec<usize> =
        std::env::args().skip(1).map(|a| a.parse().unwrap_or(16)).collect();
    let get = |i: usize| args.get(i).copied().unwrap_or(16);
    let shape = ConvShape::new3x3(get(0), get(1), get(2), get(3));
    shape.validate()?;

    let mut rng = Rng::new(7);
    let input = random_input(&shape, 30, &mut rng);
    let weights = random_weights(&shape, 9, &mut rng);
    let golden = conv2d(&shape, &input, &weights);
    let engine = EngineBuilder::new().build()?;

    println!("exploring {shape} — {} MACs\n", shape.macs());
    // One batch over the pool: all five strategies in parallel, results
    // back in request order.
    let reqs: Vec<ConvRequest> = Mapping::ALL
        .into_iter()
        .map(|m| ConvRequest::with_data(shape, m, input.clone(), weights.clone()))
        .collect();
    let mut table = Table::new(&[
        "mapping", "cycles", "MAC/cycle", "energy_uJ", "power_mW", "memory", "launches", "exact",
    ]);
    let mut reports = Vec::new();
    for res in engine.submit_batch(&reqs) {
        let res = res?;
        let exact = res.output.data == golden.data;
        let r = res.report;
        table.row(vec![
            r.mapping.label().into(),
            r.latency_cycles.to_string(),
            format!("{:.3}", r.mac_per_cycle),
            format!("{:.2}", r.energy_uj),
            format!("{:.2}", r.avg_power_mw),
            kib(r.footprint_bytes),
            r.launches.to_string(),
            if exact { "yes".into() } else { "NO".into() },
        ]);
        reports.push(r);
    }
    print!("{}", table.render());

    println!("\nMAC/cycle:");
    print!(
        "{}",
        bar_chart(
            &reports
                .iter()
                .map(|r| (r.mapping.label().to_string(), r.mac_per_cycle))
                .collect::<Vec<_>>(),
            40
        )
    );
    let best = reports
        .iter()
        .max_by(|a, b| a.mac_per_cycle.total_cmp(&b.mac_per_cycle))
        .unwrap();
    println!("\nbest mapping for this layer: {}", best.mapping);

    // What would the engine have picked? Auto encodes the paper's
    // conclusion and records its reasoning.
    let auto = engine.submit(&ConvRequest::with_data(shape, Mapping::Auto, input, weights))?;
    println!("engine's pick: {}", auto.auto.expect("auto decision"));
    Ok(())
}
