//! **End-to-end driver**: a small CNN runs inference with every conv
//! layer executed *instruction-by-instruction* on the simulated
//! OpenEdgeCGRA (WP mapping), host-side ReLU between layers, and — when
//! `artifacts/` exists — the same network replayed through the
//! AOT-compiled JAX/Pallas artifact via PJRT for a three-way bit-exact
//! check (simulator ⇔ Rust golden ⇔ XLA).
//!
//! This is experiment E7 in DESIGN.md; the run is recorded in
//! EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example cnn_inference
//! ```

use openedge_cgra::conv::random_input;
use openedge_cgra::coordinator::{golden_network, ConvNet};
use openedge_cgra::engine::EngineBuilder;
use openedge_cgra::prop::Rng;
use openedge_cgra::runtime::{ArtifactKind, Manifest, Runtime};
use openedge_cgra::util::fmt::Table;

fn main() -> anyhow::Result<()> {
    // Mirror the AOT CNN artifact: depth 3, c0=3, k=8, 12x12 input
    // (see python/compile/aot.py CNN_SPEC), weights seeded 1234 exactly
    // like runtime::verify.
    let net = ConvNet::random(3, 3, 8, 12, 12, 1234);
    let mut rng = Rng::new(2026);
    let input = random_input(&net.layers[0].shape, 8, &mut rng);

    println!(
        "CNN inference on the simulated OpenEdgeCGRA — {} layers, {} MACs\n",
        net.layers.len(),
        net.macs()
    );

    let engine = EngineBuilder::new().build()?;
    let out = engine.run_network(&net, &input)?;

    let mut table = Table::new(&[
        "layer", "shape", "mapping", "cycles", "MAC/cycle", "energy_uJ", "launches",
    ]);
    for (i, (l, r)) in net.layers.iter().zip(out.layers.iter()).enumerate() {
        table.row(vec![
            i.to_string(),
            l.shape.id(),
            r.mapping.label().into(),
            r.latency_cycles.to_string(),
            format!("{:.3}", r.mac_per_cycle),
            format!("{:.2}", r.energy_uj),
            r.launches.to_string(),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\ntotals: {} cycles ({:.3} MAC/cycle incl. host ReLU), {:.2} uJ",
        out.total_cycles,
        out.mac_per_cycle(&net),
        out.total_energy_uj
    );

    // Check 1: Rust golden model.
    let golden = golden_network(&net, &input)?;
    assert_eq!(out.output.data, golden.data);
    println!("check 1: CGRA simulator == Rust golden model ✔");

    // Check 2: the AOT JAX/Pallas artifact, when built.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let manifest = Manifest::load(&dir)?;
        let spec = manifest
            .artifacts
            .iter()
            .find(|a| a.kind == ArtifactKind::Cnn)
            .expect("cnn artifact in manifest");
        let rt = Runtime::cpu()?;
        let loaded = rt.load(&dir, spec)?;
        let ws: Vec<&openedge_cgra::conv::Weights> =
            net.layers.iter().map(|l| &l.weights).collect();
        let xla_out = loaded.execute_cnn(&input, &ws)?;
        assert_eq!(out.output.data, xla_out);
        println!("check 2: CGRA simulator == XLA artifact ({}) ✔", spec.name);
    } else {
        println!("check 2 skipped: run `make artifacts` to enable the XLA cross-check");
    }
    Ok(())
}
