//! Assembly playground: write OpenEdgeCGRA programs as text, run them
//! cycle-accurately, and inspect timing — the fastest way to get a feel
//! for the PE array (torus reads, DMA-port collisions, column PCs).
//!
//! ```sh
//! cargo run --release --example asm_playground
//! ```

use openedge_cgra::asm::assemble;
use openedge_cgra::cgra::{Cgra, CgraConfig, Memory};

/// Dot product of two 8-element vectors, split across two PEs that
/// combine through the torus; a third PE demonstrates a DMA collision.
const PROGRAM: &str = r#"
; PE(0,0): accumulates a[0..4) . b[0..4)
.pe 0 0
    mov  r0, zero        ; acc
    mov  r3, #4          ; counter
    setaddr #0           ; a[0]
loop:
    lwinc r1, #1         ; a[i]
    lw   r2, addr, #7    ; b[i] = mem[a_addr-1+8] (b starts at word 8)
    mul  r2, r1, r2
    add  r0, r0, r2
    sub  r3, r3, #1
    bne  r3, zero, loop
    mov  out, r0         ; expose partial for PE(0,1)
    nop

.pe 0 1
    mov  r0, zero
    mov  r3, #4
    setaddr #4           ; a[4]
loop:
    lwinc r1, #1
    lw   r2, addr, #7
    mul  r2, r1, r2
    add  r0, r0, r2
    sub  r3, r3, #1
    bne  r3, zero, loop
    nop                  ; W exposes its partial this step
    add  out, w, r0      ; total = west partial + own
    swat #16             ; result -> mem[16]
    exit
"#;

fn main() -> anyhow::Result<()> {
    let prog = assemble(PROGRAM)?;
    println!("{}", prog.disassemble());

    let cfg = CgraConfig::default();
    let mut mem = Memory::new(cfg.mem_words, cfg.n_banks);
    // a = 1..=8 at words 0..8, b = 8 ones at words 8..16.
    mem.poke_slice(0, &[1, 2, 3, 4, 5, 6, 7, 8]);
    mem.poke_slice(8, &[1; 8]);

    let cgra = Cgra::new(cfg)?;
    let stats = cgra.run(&prog, &mut mem)?;
    println!(
        "dot(a, ones) = {}   (expected {})",
        mem.peek(16),
        (1..=8).sum::<i32>()
    );
    println!(
        "{} steps, {} cycles ({} lost to DMA/bank contention), utilization {:.1}%",
        stats.steps,
        stats.cycles,
        stats.contention_cycles,
        stats.utilization() * 100.0
    );
    assert_eq!(mem.peek(16), 36);
    Ok(())
}
