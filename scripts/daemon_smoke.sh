#!/usr/bin/env bash
# Smoke-test the `cgra daemon` serving subsystem over its real NDJSON/TCP
# transport using nothing but bash's /dev/tcp: compile-miss, cache-hit,
# over-deadline rejection, stats shape (registry hit/miss/eviction/disk
# counters + per-tenant bottleneck attribution under --profile), clean
# shutdown (exit 0), and disk-tier persistence: a restarted daemon
# pointed at the same --artifact-dir serves its first request from the
# serialized artifact (disk hit) instead of recompiling.
#
# Usage: scripts/daemon_smoke.sh [path-to-cgra-binary]
set -euo pipefail

BIN="${1:-target/release/cgra}"
[ -x "$BIN" ] || { echo "FAIL: binary '$BIN' not found or not executable" >&2; exit 1; }

LOG="$(mktemp)"
ARTDIR="$(mktemp -d)"
trap 'kill "$DAEMON_PID" 2>/dev/null || true; rm -f "$LOG"; rm -rf "$ARTDIR"' EXIT

"$BIN" daemon --port 0 --workers 2 --batch 4 --profile --artifact-dir "$ARTDIR" >"$LOG" 2>&1 &
DAEMON_PID=$!

# Wait for the OS-assigned port to be announced.
PORT=""
for _ in $(seq 1 100); do
    PORT="$(sed -n 's/.*listening on [0-9.]*:\([0-9]*\).*/\1/p' "$LOG")"
    [ -n "$PORT" ] && break
    kill -0 "$DAEMON_PID" 2>/dev/null || { echo "FAIL: daemon died during startup" >&2; cat "$LOG" >&2; exit 1; }
    sleep 0.1
done
[ -n "$PORT" ] || { echo "FAIL: daemon never announced its port" >&2; cat "$LOG" >&2; exit 1; }
echo "daemon up on port $PORT"

# One request per connection: send a line, read a line.
req() {
    exec 3<>"/dev/tcp/127.0.0.1/$PORT"
    printf '%s\n' "$1" >&3
    IFS= read -r RESPONSE <&3
    exec 3<&- 3>&-
    echo "  -> $RESPONSE"
}

expect() { # expect <needle> <label>
    case "$RESPONSE" in
        *"$1"*) echo "  OK: $2" ;;
        *) echo "FAIL: $2 — expected '$1' in: $RESPONSE" >&2; exit 1 ;;
    esac
}

INFER='{"op":"infer","tenant":"smoke","depth":1,"c0":2,"k":2,"hw":6,"net_seed":3}'

echo "1. first inference compiles (registry miss)"
req "$INFER"
expect '"ok":true' "request served"
expect '"cache":"miss"' "artifact compiled on first use"

echo "2. repeat inference hits the registry"
req "$INFER"
expect '"cache":"hit"' "artifact served from the registry"

echo "3. impossible deadline is rejected, not executed"
req '{"op":"infer","tenant":"smoke","depth":1,"c0":2,"k":2,"hw":6,"net_seed":3,"deadline_us":0.001,"admission":"reject"}'
expect '"ok":false' "rejection is a structured error"
expect '"kind":"deadline"' "rejection names the deadline"

echo "4. stats surface has the registry, tenant and latency blocks"
req '{"op":"stats"}'
expect '"ok":true' "stats served"
expect '"served_requests":2' "two requests executed"
expect '"rejected":1' "one request rejected"
expect '"registry"' "registry counters present"
expect '"hits":1' "registry hit counter counted the repeat"
expect '"misses"' "registry miss counter present"
expect '"evictions"' "registry eviction counter present"
expect '"disk_writes":1' "first compile persisted to the artifact disk tier"
expect '"disk_hits":0' "nothing loaded from disk yet in this process"
expect '"smoke"' "per-tenant row present"
expect '"bottleneck"' "per-tenant bottleneck attribution present (--profile)"
expect '"version"' "daemon reports its crate version"
expect '"e2e_us"' "end-to-end latency histogram present"
expect '"p99"' "latency percentiles present"
case "$RESPONSE" in
    *'"e2e_us":{"count":0'*)
        echo "FAIL: e2e latency histogram is empty after two served requests" >&2
        exit 1 ;;
    *) echo "  OK: e2e latency histogram recorded the served requests" ;;
esac

echo "5. malformed input fails cleanly"
req 'this is not json'
expect '"ok":false' "bad request is an error response"
expect '"bad-request"' "error kind is bad-request"

echo "6. shutdown over the wire"
req '{"op":"shutdown"}'
expect '"ok":true' "shutdown acknowledged"

if ! wait "$DAEMON_PID"; then
    echo "FAIL: daemon exited non-zero after shutdown" >&2
    cat "$LOG" >&2
    exit 1
fi
echo "daemon exited cleanly"

echo "7. a restarted daemon loads from the disk tier instead of recompiling"
: >"$LOG"
"$BIN" daemon --port 0 --workers 2 --batch 4 --profile --artifact-dir "$ARTDIR" >"$LOG" 2>&1 &
DAEMON_PID=$!
PORT=""
for _ in $(seq 1 100); do
    PORT="$(sed -n 's/.*listening on [0-9.]*:\([0-9]*\).*/\1/p' "$LOG")"
    [ -n "$PORT" ] && break
    kill -0 "$DAEMON_PID" 2>/dev/null || { echo "FAIL: restarted daemon died during startup" >&2; cat "$LOG" >&2; exit 1; }
    sleep 0.1
done
[ -n "$PORT" ] || { echo "FAIL: restarted daemon never announced its port" >&2; cat "$LOG" >&2; exit 1; }
echo "daemon back up on port $PORT"

req "$INFER"
expect '"ok":true' "request served after restart"
expect '"cache":"miss"' "in-memory registry is cold after a restart"
req '{"op":"stats"}'
expect '"disk_hits":1' "artifact loaded from the disk tier, zero rebuilds"
expect '"disk_writes":0' "nothing re-persisted — the artifact was already on disk"

req '{"op":"shutdown"}'
expect '"ok":true' "shutdown acknowledged after restart"
if ! wait "$DAEMON_PID"; then
    echo "FAIL: restarted daemon exited non-zero after shutdown" >&2
    cat "$LOG" >&2
    exit 1
fi
trap 'rm -f "$LOG"; rm -rf "$ARTDIR"' EXIT
echo "restarted daemon exited cleanly; final summary:"
tail -n +2 "$LOG" | sed 's/^/  /'
echo "PASS: daemon smoke"
