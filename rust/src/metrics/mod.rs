//! The paper's four evaluation metrics bundled per run: latency, energy,
//! memory usage, MAC/cycle (§2.3), plus utilization and the op mix.

use crate::cgra::OpClass;
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::kernels::{ConvOutcome, Mapping};
use crate::util::Json;

/// One row of the paper's comparison: everything Figures 3–5 need about
/// a single (mapping, shape) execution.
#[derive(Clone, Debug)]
pub struct MappingReport {
    /// Strategy.
    pub mapping: Mapping,
    /// Layer id, e.g. `c16k16o16x16`.
    pub shape_id: String,
    /// End-to-end latency in cycles.
    pub latency_cycles: u64,
    /// Latency in ms at the calibrated clock.
    pub latency_ms: f64,
    /// Total energy, µJ.
    pub energy_uj: f64,
    /// Average system power, mW.
    pub avg_power_mw: f64,
    /// Energy decomposition.
    pub energy: EnergyBreakdown,
    /// MAC/cycle (paper's performance metric).
    pub mac_per_cycle: f64,
    /// Memory usage, bytes (paper's scalability metric).
    pub footprint_bytes: usize,
    /// PE utilization (0 for the CPU baseline).
    pub utilization: f64,
    /// Fraction of slots per op class, plot order (Fig. 3).
    pub op_mix: [f64; OpClass::COUNT],
    /// CGRA memory traffic (loads + stores).
    pub cgra_accesses: u64,
    /// Number of CGRA launches.
    pub launches: u64,
}

impl MappingReport {
    /// Evaluate the energy model over an outcome and assemble the row.
    pub fn from_outcome(out: &ConvOutcome, model: &EnergyModel) -> MappingReport {
        let e = model.evaluate(out);
        MappingReport {
            mapping: out.mapping,
            shape_id: out.shape.id(),
            latency_cycles: out.latency.total_cycles(),
            latency_ms: e.latency_ms,
            energy_uj: e.total_uj(),
            avg_power_mw: e.avg_power_mw(),
            energy: e,
            mac_per_cycle: out.macs_per_cycle(),
            footprint_bytes: out.footprint_bytes,
            utilization: out.cgra_stats.utilization(),
            op_mix: out.cgra_stats.class_fractions(),
            cgra_accesses: out.cgra_stats.mem.total(),
            launches: out.latency.launches,
        }
    }

    /// JSON row (for report files).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mapping", self.mapping.label().into()),
            ("shape", self.shape_id.clone().into()),
            ("latency_cycles", self.latency_cycles.into()),
            ("latency_ms", self.latency_ms.into()),
            ("energy_uj", self.energy_uj.into()),
            ("avg_power_mw", self.avg_power_mw.into()),
            ("mac_per_cycle", self.mac_per_cycle.into()),
            ("footprint_bytes", self.footprint_bytes.into()),
            ("utilization", self.utilization.into()),
            ("cgra_accesses", self.cgra_accesses.into()),
            ("launches", self.launches.into()),
            (
                "energy_split_uj",
                Json::obj(vec![
                    ("cgra", self.energy.cgra_uj.into()),
                    ("cpu", self.energy.cpu_uj.into()),
                    ("mem_static", self.energy.mem_static_uj.into()),
                    ("mem_dynamic", self.energy.mem_dynamic_uj.into()),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::{Cgra, CgraConfig};
    use crate::conv::{random_input, random_weights, ConvShape};
    use crate::kernels::dispatch;
    use crate::prop::Rng;

    #[test]
    fn report_fields_consistent() {
        let shape = ConvShape::new3x3(4, 4, 4, 4);
        let mut rng = Rng::new(1);
        let input = random_input(&shape, 10, &mut rng);
        let weights = random_weights(&shape, 10, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let out = dispatch(&cgra, Mapping::Wp, &shape, &input, &weights).unwrap();
        let r = MappingReport::from_outcome(&out, &EnergyModel::default());
        assert_eq!(r.shape_id, "c4k4o4x4");
        assert!(r.latency_cycles > 0);
        assert!(r.energy_uj > 0.0);
        assert!((r.mac_per_cycle - shape.macs() as f64 / r.latency_cycles as f64).abs() < 1e-9);
        let j = r.to_json();
        assert_eq!(j.req_str("mapping").unwrap(), "Conv-WP");
        assert!(j.req("energy_split_uj").is_ok());
        // Op-mix fractions sum to 1 for a CGRA mapping.
        assert!((r.op_mix.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cpu_report_has_no_cgra_metrics() {
        let shape = ConvShape::new3x3(2, 2, 3, 3);
        let mut rng = Rng::new(2);
        let input = random_input(&shape, 10, &mut rng);
        let weights = random_weights(&shape, 10, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let out = dispatch(&cgra, Mapping::Cpu, &shape, &input, &weights).unwrap();
        let r = MappingReport::from_outcome(&out, &EnergyModel::default());
        assert_eq!(r.utilization, 0.0);
        assert_eq!(r.cgra_accesses, 0);
        assert_eq!(r.launches, 0);
        assert!(r.energy.cgra_uj == 0.0);
    }
}
