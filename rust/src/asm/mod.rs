//! Text assembler for the OpenEdgeCGRA ISA.
//!
//! Lets tests, examples and the `cgra asm` subcommand write array
//! programs as text instead of constructing [`crate::isa::Instr`] values
//! by hand. Round-trips with [`crate::isa::Program::disassemble`]'s
//! instruction syntax.
//!
//! # Syntax
//!
//! ```text
//! ; comment (also '#' at line start)
//! .pe 0 0              ; start the program of PE(row=0, col=0)
//!     mov r0, #5       ; dst, src
//! loop:
//!     add out, r0, e   ; dst, a, b  (e = east neighbour's ROUT)
//!     sub r0, r0, #1
//!     bne r0, zero, loop
//!     setaddr #100
//!     swinc own, #1    ; store own ROUT via addr, post-increment 1
//!     exit
//! ```
//!
//! Operand tokens: `zero`, `#<imm>`, `r0`..`r3`, `own`, `n`/`s`/`e`/`w`,
//! `addr`. Destinations: `out`, `r0`..`r3`, `out+r0`..`out+r3`, `_`.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::isa::{Dir, Dst, Instr, Op, PeId, PeProgram, Program, Src};

/// Assemble a full array program from text.
pub fn assemble(text: &str) -> Result<Program> {
    let mut prog = Program::new("asm");
    let mut current: Option<PeId> = None;
    // Per-PE: instructions + (slot, label) fixups + label table.
    let mut instrs: Vec<Instr> = Vec::new();
    let mut labels: HashMap<String, usize> = HashMap::new();
    let mut fixups: Vec<(usize, String, usize)> = Vec::new(); // (slot, label, line_no)

    let flush = |prog: &mut Program,
                     current: &mut Option<PeId>,
                     instrs: &mut Vec<Instr>,
                     labels: &mut HashMap<String, usize>,
                     fixups: &mut Vec<(usize, String, usize)>|
     -> Result<()> {
        if let Some(id) = current.take() {
            for (slot, label, line) in fixups.drain(..) {
                let target = *labels
                    .get(&label)
                    .with_context(|| format!("line {line}: undefined label '{label}'"))?;
                instrs[slot].target = target as u8;
            }
            prog.set_pe(id, PeProgram::from_instrs(std::mem::take(instrs)));
            labels.clear();
        }
        Ok(())
    };

    for (line_no, raw) in text.lines().enumerate() {
        let line_no = line_no + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix(".pe") {
            flush(&mut prog, &mut current, &mut instrs, &mut labels, &mut fixups)?;
            let parts: Vec<&str> = rest.split_whitespace().collect();
            if parts.len() != 2 {
                bail!("line {line_no}: '.pe' expects ROW COL");
            }
            let row: usize = parts[0].parse().with_context(|| format!("line {line_no}"))?;
            let col: usize = parts[1].parse().with_context(|| format!("line {line_no}"))?;
            if row >= crate::isa::ROWS || col >= crate::isa::COLS {
                bail!("line {line_no}: PE ({row},{col}) out of range");
            }
            current = Some(PeId::new(row, col));
            continue;
        }
        if current.is_none() {
            bail!("line {line_no}: instruction before any '.pe' section");
        }
        // Leading `label:` (possibly with an instruction after it).
        let mut body = line;
        while let Some(idx) = body.find(':') {
            let (head, tail) = body.split_at(idx);
            let head = head.trim();
            if head.is_empty() || !head.chars().all(|c| c.is_alphanumeric() || c == '_') {
                break;
            }
            if labels.insert(head.to_string(), instrs.len()).is_some() {
                bail!("line {line_no}: duplicate label '{head}'");
            }
            body = tail[1..].trim();
        }
        if body.is_empty() {
            continue;
        }
        let instr = parse_instr(body, line_no, instrs.len(), &mut fixups)?;
        if instrs.len() >= crate::isa::PROG_CAPACITY {
            bail!(
                "line {line_no}: PE program exceeds {} words",
                crate::isa::PROG_CAPACITY
            );
        }
        instrs.push(instr);
    }
    flush(&mut prog, &mut current, &mut instrs, &mut labels, &mut fixups)?;
    Ok(prog)
}

fn strip_comment(line: &str) -> &str {
    let cut = line.find(';').unwrap_or(line.len());
    let s = &line[..cut];
    if s.trim_start().starts_with('#') && !s.trim_start().starts_with("#-") {
        // Allow full-line '#' comments but not to clash with immediates —
        // immediates only appear after a mnemonic, so a line *starting*
        // with '#' is a comment.
        ""
    } else {
        s
    }
}

fn parse_instr(
    body: &str,
    line: usize,
    slot: usize,
    fixups: &mut Vec<(usize, String, usize)>,
) -> Result<Instr> {
    let (mn, rest) = match body.split_once(char::is_whitespace) {
        Some((m, r)) => (m, r.trim()),
        None => (body, ""),
    };
    let ops: Vec<&str> =
        rest.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()).collect();
    let need = |n: usize| -> Result<()> {
        if ops.len() != n {
            bail!("line {line}: '{mn}' expects {n} operand(s), got {}", ops.len());
        }
        Ok(())
    };

    let alu = |op: Op, ops: &[&str]| -> Result<Instr> {
        Ok(Instr::new(op, src(ops[1], line)?, src(ops[2], line)?, dst(ops[0], line)?))
    };

    match mn.to_ascii_lowercase().as_str() {
        "nop" => {
            need(0)?;
            Ok(Instr::nop())
        }
        "exit" => {
            need(0)?;
            Ok(Instr::exit())
        }
        "mov" => {
            need(2)?;
            Ok(Instr::mov(dst(ops[0], line)?, src(ops[1], line)?))
        }
        "add" | "sub" | "mul" | "shl" | "shr" | "and" | "or" | "xor" | "min" | "max" => {
            need(3)?;
            let op = match mn {
                "add" => Op::Add,
                "sub" => Op::Sub,
                "mul" => Op::Mul,
                "shl" => Op::Shl,
                "shr" => Op::Shr,
                "and" => Op::And,
                "or" => Op::Or,
                "xor" => Op::Xor,
                "min" => Op::Min,
                _ => Op::Max,
            };
            alu(op, &ops)
        }
        "setaddr" => {
            // setaddr a [, b]
            if ops.is_empty() || ops.len() > 2 {
                bail!("line {line}: 'setaddr' expects 1 or 2 operands");
            }
            let b = if ops.len() == 2 { src(ops[1], line)? } else { Src::Zero };
            Ok(Instr::new(Op::SetAddr, src(ops[0], line)?, b, Dst::None))
        }
        "lw" => {
            // lw dst, a [, b]
            if ops.len() < 2 || ops.len() > 3 {
                bail!("line {line}: 'lw' expects dst, a [, b]");
            }
            let b = if ops.len() == 3 { src(ops[2], line)? } else { Src::Zero };
            Ok(Instr::new(Op::Lw, src(ops[1], line)?, b, dst(ops[0], line)?))
        }
        "lwinc" => {
            // lwinc dst, inc_a [, inc_b]
            if ops.len() < 2 || ops.len() > 3 {
                bail!("line {line}: 'lwinc' expects dst, inc [, inc2]");
            }
            let b = if ops.len() == 3 { src(ops[2], line)? } else { Src::Zero };
            Ok(Instr::new(Op::LwInc, src(ops[1], line)?, b, dst(ops[0], line)?))
        }
        "swinc" => {
            // swinc value, inc
            need(2)?;
            Ok(Instr::new(Op::SwInc, src(ops[0], line)?, src(ops[1], line)?, Dst::None))
        }
        "swat" => {
            // swat a [, b] — stores own ROUT at a+b
            if ops.is_empty() || ops.len() > 2 {
                bail!("line {line}: 'swat' expects 1 or 2 operands");
            }
            let b = if ops.len() == 2 { src(ops[1], line)? } else { Src::Zero };
            Ok(Instr::new(Op::SwAt, src(ops[0], line)?, b, Dst::None))
        }
        "beq" | "bne" | "blt" | "bge" => {
            need(3)?;
            let op = match mn {
                "beq" => Op::Beq,
                "bne" => Op::Bne,
                "blt" => Op::Blt,
                _ => Op::Bge,
            };
            let mut i = Instr::new(op, src(ops[0], line)?, src(ops[1], line)?, Dst::None);
            fixups.push((slot, ops[2].to_string(), line));
            i.target = 0;
            Ok(i)
        }
        "jump" => {
            need(1)?;
            let mut i = Instr::new(Op::Jump, Src::Zero, Src::Zero, Dst::None);
            fixups.push((slot, ops[0].to_string(), line));
            i.target = 0;
            Ok(i)
        }
        other => bail!("line {line}: unknown mnemonic '{other}'"),
    }
}

fn src(tok: &str, line: usize) -> Result<Src> {
    let t = tok.to_ascii_lowercase();
    Ok(match t.as_str() {
        "zero" => Src::Zero,
        "own" => Src::Own,
        "addr" => Src::Addr,
        "n" => Src::Neigh(Dir::North),
        "s" => Src::Neigh(Dir::South),
        "e" => Src::Neigh(Dir::East),
        "w" => Src::Neigh(Dir::West),
        "r0" => Src::Reg(0),
        "r1" => Src::Reg(1),
        "r2" => Src::Reg(2),
        "r3" => Src::Reg(3),
        _ => {
            if let Some(imm) = t.strip_prefix('#') {
                Src::Imm(
                    imm.parse::<i32>()
                        .with_context(|| format!("line {line}: bad immediate '{tok}'"))?,
                )
            } else {
                bail!("line {line}: unknown operand '{tok}'")
            }
        }
    })
}

fn dst(tok: &str, line: usize) -> Result<Dst> {
    let t = tok.to_ascii_lowercase();
    Ok(match t.as_str() {
        "out" => Dst::Out,
        "_" => Dst::None,
        "r0" => Dst::Reg(0),
        "r1" => Dst::Reg(1),
        "r2" => Dst::Reg(2),
        "r3" => Dst::Reg(3),
        "out+r0" => Dst::Both(0),
        "out+r1" => Dst::Both(1),
        "out+r2" => Dst::Both(2),
        "out+r3" => Dst::Both(3),
        _ => bail!("line {line}: unknown destination '{tok}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::{Cgra, CgraConfig, Memory};

    #[test]
    fn assemble_and_run_countdown() {
        let prog = assemble(
            r#"
            ; sum 1..=4 on one PE
            .pe 2 1
                mov r0, #4
                mov r1, zero
            loop:
                add r1, r1, r0
                sub r0, r0, #1
                bne r0, zero, loop
                mov out, r1
                swat #33
                exit
            "#,
        )
        .unwrap();
        let mut mem = Memory::new(128, 4);
        let cgra = Cgra::new(CgraConfig::functional()).unwrap();
        cgra.run(&prog, &mut mem).unwrap();
        assert_eq!(mem.peek(33), 10);
    }

    #[test]
    fn multi_pe_neighbour_program() {
        let prog = assemble(
            r#"
            .pe 0 0
                mov out, #21
                nop
                nop
            .pe 0 1
                nop
                add out, w, w    ; 21 + 21 read from west
                swat #5
                exit
            "#,
        )
        .unwrap();
        let mut mem = Memory::new(64, 4);
        let cgra = Cgra::new(CgraConfig::functional()).unwrap();
        cgra.run(&prog, &mut mem).unwrap();
        assert_eq!(mem.peek(5), 42);
    }

    #[test]
    fn lwinc_swinc_syntax() {
        let prog = assemble(
            r#"
            .pe 3 3
                setaddr #10
                lwinc r0, #1
                lwinc r1, #1
                add out, r0, r1
                setaddr #20
                swinc own, #1
                exit
            "#,
        )
        .unwrap();
        let mut mem = Memory::new(64, 4);
        mem.poke(10, 40);
        mem.poke(11, 2);
        let cgra = Cgra::new(CgraConfig::functional()).unwrap();
        cgra.run(&prog, &mut mem).unwrap();
        assert_eq!(mem.peek(20), 42);
    }

    #[test]
    fn errors_are_located() {
        let e = assemble(".pe 0 0\n  frob r0, r1\n").unwrap_err().to_string();
        assert!(e.contains("line 2"), "{e}");
        let e = assemble(".pe 9 0\n").unwrap_err().to_string();
        assert!(e.contains("out of range"), "{e}");
        let e = assemble(".pe 0 0\n bne r0, zero, nowhere\n").unwrap_err().to_string();
        assert!(e.contains("undefined label"), "{e}");
        let e = assemble("add out, r0, r1\n").unwrap_err().to_string();
        assert!(e.contains("before any"), "{e}");
    }

    #[test]
    fn duplicate_label_rejected() {
        let e = assemble(".pe 0 0\nx:\nx:\n nop\n").unwrap_err().to_string();
        assert!(e.contains("duplicate label"), "{e}");
    }

    #[test]
    fn capacity_enforced() {
        let mut text = String::from(".pe 0 0\n");
        for _ in 0..33 {
            text.push_str(" nop\n");
        }
        let e = assemble(&text).unwrap_err().to_string();
        assert!(e.contains("exceeds"), "{e}");
    }
}
