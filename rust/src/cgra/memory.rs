//! The shared memory subsystem seen through the column DMA ports.
//!
//! Word-addressed int32 memory with word-interleaved banking. The
//! simulator models *timing* contention in the executor; this module
//! provides storage, bounds checking and access accounting (the access
//! counts feed the energy model — the paper identifies memory dynamic
//! energy as the discriminator between mapping strategies).

use anyhow::{bail, Result};

/// Running totals of memory traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemStats {
    /// Number of word loads served.
    pub loads: u64,
    /// Number of word stores served.
    pub stores: u64,
}

impl MemStats {
    /// Total accesses (loads + stores).
    pub fn total(&self) -> u64 {
        self.loads + self.stores
    }
}

/// Word-addressed memory with access accounting.
#[derive(Clone, Debug)]
pub struct Memory {
    words: Vec<i32>,
    n_banks: usize,
    stats: MemStats,
    hi_water: usize,
}

impl Memory {
    /// Zero-initialized memory of `words` 32-bit words with `n_banks`
    /// word-interleaved banks.
    pub fn new(words: usize, n_banks: usize) -> Self {
        assert!(n_banks >= 1);
        Memory { words: vec![0; words], n_banks, stats: MemStats::default(), hi_water: 0 }
    }

    /// Size in words.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// True if zero-sized (never for valid configs).
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Bank index serving word address `addr` (word-interleaved).
    pub fn bank_of(&self, addr: usize) -> usize {
        addr % self.n_banks
    }

    /// Load the word at `addr` (counted).
    pub fn load(&mut self, addr: i32) -> Result<i32> {
        let a = self.check(addr, "load")?;
        self.stats.loads += 1;
        self.hi_water = self.hi_water.max(a + 1);
        Ok(self.words[a])
    }

    /// Store `value` at `addr` (counted).
    pub fn store(&mut self, addr: i32, value: i32) -> Result<()> {
        let a = self.check(addr, "store")?;
        self.stats.stores += 1;
        self.hi_water = self.hi_water.max(a + 1);
        self.words[a] = value;
        Ok(())
    }

    /// Uncounted read (host/debug access — e.g. the test harness reading
    /// back results; does not pollute the energy accounting).
    pub fn peek(&self, addr: usize) -> i32 {
        self.words[addr]
    }

    /// Uncounted slice read starting at `addr`.
    pub fn peek_slice(&self, addr: usize, len: usize) -> &[i32] {
        &self.words[addr..addr + len]
    }

    /// Uncounted write (host initialization — the paper's CPU preloads
    /// inputs/weights before launching; that traffic is charged separately
    /// by the host-side cost models, not here).
    pub fn poke(&mut self, addr: usize, value: i32) {
        self.words[addr] = value;
    }

    /// Uncounted bulk write starting at `addr`.
    pub fn poke_slice(&mut self, addr: usize, values: &[i32]) {
        self.words[addr..addr + values.len()].copy_from_slice(values);
    }

    /// Access totals so far.
    pub fn stats(&self) -> MemStats {
        self.stats
    }

    /// Reset the access counters (e.g. between measured regions).
    pub fn reset_stats(&mut self) {
        self.stats = MemStats::default();
    }

    /// Footprint watermark: highest word address the **array** touched
    /// (counted accesses only) + 1. Host pokes/peeks don't move it —
    /// the profiler reports what the launched programs reached.
    pub fn high_water(&self) -> usize {
        self.hi_water
    }

    /// Reset the footprint watermark (e.g. at walk boundaries).
    pub fn reset_high_water(&mut self) {
        self.hi_water = 0;
    }

    fn check(&self, addr: i32, what: &str) -> Result<usize> {
        if addr < 0 || addr as usize >= self.words.len() {
            bail!(
                "CGRA {what} out of bounds: word address {addr} (memory is {} words)",
                self.words.len()
            );
        }
        Ok(addr as usize)
    }
}

/// `B` independent memory images laid out structure-of-arrays for the
/// batched executor (DESIGN.md §9).
///
/// The layout is **word-major**: the `B` copies of word address `a`
/// live contiguously at `backing[a * batch_capacity ..]`, one word per
/// lane. A batched load or store of one address therefore touches one
/// contiguous slice — the memcpy the batched executor's inner loop is
/// built around — instead of `B` strided words.
///
/// Access accounting is **per lane**: one batched load counts as *one*
/// load, because [`MemStats`] feeds the per-inference energy model and
/// every lane models the same single hardware access. A batched run's
/// `RunStats` is therefore bit-identical to one scalar run's.
#[derive(Clone, Debug)]
pub struct BatchMemory {
    backing: Vec<i32>,
    words: usize,
    batch_cap: usize,
    n_banks: usize,
    stats: MemStats,
    hi_water: usize,
}

impl BatchMemory {
    /// Zero-initialized batch of `batch_cap` images, each `words` 32-bit
    /// words with `n_banks` word-interleaved banks.
    pub fn new(words: usize, n_banks: usize, batch_cap: usize) -> Self {
        assert!(n_banks >= 1);
        assert!(batch_cap >= 1);
        BatchMemory {
            backing: vec![0; words * batch_cap],
            words,
            batch_cap,
            n_banks,
            stats: MemStats::default(),
            hi_water: 0,
        }
    }

    /// Size of **one** lane's image in words (matches [`Memory::len`]).
    pub fn len(&self) -> usize {
        self.words
    }

    /// True if zero-sized (never for valid configs).
    pub fn is_empty(&self) -> bool {
        self.words == 0
    }

    /// Number of lanes this batch was allocated for. Runs may use any
    /// `1..=batch_capacity()` lanes (the ragged final chunk of a stream).
    pub fn batch_capacity(&self) -> usize {
        self.batch_cap
    }

    /// Bank index serving word address `addr` — same word-interleaving
    /// as [`Memory::bank_of`]: lanes mirror one hardware image, so
    /// banking is per-address, not per-backing-element.
    pub fn bank_of(&self, addr: usize) -> usize {
        addr % self.n_banks
    }

    /// Load word `addr` of lanes `0..out.len()` into `out` (counted as
    /// **one** load — per-lane semantics, see the type docs).
    pub fn load_lanes(&mut self, addr: i32, out: &mut [i32]) -> Result<()> {
        let a = self.check(addr, "load")?;
        debug_assert!(out.len() <= self.batch_cap);
        self.stats.loads += 1;
        self.hi_water = self.hi_water.max(a + 1);
        out.copy_from_slice(&self.backing[a * self.batch_cap..a * self.batch_cap + out.len()]);
        Ok(())
    }

    /// Store `values[l]` to word `addr` of lane `l` for lanes
    /// `0..values.len()` (counted as **one** store).
    pub fn store_lanes(&mut self, addr: i32, values: &[i32]) -> Result<()> {
        let a = self.check(addr, "store")?;
        debug_assert!(values.len() <= self.batch_cap);
        self.stats.stores += 1;
        self.hi_water = self.hi_water.max(a + 1);
        self.backing[a * self.batch_cap..a * self.batch_cap + values.len()]
            .copy_from_slice(values);
        Ok(())
    }

    /// Uncounted read of word `addr` in lane `lane` (host/debug access).
    pub fn peek_lane(&self, addr: usize, lane: usize) -> i32 {
        self.backing[addr * self.batch_cap + lane]
    }

    /// Uncounted strided gather: words `addr..addr+out.len()` of lane
    /// `lane` into `out` (the host reading one lane's output back).
    pub fn peek_slice_lane(&self, addr: usize, lane: usize, out: &mut [i32]) {
        for (k, dst) in out.iter_mut().enumerate() {
            *dst = self.backing[(addr + k) * self.batch_cap + lane];
        }
    }

    /// Uncounted write of word `addr` in lane `lane` (host initialization).
    pub fn poke_lane(&mut self, addr: usize, lane: usize, value: i32) {
        self.backing[addr * self.batch_cap + lane] = value;
    }

    /// Uncounted strided scatter: `values` into words
    /// `addr..addr+values.len()` of lane `lane` (per-lane inputs).
    pub fn poke_slice_lane(&mut self, addr: usize, lane: usize, values: &[i32]) {
        for (k, &v) in values.iter().enumerate() {
            self.backing[(addr + k) * self.batch_cap + lane] = v;
        }
    }

    /// Uncounted broadcast: `values` into words `addr..addr+values.len()`
    /// of **every** lane `0..lanes` (weights and other shared constants
    /// — poked once, visible to the whole batch).
    pub fn poke_broadcast(&mut self, addr: usize, values: &[i32], lanes: usize) {
        debug_assert!(lanes <= self.batch_cap);
        for (k, &v) in values.iter().enumerate() {
            let base = (addr + k) * self.batch_cap;
            self.backing[base..base + lanes].iter_mut().for_each(|w| *w = v);
        }
    }

    /// Access totals so far (per-lane semantics).
    pub fn stats(&self) -> MemStats {
        self.stats
    }

    /// Reset the access counters (e.g. between launches of one batch).
    pub fn reset_stats(&mut self) {
        self.stats = MemStats::default();
    }

    /// Footprint watermark: highest word address the array touched
    /// (counted accesses only) + 1 — per lane image, like [`Memory`].
    pub fn high_water(&self) -> usize {
        self.hi_water
    }

    /// Reset the footprint watermark (e.g. at walk boundaries).
    pub fn reset_high_water(&mut self) {
        self.hi_water = 0;
    }

    fn check(&self, addr: i32, what: &str) -> Result<usize> {
        if addr < 0 || addr as usize >= self.words {
            bail!(
                "CGRA {what} out of bounds: word address {addr} (memory is {} words)",
                self.words
            );
        }
        Ok(addr as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_store_roundtrip_and_counts() {
        let mut m = Memory::new(16, 4);
        m.store(3, -7).unwrap();
        assert_eq!(m.load(3).unwrap(), -7);
        assert_eq!(m.stats(), MemStats { loads: 1, stores: 1 });
    }

    #[test]
    fn peek_poke_uncounted() {
        let mut m = Memory::new(16, 4);
        m.poke(0, 42);
        assert_eq!(m.peek(0), 42);
        m.poke_slice(4, &[1, 2, 3]);
        assert_eq!(m.peek_slice(4, 3), &[1, 2, 3]);
        assert_eq!(m.stats().total(), 0);
    }

    #[test]
    fn out_of_bounds_rejected() {
        let mut m = Memory::new(8, 4);
        assert!(m.load(-1).is_err());
        assert!(m.load(8).is_err());
        assert!(m.store(8, 0).is_err());
        assert!(m.load(7).is_ok());
    }

    #[test]
    fn bank_interleave() {
        let m = Memory::new(16, 4);
        assert_eq!(m.bank_of(0), 0);
        assert_eq!(m.bank_of(5), 1);
        assert_eq!(m.bank_of(7), 3);
    }

    #[test]
    fn reset_stats_zeroes() {
        let mut m = Memory::new(8, 2);
        m.store(0, 1).unwrap();
        m.reset_stats();
        assert_eq!(m.stats().total(), 0);
    }

    #[test]
    fn high_water_tracks_counted_accesses_only() {
        let mut m = Memory::new(32, 4);
        assert_eq!(m.high_water(), 0);
        m.poke(30, 1); // host init doesn't move the watermark
        assert_eq!(m.high_water(), 0);
        m.load(5).unwrap();
        assert_eq!(m.high_water(), 6);
        m.store(17, 9).unwrap();
        assert_eq!(m.high_water(), 18);
        m.load(2).unwrap();
        assert_eq!(m.high_water(), 18, "watermark is a max");
        m.reset_high_water();
        assert_eq!(m.high_water(), 0);

        let mut b = BatchMemory::new(32, 4, 2);
        b.store_lanes(9, &[1, 2]).unwrap();
        let mut out = [0i32; 2];
        b.load_lanes(4, &mut out).unwrap();
        assert_eq!(b.high_water(), 10);
        b.reset_high_water();
        assert_eq!(b.high_water(), 0);
    }

    #[test]
    fn batch_lanes_are_independent_images() {
        let mut m = BatchMemory::new(16, 4, 3);
        m.poke_lane(5, 0, 10);
        m.poke_lane(5, 1, 20);
        m.poke_lane(5, 2, 30);
        let mut out = [0i32; 3];
        m.load_lanes(5, &mut out).unwrap();
        assert_eq!(out, [10, 20, 30]);
        m.store_lanes(6, &[-1, -2, -3]).unwrap();
        assert_eq!(m.peek_lane(6, 1), -2);
        // One batched load + one batched store = one of each, per-lane.
        assert_eq!(m.stats(), MemStats { loads: 1, stores: 1 });
    }

    #[test]
    fn batch_scatter_gather_and_broadcast() {
        let mut m = BatchMemory::new(16, 4, 4);
        m.poke_slice_lane(2, 3, &[7, 8, 9]);
        let mut got = [0i32; 3];
        m.peek_slice_lane(2, 3, &mut got);
        assert_eq!(got, [7, 8, 9]);
        assert_eq!(m.peek_lane(2, 0), 0, "other lanes untouched");

        m.poke_broadcast(10, &[41, 42], 4);
        for lane in 0..4 {
            assert_eq!(m.peek_lane(10, lane), 41);
            assert_eq!(m.peek_lane(11, lane), 42);
        }
        assert_eq!(m.stats().total(), 0, "pokes/peeks are uncounted");
    }

    #[test]
    fn batch_partial_lane_runs_leave_tail_lanes_alone() {
        let mut m = BatchMemory::new(8, 2, 4);
        m.poke_lane(0, 3, 99);
        m.store_lanes(0, &[1, 2]).unwrap(); // nb = 2 of capacity 4
        assert_eq!(m.peek_lane(0, 0), 1);
        assert_eq!(m.peek_lane(0, 1), 2);
        assert_eq!(m.peek_lane(0, 3), 99, "inactive lanes untouched");
    }

    #[test]
    fn batch_bounds_match_scalar_message() {
        let mut m = BatchMemory::new(8, 4, 2);
        let mut out = [0i32; 2];
        let e = m.load_lanes(8, &mut out).unwrap_err();
        assert_eq!(
            e.to_string(),
            "CGRA load out of bounds: word address 8 (memory is 8 words)"
        );
        assert!(m.store_lanes(-1, &[0, 0]).is_err());
        assert!(m.load_lanes(7, &mut out).is_ok());
        assert_eq!(m.len(), 8);
        assert_eq!(m.batch_capacity(), 2);
        assert_eq!(m.bank_of(5), 1);
    }
}
