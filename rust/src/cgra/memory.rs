//! The shared memory subsystem seen through the column DMA ports.
//!
//! Word-addressed int32 memory with word-interleaved banking. The
//! simulator models *timing* contention in the executor; this module
//! provides storage, bounds checking and access accounting (the access
//! counts feed the energy model — the paper identifies memory dynamic
//! energy as the discriminator between mapping strategies).

use anyhow::{bail, Result};

/// Running totals of memory traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemStats {
    /// Number of word loads served.
    pub loads: u64,
    /// Number of word stores served.
    pub stores: u64,
}

impl MemStats {
    /// Total accesses (loads + stores).
    pub fn total(&self) -> u64 {
        self.loads + self.stores
    }
}

/// Word-addressed memory with access accounting.
#[derive(Clone, Debug)]
pub struct Memory {
    words: Vec<i32>,
    n_banks: usize,
    stats: MemStats,
}

impl Memory {
    /// Zero-initialized memory of `words` 32-bit words with `n_banks`
    /// word-interleaved banks.
    pub fn new(words: usize, n_banks: usize) -> Self {
        assert!(n_banks >= 1);
        Memory { words: vec![0; words], n_banks, stats: MemStats::default() }
    }

    /// Size in words.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// True if zero-sized (never for valid configs).
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Bank index serving word address `addr` (word-interleaved).
    pub fn bank_of(&self, addr: usize) -> usize {
        addr % self.n_banks
    }

    /// Load the word at `addr` (counted).
    pub fn load(&mut self, addr: i32) -> Result<i32> {
        let a = self.check(addr, "load")?;
        self.stats.loads += 1;
        Ok(self.words[a])
    }

    /// Store `value` at `addr` (counted).
    pub fn store(&mut self, addr: i32, value: i32) -> Result<()> {
        let a = self.check(addr, "store")?;
        self.stats.stores += 1;
        self.words[a] = value;
        Ok(())
    }

    /// Uncounted read (host/debug access — e.g. the test harness reading
    /// back results; does not pollute the energy accounting).
    pub fn peek(&self, addr: usize) -> i32 {
        self.words[addr]
    }

    /// Uncounted slice read starting at `addr`.
    pub fn peek_slice(&self, addr: usize, len: usize) -> &[i32] {
        &self.words[addr..addr + len]
    }

    /// Uncounted write (host initialization — the paper's CPU preloads
    /// inputs/weights before launching; that traffic is charged separately
    /// by the host-side cost models, not here).
    pub fn poke(&mut self, addr: usize, value: i32) {
        self.words[addr] = value;
    }

    /// Uncounted bulk write starting at `addr`.
    pub fn poke_slice(&mut self, addr: usize, values: &[i32]) {
        self.words[addr..addr + values.len()].copy_from_slice(values);
    }

    /// Access totals so far.
    pub fn stats(&self) -> MemStats {
        self.stats
    }

    /// Reset the access counters (e.g. between measured regions).
    pub fn reset_stats(&mut self) {
        self.stats = MemStats::default();
    }

    fn check(&self, addr: i32, what: &str) -> Result<usize> {
        if addr < 0 || addr as usize >= self.words.len() {
            bail!(
                "CGRA {what} out of bounds: word address {addr} (memory is {} words)",
                self.words.len()
            );
        }
        Ok(addr as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_store_roundtrip_and_counts() {
        let mut m = Memory::new(16, 4);
        m.store(3, -7).unwrap();
        assert_eq!(m.load(3).unwrap(), -7);
        assert_eq!(m.stats(), MemStats { loads: 1, stores: 1 });
    }

    #[test]
    fn peek_poke_uncounted() {
        let mut m = Memory::new(16, 4);
        m.poke(0, 42);
        assert_eq!(m.peek(0), 42);
        m.poke_slice(4, &[1, 2, 3]);
        assert_eq!(m.peek_slice(4, 3), &[1, 2, 3]);
        assert_eq!(m.stats().total(), 0);
    }

    #[test]
    fn out_of_bounds_rejected() {
        let mut m = Memory::new(8, 4);
        assert!(m.load(-1).is_err());
        assert!(m.load(8).is_err());
        assert!(m.store(8, 0).is_err());
        assert!(m.load(7).is_ok());
    }

    #[test]
    fn bank_interleave() {
        let m = Memory::new(16, 4);
        assert_eq!(m.bank_of(0), 0);
        assert_eq!(m.bank_of(5), 1);
        assert_eq!(m.bank_of(7), 3);
    }

    #[test]
    fn reset_stats_zeroes() {
        let mut m = Memory::new(8, 2);
        m.store(0, 1).unwrap();
        m.reset_stats();
        assert_eq!(m.stats().total(), 0);
    }
}
