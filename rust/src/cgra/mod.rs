//! Cycle-level simulator of the OpenEdgeCGRA 4×4 array.
//!
//! The paper's hardware substrate, rebuilt in software: PEs with private
//! 32-word programs, torus neighbour links, per-column program counters
//! and DMA ports, a banked memory subsystem, and the timing model whose
//! collision behaviour drives the paper's Figure 4/5 results.
//!
//! Execution is a two-stage decode/execute engine (DESIGN.md §3.4):
//! [`decode`] lowers a program once into a dense µop form, and the
//! executor replays it; [`decode_cached`] memoizes decodes process-wide
//! for the figure drivers and benches that relaunch identical programs.
//! [`Cgra::run_decoded_batch`] replays one decoded program across a
//! [`BatchMemory`] of independent lane images in a single shared µop
//! walk (DESIGN.md §9) — per-inference stats stay bit-identical.

mod config;
mod decoded;
mod exec;
mod memory;
mod stats;

pub use config::CgraConfig;
pub use decoded::{
    clear_decode_cache, decode, decode_cache_stats, decode_cached, decode_count,
    DecodeCacheStats, DecodedProgram, DECODE_CACHE_CAPACITY,
};
pub(crate) use decoded::ProgTable;
pub use exec::{column_pes, Cgra, StepTrace};
pub use memory::{BatchMemory, MemStats, Memory};
pub use stats::{OpClass, RunStats};
