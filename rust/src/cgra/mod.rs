//! Cycle-level simulator of the OpenEdgeCGRA 4×4 array.
//!
//! The paper's hardware substrate, rebuilt in software: PEs with private
//! 32-word programs, torus neighbour links, per-column program counters
//! and DMA ports, a banked memory subsystem, and the timing model whose
//! collision behaviour drives the paper's Figure 4/5 results.

mod config;
mod exec;
mod memory;
mod stats;

pub use config::CgraConfig;
pub use exec::{column_pes, Cgra, StepTrace};
pub use memory::{MemStats, Memory};
pub use stats::{OpClass, RunStats};
