//! Cycle-level executor for the 4×4 array.
//!
//! Semantics (DESIGN.md §3.2):
//!
//! - All columns step together; each column has its own program counter
//!   and every PE of a column fetches from its private program at the
//!   column's PC.
//! - All operand reads observe the *previous* step's latched state
//!   (synchronous array): neighbour/own output registers, the register
//!   file and the DMA address register.
//! - Within a step, all loads read pre-step memory, then all stores are
//!   applied; two stores to one address in one step are a programming
//!   error and abort the run.
//! - At most one PE per column may issue control flow per step.
//! - The cycle cost of a step is the max over PEs of the op latency,
//!   widened by DMA-port serialization (one port per column) and bank
//!   conflicts — the "collisions between PEs" of the paper's §3.1.
//! - Any PE issuing `exit` halts the array at the end of the step.
//!
//! # Decode/execute split (DESIGN.md §3.4)
//!
//! The hot path is a two-stage engine: [`super::decoded`] lowers a
//! program once into dense µops (pre-resolved neighbour indices,
//! pre-split destination masks, static per-column step metadata), and
//! [`Cgra::run_decoded`] replays that representation. The original
//! enum-matching interpreter is kept, verbatim, as
//! [`Cgra::run_reference`]: it is the differential baseline the decoded
//! engine is required to match step-for-step (`RunStats` equality) and
//! the "before" side of the `sim_throughput` bench.
//!
//! # Batched execution (DESIGN.md §9)
//!
//! [`Cgra::run_decoded_batch`] replays one decoded program against `B`
//! independent memory images in a single shared program walk: the
//! per-step fixed costs (µop dispatch, column metadata, branch
//! resolution, bank/port accounting, watchdog) are paid once per step,
//! and only the data plane — ALU lanes and load/store word copies —
//! scales with `B`, as tight contiguous loops over structure-of-arrays
//! state. The batch models `B` copies of the *same* hardware run, so
//! its `RunStats` is per-inference and bit-identical to a scalar run;
//! lane-divergent control flow or addresses abort with a
//! "batch divergence" error (kernel programs derive both from
//! immediates and counters, never loaded data, so real launches never
//! diverge).

use anyhow::{bail, Context, Result};

use crate::isa::{Dst, Instr, Op, PeId, Program, Src, COLS, N_PES, N_REGS, ROWS};
use crate::obs::profile;

use super::config::CgraConfig;
use super::decoded::{self, AluFn, BrFn, DecodedProgram, UKind, USrc, NO_REG};
use super::memory::{BatchMemory, Memory};
use super::stats::{OpClass, RunStats};

/// The step-cost decomposition of one array step — the paper's §3.1
/// collision model. Shared by all three executors (scalar decoded,
/// batched, reference interpreter) so they charge identically by
/// construction and the profiler ([`crate::obs::profile`]) observes
/// the parts at a single site instead of three.
pub(crate) struct StepCost {
    /// ALU critical path: `mul_latency` if any PE multiplied this
    /// step, else `alu_latency` (never below `alu_latency`).
    pub alu_part: u64,
    /// DMA-port serialization: the busiest column's memory ops, one
    /// `mem_latency` each (one port per column).
    pub port_part: u64,
    /// Bank conflicts: the worst bank's `mem_latency + (hits-1) ·
    /// bank_penalty` (0 when the step issued no memory op).
    pub bank_part: u64,
    /// The contention-free cost this step would have had.
    pub ideal: u64,
    /// The charged cost: `max(alu, port, bank, 1)`.
    pub cycles: u64,
}

/// Compute one step's cost from the step metadata. `bank_hits` must be
/// the per-bank access counts of this step **when `any_mem`**; when no
/// memory op issued the slice may hold stale values (the executors
/// skip clearing it) — the bank term is gated off in that case.
#[inline(always)]
pub(crate) fn step_cost(
    cfg: &CgraConfig,
    any_mul: bool,
    any_mem: bool,
    max_port_ops: u32,
    bank_hits: &[u32],
) -> StepCost {
    let alu_part =
        if any_mul { cfg.mul_latency } else { cfg.alu_latency }.max(cfg.alu_latency);
    let port_part = max_port_ops as u64 * cfg.mem_latency;
    let bank_part = if any_mem {
        bank_hits
            .iter()
            .map(|&n| {
                if n == 0 {
                    0
                } else {
                    cfg.mem_latency + (n as u64 - 1) * cfg.bank_penalty
                }
            })
            .max()
            .unwrap_or(0)
    } else {
        0
    };
    let ideal = alu_part.max(if any_mem { cfg.mem_latency } else { 0 });
    let cycles = alu_part.max(port_part).max(bank_part).max(1);
    StepCost { alu_part, port_part, bank_part, ideal, cycles }
}

/// Torus neighbour lookup table: `NEIGH[pe][dir]` = neighbour PE index
/// (dir order: N, S, E, W). Precomputed so neither interpreter pays the
/// div/mod arithmetic of [`PeId::neighbour`]; the decode stage folds it
/// into the µops.
pub(crate) const NEIGH: [[usize; 4]; N_PES] = build_neigh();

const fn build_neigh() -> [[usize; 4]; N_PES] {
    let mut t = [[0usize; 4]; N_PES];
    let mut i = 0;
    while i < N_PES {
        let (r, c) = (i / COLS, i % COLS);
        t[i][0] = ((r + ROWS - 1) % ROWS) * COLS + c; // N
        t[i][1] = ((r + 1) % ROWS) * COLS + c; // S
        t[i][2] = r * COLS + (c + 1) % COLS; // E
        t[i][3] = r * COLS + (c + COLS - 1) % COLS; // W
        i += 1;
    }
    t
}

#[inline(always)]
pub(crate) const fn dir_idx(d: crate::isa::Dir) -> usize {
    match d {
        crate::isa::Dir::North => 0,
        crate::isa::Dir::South => 1,
        crate::isa::Dir::East => 2,
        crate::isa::Dir::West => 3,
    }
}

/// Architectural state of one PE.
#[derive(Clone, Copy, Debug, Default)]
struct PeState {
    regs: [i32; N_REGS],
    rout: i32,
    addr: i32,
}

/// One deferred result latch of the decoded engine's current step.
#[derive(Clone, Copy, Debug)]
struct Latch {
    pe: u8,
    wout: bool,
    wreg: u8,
    val: i32,
}

/// Per-step observation passed to trace hooks.
#[derive(Clone, Debug)]
pub struct StepTrace {
    /// Step index (0-based).
    pub step: u64,
    /// Column PCs *before* this step.
    pub pcs: [usize; COLS],
    /// The instruction each PE issued.
    pub instrs: [Instr; N_PES],
    /// Result value each PE produced (0 for no-result ops).
    pub results: [i32; N_PES],
    /// Cycle cost charged for this step.
    pub cycles: u64,
}

/// The simulator. Stateless between runs apart from configuration;
/// `run` owns all architectural state for one launch.
#[derive(Clone, Debug)]
pub struct Cgra {
    cfg: CgraConfig,
}

impl Cgra {
    /// Build a simulator with the given configuration.
    pub fn new(cfg: CgraConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(Cgra { cfg })
    }

    /// The active configuration.
    pub fn config(&self) -> &CgraConfig {
        &self.cfg
    }

    /// Execute `prog` against `mem` until `exit` (or the watchdog trips).
    ///
    /// Decodes through the process-wide memo ([`decoded::decode_cached`])
    /// and runs the µop engine; callers that launch the same decoded
    /// program repeatedly should hold the [`DecodedProgram`] themselves
    /// and call [`Cgra::run_decoded`].
    pub fn run(&self, prog: &Program, mem: &mut Memory) -> Result<RunStats> {
        let dp = decoded::decode_cached(prog);
        self.run_decoded(&dp, mem)
    }

    /// Execute an already-decoded program — the hot entry point used by
    /// the kernel drivers.
    pub fn run_decoded(&self, dp: &DecodedProgram, mem: &mut Memory) -> Result<RunStats> {
        // TRACE = false compiles the StepTrace construction out of the
        // hot loop entirely (measured ~10% on the executor bench).
        self.run_decoded_inner::<false>(dp, None, mem, &mut |_| {})
    }

    /// Execute with a per-step trace hook (debugging, pipeline tests).
    /// The source program rides along so traces can report the raw
    /// fetched instructions (the decoded form drops them).
    pub fn run_hooked(
        &self,
        prog: &Program,
        mem: &mut Memory,
        mut hook: impl FnMut(&StepTrace),
    ) -> Result<RunStats> {
        let dp = decoded::decode(prog);
        self.run_decoded_inner::<true>(&dp, Some(prog), mem, &mut hook)
    }

    fn run_decoded_inner<const TRACE: bool>(
        &self,
        dp: &DecodedProgram,
        raw: Option<&Program>,
        mem: &mut Memory,
        hook: &mut dyn FnMut(&StepTrace),
    ) -> Result<RunStats> {
        let mut st = [PeState::default(); N_PES];
        let mut pcs = [0usize; COLS];
        let mut stats = RunStats::new();
        let mem0 = mem.stats();
        // Latched once per run: with profiling off the whole subsystem
        // costs this single relaxed load (free-when-off contract).
        let prof = profile::enabled();
        if prof {
            profile::begin_walk();
            mem.reset_high_water();
        }

        // Per-(column, slot) visit counters: the op class of every slot
        // is static, so the per-step histogram update of the reference
        // interpreter collapses to one counter increment per column,
        // folded into `stats.op_mix` once at the end.
        let mut visits: [Vec<u64>; COLS] =
            std::array::from_fn(|c| vec![0u64; dp.col_meta(c).len()]);

        // Scratch reused across steps.
        let mut instrs = [Instr::nop(); N_PES]; // TRACE only
        let mut results = [0i32; N_PES]; // TRACE only
        // Deferred writebacks (synchronous array): each PE issues at most
        // one instruction per step, so at most one latch and one address
        // record each — applied after every operand read of the step.
        let mut latches = [Latch { pe: 0, wout: false, wreg: NO_REG, val: 0 }; N_PES];
        let mut addrs = [(0u8, 0i32); N_PES];
        // Pending stores: (addr, value, pe_index).
        let mut pending_stores: Vec<(i32, i32, usize)> = Vec::with_capacity(N_PES);
        // Branch decision per column: (taken, target).
        let mut branch: [Option<(bool, usize)>; COLS];
        let mut bank_hits = vec![0u32; self.cfg.n_banks.max(1)];

        loop {
            if stats.steps >= self.cfg.max_steps {
                bail!(
                    "watchdog: program '{}' exceeded {} steps without exit",
                    dp.name(),
                    self.cfg.max_steps
                );
            }

            // ---- static per-column step metadata ----
            let mut any_mul = false;
            let mut any_mem = false;
            let mut max_port_ops = 0u32;
            for c in 0..COLS {
                let meta = dp.col_meta(c);
                let idx = pcs[c].min(meta.len() - 1);
                visits[c][idx] += 1;
                let m = meta[idx];
                any_mul |= m.any_mul;
                any_mem |= m.mem_ops > 0;
                max_port_ops = max_port_ops.max(m.mem_ops);
            }

            // ---- evaluate & execute ----
            let mut exit = false;
            let mut n_latch = 0usize;
            let mut n_addr = 0usize;
            pending_stores.clear();
            branch = [None; COLS];
            if any_mem {
                bank_hits.iter_mut().for_each(|x| *x = 0);
            }

            for i in 0..N_PES {
                let col = i % COLS;
                let pc = pcs[col];
                let u = dp.uop(i, pc);
                if TRACE {
                    instrs[i] = raw
                        .map(|p| p.pe(PeId::from_index(i)).fetch(pc))
                        .unwrap_or_else(Instr::nop);
                    results[i] = 0;
                }

                match u.kind {
                    UKind::Nop => {}
                    UKind::Exit => exit = true,
                    UKind::Alu(f) => {
                        let a = read_usrc(u.a, i, &st);
                        let b = read_usrc(u.b, i, &st);
                        let v = match f {
                            AluFn::Mov => a,
                            AluFn::Add => a.wrapping_add(b),
                            AluFn::Sub => a.wrapping_sub(b),
                            AluFn::Mul => a.wrapping_mul(b),
                            AluFn::Shl => a.wrapping_shl(b as u32 & 31),
                            AluFn::Shr => a.wrapping_shr(b as u32 & 31),
                            AluFn::And => a & b,
                            AluFn::Or => a | b,
                            AluFn::Xor => a ^ b,
                            AluFn::Min => a.min(b),
                            AluFn::Max => a.max(b),
                        };
                        if TRACE {
                            results[i] = v;
                        }
                        if u.wout || u.wreg != NO_REG {
                            latches[n_latch] =
                                Latch { pe: i as u8, wout: u.wout, wreg: u.wreg, val: v };
                            n_latch += 1;
                        }
                    }
                    UKind::SetAddr => {
                        let v = read_usrc(u.a, i, &st).wrapping_add(read_usrc(u.b, i, &st));
                        addrs[n_addr] = (i as u8, v);
                        n_addr += 1;
                        if TRACE {
                            results[i] = v;
                        }
                    }
                    UKind::Lw => {
                        let addr =
                            read_usrc(u.a, i, &st).wrapping_add(read_usrc(u.b, i, &st));
                        bank_hits[mem.bank_of(addr.max(0) as usize % mem.len())] += 1;
                        let v = mem.load(addr).with_context(|| {
                            format!("{} lw at step {}", PeId::from_index(i), stats.steps)
                        })?;
                        if TRACE {
                            results[i] = v;
                        }
                        if u.wout || u.wreg != NO_REG {
                            latches[n_latch] =
                                Latch { pe: i as u8, wout: u.wout, wreg: u.wreg, val: v };
                            n_latch += 1;
                        }
                    }
                    UKind::LwInc => {
                        let addr = st[i].addr;
                        bank_hits[mem.bank_of(addr.max(0) as usize % mem.len())] += 1;
                        let v = mem.load(addr).with_context(|| {
                            format!("{} lwinc at step {}", PeId::from_index(i), stats.steps)
                        })?;
                        let inc =
                            read_usrc(u.a, i, &st).wrapping_add(read_usrc(u.b, i, &st));
                        addrs[n_addr] = (i as u8, addr.wrapping_add(inc));
                        n_addr += 1;
                        if TRACE {
                            results[i] = v;
                        }
                        if u.wout || u.wreg != NO_REG {
                            latches[n_latch] =
                                Latch { pe: i as u8, wout: u.wout, wreg: u.wreg, val: v };
                            n_latch += 1;
                        }
                    }
                    UKind::SwInc => {
                        let addr = st[i].addr;
                        bank_hits[mem.bank_of(addr.max(0) as usize % mem.len())] += 1;
                        pending_stores.push((addr, read_usrc(u.a, i, &st), i));
                        addrs[n_addr] = (i as u8, addr.wrapping_add(read_usrc(u.b, i, &st)));
                        n_addr += 1;
                    }
                    UKind::SwAt => {
                        let addr =
                            read_usrc(u.a, i, &st).wrapping_add(read_usrc(u.b, i, &st));
                        bank_hits[mem.bank_of(addr.max(0) as usize % mem.len())] += 1;
                        pending_stores.push((addr, st[i].rout, i));
                    }
                    UKind::Br(f) => {
                        let a = read_usrc(u.a, i, &st);
                        let b = read_usrc(u.b, i, &st);
                        let taken = match f {
                            BrFn::Eq => a == b,
                            BrFn::Ne => a != b,
                            BrFn::Lt => a < b,
                            BrFn::Ge => a >= b,
                            BrFn::Always => true,
                        };
                        if branch[col].is_some() {
                            bail!(
                                "two control-flow ops in column {} at step {} (program '{}')",
                                col,
                                stats.steps,
                                dp.name()
                            );
                        }
                        branch[col] = Some((taken, u.target as usize));
                    }
                }
            }

            // ---- apply stores (loads already saw pre-step memory) ----
            pending_stores.sort_unstable_by_key(|&(a, _, _)| a);
            for w in pending_stores.windows(2) {
                if w[0].0 == w[1].0 {
                    bail!(
                        "store conflict: PEs {} and {} both store to word {} at step {} \
                         (program '{}')",
                        PeId::from_index(w[0].2),
                        PeId::from_index(w[1].2),
                        w[0].0,
                        stats.steps,
                        dp.name()
                    );
                }
            }
            for &(addr, val, pe) in &pending_stores {
                mem.store(addr, val).with_context(|| {
                    format!("{} store at step {}", PeId::from_index(pe), stats.steps)
                })?;
            }

            // ---- cycle cost (shared helper — see step_cost) ----
            let sc = step_cost(&self.cfg, any_mul, any_mem, max_port_ops, &bank_hits);
            let step_cycles = sc.cycles;
            stats.cycles += step_cycles;
            stats.contention_cycles += step_cycles - sc.ideal.min(step_cycles);
            if prof {
                let mut pe_cls = [0usize; N_PES];
                for (i, cls) in pe_cls.iter_mut().enumerate() {
                    let c = i % COLS;
                    *cls = dp.class_at(i, pcs[c].min(dp.col_meta(c).len() - 1));
                }
                profile::observe_step(
                    sc.alu_part,
                    sc.port_part,
                    sc.bank_part,
                    step_cycles,
                    any_mem,
                    &bank_hits,
                    &pe_cls,
                );
            }

            // ---- trace hook ----
            if TRACE {
                hook(&StepTrace { step: stats.steps, pcs, instrs, results, cycles: step_cycles });
            }

            // ---- writeback (at most one latch + one addr per PE) ----
            for l in &latches[..n_latch] {
                let s = &mut st[l.pe as usize];
                if l.wout {
                    s.rout = l.val;
                }
                if l.wreg != NO_REG {
                    s.regs[l.wreg as usize] = l.val;
                }
            }
            for &(pe, a) in &addrs[..n_addr] {
                st[pe as usize].addr = a;
            }

            // ---- PC update ----
            for c in 0..COLS {
                pcs[c] = match branch[c] {
                    Some((true, t)) => t,
                    _ => pcs[c] + 1,
                };
            }

            stats.steps += 1;
            if exit {
                stats.exited = true;
                break;
            }
        }

        // Fold the per-slot visit counters into the op-mix histogram.
        for c in 0..COLS {
            for (p, &n) in visits[c].iter().enumerate() {
                if n == 0 {
                    continue;
                }
                for r in 0..ROWS {
                    let i = r * COLS + c;
                    stats.op_mix[i][dp.class_at(i, p)] += n;
                }
            }
        }
        let m1 = mem.stats();
        stats.mem.loads = m1.loads - mem0.loads;
        stats.mem.stores = m1.stores - mem0.stores;
        if prof {
            profile::end_walk(mem.high_water());
        }
        Ok(stats)
    }

    /// Execute an already-decoded program against `lanes` independent
    /// memory images in **one shared µop program walk** (DESIGN.md §9).
    ///
    /// All lanes run in strict lockstep: column PCs, branch decisions,
    /// memory addresses, the watchdog and every piece of timing/energy
    /// accounting are shared, and only register/memory *values* are
    /// per-lane (structure-of-arrays, contiguous per µop — the inner
    /// loops autovectorize). The returned [`RunStats`] is therefore
    /// **per-inference** and bit-identical to what [`Cgra::run_decoded`]
    /// reports for any single lane: batching is a simulator-throughput
    /// trick, not a hardware-model change.
    ///
    /// `lanes` may be any `1..=mem.batch_capacity()` (the ragged final
    /// chunk of a request stream); inactive tail lanes are never read
    /// or written. If lanes disagree on a branch outcome or a memory
    /// address — impossible for the generated kernel programs, whose
    /// control flow and addressing derive from immediates and loop
    /// counters only — the run aborts with a "batch divergence" error
    /// naming the program, step and PE; rerun such inputs scalar.
    pub fn run_decoded_batch(
        &self,
        dp: &DecodedProgram,
        mem: &mut BatchMemory,
        lanes: usize,
    ) -> Result<RunStats> {
        let nb = lanes;
        if nb == 0 || nb > mem.batch_capacity() {
            bail!(
                "batch lane count {} out of range 1..={} (program '{}')",
                nb,
                mem.batch_capacity(),
                dp.name()
            );
        }

        // Per-lane architectural state, structure-of-arrays: the B
        // copies of one register live contiguously, so every operand
        // read/writeback is a contiguous copy of `nb` words.
        let mut rout = vec![0i32; N_PES * nb];
        let mut regs = vec![0i32; N_PES * N_REGS * nb];
        let mut addr_reg = vec![0i32; N_PES * nb];

        let mut pcs = [0usize; COLS];
        let mut stats = RunStats::new();
        let mem0 = mem.stats();
        // Latched once per run (free-when-off contract). The walk is
        // shared by every lane and its costs are per-inference, so the
        // profile delta of a batch walk is lane-for-lane identical to
        // a scalar run's.
        let prof = profile::enabled();
        if prof {
            profile::begin_walk();
            mem.reset_high_water();
        }

        let mut visits: [Vec<u64>; COLS] =
            std::array::from_fn(|c| vec![0u64; dp.col_meta(c).len()]);

        // Scratch reused across steps (no per-step allocation).
        let mut abuf = vec![0i32; nb];
        let mut bbuf = vec![0i32; nb];
        // Deferred writebacks: value arenas indexed by slot, metadata
        // alongside — the batched mirror of the scalar `Latch` records.
        let mut latch_vals = vec![0i32; N_PES * nb];
        let mut latch_meta = [(0u8, false, NO_REG); N_PES];
        let mut addr_vals = vec![0i32; N_PES * nb];
        let mut addr_meta = [0u8; N_PES];
        let mut store_vals = vec![0i32; N_PES * nb];
        // Pending stores: (addr, value_slot, pe_index).
        let mut store_meta: Vec<(i32, usize, usize)> = Vec::with_capacity(N_PES);
        let mut branch: [Option<(bool, usize)>; COLS];
        let mut bank_hits = vec![0u32; self.cfg.n_banks.max(1)];

        loop {
            if stats.steps >= self.cfg.max_steps {
                bail!(
                    "watchdog: program '{}' exceeded {} steps without exit",
                    dp.name(),
                    self.cfg.max_steps
                );
            }

            // ---- static per-column step metadata (shared by all lanes) ----
            let mut any_mul = false;
            let mut any_mem = false;
            let mut max_port_ops = 0u32;
            for c in 0..COLS {
                let meta = dp.col_meta(c);
                let idx = pcs[c].min(meta.len() - 1);
                visits[c][idx] += 1;
                let m = meta[idx];
                any_mul |= m.any_mul;
                any_mem |= m.mem_ops > 0;
                max_port_ops = max_port_ops.max(m.mem_ops);
            }

            // ---- evaluate & execute ----
            let mut exit = false;
            let mut n_latch = 0usize;
            let mut n_addr = 0usize;
            store_meta.clear();
            branch = [None; COLS];
            if any_mem {
                bank_hits.iter_mut().for_each(|x| *x = 0);
            }

            for i in 0..N_PES {
                let col = i % COLS;
                let pc = pcs[col];
                let u = dp.uop(i, pc);

                match u.kind {
                    UKind::Nop => {}
                    UKind::Exit => exit = true,
                    UKind::Alu(f) => {
                        // An ALU op with no destination has no
                        // architectural effect — skip the lane loop.
                        if u.wout || u.wreg != NO_REG {
                            read_batch(u.a, i, nb, &rout, &regs, &addr_reg, &mut abuf);
                            read_batch(u.b, i, nb, &rout, &regs, &addr_reg, &mut bbuf);
                            let dst = &mut latch_vals[n_latch * nb..(n_latch + 1) * nb];
                            match f {
                                AluFn::Mov => dst.copy_from_slice(&abuf),
                                AluFn::Add => {
                                    for l in 0..nb {
                                        dst[l] = abuf[l].wrapping_add(bbuf[l]);
                                    }
                                }
                                AluFn::Sub => {
                                    for l in 0..nb {
                                        dst[l] = abuf[l].wrapping_sub(bbuf[l]);
                                    }
                                }
                                AluFn::Mul => {
                                    for l in 0..nb {
                                        dst[l] = abuf[l].wrapping_mul(bbuf[l]);
                                    }
                                }
                                AluFn::Shl => {
                                    for l in 0..nb {
                                        dst[l] = abuf[l].wrapping_shl(bbuf[l] as u32 & 31);
                                    }
                                }
                                AluFn::Shr => {
                                    for l in 0..nb {
                                        dst[l] = abuf[l].wrapping_shr(bbuf[l] as u32 & 31);
                                    }
                                }
                                AluFn::And => {
                                    for l in 0..nb {
                                        dst[l] = abuf[l] & bbuf[l];
                                    }
                                }
                                AluFn::Or => {
                                    for l in 0..nb {
                                        dst[l] = abuf[l] | bbuf[l];
                                    }
                                }
                                AluFn::Xor => {
                                    for l in 0..nb {
                                        dst[l] = abuf[l] ^ bbuf[l];
                                    }
                                }
                                AluFn::Min => {
                                    for l in 0..nb {
                                        dst[l] = abuf[l].min(bbuf[l]);
                                    }
                                }
                                AluFn::Max => {
                                    for l in 0..nb {
                                        dst[l] = abuf[l].max(bbuf[l]);
                                    }
                                }
                            }
                            latch_meta[n_latch] = (i as u8, u.wout, u.wreg);
                            n_latch += 1;
                        }
                    }
                    UKind::SetAddr => {
                        read_batch(u.a, i, nb, &rout, &regs, &addr_reg, &mut abuf);
                        read_batch(u.b, i, nb, &rout, &regs, &addr_reg, &mut bbuf);
                        let dst = &mut addr_vals[n_addr * nb..(n_addr + 1) * nb];
                        for l in 0..nb {
                            dst[l] = abuf[l].wrapping_add(bbuf[l]);
                        }
                        addr_meta[n_addr] = i as u8;
                        n_addr += 1;
                    }
                    UKind::Lw => {
                        read_batch(u.a, i, nb, &rout, &regs, &addr_reg, &mut abuf);
                        read_batch(u.b, i, nb, &rout, &regs, &addr_reg, &mut bbuf);
                        for l in 0..nb {
                            abuf[l] = abuf[l].wrapping_add(bbuf[l]);
                        }
                        let addr = uniform_addr(&abuf, i, "lw", stats.steps, dp)?;
                        bank_hits[mem.bank_of(addr.max(0) as usize % mem.len())] += 1;
                        if u.wout || u.wreg != NO_REG {
                            let dst = &mut latch_vals[n_latch * nb..(n_latch + 1) * nb];
                            mem.load_lanes(addr, dst).with_context(|| {
                                format!("{} lw at step {}", PeId::from_index(i), stats.steps)
                            })?;
                            latch_meta[n_latch] = (i as u8, u.wout, u.wreg);
                            n_latch += 1;
                        } else {
                            // Destination-less load: still counted.
                            mem.load_lanes(addr, &mut abuf).with_context(|| {
                                format!("{} lw at step {}", PeId::from_index(i), stats.steps)
                            })?;
                        }
                    }
                    UKind::LwInc => {
                        let addr =
                            uniform_addr(&addr_reg[i * nb..(i + 1) * nb], i, "lwinc", stats.steps, dp)?;
                        bank_hits[mem.bank_of(addr.max(0) as usize % mem.len())] += 1;
                        if u.wout || u.wreg != NO_REG {
                            let dst = &mut latch_vals[n_latch * nb..(n_latch + 1) * nb];
                            mem.load_lanes(addr, dst).with_context(|| {
                                format!("{} lwinc at step {}", PeId::from_index(i), stats.steps)
                            })?;
                            latch_meta[n_latch] = (i as u8, u.wout, u.wreg);
                            n_latch += 1;
                        } else {
                            mem.load_lanes(addr, &mut abuf).with_context(|| {
                                format!("{} lwinc at step {}", PeId::from_index(i), stats.steps)
                            })?;
                        }
                        read_batch(u.a, i, nb, &rout, &regs, &addr_reg, &mut abuf);
                        read_batch(u.b, i, nb, &rout, &regs, &addr_reg, &mut bbuf);
                        let dst = &mut addr_vals[n_addr * nb..(n_addr + 1) * nb];
                        for l in 0..nb {
                            dst[l] = addr_reg[i * nb + l]
                                .wrapping_add(abuf[l].wrapping_add(bbuf[l]));
                        }
                        addr_meta[n_addr] = i as u8;
                        n_addr += 1;
                    }
                    UKind::SwInc => {
                        let addr =
                            uniform_addr(&addr_reg[i * nb..(i + 1) * nb], i, "swinc", stats.steps, dp)?;
                        bank_hits[mem.bank_of(addr.max(0) as usize % mem.len())] += 1;
                        let slot = store_meta.len();
                        read_batch(u.a, i, nb, &rout, &regs, &addr_reg, &mut abuf);
                        store_vals[slot * nb..(slot + 1) * nb].copy_from_slice(&abuf);
                        store_meta.push((addr, slot, i));
                        read_batch(u.b, i, nb, &rout, &regs, &addr_reg, &mut bbuf);
                        let dst = &mut addr_vals[n_addr * nb..(n_addr + 1) * nb];
                        for l in 0..nb {
                            dst[l] = addr_reg[i * nb + l].wrapping_add(bbuf[l]);
                        }
                        addr_meta[n_addr] = i as u8;
                        n_addr += 1;
                    }
                    UKind::SwAt => {
                        read_batch(u.a, i, nb, &rout, &regs, &addr_reg, &mut abuf);
                        read_batch(u.b, i, nb, &rout, &regs, &addr_reg, &mut bbuf);
                        for l in 0..nb {
                            abuf[l] = abuf[l].wrapping_add(bbuf[l]);
                        }
                        let addr = uniform_addr(&abuf, i, "swat", stats.steps, dp)?;
                        bank_hits[mem.bank_of(addr.max(0) as usize % mem.len())] += 1;
                        let slot = store_meta.len();
                        store_vals[slot * nb..(slot + 1) * nb]
                            .copy_from_slice(&rout[i * nb..(i + 1) * nb]);
                        store_meta.push((addr, slot, i));
                    }
                    UKind::Br(f) => {
                        read_batch(u.a, i, nb, &rout, &regs, &addr_reg, &mut abuf);
                        read_batch(u.b, i, nb, &rout, &regs, &addr_reg, &mut bbuf);
                        let decide = |a: i32, b: i32| match f {
                            BrFn::Eq => a == b,
                            BrFn::Ne => a != b,
                            BrFn::Lt => a < b,
                            BrFn::Ge => a >= b,
                            BrFn::Always => true,
                        };
                        let taken = decide(abuf[0], bbuf[0]);
                        for l in 1..nb {
                            if decide(abuf[l], bbuf[l]) != taken {
                                bail!(
                                    "batch divergence: branch at {} resolves differently \
                                     across lanes at step {} (program '{}'); batched \
                                     execution requires lane-uniform control flow — rerun \
                                     these inputs through the scalar executor",
                                    PeId::from_index(i),
                                    stats.steps,
                                    dp.name()
                                );
                            }
                        }
                        if branch[col].is_some() {
                            bail!(
                                "two control-flow ops in column {} at step {} (program '{}')",
                                col,
                                stats.steps,
                                dp.name()
                            );
                        }
                        branch[col] = Some((taken, u.target as usize));
                    }
                }
            }

            // ---- apply stores (loads already saw pre-step memory) ----
            store_meta.sort_unstable_by_key(|&(a, _, _)| a);
            for w in store_meta.windows(2) {
                if w[0].0 == w[1].0 {
                    bail!(
                        "store conflict: PEs {} and {} both store to word {} at step {} \
                         (program '{}')",
                        PeId::from_index(w[0].2),
                        PeId::from_index(w[1].2),
                        w[0].0,
                        stats.steps,
                        dp.name()
                    );
                }
            }
            for &(addr, slot, pe) in &store_meta {
                mem.store_lanes(addr, &store_vals[slot * nb..(slot + 1) * nb]).with_context(
                    || format!("{} store at step {}", PeId::from_index(pe), stats.steps),
                )?;
            }

            // ---- cycle cost (identical to the scalar engine: the batch
            // models B copies of the same hardware run) ----
            let sc = step_cost(&self.cfg, any_mul, any_mem, max_port_ops, &bank_hits);
            let step_cycles = sc.cycles;
            stats.cycles += step_cycles;
            stats.contention_cycles += step_cycles - sc.ideal.min(step_cycles);
            if prof {
                let mut pe_cls = [0usize; N_PES];
                for (i, cls) in pe_cls.iter_mut().enumerate() {
                    let c = i % COLS;
                    *cls = dp.class_at(i, pcs[c].min(dp.col_meta(c).len() - 1));
                }
                profile::observe_step(
                    sc.alu_part,
                    sc.port_part,
                    sc.bank_part,
                    step_cycles,
                    any_mem,
                    &bank_hits,
                    &pe_cls,
                );
            }

            // ---- writeback (latches, then addresses — scalar order) ----
            for k in 0..n_latch {
                let (pe, wout, wreg) = latch_meta[k];
                let vals = &latch_vals[k * nb..(k + 1) * nb];
                if wout {
                    rout[pe as usize * nb..(pe as usize + 1) * nb].copy_from_slice(vals);
                }
                if wreg != NO_REG {
                    let base = (pe as usize * N_REGS + wreg as usize) * nb;
                    regs[base..base + nb].copy_from_slice(vals);
                }
            }
            for k in 0..n_addr {
                let pe = addr_meta[k] as usize;
                addr_reg[pe * nb..(pe + 1) * nb]
                    .copy_from_slice(&addr_vals[k * nb..(k + 1) * nb]);
            }

            // ---- PC update ----
            for c in 0..COLS {
                pcs[c] = match branch[c] {
                    Some((true, t)) => t,
                    _ => pcs[c] + 1,
                };
            }

            stats.steps += 1;
            if exit {
                stats.exited = true;
                break;
            }
        }

        // Fold the per-slot visit counters into the op-mix histogram.
        for c in 0..COLS {
            for (p, &n) in visits[c].iter().enumerate() {
                if n == 0 {
                    continue;
                }
                for r in 0..ROWS {
                    let i = r * COLS + c;
                    stats.op_mix[i][dp.class_at(i, p)] += n;
                }
            }
        }
        let m1 = mem.stats();
        stats.mem.loads = m1.loads - mem0.loads;
        stats.mem.stores = m1.stores - mem0.stores;
        if prof {
            profile::end_walk(mem.high_water());
        }
        Ok(stats)
    }

    /// The pre-refactor enum-matching interpreter, kept verbatim as the
    /// differential baseline: the decoded engine must produce identical
    /// `RunStats` and memory effects on every program. Also the "before"
    /// side of the `sim_throughput` bench. Not a hot path — use
    /// [`Cgra::run`] / [`Cgra::run_decoded`] for real work.
    pub fn run_reference(&self, prog: &Program, mem: &mut Memory) -> Result<RunStats> {
        let mut st = [PeState::default(); N_PES];
        let mut pcs = [0usize; COLS];
        let mut stats = RunStats::new();
        let mem_loads0 = mem.stats();
        // Latched once per run (free-when-off contract); the reference
        // interpreter profiles too so differential tests can pin the
        // decoded engine's attribution against it.
        let prof = profile::enabled();
        if prof {
            profile::begin_walk();
            mem.reset_high_water();
        }
        // Hot-loop locals: pre-resolved per-PE code and a fixed-size
        // op-mix accumulator (folded into `stats` at the end).
        let code: [&[Instr]; N_PES] =
            std::array::from_fn(|i| prog.pe(PeId::from_index(i)).instrs());
        let mut op_mix = [[0u64; OpClass::COUNT]; N_PES];

        // Scratch reused across steps.
        let mut instrs = [Instr::nop(); N_PES];
        let mut results = [0i32; N_PES];
        let mut write_out = [false; N_PES];
        let mut write_reg: [Option<u8>; N_PES] = [None; N_PES];
        let mut new_addr: [Option<i32>; N_PES] = [None; N_PES];
        // Pending stores: (addr, value, pe_index).
        let mut pending_stores: Vec<(i32, i32, usize)> = Vec::with_capacity(N_PES);
        // Branch decision per column: (taken, target).
        let mut branch: [Option<(bool, usize)>; COLS];
        let mut bank_hits = vec![0u32; self.cfg.n_banks.max(1)];

        loop {
            if stats.steps >= self.cfg.max_steps {
                bail!(
                    "watchdog: program '{}' exceeded {} steps without exit",
                    prog.name,
                    self.cfg.max_steps
                );
            }

            // ---- fetch ----
            for i in 0..N_PES {
                let pc = pcs[i % COLS];
                instrs[i] = code[i].get(pc).copied().unwrap_or_else(Instr::nop);
            }

            // ---- evaluate & execute ----
            let mut exit = false;
            pending_stores.clear();
            branch = [None; COLS];
            bank_hits.iter_mut().for_each(|x| *x = 0);
            let mut mem_ops_per_col = [0u32; COLS];
            let mut any_mul = false;
            let mut any_mem = false;

            for i in 0..N_PES {
                let id = PeId::from_index(i);
                let ins = instrs[i];
                write_out[i] = false;
                write_reg[i] = None;
                new_addr[i] = None;
                results[i] = 0;

                let a = read_src(ins.a, i, &st);
                let b = read_src(ins.b, i, &st);

                op_mix[i][OpClass::classify(ins.op).idx()] += 1;

                match ins.op {
                    Op::Nop => {}
                    Op::Exit => exit = true,
                    Op::Mov => apply_alu(a, ins, i, &mut results, &mut write_out, &mut write_reg),
                    Op::Add => apply_alu(
                        a.wrapping_add(b),
                        ins,
                        i,
                        &mut results,
                        &mut write_out,
                        &mut write_reg,
                    ),
                    Op::Sub => apply_alu(
                        a.wrapping_sub(b),
                        ins,
                        i,
                        &mut results,
                        &mut write_out,
                        &mut write_reg,
                    ),
                    Op::Mul => {
                        any_mul = true;
                        apply_alu(
                            a.wrapping_mul(b),
                            ins,
                            i,
                            &mut results,
                            &mut write_out,
                            &mut write_reg,
                        )
                    }
                    Op::Shl => apply_alu(
                        a.wrapping_shl(b as u32 & 31),
                        ins,
                        i,
                        &mut results,
                        &mut write_out,
                        &mut write_reg,
                    ),
                    Op::Shr => apply_alu(
                        a.wrapping_shr(b as u32 & 31),
                        ins,
                        i,
                        &mut results,
                        &mut write_out,
                        &mut write_reg,
                    ),
                    Op::And => {
                        apply_alu(a & b, ins, i, &mut results, &mut write_out, &mut write_reg)
                    }
                    Op::Or => {
                        apply_alu(a | b, ins, i, &mut results, &mut write_out, &mut write_reg)
                    }
                    Op::Xor => {
                        apply_alu(a ^ b, ins, i, &mut results, &mut write_out, &mut write_reg)
                    }
                    Op::Min => {
                        apply_alu(a.min(b), ins, i, &mut results, &mut write_out, &mut write_reg)
                    }
                    Op::Max => {
                        apply_alu(a.max(b), ins, i, &mut results, &mut write_out, &mut write_reg)
                    }
                    Op::SetAddr => {
                        let v = a.wrapping_add(b);
                        new_addr[i] = Some(v);
                        results[i] = v;
                    }
                    Op::Lw => {
                        any_mem = true;
                        mem_ops_per_col[id.col] += 1;
                        let addr = a.wrapping_add(b);
                        bank_hits[mem.bank_of(addr.max(0) as usize % mem.len())] += 1;
                        let v = mem
                            .load(addr)
                            .with_context(|| format!("{id} lw at step {}", stats.steps))?;
                        apply_alu(v, ins, i, &mut results, &mut write_out, &mut write_reg);
                    }
                    Op::LwInc => {
                        any_mem = true;
                        mem_ops_per_col[id.col] += 1;
                        let addr = st[i].addr;
                        bank_hits[mem.bank_of(addr.max(0) as usize % mem.len())] += 1;
                        let v = mem
                            .load(addr)
                            .with_context(|| format!("{id} lwinc at step {}", stats.steps))?;
                        new_addr[i] = Some(addr.wrapping_add(a.wrapping_add(b)));
                        apply_alu(v, ins, i, &mut results, &mut write_out, &mut write_reg);
                    }
                    Op::SwInc => {
                        any_mem = true;
                        mem_ops_per_col[id.col] += 1;
                        let addr = st[i].addr;
                        bank_hits[mem.bank_of(addr.max(0) as usize % mem.len())] += 1;
                        pending_stores.push((addr, a, i));
                        new_addr[i] = Some(addr.wrapping_add(b));
                    }
                    Op::SwAt => {
                        any_mem = true;
                        mem_ops_per_col[id.col] += 1;
                        let addr = a.wrapping_add(b);
                        bank_hits[mem.bank_of(addr.max(0) as usize % mem.len())] += 1;
                        pending_stores.push((addr, st[i].rout, i));
                    }
                    Op::Beq | Op::Bne | Op::Blt | Op::Bge | Op::Jump => {
                        let taken = match ins.op {
                            Op::Beq => a == b,
                            Op::Bne => a != b,
                            Op::Blt => a < b,
                            Op::Bge => a >= b,
                            Op::Jump => true,
                            _ => unreachable!(),
                        };
                        if branch[id.col].is_some() {
                            bail!(
                                "two control-flow ops in column {} at step {} (program '{}')",
                                id.col,
                                stats.steps,
                                prog.name
                            );
                        }
                        branch[id.col] = Some((taken, ins.target as usize));
                    }
                }
            }

            // ---- apply stores (loads already saw pre-step memory) ----
            pending_stores.sort_unstable_by_key(|&(a, _, _)| a);
            for w in pending_stores.windows(2) {
                if w[0].0 == w[1].0 {
                    bail!(
                        "store conflict: PEs {} and {} both store to word {} at step {} \
                         (program '{}')",
                        PeId::from_index(w[0].2),
                        PeId::from_index(w[1].2),
                        w[0].0,
                        stats.steps,
                        prog.name
                    );
                }
            }
            for &(addr, val, pe) in &pending_stores {
                mem.store(addr, val).with_context(|| {
                    format!("{} store at step {}", PeId::from_index(pe), stats.steps)
                })?;
            }

            // ---- cycle cost (shared helper — see step_cost). The
            // port term folds max-over-columns of n·latency into
            // max(n)·latency, and the bank term's any_mem gate is
            // equivalent here because bank_hits is cleared every step:
            // both identities are bit-exact. ----
            let max_port_ops = mem_ops_per_col.iter().copied().max().unwrap_or(0);
            let sc = step_cost(&self.cfg, any_mul, any_mem, max_port_ops, &bank_hits);
            let step_cycles = sc.cycles;
            stats.cycles += step_cycles;
            stats.contention_cycles += step_cycles - sc.ideal.min(step_cycles);
            if prof {
                let mut pe_cls = [0usize; N_PES];
                for (i, cls) in pe_cls.iter_mut().enumerate() {
                    *cls = OpClass::classify(instrs[i].op).idx();
                }
                profile::observe_step(
                    sc.alu_part,
                    sc.port_part,
                    sc.bank_part,
                    step_cycles,
                    any_mem,
                    &bank_hits,
                    &pe_cls,
                );
            }

            // ---- writeback ----
            for i in 0..N_PES {
                if write_out[i] {
                    st[i].rout = results[i];
                }
                if let Some(r) = write_reg[i] {
                    st[i].regs[r as usize] = results[i];
                }
                if let Some(a) = new_addr[i] {
                    st[i].addr = a;
                }
            }

            // ---- PC update ----
            for c in 0..COLS {
                pcs[c] = match branch[c] {
                    Some((true, t)) => t,
                    _ => pcs[c] + 1,
                };
            }

            stats.steps += 1;
            if exit {
                stats.exited = true;
                break;
            }
        }

        for (dst, src) in stats.op_mix.iter_mut().zip(op_mix.iter()) {
            *dst = *src;
        }
        let m1 = mem.stats();
        stats.mem.loads = m1.loads - mem_loads0.loads;
        stats.mem.stores = m1.stores - mem_loads0.stores;
        if prof {
            profile::end_walk(mem.high_water());
        }
        Ok(stats)
    }
}

/// Batched operand read: fill `out` (one word per lane) from the
/// structure-of-arrays state. Every case is a fill or a contiguous copy
/// of `nb` words — the batched mirror of [`read_usrc`].
#[inline(always)]
fn read_batch(
    s: USrc,
    i: usize,
    nb: usize,
    rout: &[i32],
    regs: &[i32],
    addr: &[i32],
    out: &mut [i32],
) {
    match s {
        USrc::Zero => out.fill(0),
        USrc::Imm(v) => out.fill(v),
        USrc::Reg(r) => {
            let base = (i * N_REGS + r as usize) * nb;
            out.copy_from_slice(&regs[base..base + nb]);
        }
        USrc::Own => out.copy_from_slice(&rout[i * nb..(i + 1) * nb]),
        USrc::Neigh(n) => out.copy_from_slice(&rout[n as usize * nb..(n as usize + 1) * nb]),
        USrc::Addr => out.copy_from_slice(&addr[i * nb..(i + 1) * nb]),
    }
}

/// Require a per-lane address vector to be lane-uniform (the batched
/// lockstep contract) and return the shared value.
#[inline(always)]
fn uniform_addr(
    vals: &[i32],
    pe: usize,
    what: &str,
    step: u64,
    dp: &DecodedProgram,
) -> Result<i32> {
    let v0 = vals[0];
    if vals.iter().any(|&v| v != v0) {
        bail!(
            "batch divergence: {} {what} at step {step} computed a lane-varying address \
             (program '{}'); batched execution requires lane-uniform addresses — rerun \
             these inputs through the scalar executor",
            PeId::from_index(pe),
            dp.name()
        );
    }
    Ok(v0)
}

#[inline(always)]
fn read_usrc(s: USrc, i: usize, st: &[PeState; N_PES]) -> i32 {
    match s {
        USrc::Zero => 0,
        USrc::Imm(v) => v,
        USrc::Reg(r) => st[i].regs[r as usize],
        USrc::Own => st[i].rout,
        USrc::Neigh(n) => st[n as usize].rout,
        USrc::Addr => st[i].addr,
    }
}

#[inline(always)]
fn read_src(s: Src, i: usize, st: &[PeState; N_PES]) -> i32 {
    match s {
        Src::Zero => 0,
        Src::Imm(v) => v,
        Src::Reg(r) => st[i].regs[r as usize],
        Src::Own => st[i].rout,
        Src::Neigh(d) => st[NEIGH[i][dir_idx(d)]].rout,
        Src::Addr => st[i].addr,
    }
}

#[inline]
fn apply_alu(
    v: i32,
    ins: Instr,
    i: usize,
    results: &mut [i32; N_PES],
    write_out: &mut [bool; N_PES],
    write_reg: &mut [Option<u8>; N_PES],
) {
    results[i] = v;
    match ins.dst {
        Dst::Out => write_out[i] = true,
        Dst::Reg(r) => write_reg[i] = Some(r),
        Dst::Both(r) => {
            write_out[i] = true;
            write_reg[i] = Some(r);
        }
        Dst::None => {}
    }
}

/// Convenience: the row-major list of PEs in one column.
pub fn column_pes(col: usize) -> impl Iterator<Item = PeId> {
    assert!(col < COLS);
    (0..ROWS).map(move |r| PeId::new(r, col))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Dir;

    fn cgra() -> Cgra {
        Cgra::new(CgraConfig::functional()).unwrap()
    }

    fn mem() -> Memory {
        Memory::new(1024, 4)
    }

    /// Single PE computes 2+3, stores to memory, exits.
    #[test]
    fn add_and_store() {
        let mut prog = Program::new("add_store");
        let p = prog.pe_mut(PeId::new(0, 0));
        p.push(Instr::new(Op::Add, Src::Imm(2), Src::Imm(3), Dst::Out));
        p.push(Instr::new(Op::SwAt, Src::Imm(100), Src::Zero, Dst::None));
        p.push(Instr::exit());
        let mut m = mem();
        let stats = cgra().run(&prog, &mut m).unwrap();
        assert!(stats.exited);
        assert_eq!(m.peek(100), 5);
        assert_eq!(stats.steps, 3);
        assert_eq!(stats.mem.stores, 1);
    }

    /// Neighbour reads observe the previous cycle's ROUT (synchronous).
    #[test]
    fn neighbour_reads_are_synchronous() {
        let mut prog = Program::new("sync");
        // PE(0,0): rout = 7 at step0, rout = 9 at step1.
        let p00 = prog.pe_mut(PeId::new(0, 0));
        p00.push(Instr::mov(Dst::Out, Src::Imm(7)));
        p00.push(Instr::mov(Dst::Out, Src::Imm(9)));
        // PE(0,1) reads its west neighbour at step1 — must see 7 (the
        // value latched at the END of step0), not 9.
        let p01 = prog.pe_mut(PeId::new(0, 1));
        p01.push(Instr::nop());
        p01.push(Instr::mov(Dst::Reg(0), Src::Neigh(Dir::West)));
        p01.push(Instr::new(Op::Mov, Src::Reg(0), Src::Zero, Dst::Out));
        p01.push(Instr::new(Op::SwAt, Src::Imm(50), Src::Zero, Dst::None));
        p01.push(Instr::exit());
        let mut m = mem();
        cgra().run(&prog, &mut m).unwrap();
        assert_eq!(m.peek(50), 7);
    }

    /// lwinc streams through memory with post-increment.
    #[test]
    fn lwinc_auto_increment() {
        let mut prog = Program::new("lwinc");
        let p = prog.pe_mut(PeId::new(2, 1));
        p.push(Instr::new(Op::SetAddr, Src::Imm(10), Src::Zero, Dst::None));
        p.push(Instr::new(Op::LwInc, Src::Imm(2), Src::Zero, Dst::Reg(0))); // mem[10], addr=12
        p.push(Instr::new(Op::LwInc, Src::Imm(2), Src::Zero, Dst::Reg(1))); // mem[12], addr=14
        p.push(Instr::new(Op::Add, Src::Reg(0), Src::Reg(1), Dst::Out));
        p.push(Instr::new(Op::SwAt, Src::Imm(20), Src::Zero, Dst::None));
        p.push(Instr::exit());
        let mut m = mem();
        m.poke(10, 11);
        m.poke(12, 31);
        cgra().run(&prog, &mut m).unwrap();
        assert_eq!(m.peek(20), 42);
    }

    /// swinc stores with post-increment.
    #[test]
    fn swinc_stores_sequentially() {
        let mut prog = Program::new("swinc");
        let p = prog.pe_mut(PeId::new(0, 0));
        p.push(Instr::new(Op::SetAddr, Src::Imm(200), Src::Zero, Dst::None));
        p.push(Instr::new(Op::SwInc, Src::Imm(5), Src::Imm(1), Dst::None));
        p.push(Instr::new(Op::SwInc, Src::Imm(6), Src::Imm(1), Dst::None));
        p.push(Instr::exit());
        let mut m = mem();
        cgra().run(&prog, &mut m).unwrap();
        assert_eq!(m.peek(200), 5);
        assert_eq!(m.peek(201), 6);
    }

    /// A loop: sum 1..=5 with a counter and bne.
    #[test]
    fn loop_with_branch() {
        let mut prog = Program::new("loop");
        let p = prog.pe_mut(PeId::new(1, 3));
        p.push(Instr::mov(Dst::Reg(0), Src::Imm(5))); // counter
        p.push(Instr::mov(Dst::Reg(1), Src::Zero)); // acc
        // loop body @2:
        p.push(Instr::new(Op::Add, Src::Reg(1), Src::Reg(0), Dst::Reg(1)));
        p.push(Instr::new(Op::Sub, Src::Reg(0), Src::Imm(1), Dst::Reg(0)));
        p.push(Instr::branch(Op::Bne, Src::Reg(0), Src::Zero, 2));
        p.push(Instr::new(Op::Mov, Src::Reg(1), Src::Zero, Dst::Out));
        p.push(Instr::new(Op::SwAt, Src::Imm(0), Src::Zero, Dst::None));
        p.push(Instr::exit());
        let mut m = mem();
        cgra().run(&prog, &mut m).unwrap();
        assert_eq!(m.peek(0), 15);
    }

    /// Two PEs in one column both branching is a program error.
    #[test]
    fn double_branch_in_column_rejected() {
        let mut prog = Program::new("dbl");
        prog.pe_mut(PeId::new(0, 0)).push(Instr::jump(0));
        prog.pe_mut(PeId::new(1, 0)).push(Instr::jump(0));
        let err = cgra().run(&prog, &mut mem()).unwrap_err();
        assert!(err.to_string().contains("two control-flow ops"));
    }

    /// Two stores to the same word in one step is a program error.
    #[test]
    fn store_conflict_rejected() {
        let mut prog = Program::new("conflict");
        for col in [0, 1] {
            let p = prog.pe_mut(PeId::new(0, col));
            p.push(Instr::new(Op::SetAddr, Src::Imm(9), Src::Zero, Dst::None));
            p.push(Instr::new(Op::SwInc, Src::Imm(1), Src::Zero, Dst::None));
        }
        prog.pe_mut(PeId::new(3, 3)).push(Instr::nop());
        prog.pe_mut(PeId::new(3, 3)).push(Instr::nop());
        prog.pe_mut(PeId::new(3, 3)).push(Instr::exit());
        let err = cgra().run(&prog, &mut mem()).unwrap_err();
        assert!(err.to_string().contains("store conflict"), "{err}");
    }

    /// Watchdog trips on a program that never exits.
    #[test]
    fn watchdog() {
        let mut cfg = CgraConfig::functional();
        cfg.max_steps = 100;
        let c = Cgra::new(cfg).unwrap();
        let mut prog = Program::new("spin");
        prog.pe_mut(PeId::new(0, 0)).push(Instr::jump(0));
        let err = c.run(&prog, &mut mem()).unwrap_err();
        assert!(err.to_string().contains("watchdog"));
    }

    /// Port serialization: 4 loads from one column in one step cost
    /// 4×mem_latency; 4 loads spread over 4 columns cost mem_latency
    /// (+ possible bank conflicts, disabled here).
    #[test]
    fn port_contention_model() {
        let mut cfg = CgraConfig::functional();
        cfg.mem_latency = 3;
        cfg.bank_penalty = 0;
        let c = Cgra::new(cfg).unwrap();

        // Same column: PEs (0..4, 0) all load.
        let mut prog = Program::new("same_col");
        for r in 0..ROWS {
            let p = prog.pe_mut(PeId::new(r, 0));
            p.push(Instr::new(Op::Lw, Src::Imm(r as i32), Src::Zero, Dst::Out));
        }
        prog.pe_mut(PeId::new(0, 1)).push(Instr::nop());
        prog.pe_mut(PeId::new(0, 1)).push(Instr::exit());
        let mut m = mem();
        let s = c.run(&prog, &mut m).unwrap();
        // step0: 4 loads × 3 = 12 cycles; step1: exit = 1 cycle.
        assert_eq!(s.cycles, 13);
        assert_eq!(s.contention_cycles, 9);

        // Spread over columns: 3 cycles + 1.
        let mut prog2 = Program::new("spread");
        for col in 0..COLS {
            let p = prog2.pe_mut(PeId::new(0, col));
            // Different banks: addresses 0..=3 with 4 banks.
            p.push(Instr::new(Op::Lw, Src::Imm(col as i32), Src::Zero, Dst::Out));
        }
        prog2.pe_mut(PeId::new(1, 0)).push(Instr::nop());
        prog2.pe_mut(PeId::new(1, 0)).push(Instr::exit());
        let s2 = c.run(&prog2, &mut mem()).unwrap();
        assert_eq!(s2.cycles, 4);
        assert_eq!(s2.contention_cycles, 0);
    }

    /// Bank conflicts across columns widen the step.
    #[test]
    fn bank_conflicts_penalized() {
        let mut cfg = CgraConfig::functional();
        cfg.mem_latency = 2;
        cfg.bank_penalty = 5;
        cfg.n_banks = 4;
        let c = Cgra::new(cfg).unwrap();
        let mut prog = Program::new("bank");
        // Four columns all load bank 0 (addresses multiple of 4).
        for col in 0..COLS {
            let p = prog.pe_mut(PeId::new(0, col));
            p.push(Instr::new(Op::Lw, Src::Imm(4 * col as i32), Src::Zero, Dst::Out));
        }
        prog.pe_mut(PeId::new(1, 0)).push(Instr::nop());
        prog.pe_mut(PeId::new(1, 0)).push(Instr::exit());
        let s = c.run(&prog, &mut mem()).unwrap();
        // step0: bank part = 2 + 3×5 = 17; step1: 1.
        assert_eq!(s.cycles, 18);
    }

    /// Mul latency dominates a step.
    #[test]
    fn mul_latency_charged() {
        let mut cfg = CgraConfig::functional();
        cfg.mul_latency = 7;
        let c = Cgra::new(cfg).unwrap();
        let mut prog = Program::new("mul");
        let p = prog.pe_mut(PeId::new(0, 0));
        p.push(Instr::new(Op::Mul, Src::Imm(6), Src::Imm(7), Dst::Out));
        p.push(Instr::new(Op::SwAt, Src::Imm(0), Src::Zero, Dst::None));
        p.push(Instr::exit());
        let mut m = mem();
        let s = c.run(&prog, &mut m).unwrap();
        assert_eq!(m.peek(0), 42);
        assert_eq!(s.cycles, 7 + 1 + 1);
    }

    /// Op-mix accounting counts implicit nops of idle PEs.
    #[test]
    fn op_mix_counts_idle_pes() {
        let mut prog = Program::new("mix");
        let p = prog.pe_mut(PeId::new(0, 0));
        p.push(Instr::new(Op::Mul, Src::Imm(1), Src::Imm(1), Dst::Out));
        p.push(Instr::exit());
        let s = cgra().run(&prog, &mut mem()).unwrap();
        assert_eq!(s.class_total(OpClass::Mul), 1);
        // 2 steps × 16 PEs = 32 slots; 2 active on PE(0,0).
        assert_eq!(s.total_slots(), 32);
        assert_eq!(s.class_total(OpClass::Nop), 30);
        assert!((s.utilization() - 2.0 / 32.0).abs() < 1e-12);
    }

    /// Columns diverge: column 1 loops twice while column 0 runs straight.
    #[test]
    fn independent_column_pcs() {
        let mut prog = Program::new("diverge");
        // Column 1: loop 3 times, then signal via memory and exit is done
        // by column 0 spinning on a flag? Keep it simple: column 1 loops,
        // stores, and exits itself.
        let p = prog.pe_mut(PeId::new(0, 1));
        p.push(Instr::mov(Dst::Reg(0), Src::Imm(3)));
        p.push(Instr::new(Op::Sub, Src::Reg(0), Src::Imm(1), Dst::Reg(0)));
        p.push(Instr::branch(Op::Bne, Src::Reg(0), Src::Zero, 1));
        p.push(Instr::new(Op::Mov, Src::Reg(0), Src::Zero, Dst::Out));
        p.push(Instr::new(Op::SwAt, Src::Imm(7), Src::Zero, Dst::None));
        p.push(Instr::exit());
        let mut m = mem();
        let s = cgra().run(&prog, &mut m).unwrap();
        assert_eq!(m.peek(7), 0);
        assert!(s.exited);
    }

    /// Torus data movement: a value injected at PE(0,0) hops east across
    /// the full ring back to its origin in 4 steps.
    #[test]
    fn ring_pass_east() {
        let mut prog = Program::new("ring");
        for col in 0..COLS {
            let p = prog.pe_mut(PeId::new(0, col));
            if col == 0 {
                p.push(Instr::mov(Dst::Out, Src::Imm(99)));
            } else {
                p.push(Instr::nop());
            }
            // Everybody shifts from the west each step.
            for _ in 0..COLS {
                p.push(Instr::mov(Dst::Out, Src::Neigh(Dir::West)));
            }
        }
        // After 4 shift steps, PE(0,0) has its own value back. Store it.
        let p0 = prog.pe_mut(PeId::new(0, 0));
        p0.push(Instr::new(Op::SwAt, Src::Imm(11), Src::Zero, Dst::None));
        p0.push(Instr::exit());
        let mut m = mem();
        cgra().run(&prog, &mut m).unwrap();
        assert_eq!(m.peek(11), 99);
    }

    /// The decoded engine and the reference enum interpreter agree
    /// step-for-step (stats) and word-for-word (memory) on a menagerie
    /// of programs: arithmetic, torus shifts, auto-increment streaming,
    /// branching loops, port and bank contention.
    #[test]
    fn decoded_matches_reference_interpreter() {
        let mut programs: Vec<Program> = Vec::new();

        let mut p1 = Program::new("diff-alu");
        let q = p1.pe_mut(PeId::new(0, 0));
        q.push(Instr::new(Op::Add, Src::Imm(2), Src::Imm(3), Dst::Both(0)));
        q.push(Instr::new(Op::Mul, Src::Reg(0), Src::Imm(-7), Dst::Out));
        q.push(Instr::new(Op::Xor, Src::Own, Src::Imm(0x55), Dst::Out));
        q.push(Instr::new(Op::Min, Src::Own, Src::Imm(4), Dst::Out));
        q.push(Instr::new(Op::SwAt, Src::Imm(40), Src::Zero, Dst::None));
        q.push(Instr::exit());
        programs.push(p1);

        let mut p2 = Program::new("diff-stream");
        for col in 0..COLS {
            let q = p2.pe_mut(PeId::new(0, col));
            q.push(Instr::new(Op::SetAddr, Src::Imm(col as i32 * 8), Src::Zero, Dst::None));
            q.push(Instr::mov(Dst::Reg(0), Src::Imm(4)));
            q.push(Instr::new(Op::LwInc, Src::Imm(1), Src::Zero, Dst::Out));
            q.push(Instr::new(Op::Sub, Src::Reg(0), Src::Imm(1), Dst::Reg(0)));
            q.push(Instr::branch(Op::Bne, Src::Reg(0), Src::Zero, 2));
            q.push(Instr::new(Op::SwAt, Src::Imm(64 + col as i32), Src::Zero, Dst::None));
            if col == 3 {
                q.push(Instr::exit());
            }
        }
        programs.push(p2);

        let mut p3 = Program::new("diff-torus");
        for col in 0..COLS {
            let q = p3.pe_mut(PeId::new(1, col));
            q.push(Instr::mov(Dst::Out, Src::Imm(10 + col as i32)));
            for _ in 0..3 {
                q.push(Instr::mov(Dst::Out, Src::Neigh(Dir::East)));
            }
            q.push(Instr::new(Op::SwAt, Src::Imm(80 + col as i32), Src::Zero, Dst::None));
            if col == 0 {
                q.push(Instr::exit());
            }
        }
        programs.push(p3);

        for cfg in [CgraConfig::functional(), CgraConfig::default()] {
            let c = Cgra::new(cfg).unwrap();
            for prog in &programs {
                let mut m_ref = mem();
                let mut m_dec = mem();
                for a in 0..32 {
                    m_ref.poke(a, (a * a) as i32 - 17);
                    m_dec.poke(a, (a * a) as i32 - 17);
                }
                let s_ref = c.run_reference(prog, &mut m_ref).unwrap();
                let s_dec = c.run(prog, &mut m_dec).unwrap();
                assert_eq!(s_ref, s_dec, "stats diverge on '{}'", prog.name);
                assert_eq!(
                    m_ref.peek_slice(0, 128),
                    m_dec.peek_slice(0, 128),
                    "memory diverges on '{}'",
                    prog.name
                );
            }
        }
    }

    /// Error paths agree between the engines (same message text).
    #[test]
    fn decoded_matches_reference_errors() {
        // Double branch.
        let mut dbl = Program::new("dbl");
        dbl.pe_mut(PeId::new(0, 0)).push(Instr::jump(0));
        dbl.pe_mut(PeId::new(1, 0)).push(Instr::jump(0));
        // Store conflict.
        let mut conflict = Program::new("conflict");
        for col in [0, 1] {
            let p = conflict.pe_mut(PeId::new(0, col));
            p.push(Instr::new(Op::SetAddr, Src::Imm(9), Src::Zero, Dst::None));
            p.push(Instr::new(Op::SwInc, Src::Imm(1), Src::Zero, Dst::None));
        }
        // Out-of-bounds load.
        let mut oob = Program::new("oob");
        oob.pe_mut(PeId::new(2, 2)).push(Instr::new(
            Op::Lw,
            Src::Imm(1 << 20),
            Src::Zero,
            Dst::Out,
        ));
        let c = cgra();
        for prog in [&dbl, &conflict, &oob] {
            let e_ref = format!("{:#}", c.run_reference(prog, &mut mem()).unwrap_err());
            let e_dec = format!("{:#}", c.run(prog, &mut mem()).unwrap_err());
            assert_eq!(e_ref, e_dec, "error text diverges on '{}'", prog.name);
        }
    }

    /// The trace hook sees the same fetched instructions and per-step
    /// results the reference interpreter produced.
    #[test]
    fn hooked_trace_reports_fetched_instrs() {
        let mut prog = Program::new("trace");
        let p = prog.pe_mut(PeId::new(0, 0));
        p.push(Instr::new(Op::Add, Src::Imm(20), Src::Imm(22), Dst::Out));
        p.push(Instr::exit());
        let mut steps = Vec::new();
        cgra()
            .run_hooked(&prog, &mut mem(), |t| steps.push((t.step, t.instrs[0], t.results[0])))
            .unwrap();
        assert_eq!(steps.len(), 2);
        assert_eq!(steps[0].1.op, Op::Add);
        assert_eq!(steps[0].2, 42);
        assert_eq!(steps[1].1.op, Op::Exit);
        // Idle PEs trace as nop.
        assert_eq!(steps[0].0, 0);
    }

    /// Lane-varying memory images for the batched differential tests.
    fn poke_batch_lane_images(bm: &mut BatchMemory, scalars: &mut [Memory]) {
        for (lane, sm) in scalars.iter_mut().enumerate() {
            for a in 0..32 {
                let v = (a * a) as i32 - 17 + lane as i32 * 1000;
                bm.poke_lane(a, lane, v);
                sm.poke(a, v);
            }
        }
    }

    /// The batched executor is lane-for-lane identical to the scalar
    /// decoded engine: same per-inference `RunStats` (steps, cycles,
    /// contention, op mix, memory counts) and each lane's memory image
    /// matches a scalar run over that lane's data — across streaming
    /// loops, torus shifts and multiplies, at B = 1 (degeneracy) and at
    /// a partial lane count below the batch capacity.
    #[test]
    fn batched_matches_scalar_per_lane() {
        let mut programs: Vec<Program> = Vec::new();

        let mut p1 = Program::new("batch-stream");
        for col in 0..COLS {
            let q = p1.pe_mut(PeId::new(0, col));
            q.push(Instr::new(Op::SetAddr, Src::Imm(col as i32 * 8), Src::Zero, Dst::None));
            q.push(Instr::mov(Dst::Reg(0), Src::Imm(4)));
            q.push(Instr::new(Op::LwInc, Src::Imm(1), Src::Zero, Dst::Out));
            q.push(Instr::new(Op::Sub, Src::Reg(0), Src::Imm(1), Dst::Reg(0)));
            q.push(Instr::branch(Op::Bne, Src::Reg(0), Src::Zero, 2));
            q.push(Instr::new(Op::SwAt, Src::Imm(64 + col as i32), Src::Zero, Dst::None));
            if col == 3 {
                q.push(Instr::exit());
            }
        }
        programs.push(p1);

        let mut p2 = Program::new("batch-torus-mul");
        for col in 0..COLS {
            let q = p2.pe_mut(PeId::new(1, col));
            q.push(Instr::new(Op::Lw, Src::Imm(col as i32), Src::Zero, Dst::Out));
            q.push(Instr::new(Op::Mul, Src::Own, Src::Imm(3), Dst::Out));
            for _ in 0..2 {
                q.push(Instr::mov(Dst::Out, Src::Neigh(Dir::East)));
            }
            q.push(Instr::new(Op::SwAt, Src::Imm(80 + col as i32), Src::Zero, Dst::None));
            if col == 0 {
                q.push(Instr::exit());
            }
        }
        programs.push(p2);

        for cfg in [CgraConfig::functional(), CgraConfig::default()] {
            let c = Cgra::new(cfg).unwrap();
            for prog in &programs {
                let dp = super::decoded::decode(prog);
                // nb = 1 (degeneracy), nb = 3 at capacity, nb = 3 of 5
                // (partial — tail lanes must stay untouched).
                for (nb, cap) in [(1usize, 1usize), (3, 3), (3, 5)] {
                    let mut bm = BatchMemory::new(1024, 4, cap);
                    let mut scalars: Vec<Memory> = (0..nb).map(|_| mem()).collect();
                    poke_batch_lane_images(&mut bm, &mut scalars);
                    let sb = c.run_decoded_batch(&dp, &mut bm, nb).unwrap();
                    for (lane, sm) in scalars.iter_mut().enumerate() {
                        let ss = c.run_decoded(&dp, sm).unwrap();
                        assert_eq!(
                            ss, sb,
                            "per-inference stats diverge on '{}' lane {lane}",
                            prog.name
                        );
                        let mut got = vec![0i32; 128];
                        bm.peek_slice_lane(0, lane, &mut got);
                        assert_eq!(
                            &got[..],
                            sm.peek_slice(0, 128),
                            "memory diverges on '{}' lane {lane}",
                            prog.name
                        );
                    }
                    if cap > nb {
                        // Inactive tail lanes: still all-zero.
                        let mut tail = vec![0i32; 128];
                        bm.peek_slice_lane(0, cap - 1, &mut tail);
                        assert!(tail.iter().all(|&v| v == 0), "tail lane written");
                    }
                }
            }
        }
    }

    /// A branch whose outcome depends on loaded (lane-varying) data
    /// breaks the lockstep contract and must abort with a divergence
    /// error, not silently follow one lane.
    #[test]
    fn lane_divergent_branch_rejected() {
        let mut prog = Program::new("div-branch");
        let p = prog.pe_mut(PeId::new(0, 0));
        p.push(Instr::new(Op::Lw, Src::Imm(0), Src::Zero, Dst::Reg(0)));
        p.push(Instr::branch(Op::Bne, Src::Reg(0), Src::Zero, 0));
        p.push(Instr::exit());
        let dp = super::decoded::decode(&prog);
        let mut bm = BatchMemory::new(64, 4, 2);
        bm.poke_lane(0, 0, 0);
        bm.poke_lane(0, 1, 1);
        let err = cgra().run_decoded_batch(&dp, &mut bm, 2).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("batch divergence"), "{msg}");
        assert!(msg.contains("lane-uniform control flow"), "{msg}");
    }

    /// A memory address computed from loaded (lane-varying) data must
    /// abort with a divergence error naming the PE and op.
    #[test]
    fn lane_divergent_address_rejected() {
        let mut prog = Program::new("div-addr");
        let p = prog.pe_mut(PeId::new(0, 0));
        p.push(Instr::new(Op::Lw, Src::Imm(0), Src::Zero, Dst::Reg(0)));
        p.push(Instr::new(Op::Lw, Src::Reg(0), Src::Zero, Dst::Out));
        p.push(Instr::exit());
        let dp = super::decoded::decode(&prog);
        let mut bm = BatchMemory::new(64, 4, 2);
        bm.poke_lane(0, 0, 3);
        bm.poke_lane(0, 1, 4);
        let err = cgra().run_decoded_batch(&dp, &mut bm, 2).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("lane-varying address"), "{msg}");
        assert!(msg.contains("lw"), "{msg}");
    }

    /// Uniform error paths (watchdog, double branch, store conflict,
    /// out-of-bounds) report the same text as the scalar engines.
    #[test]
    fn batched_error_paths_match_scalar() {
        let mut dbl = Program::new("dbl");
        dbl.pe_mut(PeId::new(0, 0)).push(Instr::jump(0));
        dbl.pe_mut(PeId::new(1, 0)).push(Instr::jump(0));
        let mut conflict = Program::new("conflict");
        for col in [0, 1] {
            let p = conflict.pe_mut(PeId::new(0, col));
            p.push(Instr::new(Op::SetAddr, Src::Imm(9), Src::Zero, Dst::None));
            p.push(Instr::new(Op::SwInc, Src::Imm(1), Src::Zero, Dst::None));
        }
        let mut oob = Program::new("oob");
        oob.pe_mut(PeId::new(2, 2)).push(Instr::new(
            Op::Lw,
            Src::Imm(1 << 20),
            Src::Zero,
            Dst::Out,
        ));
        let mut spin = Program::new("spin");
        spin.pe_mut(PeId::new(0, 0)).push(Instr::jump(0));

        let mut cfg = CgraConfig::functional();
        cfg.max_steps = 100;
        let c = Cgra::new(cfg).unwrap();
        for prog in [&dbl, &conflict, &oob, &spin] {
            let e_ref = format!("{:#}", c.run_reference(prog, &mut mem()).unwrap_err());
            let dp = super::decoded::decode(prog);
            let mut bm = BatchMemory::new(1024, 4, 2);
            let e_bat = format!("{:#}", c.run_decoded_batch(&dp, &mut bm, 2).unwrap_err());
            assert_eq!(e_ref, e_bat, "error text diverges on '{}'", prog.name);
        }
    }

    /// Lane counts outside `1..=capacity` are rejected up front.
    #[test]
    fn batch_lane_count_validated() {
        let mut prog = Program::new("one");
        prog.pe_mut(PeId::new(0, 0)).push(Instr::exit());
        let dp = super::decoded::decode(&prog);
        let c = cgra();
        let mut bm = BatchMemory::new(64, 4, 2);
        assert!(c.run_decoded_batch(&dp, &mut bm, 0).is_err());
        assert!(c.run_decoded_batch(&dp, &mut bm, 3).is_err());
        assert!(c.run_decoded_batch(&dp, &mut bm, 2).is_ok());
    }
}
