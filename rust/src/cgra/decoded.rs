//! Decode stage of the two-phase decode/execute engine.
//!
//! The original interpreter (kept as [`super::Cgra::run_reference`] for
//! differential testing) re-matches the `isa::Instr`/`Src`/`Dst` enums on
//! every step of every PE. This module lowers an [`isa::Program`] **once**
//! into a dense µop representation the executor can replay cheaply:
//!
//! - operand muxes are pre-resolved ([`USrc`]): torus neighbour reads
//!   become absolute PE indices via the `NEIGH` table, so the hot loop
//!   never touches `Dir`/`PeId::neighbour`;
//! - destinations are pre-split into a `wout` flag + register index, and
//!   non-latching ops (stores, branches, `setaddr`, `nop`, `exit`) are
//!   normalized to "no write" exactly as the executor treats them;
//! - ops are pre-split into lanes ([`UKind`]): ALU, address, load, store
//!   and branch, with the ALU function ([`AluFn`]) and branch condition
//!   ([`BrFn`]) resolved at decode time;
//! - per-(column, slot) step metadata ([`ColMeta`]) — DMA-port op count
//!   and multiply presence — is *static* per fetched slot, so the cycle
//!   model reads two table entries per column instead of classifying 16
//!   instructions per step;
//! - the per-PE op-class of every slot (`OpClass::idx()`) is precomputed,
//!   letting the executor count *visits per slot* and fold them into the
//!   op-mix histogram once at the end of the run.
//!
//! Every PE stream carries one trailing sentinel `nop`, so the executor
//! clamps the column PC (`pc.min(len)`) instead of bounds-checking an
//! `Option` — a PE whose PC runs past its program idles, as in hardware.
//!
//! [`decode_cached`] adds a bounded, sharded, process-wide memo keyed by
//! a 128-bit content fingerprint: the Fig. 3/4/5 drivers and the benches
//! re-launch identical programs constantly (WP alone relaunches 256
//! times per baseline convolution, and every bench sample repeats them),
//! and the cache turns those re-decodes into an `Arc` clone.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::isa::{Dst, Instr, Op, PeId, Program, Src, COLS, N_PES, ROWS};

use super::exec::{dir_idx, NEIGH};
use super::stats::OpClass;

/// Sentinel register index meaning "no register write".
pub(crate) const NO_REG: u8 = u8::MAX;

/// Pre-resolved operand source. Identical semantics to [`isa::Src`]
/// except that neighbour reads carry the absolute PE index.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum USrc {
    /// Constant zero.
    Zero,
    /// Immediate.
    Imm(i32),
    /// Register-file entry.
    Reg(u8),
    /// The PE's own output register.
    Own,
    /// A neighbour's output register, by absolute PE index.
    Neigh(u8),
    /// The PE's DMA address register.
    Addr,
}

/// ALU function of an [`UKind::Alu`] µop.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum AluFn {
    Mov,
    Add,
    Sub,
    Mul,
    Shl,
    Shr,
    And,
    Or,
    Xor,
    Min,
    Max,
}

/// Branch condition of an [`UKind::Br`] µop.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum BrFn {
    Eq,
    Ne,
    Lt,
    Ge,
    Always,
}

/// Execution lane of a µop.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum UKind {
    /// Idle slot (explicit or implicit `nop`).
    Nop,
    /// Halt the array at the end of the step.
    Exit,
    /// ALU lane (latches via `wout`/`wreg`).
    Alu(AluFn),
    /// `addr = a + b`.
    SetAddr,
    /// `dst = mem[a + b]`.
    Lw,
    /// `dst = mem[addr]; addr += a + b`.
    LwInc,
    /// `mem[addr] = a; addr += b`.
    SwInc,
    /// `mem[a + b] = rout`.
    SwAt,
    /// Control flow steering the column PC.
    Br(BrFn),
}

/// One decoded µop.
#[derive(Clone, Copy, Debug)]
pub(crate) struct UInstr {
    /// Lane + function.
    pub kind: UKind,
    /// First operand.
    pub a: USrc,
    /// Second operand.
    pub b: USrc,
    /// Latch result into ROUT?
    pub wout: bool,
    /// Register to latch into, or [`NO_REG`].
    pub wreg: u8,
    /// Branch target (absolute slot).
    pub target: u16,
}

/// Static per-(column, slot) step metadata.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct ColMeta {
    /// DMA-port operations issued by this column at this slot.
    pub mem_ops: u32,
    /// True if any PE of the column multiplies at this slot.
    pub any_mul: bool,
}

fn lower_src(s: Src, pe: usize) -> USrc {
    match s {
        Src::Zero => USrc::Zero,
        Src::Imm(v) => USrc::Imm(v),
        Src::Reg(r) => USrc::Reg(r),
        Src::Own => USrc::Own,
        Src::Neigh(d) => USrc::Neigh(NEIGH[pe][dir_idx(d)] as u8),
        Src::Addr => USrc::Addr,
    }
}

fn lower(ins: Instr, pe: usize) -> UInstr {
    let kind = match ins.op {
        Op::Nop => UKind::Nop,
        Op::Exit => UKind::Exit,
        Op::Mov => UKind::Alu(AluFn::Mov),
        Op::Add => UKind::Alu(AluFn::Add),
        Op::Sub => UKind::Alu(AluFn::Sub),
        Op::Mul => UKind::Alu(AluFn::Mul),
        Op::Shl => UKind::Alu(AluFn::Shl),
        Op::Shr => UKind::Alu(AluFn::Shr),
        Op::And => UKind::Alu(AluFn::And),
        Op::Or => UKind::Alu(AluFn::Or),
        Op::Xor => UKind::Alu(AluFn::Xor),
        Op::Min => UKind::Alu(AluFn::Min),
        Op::Max => UKind::Alu(AluFn::Max),
        Op::SetAddr => UKind::SetAddr,
        Op::Lw => UKind::Lw,
        Op::LwInc => UKind::LwInc,
        Op::SwInc => UKind::SwInc,
        Op::SwAt => UKind::SwAt,
        Op::Beq => UKind::Br(BrFn::Eq),
        Op::Bne => UKind::Br(BrFn::Ne),
        Op::Blt => UKind::Br(BrFn::Lt),
        Op::Bge => UKind::Br(BrFn::Ge),
        Op::Jump => UKind::Br(BrFn::Always),
    };
    // Only ALU ops and loads latch results; the reference interpreter
    // ignores `dst` for every other op and so must the decoded form.
    let latches = matches!(kind, UKind::Alu(_) | UKind::Lw | UKind::LwInc);
    let (wout, wreg) = if latches {
        match ins.dst {
            Dst::Out => (true, NO_REG),
            Dst::Reg(r) => (false, r),
            Dst::Both(r) => (true, r),
            Dst::None => (false, NO_REG),
        }
    } else {
        (false, NO_REG)
    };
    UInstr {
        kind,
        a: lower_src(ins.a, pe),
        b: lower_src(ins.b, pe),
        wout,
        wreg,
        target: ins.target as u16,
    }
}

/// A program lowered to the dense µop representation, plus the static
/// step metadata the executor's cycle model consumes.
///
/// Deliberately does **not** hold a copy of the source `Program`: the
/// lane kernels decode a fresh program per launch, and the only
/// consumers of raw instructions are trace hooks, which receive the
/// source program separately (`Cgra::run_hooked`).
#[derive(Clone, Debug)]
pub struct DecodedProgram {
    /// Source program name (error messages, traces).
    name: String,
    /// Per-PE µop streams, each with a trailing sentinel `nop`.
    code: [Vec<UInstr>; N_PES],
    /// Per-column step metadata, indexed by clamped PC; the last entry
    /// is the all-idle sentinel.
    col_meta: [Vec<ColMeta>; COLS],
    /// Per-PE `OpClass::idx()` of every (clamped) slot.
    classes: [Vec<u8>; N_PES],
}

impl DecodedProgram {
    /// Program name (as shown in errors and traces).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Total µops across all PEs (sentinels included).
    pub fn total_uops(&self) -> usize {
        self.code.iter().map(|v| v.len()).sum()
    }

    /// Fetch the µop of `pe` at `pc`, clamping past-the-end PCs to the
    /// sentinel `nop`.
    #[inline(always)]
    pub(crate) fn uop(&self, pe: usize, pc: usize) -> UInstr {
        let v = &self.code[pe];
        v[pc.min(v.len() - 1)]
    }

    /// Static step metadata of column `c` (length = longest PE program
    /// in the column + 1 sentinel).
    #[inline(always)]
    pub(crate) fn col_meta(&self, c: usize) -> &[ColMeta] {
        &self.col_meta[c]
    }

    /// Pre-computed `OpClass::idx()` of `pe`'s slot `slot` (clamped
    /// indices only — callers index with the same clamp as `col_meta`).
    #[inline(always)]
    pub(crate) fn class_at(&self, pe: usize, slot: usize) -> usize {
        self.classes[pe][slot] as usize
    }
}

/// Process-wide count of µop decodes actually performed (cache hits do
/// not count — they re-lower nothing). The compile-once / run-many
/// tests assert a warm `CompiledNet::run` leaves this unchanged.
static DECODES: AtomicU64 = AtomicU64::new(0);

/// Total µop decodes performed so far in this process.
pub fn decode_count() -> u64 {
    DECODES.load(Ordering::Relaxed)
}

/// Lower `prog` into its µop representation.
pub fn decode(prog: &Program) -> DecodedProgram {
    DECODES.fetch_add(1, Ordering::Relaxed);
    let code: [Vec<UInstr>; N_PES] = std::array::from_fn(|i| {
        let pe = prog.pe(PeId::from_index(i));
        let mut v: Vec<UInstr> = pe.instrs().iter().map(|&ins| lower(ins, i)).collect();
        v.push(lower(Instr::nop(), i)); // sentinel
        v
    });
    let mut col_meta: [Vec<ColMeta>; COLS] = std::array::from_fn(|_| Vec::new());
    let mut classes: [Vec<u8>; N_PES] = std::array::from_fn(|_| Vec::new());
    for c in 0..COLS {
        let max_len = (0..ROWS).map(|r| prog.pe(PeId::new(r, c)).len()).max().unwrap_or(0);
        let mut meta = vec![ColMeta::default(); max_len + 1];
        for (p, slot) in meta.iter_mut().enumerate() {
            for r in 0..ROWS {
                let op = prog.pe(PeId::new(r, c)).fetch(p).op;
                if op.is_mem() {
                    slot.mem_ops += 1;
                }
                slot.any_mul |= op == Op::Mul;
            }
        }
        for r in 0..ROWS {
            let i = r * COLS + c;
            classes[i] = (0..=max_len)
                .map(|p| OpClass::classify(prog.pe(PeId::from_index(i)).fetch(p).op).idx() as u8)
                .collect();
        }
        col_meta[c] = meta;
    }
    DecodedProgram { name: prog.name.clone(), code, col_meta, classes }
}

// ---------------------------------------------------------------------------
// Decode cache
// ---------------------------------------------------------------------------

/// Number of lock shards in the process-wide decode cache.
const DECODE_SHARDS: usize = 8;
/// Entries per shard before the shard is wholesale evicted. Bounds the
/// cache to `DECODE_SHARDS × DECODE_SHARD_CAP` decoded programs so that
/// sweeps with thousands of unique per-launch programs cannot grow it
/// without limit.
const DECODE_SHARD_CAP: usize = 64;

/// Total decode-cache capacity. Callers with a statically known launch
/// set (e.g. WP's k×c programs per convolution) can compare against
/// this to decide whether memoizing will hit or merely churn.
pub const DECODE_CACHE_CAPACITY: usize = DECODE_SHARDS * DECODE_SHARD_CAP;

type Shard = Mutex<HashMap<(u64, u64), Arc<DecodedProgram>>>;

static DECODE_CACHE: OnceLock<Vec<Shard>> = OnceLock::new();
static DECODE_HITS: AtomicU64 = AtomicU64::new(0);
static DECODE_MISSES: AtomicU64 = AtomicU64::new(0);
static DECODE_EVICTIONS: AtomicU64 = AtomicU64::new(0);

fn shards() -> &'static Vec<Shard> {
    DECODE_CACHE.get_or_init(|| (0..DECODE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect())
}

/// Counters of the process-wide decode cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DecodeCacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to decode.
    pub misses: u64,
    /// Entries dropped by shard eviction.
    pub evictions: u64,
    /// Decoded programs currently resident.
    pub entries: usize,
}

/// 128-bit content fingerprint of a program: name + every instruction
/// field, mixed through two independent multiply-xor streams. Two
/// programs collide only if both 64-bit streams collide — negligible for
/// the program counts any sweep can produce.
fn fingerprint(prog: &Program) -> (u64, u64) {
    let mut a = 0xcbf2_9ce4_8422_2325u64;
    let mut b = 0x6c62_272e_07bb_0142u64;
    let mut word = |x: u64| {
        a = (a ^ x).wrapping_mul(0x1000_0000_01b3);
        b = (b ^ x.rotate_left(17)).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        b ^= b >> 29;
    };
    for byte in prog.name.bytes() {
        word(byte as u64);
    }
    let src_word = |s: Src| -> u64 {
        match s {
            Src::Zero => 0,
            Src::Imm(v) => 1 | (v as u32 as u64) << 8,
            Src::Reg(r) => 2 | (r as u64) << 8,
            Src::Own => 3,
            Src::Neigh(d) => 4 | (dir_idx(d) as u64) << 8,
            Src::Addr => 5,
        }
    };
    let dst_word = |d: Dst| -> u64 {
        match d {
            Dst::Out => 0,
            Dst::Reg(r) => 1 | (r as u64) << 8,
            Dst::Both(r) => 2 | (r as u64) << 8,
            Dst::None => 3,
        }
    };
    for id in PeId::all() {
        let pe = prog.pe(id);
        word(pe.len() as u64);
        for ins in pe.instrs() {
            // The mnemonic is unique per op and stable.
            let op_hash = ins
                .op
                .mnemonic()
                .bytes()
                .fold(0u64, |h, c| h.wrapping_mul(31).wrapping_add(c as u64));
            word(op_hash);
            word(src_word(ins.a));
            word(src_word(ins.b));
            word(dst_word(ins.dst));
            word(ins.target as u64);
        }
    }
    (a, b)
}

/// Decode `prog`, memoizing the result in the process-wide sharded
/// cache. Repeated launches of the same program (the normal case for
/// every figure driver and bench) return a shared `Arc` without
/// re-lowering anything.
pub fn decode_cached(prog: &Program) -> Arc<DecodedProgram> {
    let key = fingerprint(prog);
    let shard = &shards()[key.0 as usize % DECODE_SHARDS];
    if let Some(dp) = shard.lock().unwrap().get(&key) {
        DECODE_HITS.fetch_add(1, Ordering::Relaxed);
        return dp.clone();
    }
    DECODE_MISSES.fetch_add(1, Ordering::Relaxed);
    let dp = Arc::new(decode(prog));
    let mut map = shard.lock().unwrap();
    if map.len() >= DECODE_SHARD_CAP {
        DECODE_EVICTIONS.fetch_add(map.len() as u64, Ordering::Relaxed);
        map.clear();
    }
    map.insert(key, dp.clone());
    dp
}

/// Snapshot of the decode cache counters.
pub fn decode_cache_stats() -> DecodeCacheStats {
    DecodeCacheStats {
        hits: DECODE_HITS.load(Ordering::Relaxed),
        misses: DECODE_MISSES.load(Ordering::Relaxed),
        evictions: DECODE_EVICTIONS.load(Ordering::Relaxed),
        entries: shards().iter().map(|s| s.lock().unwrap().len()).sum(),
    }
}

/// Drop every cached decode (counters are preserved).
pub fn clear_decode_cache() {
    for s in shards() {
        s.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Dir;

    #[test]
    fn neighbour_sources_are_pre_resolved() {
        let mut prog = Program::new("t");
        prog.pe_mut(PeId::new(1, 2)).push(Instr::mov(Dst::Out, Src::Neigh(Dir::East)));
        let dp = decode(&prog);
        let i = PeId::new(1, 2).index();
        let u = dp.uop(i, 0);
        assert_eq!(u.a, USrc::Neigh(PeId::new(1, 3).index() as u8));
        assert!(u.wout);
        assert_eq!(u.wreg, NO_REG);
    }

    #[test]
    fn sentinel_nop_past_end() {
        let mut prog = Program::new("t");
        prog.pe_mut(PeId::new(0, 0)).push(Instr::exit());
        let dp = decode(&prog);
        assert_eq!(dp.uop(0, 0).kind, UKind::Exit);
        assert_eq!(dp.uop(0, 1).kind, UKind::Nop);
        assert_eq!(dp.uop(0, 999).kind, UKind::Nop);
        // Empty PEs are a single sentinel.
        assert_eq!(dp.uop(5, 0).kind, UKind::Nop);
    }

    #[test]
    fn non_latching_ops_never_write() {
        let mut prog = Program::new("t");
        let p = prog.pe_mut(PeId::new(0, 0));
        // A store with a (nonsensical) Out destination must not latch.
        p.push(Instr { op: Op::SwAt, a: Src::Imm(0), b: Src::Zero, dst: Dst::Out, target: 0 });
        p.push(Instr { op: Op::SetAddr, a: Src::Zero, b: Src::Zero, dst: Dst::reg(1), target: 0 });
        let dp = decode(&prog);
        for pc in 0..2 {
            let u = dp.uop(0, pc);
            assert!(!u.wout, "slot {pc}");
            assert_eq!(u.wreg, NO_REG, "slot {pc}");
        }
    }

    #[test]
    fn col_meta_counts_static_mem_and_mul() {
        let mut prog = Program::new("t");
        // Column 0: two loads + a mul at slot 0.
        prog.pe_mut(PeId::new(0, 0)).push(Instr::new(Op::Lw, Src::Imm(0), Src::Zero, Dst::Out));
        prog.pe_mut(PeId::new(1, 0)).push(Instr::new(Op::Lw, Src::Imm(1), Src::Zero, Dst::Out));
        prog.pe_mut(PeId::new(2, 0)).push(Instr::new(Op::Mul, Src::Imm(2), Src::Imm(3), Dst::Out));
        let dp = decode(&prog);
        let m = dp.col_meta(0);
        assert_eq!(m[0].mem_ops, 2);
        assert!(m[0].any_mul);
        // Sentinel slot is idle.
        assert_eq!(m[m.len() - 1].mem_ops, 0);
        assert!(!m[m.len() - 1].any_mul);
        // Column 1 has no code: single idle sentinel.
        assert_eq!(dp.col_meta(1).len(), 1);
    }

    #[test]
    fn classes_match_static_classification() {
        let mut prog = Program::new("t");
        let p = prog.pe_mut(PeId::new(0, 0));
        p.push(Instr::new(Op::Add, Src::Imm(1), Src::Imm(2), Dst::Out));
        p.push(Instr::new(Op::Lw, Src::Imm(0), Src::Zero, Dst::Out));
        let dp = decode(&prog);
        assert_eq!(dp.class_at(0, 0), OpClass::Sum.idx());
        assert_eq!(dp.class_at(0, 1), OpClass::Load.idx());
        assert_eq!(dp.class_at(0, 2), OpClass::Nop.idx()); // sentinel
    }

    #[test]
    fn fingerprints_separate_distinct_programs() {
        let mut a = Program::new("p");
        a.pe_mut(PeId::new(0, 0)).push(Instr::mov(Dst::Out, Src::Imm(1)));
        let mut b = Program::new("p");
        b.pe_mut(PeId::new(0, 0)).push(Instr::mov(Dst::Out, Src::Imm(2)));
        assert_ne!(fingerprint(&a), fingerprint(&b));
        let mut c = Program::new("q");
        c.pe_mut(PeId::new(0, 0)).push(Instr::mov(Dst::Out, Src::Imm(1)));
        assert_ne!(fingerprint(&a), fingerprint(&c), "name participates");
        assert_eq!(fingerprint(&a), fingerprint(&a.clone()));
    }

    #[test]
    fn decode_cached_hits_on_repeat() {
        // Other tests in this binary use the process-wide cache
        // concurrently and can trigger an epoch eviction between two
        // adjacent calls, so allow a few attempts before declaring the
        // cache broken.
        let mut prog = Program::new("decode-cache-hit-test-unique-name");
        prog.pe_mut(PeId::new(0, 0)).push(Instr::exit());
        let before = decode_cache_stats();
        let mut hit = false;
        for _ in 0..32 {
            let a = decode_cached(&prog);
            let b = decode_cached(&prog);
            if Arc::ptr_eq(&a, &b) {
                hit = true;
                break;
            }
        }
        let after = decode_cache_stats();
        assert!(hit, "decode_cached never returned a shared Arc in 32 attempts");
        assert!(after.hits > before.hits);
        assert!(after.misses >= before.misses + 1);
    }
}
