//! Decode stage of the two-phase decode/execute engine.
//!
//! The original interpreter (kept as [`super::Cgra::run_reference`] for
//! differential testing) re-matches the `isa::Instr`/`Src`/`Dst` enums on
//! every step of every PE. This module lowers an [`isa::Program`] **once**
//! into a dense µop representation the executor can replay cheaply:
//!
//! - operand muxes are pre-resolved ([`USrc`]): torus neighbour reads
//!   become absolute PE indices via the `NEIGH` table, so the hot loop
//!   never touches `Dir`/`PeId::neighbour`;
//! - destinations are pre-split into a `wout` flag + register index, and
//!   non-latching ops (stores, branches, `setaddr`, `nop`, `exit`) are
//!   normalized to "no write" exactly as the executor treats them;
//! - ops are pre-split into lanes ([`UKind`]): ALU, address, load, store
//!   and branch, with the ALU function ([`AluFn`]) and branch condition
//!   ([`BrFn`]) resolved at decode time;
//! - per-(column, slot) step metadata ([`ColMeta`]) — DMA-port op count
//!   and multiply presence — is *static* per fetched slot, so the cycle
//!   model reads two table entries per column instead of classifying 16
//!   instructions per step;
//! - the per-PE op-class of every slot (`OpClass::idx()`) is precomputed,
//!   letting the executor count *visits per slot* and fold them into the
//!   op-mix histogram once at the end of the run.
//!
//! Every PE stream carries one trailing sentinel `nop`, so the executor
//! clamps the column PC (`pc.min(len)`) instead of bounds-checking an
//! `Option` — a PE whose PC runs past its program idles, as in hardware.
//!
//! [`decode_cached`] adds a bounded, sharded, process-wide memo keyed by
//! a 128-bit content fingerprint: the Fig. 3/4/5 drivers and the benches
//! re-launch identical programs constantly (WP alone relaunches 256
//! times per baseline convolution, and every bench sample repeats them),
//! and the cache turns those re-decodes into an `Arc` clone.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, ensure, Result};

use crate::isa::{Dst, Instr, Op, PeId, Program, Src, COLS, N_PES, N_REGS, ROWS};
use crate::util::wire::{Reader, Writer};

use super::exec::{dir_idx, NEIGH};
use super::stats::OpClass;

/// Sentinel register index meaning "no register write".
pub(crate) const NO_REG: u8 = u8::MAX;

/// Pre-resolved operand source. Identical semantics to [`isa::Src`]
/// except that neighbour reads carry the absolute PE index.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum USrc {
    /// Constant zero.
    Zero,
    /// Immediate.
    Imm(i32),
    /// Register-file entry.
    Reg(u8),
    /// The PE's own output register.
    Own,
    /// A neighbour's output register, by absolute PE index.
    Neigh(u8),
    /// The PE's DMA address register.
    Addr,
}

/// ALU function of an [`UKind::Alu`] µop.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum AluFn {
    Mov,
    Add,
    Sub,
    Mul,
    Shl,
    Shr,
    And,
    Or,
    Xor,
    Min,
    Max,
}

/// Branch condition of an [`UKind::Br`] µop.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum BrFn {
    Eq,
    Ne,
    Lt,
    Ge,
    Always,
}

/// Execution lane of a µop.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum UKind {
    /// Idle slot (explicit or implicit `nop`).
    Nop,
    /// Halt the array at the end of the step.
    Exit,
    /// ALU lane (latches via `wout`/`wreg`).
    Alu(AluFn),
    /// `addr = a + b`.
    SetAddr,
    /// `dst = mem[a + b]`.
    Lw,
    /// `dst = mem[addr]; addr += a + b`.
    LwInc,
    /// `mem[addr] = a; addr += b`.
    SwInc,
    /// `mem[a + b] = rout`.
    SwAt,
    /// Control flow steering the column PC.
    Br(BrFn),
}

/// One decoded µop.
#[derive(Clone, Copy, Debug)]
pub(crate) struct UInstr {
    /// Lane + function.
    pub kind: UKind,
    /// First operand.
    pub a: USrc,
    /// Second operand.
    pub b: USrc,
    /// Latch result into ROUT?
    pub wout: bool,
    /// Register to latch into, or [`NO_REG`].
    pub wreg: u8,
    /// Branch target (absolute slot).
    pub target: u16,
}

/// Static per-(column, slot) step metadata.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct ColMeta {
    /// DMA-port operations issued by this column at this slot.
    pub mem_ops: u32,
    /// True if any PE of the column multiplies at this slot.
    pub any_mul: bool,
}

fn lower_src(s: Src, pe: usize) -> USrc {
    match s {
        Src::Zero => USrc::Zero,
        Src::Imm(v) => USrc::Imm(v),
        Src::Reg(r) => USrc::Reg(r),
        Src::Own => USrc::Own,
        Src::Neigh(d) => USrc::Neigh(NEIGH[pe][dir_idx(d)] as u8),
        Src::Addr => USrc::Addr,
    }
}

fn lower(ins: Instr, pe: usize) -> UInstr {
    let kind = match ins.op {
        Op::Nop => UKind::Nop,
        Op::Exit => UKind::Exit,
        Op::Mov => UKind::Alu(AluFn::Mov),
        Op::Add => UKind::Alu(AluFn::Add),
        Op::Sub => UKind::Alu(AluFn::Sub),
        Op::Mul => UKind::Alu(AluFn::Mul),
        Op::Shl => UKind::Alu(AluFn::Shl),
        Op::Shr => UKind::Alu(AluFn::Shr),
        Op::And => UKind::Alu(AluFn::And),
        Op::Or => UKind::Alu(AluFn::Or),
        Op::Xor => UKind::Alu(AluFn::Xor),
        Op::Min => UKind::Alu(AluFn::Min),
        Op::Max => UKind::Alu(AluFn::Max),
        Op::SetAddr => UKind::SetAddr,
        Op::Lw => UKind::Lw,
        Op::LwInc => UKind::LwInc,
        Op::SwInc => UKind::SwInc,
        Op::SwAt => UKind::SwAt,
        Op::Beq => UKind::Br(BrFn::Eq),
        Op::Bne => UKind::Br(BrFn::Ne),
        Op::Blt => UKind::Br(BrFn::Lt),
        Op::Bge => UKind::Br(BrFn::Ge),
        Op::Jump => UKind::Br(BrFn::Always),
    };
    // Only ALU ops and loads latch results; the reference interpreter
    // ignores `dst` for every other op and so must the decoded form.
    let latches = matches!(kind, UKind::Alu(_) | UKind::Lw | UKind::LwInc);
    let (wout, wreg) = if latches {
        match ins.dst {
            Dst::Out => (true, NO_REG),
            Dst::Reg(r) => (false, r),
            Dst::Both(r) => (true, r),
            Dst::None => (false, NO_REG),
        }
    } else {
        (false, NO_REG)
    };
    UInstr {
        kind,
        a: lower_src(ins.a, pe),
        b: lower_src(ins.b, pe),
        wout,
        wreg,
        target: ins.target as u16,
    }
}

/// A program lowered to the dense µop representation, plus the static
/// step metadata the executor's cycle model consumes.
///
/// Deliberately does **not** hold a copy of the source `Program`: the
/// lane kernels decode a fresh program per launch, and the only
/// consumers of raw instructions are trace hooks, which receive the
/// source program separately (`Cgra::run_hooked`).
#[derive(Clone, Debug)]
pub struct DecodedProgram {
    /// Source program name (error messages, traces).
    name: String,
    /// Per-PE µop streams, each with a trailing sentinel `nop`.
    code: [Vec<UInstr>; N_PES],
    /// Per-column step metadata, indexed by clamped PC; the last entry
    /// is the all-idle sentinel.
    col_meta: [Vec<ColMeta>; COLS],
    /// Per-PE `OpClass::idx()` of every (clamped) slot.
    classes: [Vec<u8>; N_PES],
}

impl DecodedProgram {
    /// Program name (as shown in errors and traces).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Total µops across all PEs (sentinels included).
    pub fn total_uops(&self) -> usize {
        self.code.iter().map(|v| v.len()).sum()
    }

    /// Fetch the µop of `pe` at `pc`, clamping past-the-end PCs to the
    /// sentinel `nop`.
    #[inline(always)]
    pub(crate) fn uop(&self, pe: usize, pc: usize) -> UInstr {
        let v = &self.code[pe];
        v[pc.min(v.len() - 1)]
    }

    /// Static step metadata of column `c` (length = longest PE program
    /// in the column + 1 sentinel).
    #[inline(always)]
    pub(crate) fn col_meta(&self, c: usize) -> &[ColMeta] {
        &self.col_meta[c]
    }

    /// Pre-computed `OpClass::idx()` of `pe`'s slot `slot` (clamped
    /// indices only — callers index with the same clamp as `col_meta`).
    #[inline(always)]
    pub(crate) fn class_at(&self, pe: usize, slot: usize) -> usize {
        self.classes[pe][slot] as usize
    }
}

/// Process-wide count of µop decodes actually performed (cache hits do
/// not count — they re-lower nothing). The compile-once / run-many
/// tests assert a warm `CompiledNet::run` leaves this unchanged.
static DECODES: AtomicU64 = AtomicU64::new(0);

/// Total µop decodes performed so far in this process.
pub fn decode_count() -> u64 {
    DECODES.load(Ordering::Relaxed)
}

/// Lower `prog` into its µop representation.
pub fn decode(prog: &Program) -> DecodedProgram {
    DECODES.fetch_add(1, Ordering::Relaxed);
    let code: [Vec<UInstr>; N_PES] = std::array::from_fn(|i| {
        let pe = prog.pe(PeId::from_index(i));
        let mut v: Vec<UInstr> = pe.instrs().iter().map(|&ins| lower(ins, i)).collect();
        v.push(lower(Instr::nop(), i)); // sentinel
        v
    });
    let mut col_meta: [Vec<ColMeta>; COLS] = std::array::from_fn(|_| Vec::new());
    let mut classes: [Vec<u8>; N_PES] = std::array::from_fn(|_| Vec::new());
    for c in 0..COLS {
        let max_len = (0..ROWS).map(|r| prog.pe(PeId::new(r, c)).len()).max().unwrap_or(0);
        let mut meta = vec![ColMeta::default(); max_len + 1];
        for (p, slot) in meta.iter_mut().enumerate() {
            for r in 0..ROWS {
                let op = prog.pe(PeId::new(r, c)).fetch(p).op;
                if op.is_mem() {
                    slot.mem_ops += 1;
                }
                slot.any_mul |= op == Op::Mul;
            }
        }
        for r in 0..ROWS {
            let i = r * COLS + c;
            classes[i] = (0..=max_len)
                .map(|p| OpClass::classify(prog.pe(PeId::from_index(i)).fetch(p).op).idx() as u8)
                .collect();
        }
        col_meta[c] = meta;
    }
    DecodedProgram { name: prog.name.clone(), code, col_meta, classes }
}

// ---------------------------------------------------------------------------
// Decode cache
// ---------------------------------------------------------------------------

/// Number of lock shards in the process-wide decode cache.
const DECODE_SHARDS: usize = 8;
/// Entries per shard before the shard is wholesale evicted. Bounds the
/// cache to `DECODE_SHARDS × DECODE_SHARD_CAP` decoded programs so that
/// sweeps with thousands of unique per-launch programs cannot grow it
/// without limit.
const DECODE_SHARD_CAP: usize = 64;

/// Total decode-cache capacity. Callers with a statically known launch
/// set (e.g. WP's k×c programs per convolution) can compare against
/// this to decide whether memoizing will hit or merely churn.
pub const DECODE_CACHE_CAPACITY: usize = DECODE_SHARDS * DECODE_SHARD_CAP;

type Shard = Mutex<HashMap<(u64, u64), Arc<DecodedProgram>>>;

static DECODE_CACHE: OnceLock<Vec<Shard>> = OnceLock::new();
static DECODE_HITS: AtomicU64 = AtomicU64::new(0);
static DECODE_MISSES: AtomicU64 = AtomicU64::new(0);
static DECODE_EVICTIONS: AtomicU64 = AtomicU64::new(0);

fn shards() -> &'static Vec<Shard> {
    DECODE_CACHE.get_or_init(|| (0..DECODE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect())
}

/// Counters of the process-wide decode cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DecodeCacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to decode.
    pub misses: u64,
    /// Entries dropped by shard eviction.
    pub evictions: u64,
    /// Decoded programs currently resident.
    pub entries: usize,
}

/// 128-bit content fingerprint of a program: name + every instruction
/// field, mixed through two independent multiply-xor streams. Two
/// programs collide only if both 64-bit streams collide — negligible for
/// the program counts any sweep can produce.
fn fingerprint(prog: &Program) -> (u64, u64) {
    let mut a = 0xcbf2_9ce4_8422_2325u64;
    let mut b = 0x6c62_272e_07bb_0142u64;
    let mut word = |x: u64| {
        a = (a ^ x).wrapping_mul(0x1000_0000_01b3);
        b = (b ^ x.rotate_left(17)).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        b ^= b >> 29;
    };
    for byte in prog.name.bytes() {
        word(byte as u64);
    }
    let src_word = |s: Src| -> u64 {
        match s {
            Src::Zero => 0,
            Src::Imm(v) => 1 | (v as u32 as u64) << 8,
            Src::Reg(r) => 2 | (r as u64) << 8,
            Src::Own => 3,
            Src::Neigh(d) => 4 | (dir_idx(d) as u64) << 8,
            Src::Addr => 5,
        }
    };
    let dst_word = |d: Dst| -> u64 {
        match d {
            Dst::Out => 0,
            Dst::Reg(r) => 1 | (r as u64) << 8,
            Dst::Both(r) => 2 | (r as u64) << 8,
            Dst::None => 3,
        }
    };
    for id in PeId::all() {
        let pe = prog.pe(id);
        word(pe.len() as u64);
        for ins in pe.instrs() {
            // The mnemonic is unique per op and stable.
            let op_hash = ins
                .op
                .mnemonic()
                .bytes()
                .fold(0u64, |h, c| h.wrapping_mul(31).wrapping_add(c as u64));
            word(op_hash);
            word(src_word(ins.a));
            word(src_word(ins.b));
            word(dst_word(ins.dst));
            word(ins.target as u64);
        }
    }
    (a, b)
}

/// Decode `prog`, memoizing the result in the process-wide sharded
/// cache. Repeated launches of the same program (the normal case for
/// every figure driver and bench) return a shared `Arc` without
/// re-lowering anything.
pub fn decode_cached(prog: &Program) -> Arc<DecodedProgram> {
    let key = fingerprint(prog);
    let shard = &shards()[key.0 as usize % DECODE_SHARDS];
    if let Some(dp) = shard.lock().unwrap().get(&key) {
        DECODE_HITS.fetch_add(1, Ordering::Relaxed);
        return dp.clone();
    }
    DECODE_MISSES.fetch_add(1, Ordering::Relaxed);
    let dp = Arc::new(decode(prog));
    let mut map = shard.lock().unwrap();
    if map.len() >= DECODE_SHARD_CAP {
        DECODE_EVICTIONS.fetch_add(map.len() as u64, Ordering::Relaxed);
        map.clear();
    }
    map.insert(key, dp.clone());
    dp
}

/// Snapshot of the decode cache counters.
pub fn decode_cache_stats() -> DecodeCacheStats {
    DecodeCacheStats {
        hits: DECODE_HITS.load(Ordering::Relaxed),
        misses: DECODE_MISSES.load(Ordering::Relaxed),
        evictions: DECODE_EVICTIONS.load(Ordering::Relaxed),
        entries: shards().iter().map(|s| s.lock().unwrap().len()).sum(),
    }
}

/// Drop every cached decode (counters are preserved).
pub fn clear_decode_cache() {
    for s in shards() {
        s.lock().unwrap().clear();
    }
}

// ---------------------------------------------------------------------------
// Wire codec (AOT artifacts, DESIGN.md §13)
// ---------------------------------------------------------------------------
//
// The artifact load path must reconstruct a `DecodedProgram` *without*
// calling [`decode`] — zero µop decodes on load is the contract
// `tests/compiled_counters.rs` pins — so the codec round-trips every
// field of the decoded form verbatim (sentinels included) and builds
// the struct directly. `DECODES` is untouched by [`DecodedProgram::wire_decode`].

/// Dedup table mapping shared `Arc<DecodedProgram>`s to artifact
/// program-table indices. Kernels that share programs (grouped layers
/// via `with_weights`) serialize the program once and reference it by
/// index, and the load path restores the sharing by cloning out of one
/// `Vec<Arc<DecodedProgram>>`.
#[derive(Debug, Default)]
pub(crate) struct ProgTable {
    by_ptr: HashMap<usize, u32>,
    progs: Vec<Arc<DecodedProgram>>,
}

impl ProgTable {
    /// An empty table.
    pub(crate) fn new() -> ProgTable {
        ProgTable::default()
    }

    /// The table index of `p`, interning it on first sight. Identity is
    /// by `Arc` pointer: two kernels holding the same `Arc` map to one
    /// table entry.
    pub(crate) fn index_of(&mut self, p: &Arc<DecodedProgram>) -> u32 {
        let key = Arc::as_ptr(p) as usize;
        *self.by_ptr.entry(key).or_insert_with(|| {
            self.progs.push(p.clone());
            (self.progs.len() - 1) as u32
        })
    }

    /// The interned programs, in index order.
    pub(crate) fn progs(&self) -> &[Arc<DecodedProgram>] {
        &self.progs
    }
}

fn encode_usrc(w: &mut Writer, s: USrc) {
    match s {
        USrc::Zero => w.u8(0),
        USrc::Imm(v) => {
            w.u8(1);
            w.i32(v);
        }
        USrc::Reg(r) => {
            w.u8(2);
            w.u8(r);
        }
        USrc::Own => w.u8(3),
        USrc::Neigh(p) => {
            w.u8(4);
            w.u8(p);
        }
        USrc::Addr => w.u8(5),
    }
}

fn decode_usrc(r: &mut Reader) -> Result<USrc> {
    let at = r.pos();
    Ok(match r.u8()? {
        0 => USrc::Zero,
        1 => USrc::Imm(r.i32()?),
        2 => {
            let reg = r.u8()?;
            ensure!((reg as usize) < N_REGS, "register index {reg} out of range at offset {at}");
            USrc::Reg(reg)
        }
        3 => USrc::Own,
        4 => {
            let pe = r.u8()?;
            ensure!((pe as usize) < N_PES, "neighbour PE index {pe} out of range at offset {at}");
            USrc::Neigh(pe)
        }
        5 => USrc::Addr,
        t => bail!("unknown operand-source tag {t} at offset {at}"),
    })
}

const ALU_FNS: [AluFn; 11] = [
    AluFn::Mov,
    AluFn::Add,
    AluFn::Sub,
    AluFn::Mul,
    AluFn::Shl,
    AluFn::Shr,
    AluFn::And,
    AluFn::Or,
    AluFn::Xor,
    AluFn::Min,
    AluFn::Max,
];

const BR_FNS: [BrFn; 5] = [BrFn::Eq, BrFn::Ne, BrFn::Lt, BrFn::Ge, BrFn::Always];

fn encode_uinstr(w: &mut Writer, u: &UInstr) {
    match u.kind {
        UKind::Nop => w.u8(0),
        UKind::Exit => w.u8(1),
        UKind::Alu(f) => {
            w.u8(2);
            w.u8(ALU_FNS.iter().position(|&x| x == f).unwrap_or(0) as u8);
        }
        UKind::SetAddr => w.u8(3),
        UKind::Lw => w.u8(4),
        UKind::LwInc => w.u8(5),
        UKind::SwInc => w.u8(6),
        UKind::SwAt => w.u8(7),
        UKind::Br(f) => {
            w.u8(8);
            w.u8(BR_FNS.iter().position(|&x| x == f).unwrap_or(0) as u8);
        }
    }
    encode_usrc(w, u.a);
    encode_usrc(w, u.b);
    w.bool(u.wout);
    w.u8(u.wreg);
    w.u16(u.target);
}

fn decode_uinstr(r: &mut Reader) -> Result<UInstr> {
    let at = r.pos();
    let kind = match r.u8()? {
        0 => UKind::Nop,
        1 => UKind::Exit,
        2 => {
            let f = r.u8()? as usize;
            ensure!(f < ALU_FNS.len(), "unknown ALU function {f} at offset {at}");
            UKind::Alu(ALU_FNS[f])
        }
        3 => UKind::SetAddr,
        4 => UKind::Lw,
        5 => UKind::LwInc,
        6 => UKind::SwInc,
        7 => UKind::SwAt,
        8 => {
            let f = r.u8()? as usize;
            ensure!(f < BR_FNS.len(), "unknown branch condition {f} at offset {at}");
            UKind::Br(BR_FNS[f])
        }
        t => bail!("unknown µop tag {t} at offset {at}"),
    };
    let a = decode_usrc(r)?;
    let b = decode_usrc(r)?;
    let wout = r.bool()?;
    let wreg = r.u8()?;
    ensure!(
        wreg == NO_REG || (wreg as usize) < N_REGS,
        "write-register index {wreg} out of range at offset {at}"
    );
    let target = r.u16()?;
    Ok(UInstr { kind, a, b, wout, wreg, target })
}

impl DecodedProgram {
    /// Serialize the decoded form verbatim (DESIGN.md §13): name, the
    /// per-PE µop streams with their sentinels, the per-column step
    /// metadata, and the per-slot op classes.
    pub(crate) fn wire_encode(&self, w: &mut Writer) {
        w.str(&self.name);
        for pe in &self.code {
            w.u32(pe.len() as u32);
            for u in pe {
                encode_uinstr(w, u);
            }
        }
        for col in &self.col_meta {
            w.u32(col.len() as u32);
            for m in col {
                w.u32(m.mem_ops);
                w.bool(m.any_mul);
            }
        }
        for pe in &self.classes {
            w.u32(pe.len() as u32);
            for &c in pe {
                w.u8(c);
            }
        }
    }

    /// Reconstruct a decoded program from its wire form **without
    /// re-decoding anything** — [`decode_count`] is untouched. The
    /// executor's indexing invariants (non-empty sentinel-terminated
    /// streams, per-column class tables matching the column metadata
    /// length) are re-validated so a corrupted payload fails here with
    /// an actionable error instead of panicking in the hot loop.
    pub(crate) fn wire_decode(r: &mut Reader) -> Result<DecodedProgram> {
        let name = r.str()?;
        let mut code: Vec<Vec<UInstr>> = Vec::with_capacity(N_PES);
        for pe in 0..N_PES {
            let n = r.u32()? as usize;
            ensure!(n >= 1, "PE {pe} µop stream of '{name}' lost its sentinel");
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(decode_uinstr(r)?);
            }
            code.push(v);
        }
        let mut col_meta: Vec<Vec<ColMeta>> = Vec::with_capacity(COLS);
        for c in 0..COLS {
            let n = r.u32()? as usize;
            ensure!(n >= 1, "column {c} step metadata of '{name}' is empty");
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                let mem_ops = r.u32()?;
                let any_mul = r.bool()?;
                v.push(ColMeta { mem_ops, any_mul });
            }
            col_meta.push(v);
        }
        let mut classes: Vec<Vec<u8>> = Vec::with_capacity(N_PES);
        for pe in 0..N_PES {
            let n = r.u32()? as usize;
            let expect = col_meta[pe % COLS].len();
            ensure!(
                n == expect,
                "PE {pe} class table of '{name}' has {n} slots, column metadata has {expect}"
            );
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(r.u8()?);
            }
            classes.push(v);
        }
        for (pe, v) in code.iter().enumerate() {
            let cols = col_meta[pe % COLS].len();
            ensure!(
                v.len() <= cols,
                "PE {pe} µop stream of '{name}' has {} slots, column metadata covers {cols}",
                v.len()
            );
        }
        let into_arr = "element count checked by the loops above";
        Ok(DecodedProgram {
            name,
            code: code.try_into().expect(into_arr),
            col_meta: col_meta.try_into().expect(into_arr),
            classes: classes.try_into().expect(into_arr),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Dir;

    #[test]
    fn neighbour_sources_are_pre_resolved() {
        let mut prog = Program::new("t");
        prog.pe_mut(PeId::new(1, 2)).push(Instr::mov(Dst::Out, Src::Neigh(Dir::East)));
        let dp = decode(&prog);
        let i = PeId::new(1, 2).index();
        let u = dp.uop(i, 0);
        assert_eq!(u.a, USrc::Neigh(PeId::new(1, 3).index() as u8));
        assert!(u.wout);
        assert_eq!(u.wreg, NO_REG);
    }

    #[test]
    fn sentinel_nop_past_end() {
        let mut prog = Program::new("t");
        prog.pe_mut(PeId::new(0, 0)).push(Instr::exit());
        let dp = decode(&prog);
        assert_eq!(dp.uop(0, 0).kind, UKind::Exit);
        assert_eq!(dp.uop(0, 1).kind, UKind::Nop);
        assert_eq!(dp.uop(0, 999).kind, UKind::Nop);
        // Empty PEs are a single sentinel.
        assert_eq!(dp.uop(5, 0).kind, UKind::Nop);
    }

    #[test]
    fn non_latching_ops_never_write() {
        let mut prog = Program::new("t");
        let p = prog.pe_mut(PeId::new(0, 0));
        // A store with a (nonsensical) Out destination must not latch.
        p.push(Instr { op: Op::SwAt, a: Src::Imm(0), b: Src::Zero, dst: Dst::Out, target: 0 });
        p.push(Instr { op: Op::SetAddr, a: Src::Zero, b: Src::Zero, dst: Dst::reg(1), target: 0 });
        let dp = decode(&prog);
        for pc in 0..2 {
            let u = dp.uop(0, pc);
            assert!(!u.wout, "slot {pc}");
            assert_eq!(u.wreg, NO_REG, "slot {pc}");
        }
    }

    #[test]
    fn col_meta_counts_static_mem_and_mul() {
        let mut prog = Program::new("t");
        // Column 0: two loads + a mul at slot 0.
        prog.pe_mut(PeId::new(0, 0)).push(Instr::new(Op::Lw, Src::Imm(0), Src::Zero, Dst::Out));
        prog.pe_mut(PeId::new(1, 0)).push(Instr::new(Op::Lw, Src::Imm(1), Src::Zero, Dst::Out));
        prog.pe_mut(PeId::new(2, 0)).push(Instr::new(Op::Mul, Src::Imm(2), Src::Imm(3), Dst::Out));
        let dp = decode(&prog);
        let m = dp.col_meta(0);
        assert_eq!(m[0].mem_ops, 2);
        assert!(m[0].any_mul);
        // Sentinel slot is idle.
        assert_eq!(m[m.len() - 1].mem_ops, 0);
        assert!(!m[m.len() - 1].any_mul);
        // Column 1 has no code: single idle sentinel.
        assert_eq!(dp.col_meta(1).len(), 1);
    }

    #[test]
    fn classes_match_static_classification() {
        let mut prog = Program::new("t");
        let p = prog.pe_mut(PeId::new(0, 0));
        p.push(Instr::new(Op::Add, Src::Imm(1), Src::Imm(2), Dst::Out));
        p.push(Instr::new(Op::Lw, Src::Imm(0), Src::Zero, Dst::Out));
        let dp = decode(&prog);
        assert_eq!(dp.class_at(0, 0), OpClass::Sum.idx());
        assert_eq!(dp.class_at(0, 1), OpClass::Load.idx());
        assert_eq!(dp.class_at(0, 2), OpClass::Nop.idx()); // sentinel
    }

    #[test]
    fn fingerprints_separate_distinct_programs() {
        let mut a = Program::new("p");
        a.pe_mut(PeId::new(0, 0)).push(Instr::mov(Dst::Out, Src::Imm(1)));
        let mut b = Program::new("p");
        b.pe_mut(PeId::new(0, 0)).push(Instr::mov(Dst::Out, Src::Imm(2)));
        assert_ne!(fingerprint(&a), fingerprint(&b));
        let mut c = Program::new("q");
        c.pe_mut(PeId::new(0, 0)).push(Instr::mov(Dst::Out, Src::Imm(1)));
        assert_ne!(fingerprint(&a), fingerprint(&c), "name participates");
        assert_eq!(fingerprint(&a), fingerprint(&a.clone()));
    }

    #[test]
    fn decode_cached_hits_on_repeat() {
        // Other tests in this binary use the process-wide cache
        // concurrently and can trigger an epoch eviction between two
        // adjacent calls, so allow a few attempts before declaring the
        // cache broken.
        let mut prog = Program::new("decode-cache-hit-test-unique-name");
        prog.pe_mut(PeId::new(0, 0)).push(Instr::exit());
        let before = decode_cache_stats();
        let mut hit = false;
        for _ in 0..32 {
            let a = decode_cached(&prog);
            let b = decode_cached(&prog);
            if Arc::ptr_eq(&a, &b) {
                hit = true;
                break;
            }
        }
        let after = decode_cache_stats();
        assert!(hit, "decode_cached never returned a shared Arc in 32 attempts");
        assert!(after.hits > before.hits);
        assert!(after.misses >= before.misses + 1);
    }
}
