//! Timing/geometry configuration of the simulated OpenEdgeCGRA instance.

/// Cycle-level timing knobs.
///
/// The defaults are the *calibrated* values used throughout the
/// reproduction; see `energy::calibration` and EXPERIMENTS.md for how they
/// were anchored to the paper's reported numbers (WP ≈ 0.6 MAC/cycle on
/// the baseline layer, CPU-only ≈ 9.9× slower, non-WP mappings dominated
/// by DMA-port collisions).
#[derive(Clone, Debug, PartialEq)]
pub struct CgraConfig {
    /// Cycles for a plain ALU / mov / control slot.
    pub alu_latency: u64,
    /// Cycles for a 32-bit multiply (the ALU is not pipelined for
    /// multiplies on this class of low-power PE).
    pub mul_latency: u64,
    /// Cycles for one memory access through a column DMA port, conflict
    /// free. Multiple accesses from the same column in one step serialize
    /// at this cost each (the port is the paper's collision point).
    pub mem_latency: u64,
    /// Extra cycles per additional access hitting the same memory bank in
    /// the same step (cross-column interleave conflicts).
    pub bank_penalty: u64,
    /// Number of word-interleaved memory banks in the subsystem.
    pub n_banks: usize,
    /// Memory size in 32-bit words. The paper's HEEPsilon instance has
    /// 512 KiB of RAM = 131072 words; the Fig. 5 sweep is bounded by it.
    pub mem_words: usize,
    /// Cycles charged per CGRA kernel launch (CPU writes the
    /// configuration registers and triggers execution). The paper counts
    /// this overhead — it is what sinks Im2col-IP, which launches per
    /// output position.
    pub launch_overhead: u64,
    /// Cycles to load the instruction memories before the *first* launch.
    /// The paper neglects it ("the time required to load the instructions
    /// before the first iteration is neglected"), so the default is 0,
    /// but it is kept as a knob for ablations.
    pub instruction_load_overhead: u64,
    /// Safety watchdog: abort execution after this many steps.
    pub max_steps: u64,
}

impl Default for CgraConfig {
    fn default() -> Self {
        CgraConfig {
            alu_latency: 1,
            mul_latency: 1,
            mem_latency: 4,
            bank_penalty: 1,
            n_banks: 4,
            mem_words: 512 * 1024 / 4,
            launch_overhead: 24,
            instruction_load_overhead: 0,
            max_steps: 2_000_000_000,
        }
    }
}

impl CgraConfig {
    /// Configuration with contention disabled — used by unit tests that
    /// check functional behaviour only, and by the `no-collision`
    /// ablation bench.
    pub fn functional() -> Self {
        CgraConfig {
            alu_latency: 1,
            mul_latency: 1,
            mem_latency: 1,
            bank_penalty: 0,
            launch_overhead: 0,
            ..Default::default()
        }
    }

    /// Validate invariants (positive latencies, at least one bank, …).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.alu_latency >= 1, "alu_latency must be >= 1");
        anyhow::ensure!(self.mul_latency >= 1, "mul_latency must be >= 1");
        anyhow::ensure!(self.mem_latency >= 1, "mem_latency must be >= 1");
        anyhow::ensure!(self.n_banks >= 1, "need at least one memory bank");
        anyhow::ensure!(self.mem_words >= 1, "need a non-empty memory");
        anyhow::ensure!(self.max_steps >= 1, "watchdog must allow progress");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        CgraConfig::default().validate().unwrap();
        CgraConfig::functional().validate().unwrap();
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = CgraConfig::default();
        c.n_banks = 0;
        assert!(c.validate().is_err());
        let mut c = CgraConfig::default();
        c.mem_latency = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn default_memory_is_512kib() {
        assert_eq!(CgraConfig::default().mem_words * 4, 512 * 1024);
    }
}
