//! Execution statistics: per-PE operation mix, utilization, cycle counts.
//!
//! These feed Figure 3 (operation distribution / PE utilization) and the
//! latency / MAC-per-cycle numbers of Figures 4 and 5.

use crate::isa::{Op, N_PES};

use super::memory::MemStats;

/// Operation classes as plotted in the paper's Figure 3.
///
/// Classification convention (see `kernels::common`): generators use
/// `Add` **only** for genuine accumulation ("sum"); index arithmetic uses
/// `Sub`/`SetAddr`/auto-increment addressing, so the static class of an
/// instruction matches its semantic role.
#[derive(Clone, Copy, PartialEq, Eq, Debug, PartialOrd, Ord, Hash)]
pub enum OpClass {
    /// Memory loads (`lw`, `lwinc`).
    Load,
    /// Multiplications.
    Mul,
    /// Accumulations (`add`).
    Sum,
    /// Memory stores (`swinc`, `swat`).
    Store,
    /// Index updates, moves, branches, comparisons, `exit` — the paper's
    /// "Other".
    Other,
    /// Idle slots.
    Nop,
}

impl OpClass {
    /// Number of classes (array sizing).
    pub const COUNT: usize = 6;

    /// All classes in plot order.
    pub const ALL: [OpClass; 6] =
        [OpClass::Load, OpClass::Mul, OpClass::Sum, OpClass::Store, OpClass::Other, OpClass::Nop];

    /// Static classification of an op.
    pub fn classify(op: Op) -> OpClass {
        match op {
            Op::Lw | Op::LwInc => OpClass::Load,
            Op::Mul => OpClass::Mul,
            Op::Add => OpClass::Sum,
            Op::SwInc | Op::SwAt => OpClass::Store,
            Op::Nop => OpClass::Nop,
            _ => OpClass::Other,
        }
    }

    /// Plot label.
    pub fn label(self) -> &'static str {
        match self {
            OpClass::Load => "load",
            OpClass::Mul => "mul",
            OpClass::Sum => "sum",
            OpClass::Store => "store",
            OpClass::Other => "other",
            OpClass::Nop => "nop",
        }
    }

    /// Index into `[u64; COUNT]` histograms.
    pub fn idx(self) -> usize {
        match self {
            OpClass::Load => 0,
            OpClass::Mul => 1,
            OpClass::Sum => 2,
            OpClass::Store => 3,
            OpClass::Other => 4,
            OpClass::Nop => 5,
        }
    }
}

/// Statistics of one CGRA run (one launch).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunStats {
    /// Instruction steps executed (array-wide issue slots).
    pub steps: u64,
    /// Cycles consumed (≥ steps; includes multi-cycle ops + contention).
    pub cycles: u64,
    /// Cycles lost to DMA-port / bank contention specifically (the
    /// "collision" cost the paper attributes the WP advantage to).
    pub contention_cycles: u64,
    /// Per-PE op-class histogram, indexed `[pe][OpClass::idx()]`.
    pub op_mix: Vec<[u64; OpClass::COUNT]>,
    /// Memory traffic issued by the array during the run.
    pub mem: MemStats,
    /// Whether the program terminated via `exit` (vs the watchdog).
    pub exited: bool,
}

impl RunStats {
    /// Fresh zeroed stats.
    pub fn new() -> Self {
        RunStats { op_mix: vec![[0; OpClass::COUNT]; N_PES], ..Default::default() }
    }

    /// Total slots of a class across all PEs.
    pub fn class_total(&self, c: OpClass) -> u64 {
        self.op_mix.iter().map(|h| h[c.idx()]).sum()
    }

    /// Total issue slots (steps × 16 when all PEs have code).
    pub fn total_slots(&self) -> u64 {
        self.op_mix.iter().map(|h| h.iter().sum::<u64>()).sum()
    }

    /// PE utilization as in Fig. 3: fraction of non-nop slots.
    pub fn utilization(&self) -> f64 {
        let total = self.total_slots();
        if total == 0 {
            return 0.0;
        }
        1.0 - self.class_total(OpClass::Nop) as f64 / total as f64
    }

    /// Class fractions in plot order (sums to 1 for non-empty runs).
    pub fn class_fractions(&self) -> [f64; OpClass::COUNT] {
        let total = self.total_slots().max(1) as f64;
        let mut out = [0.0; OpClass::COUNT];
        for c in OpClass::ALL {
            out[c.idx()] = self.class_total(c) as f64 / total;
        }
        out
    }

    /// Attribute the run's cycles to op classes, proportional to each
    /// class's share of issue slots (largest-remainder rounding so the
    /// attribution sums to `cycles` exactly). This is what per-launch
    /// trace spans report (DESIGN.md §11): "where did this launch's
    /// cycles go", in the same classes as the paper's Figure 3.
    pub fn class_cycles(&self) -> [u64; OpClass::COUNT] {
        let total = self.total_slots();
        let mut out = [0u64; OpClass::COUNT];
        if total == 0 || self.cycles == 0 {
            return out;
        }
        let mut assigned = 0u64;
        let mut rem: Vec<(u64, usize)> = Vec::with_capacity(OpClass::COUNT);
        for c in OpClass::ALL {
            let slots = self.class_total(c);
            let exact = self.cycles as u128 * slots as u128;
            out[c.idx()] = (exact / total as u128) as u64;
            assigned += out[c.idx()];
            rem.push(((exact % total as u128) as u64, c.idx()));
        }
        // Hand the rounding shortfall to the largest remainders.
        rem.sort_by(|a, b| b.0.cmp(&a.0));
        for (_, idx) in rem.into_iter().take((self.cycles - assigned) as usize) {
            out[idx] += 1;
        }
        out
    }

    /// Merge another run into this one (host drivers aggregate the
    /// per-launch stats of a full convolution).
    pub fn merge(&mut self, other: &RunStats) {
        self.steps += other.steps;
        self.cycles += other.cycles;
        self.contention_cycles += other.contention_cycles;
        if self.op_mix.len() < other.op_mix.len() {
            self.op_mix.resize(other.op_mix.len(), [0; OpClass::COUNT]);
        }
        for (a, b) in self.op_mix.iter_mut().zip(other.op_mix.iter()) {
            for k in 0..OpClass::COUNT {
                a[k] += b[k];
            }
        }
        self.mem.loads += other.mem.loads;
        self.mem.stores += other.mem.stores;
        self.exited &= other.exited;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_matches_convention() {
        assert_eq!(OpClass::classify(Op::LwInc), OpClass::Load);
        assert_eq!(OpClass::classify(Op::Add), OpClass::Sum);
        assert_eq!(OpClass::classify(Op::Sub), OpClass::Other);
        assert_eq!(OpClass::classify(Op::SwInc), OpClass::Store);
        assert_eq!(OpClass::classify(Op::Mul), OpClass::Mul);
        assert_eq!(OpClass::classify(Op::Nop), OpClass::Nop);
        assert_eq!(OpClass::classify(Op::Bne), OpClass::Other);
    }

    #[test]
    fn utilization_and_fractions() {
        let mut s = RunStats::new();
        s.op_mix[0][OpClass::Mul.idx()] = 3;
        s.op_mix[0][OpClass::Nop.idx()] = 1;
        assert!((s.utilization() - 0.75).abs() < 1e-12);
        let f = s.class_fractions();
        assert!((f[OpClass::Mul.idx()] - 0.75).abs() < 1e-12);
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = RunStats::new();
        a.exited = true;
        a.steps = 2;
        a.cycles = 5;
        let mut b = RunStats::new();
        b.exited = true;
        b.steps = 3;
        b.cycles = 7;
        b.op_mix[4][OpClass::Load.idx()] = 2;
        b.mem.loads = 2;
        a.merge(&b);
        assert_eq!(a.steps, 5);
        assert_eq!(a.cycles, 12);
        assert_eq!(a.class_total(OpClass::Load), 2);
        assert_eq!(a.mem.loads, 2);
        assert!(a.exited);
    }

    #[test]
    fn empty_stats_have_zero_utilization() {
        assert_eq!(RunStats::new().utilization(), 0.0);
    }

    #[test]
    fn class_cycles_sum_exactly() {
        let mut s = RunStats::new();
        s.cycles = 100;
        s.op_mix[0][OpClass::Load.idx()] = 1;
        s.op_mix[0][OpClass::Mul.idx()] = 1;
        s.op_mix[0][OpClass::Sum.idx()] = 1;
        let cc = s.class_cycles();
        assert_eq!(cc.iter().sum::<u64>(), 100, "attribution must sum to cycles");
        // Three equal classes: 33/33/33 plus one largest-remainder cycle.
        assert!(cc[OpClass::Load.idx()] >= 33 && cc[OpClass::Load.idx()] <= 34);
        assert_eq!(cc[OpClass::Nop.idx()], 0);
        assert_eq!(RunStats::new().class_cycles(), [0; OpClass::COUNT]);
    }

    #[test]
    fn class_cycles_largest_remainder_adversarial() {
        // Prime cycle count over a skewed mix: floor division drops
        // cycles on every class; largest-remainder must restore them.
        let mut s = RunStats::new();
        s.cycles = 97;
        s.op_mix[0][OpClass::Load.idx()] = 7;
        s.op_mix[1][OpClass::Mul.idx()] = 11;
        s.op_mix[2][OpClass::Sum.idx()] = 13;
        s.op_mix[3][OpClass::Store.idx()] = 1;
        s.op_mix[4][OpClass::Other.idx()] = 1;
        s.op_mix[5][OpClass::Nop.idx()] = 1;
        let cc = s.class_cycles();
        assert_eq!(cc.iter().sum::<u64>(), 97);
        // Every class has slots, so every class gets at least its floor;
        // nobody receives more than floor + 1.
        let total = s.total_slots();
        for c in OpClass::ALL {
            let slots = s.class_total(c);
            let floor = (97u128 * slots as u128 / total as u128) as u64;
            assert!(cc[c.idx()] == floor || cc[c.idx()] == floor + 1, "{c:?}: {}", cc[c.idx()]);
        }

        // Fewer cycles than classes: only the largest remainders get a
        // cycle at all, and the sum is still exact.
        let mut s = RunStats::new();
        s.cycles = 2;
        for c in OpClass::ALL {
            s.op_mix[c.idx()][c.idx()] = 1;
        }
        let cc = s.class_cycles();
        assert_eq!(cc.iter().sum::<u64>(), 2);
        assert_eq!(cc.iter().filter(|&&v| v == 1).count(), 2);

        // u64-scale products: cycles * slots overflows u64 but the u128
        // intermediate keeps the attribution exact.
        let mut s = RunStats::new();
        s.cycles = u64::MAX / 2;
        s.op_mix[0][OpClass::Load.idx()] = u64::MAX / 3;
        s.op_mix[1][OpClass::Mul.idx()] = u64::MAX / 5;
        let cc = s.class_cycles();
        assert_eq!(cc.iter().sum::<u64>(), u64::MAX / 2);
        assert!(cc[OpClass::Load.idx()] > cc[OpClass::Mul.idx()]);

        // Exhaustive small sweep: all 3-class slot mixes up to 4 slots,
        // cycles 1..=13 — the invariant holds everywhere.
        for a in 0..=4u64 {
            for b in 0..=4u64 {
                for c in 0..=4u64 {
                    for cycles in 1..=13u64 {
                        let mut s = RunStats::new();
                        s.cycles = cycles;
                        s.op_mix[0][OpClass::Load.idx()] = a;
                        s.op_mix[0][OpClass::Mul.idx()] = b;
                        s.op_mix[0][OpClass::Nop.idx()] = c;
                        let cc = s.class_cycles();
                        let expect = if a + b + c == 0 { 0 } else { cycles };
                        assert_eq!(cc.iter().sum::<u64>(), expect, "a={a} b={b} c={c} cy={cycles}");
                    }
                }
            }
        }
    }
}
