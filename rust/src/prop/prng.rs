//! SplitMix64 — small, fast, well-distributed PRNG for property tests
//! and workload generation. Deterministic across platforms.

/// SplitMix64 state.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded construction.
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. Uses rejection sampling to avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform signed integer in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u128;
        let v = (self.next_u64() as u128) % span;
        (lo as i128 + v as i128) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Random i32 covering the full range (for conv test data we usually
    /// restrict magnitudes to avoid wrap-around in oracles; see callers).
    pub fn i32_full(&mut self) -> i32 {
        self.next_u64() as i32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_i64_inclusive_bounds_hit() {
        let mut r = Rng::new(3);
        let (mut lo_hit, mut hi_hit) = (false, false);
        for _ in 0..5000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_hit |= v == -3;
            hi_hit |= v == 3;
        }
        assert!(lo_hit && hi_hit);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<u32>>());
    }
}
