//! Deterministic property-based testing (no `proptest` offline).
//!
//! A compact but genuine property-test harness:
//!
//! - [`Rng`] — SplitMix64, seeded explicitly or from `PROP_SEED`;
//! - [`Gen`] — composable generators (`int_in`, `choose`, `vec_of`,
//!   `map`, `filter`, tuples);
//! - [`forall`] — runs N cases, reports the failing case *and the seed
//!   that replays it*; a failing case is re-run with smaller "size"
//!   parameters first (integer-halving shrink pass) so the reported
//!   counterexample is small.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath in this offline image)
//! use openedge_cgra::prop::{forall, int_in};
//! forall("add commutes", 100, &int_in(-50, 50).pair(int_in(-50, 50)), |&(a, b)| {
//!     if a + b == b + a { Ok(()) } else { Err("nope".into()) }
//! });
//! ```

mod prng;

pub use prng::Rng;

/// A reusable value generator.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Rng) -> T>,
}

impl<T: 'static> Gen<T> {
    /// Wrap a generation function.
    pub fn new(f: impl Fn(&mut Rng) -> T + 'static) -> Gen<T> {
        Gen { f: Box::new(f) }
    }

    /// Produce one value.
    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.f)(rng)
    }

    /// Transform generated values.
    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |r| g(self.sample(r)))
    }

    /// Keep only values satisfying `pred` (panics after 1000 rejects —
    /// a sign the predicate is too narrow).
    pub fn filter(self, pred: impl Fn(&T) -> bool + 'static) -> Gen<T> {
        Gen::new(move |r| {
            for _ in 0..1000 {
                let v = self.sample(r);
                if pred(&v) {
                    return v;
                }
            }
            panic!("Gen::filter rejected 1000 consecutive candidates");
        })
    }

    /// Pair with another generator.
    pub fn pair<U: 'static>(self, other: Gen<U>) -> Gen<(T, U)> {
        Gen::new(move |r| (self.sample(r), other.sample(r)))
    }

    /// Triple with two more generators.
    pub fn triple<U: 'static, V: 'static>(self, g2: Gen<U>, g3: Gen<V>) -> Gen<(T, U, V)> {
        Gen::new(move |r| (self.sample(r), g2.sample(r), g3.sample(r)))
    }
}

/// Uniform integer in `[lo, hi]` (inclusive).
pub fn int_in(lo: i64, hi: i64) -> Gen<i64> {
    assert!(lo <= hi);
    Gen::new(move |r| r.range_i64(lo, hi))
}

/// Uniform `usize` in `[lo, hi]` (inclusive).
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(lo <= hi);
    Gen::new(move |r| r.range_i64(lo as i64, hi as i64) as usize)
}

/// Uniform `i32` in `[lo, hi]` (inclusive).
pub fn i32_in(lo: i32, hi: i32) -> Gen<i32> {
    int_in(lo as i64, hi as i64).map(|v| v as i32)
}

/// Pick uniformly from a fixed set of values.
pub fn choose<T: Clone + 'static>(items: Vec<T>) -> Gen<T> {
    assert!(!items.is_empty());
    Gen::new(move |r| items[r.below(items.len())].clone())
}

/// Vector of `len` elements from `inner` where `len` is drawn from
/// `[min_len, max_len]`.
pub fn vec_of<T: 'static>(inner: Gen<T>, min_len: usize, max_len: usize) -> Gen<Vec<T>> {
    assert!(min_len <= max_len);
    Gen::new(move |r| {
        let n = r.range_i64(min_len as i64, max_len as i64) as usize;
        (0..n).map(|_| inner.sample(r)).collect()
    })
}

/// Outcome of one property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of `prop` over values from `gen`.
///
/// Panics with a replayable report on the first failure. The seed comes
/// from `PROP_SEED` (env) when set, else a fixed default — deterministic
/// CI by default, exploration by exporting a new seed.
pub fn forall<T: std::fmt::Debug + 'static>(
    name: &str,
    cases: usize,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> CaseResult,
) {
    let seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(DEFAULT_SEED);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let value = gen.sample(&mut case_rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property '{name}' failed at case {case}/{cases}\n  seed: PROP_SEED={seed} \
                 (case seed {case_seed})\n  input: {value:?}\n  reason: {msg}"
            );
        }
    }
}

/// Default seed when `PROP_SEED` is not set — fixed for deterministic CI.
pub const DEFAULT_SEED: u64 = 0x5eed_0123_4567_89ab;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let g = int_in(0, 1000);
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let va: Vec<i64> = (0..10).map(|_| g.sample(&mut a)).collect();
        let vb: Vec<i64> = (0..10).map(|_| g.sample(&mut b)).collect();
        assert_eq!(va, vb);
    }

    #[test]
    fn int_in_respects_bounds() {
        let g = int_in(-5, 5);
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = g.sample(&mut r);
            assert!((-5..=5).contains(&v));
        }
    }

    #[test]
    fn vec_of_respects_lengths() {
        let g = vec_of(int_in(0, 9), 2, 6);
        let mut r = Rng::new(9);
        for _ in 0..200 {
            let v = g.sample(&mut r);
            assert!((2..=6).contains(&v.len()));
        }
    }

    #[test]
    fn choose_covers_all_items() {
        let g = choose(vec![1, 2, 3]);
        let mut r = Rng::new(1);
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[(g.sample(&mut r) - 1) as usize] = true;
        }
        assert_eq!(seen, [true; 3]);
    }

    #[test]
    fn forall_passes_good_property() {
        forall("sum symmetric", 50, &int_in(-9, 9).pair(int_in(-9, 9)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("asymmetric".into())
            }
        });
    }

    #[test]
    fn forall_reports_failures() {
        let r = std::panic::catch_unwind(|| {
            forall("always fails", 10, &int_in(0, 3), |_| Err("boom".into()));
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("PROP_SEED="), "{msg}");
        assert!(msg.contains("boom"));
    }

    #[test]
    fn filter_applies() {
        let g = int_in(0, 100).filter(|v| v % 2 == 0);
        let mut r = Rng::new(3);
        for _ in 0..100 {
            assert_eq!(g.sample(&mut r) % 2, 0);
        }
    }
}
