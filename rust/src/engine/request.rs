//! The typed request/response surface of the [`super::Engine`].

use crate::conv::{ConvShape, TensorChw, Weights};
use crate::kernels::Mapping;
use crate::metrics::MappingReport;

use super::auto::AutoDecision;

/// Default input-data magnitude for seeded requests (the Fig. 3/4 data
/// protocol: input values drawn from `[-30, 30]`).
pub const DEFAULT_INPUT_MAG: i32 = 30;

/// Default weight-data magnitude for seeded requests (Fig. 3/4: weights
/// drawn from `[-9, 9]`).
pub const DEFAULT_WEIGHT_MAG: i32 = 9;

/// Where a request's tensors come from.
#[derive(Clone, Debug)]
pub enum RequestData {
    /// Deterministic data derived from a seed (the figure/sweep
    /// protocol). Seeded requests are *cacheable*: the tuple
    /// `(mapping, shape, magnitudes, seed, config)` fully determines
    /// the result, so repeats are served from the engine's point cache.
    Seed {
        /// Data RNG seed (input then weights are drawn from one
        /// `Rng::new(seed)` stream, in that order).
        seed: u64,
        /// Input values are uniform in `[-in_mag, in_mag]`.
        in_mag: i32,
        /// Weight values are uniform in `[-w_mag, w_mag]`.
        w_mag: i32,
    },
    /// Caller-supplied tensors (e.g. real activations chained through a
    /// network). Never cached: the data is not part of any cache key.
    Tensors {
        /// Input feature map, CHW.
        input: TensorChw,
        /// Layer weights.
        weights: Weights,
    },
}

/// One convolution to execute.
#[derive(Clone, Debug)]
pub struct ConvRequest {
    /// Layer shape.
    pub shape: ConvShape,
    /// Strategy — concrete, or [`Mapping::Auto`] to let the engine pick
    /// (the decision is recorded in [`ConvResult::auto`]).
    pub mapping: Mapping,
    /// Tensor source.
    pub data: RequestData,
    /// Apply a host-side ReLU to the output (accounted separately from
    /// the convolution metrics, as in the CNN runner).
    pub relu: bool,
}

impl ConvRequest {
    /// A cacheable request with deterministic seeded data at the
    /// figure-protocol magnitudes ([`DEFAULT_INPUT_MAG`] /
    /// [`DEFAULT_WEIGHT_MAG`]).
    pub fn seeded(shape: ConvShape, mapping: Mapping, seed: u64) -> ConvRequest {
        ConvRequest {
            shape,
            mapping,
            data: RequestData::Seed {
                seed,
                in_mag: DEFAULT_INPUT_MAG,
                w_mag: DEFAULT_WEIGHT_MAG,
            },
            relu: false,
        }
    }

    /// A cacheable seeded request with explicit data magnitudes (the
    /// sweep protocol uses one magnitude for both tensors).
    pub fn seeded_with_mags(
        shape: ConvShape,
        mapping: Mapping,
        seed: u64,
        in_mag: i32,
        w_mag: i32,
    ) -> ConvRequest {
        ConvRequest { shape, mapping, data: RequestData::Seed { seed, in_mag, w_mag }, relu: false }
    }

    /// A request over caller-supplied tensors (uncached).
    pub fn with_data(
        shape: ConvShape,
        mapping: Mapping,
        input: TensorChw,
        weights: Weights,
    ) -> ConvRequest {
        ConvRequest { shape, mapping, data: RequestData::Tensors { input, weights }, relu: false }
    }

    /// Toggle the host-side ReLU (builder-style).
    pub fn relu(mut self, on: bool) -> ConvRequest {
        self.relu = on;
        self
    }
}

/// Everything one submission produces.
#[derive(Clone, Debug)]
pub struct ConvResult {
    /// Output tensor `(K, Ox, Oy)`, bit-exact wrapping int32 (ReLU
    /// applied when the request asked for it).
    pub output: TensorChw,
    /// The paper's metric row for the convolution itself (latency,
    /// energy, MAC/cycle, footprint, op mix — excludes the ReLU).
    pub report: MappingReport,
    /// Whether the metrics were served from the engine's point cache
    /// (seeded requests only; the output is then reconstructed through
    /// the golden model, which the simulator matches bit-exactly).
    pub cache_hit: bool,
    /// The concrete strategy that executed (resolves `Auto`).
    pub mapping: Mapping,
    /// The auto-mapping decision, when the request asked for
    /// [`Mapping::Auto`].
    pub auto: Option<AutoDecision>,
    /// Host cycles charged for the ReLU (0 unless requested).
    pub relu_cycles: u64,
    /// Energy charged for the ReLU, µJ (0 unless requested).
    pub relu_energy_uj: f64,
}

impl ConvResult {
    /// End-to-end latency including the ReLU, cycles.
    pub fn total_cycles(&self) -> u64 {
        self.report.latency_cycles + self.relu_cycles
    }

    /// End-to-end energy including the ReLU, µJ.
    pub fn total_energy_uj(&self) -> f64 {
        self.report.energy_uj + self.relu_energy_uj
    }
}

/// What a metrics-only planned submission produces
/// ([`super::Engine::submit_planned`]): the predicted metric row and
/// the same strategy-resolution and ReLU bookkeeping as
/// [`ConvResult`], without ever simulating or materializing an output
/// tensor.
#[derive(Clone, Debug)]
pub struct PlannedResult {
    /// The concrete strategy the plan costs (resolves `Auto`).
    pub mapping: Mapping,
    /// The auto-mapping decision, when the request asked for
    /// [`Mapping::Auto`] (decided by predicted cost).
    pub auto: Option<AutoDecision>,
    /// The cost model's full prediction (latency breakdown + metric
    /// row; excludes the ReLU, like [`ConvResult::report`]).
    pub estimate: crate::planner::CostEstimate,
    /// Host cycles charged for the requested ReLU (0 unless the
    /// request asked for one) — same formula as the execution path.
    pub relu_cycles: u64,
    /// Energy charged for the requested ReLU, µJ.
    pub relu_energy_uj: f64,
}

impl PlannedResult {
    /// Predicted end-to-end latency including the ReLU, cycles
    /// (comparable to [`ConvResult::total_cycles`]).
    pub fn total_cycles(&self) -> u64 {
        self.estimate.cycles() + self.relu_cycles
    }

    /// Predicted end-to-end energy including the ReLU, µJ.
    pub fn total_energy_uj(&self) -> f64 {
        self.estimate.energy_uj() + self.relu_energy_uj
    }
}
