//! **The AOT artifact file format** — `CompiledNet` on disk
//! (DESIGN.md §13).
//!
//! A compiled network is expensive to produce (planner resolution,
//! program building, µop decoding, weight baking) and cheap to replay;
//! this module makes the expensive half a *build step*. The file is:
//!
//! ```text
//! [ magic "CGRART01" | u32 manifest_len | JSON manifest | binary payload ]
//! ```
//!
//! The manifest is human-readable JSON (rendered by [`crate::util::json`]
//! — the crate vendors no serde) carrying the format version, the crate
//! version, the net and session fingerprints, the payload length, and an
//! FNV-1a checksum of the payload. The payload is the compact
//! little-endian encoding of everything [`CompiledNet`] froze at compile
//! time: the deduplicated decoded-program table, the source graph
//! (weights included), per-layer plans with kernels referencing programs
//! by table index, and the arena sizing
//! ([`CompiledNet::wire_encode_body`]).
//!
//! **Invalidation** is the ⊕ of four identities, each checked on load
//! with its own actionable error: the *format version* (this module's
//! constant), the *crate version* (`CARGO_PKG_VERSION` — layouts and
//! charge formulas may change between releases, so artifacts never
//! cross builds), the *net fingerprint* ([`Net::fingerprint`]) and the
//! *session fingerprint* (config ⊕ energy model,
//! [`super::Engine::session_fingerprint`]). The checksum rejects
//! corruption before any payload byte is trusted, and the payload
//! reader ([`crate::util::wire::Reader`]) is bounds-checked throughout,
//! so a hostile file fails with a message, never a panic or a
//! silently-wrong artifact (`tests/artifact.rs`).
//!
//! **Why load is rebuild-free:** the payload stores the *decoded* µop
//! form, the frozen layouts and the baked weight blocks — exactly the
//! structures the warm path replays — so loading is a validated copy,
//! not a compilation. The load path performs zero program builds, zero
//! µop decodes and zero planner calls, pinned by `RunCounters` in
//! `tests/compiled_counters.rs`.

use std::fs;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::conv::{GenConvShape, Weights};
use crate::kernels::Mapping;
use crate::nn::graph::{Layer, Net};
use crate::util::json::{self, Json};
use crate::util::wire::{fnv1a, Reader, Writer};

use super::{CompiledNet, Engine};

/// Version of the on-disk encoding. Bump on any layout change to the
/// manifest or payload; loaders reject other versions outright.
pub const FORMAT_VERSION: u32 = 1;

/// File magic: identifies the container before anything is parsed.
const MAGIC: &[u8; 8] = b"CGRART01";

/// Fixed header size: magic + little-endian `u32` manifest length.
const HEADER_LEN: usize = MAGIC.len() + 4;

/// Identity and size of a serialized artifact — what `cgra compile
/// --out` summarizes and `cgra serve --artifact` prints for operators.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactInfo {
    /// Network name recorded in the artifact.
    pub net: String,
    /// [`Net::fingerprint`] of the compiled graph.
    pub net_fp: u64,
    /// Config ⊕ energy-model fingerprint the artifact was compiled
    /// under.
    pub session_fp: u64,
    /// FNV-1a checksum of the binary payload.
    pub checksum: u64,
    /// Binary payload size in bytes.
    pub payload_bytes: usize,
    /// Whole-file size in bytes (header + manifest + payload).
    pub file_bytes: usize,
    /// Crate version that wrote the artifact.
    pub crate_version: String,
}

/// Serialize an artifact into the full file image (header + manifest +
/// payload).
pub(crate) fn serialize(cn: &CompiledNet) -> Vec<u8> {
    parts(cn).0
}

/// Serialize to `path`, returning the written artifact's identity.
pub(crate) fn save(cn: &CompiledNet, path: &Path) -> Result<ArtifactInfo> {
    let (bytes, info) = parts(cn);
    fs::write(path, &bytes)
        .with_context(|| format!("writing artifact to {}", path.display()))?;
    Ok(info)
}

/// Build the file image and its identity in one pass.
fn parts(cn: &CompiledNet) -> (Vec<u8>, ArtifactInfo) {
    let mut w = Writer::new();
    cn.wire_encode_body(&mut w);
    let payload = w.into_bytes();
    let manifest = manifest_json(cn, &payload).to_string_compact();
    let mut bytes = Vec::with_capacity(HEADER_LEN + manifest.len() + payload.len());
    bytes.extend_from_slice(MAGIC);
    bytes.extend_from_slice(&(manifest.len() as u32).to_le_bytes());
    bytes.extend_from_slice(manifest.as_bytes());
    bytes.extend_from_slice(&payload);
    let info = ArtifactInfo {
        net: cn.name().to_string(),
        net_fp: cn.net().fingerprint(),
        session_fp: cn.session_fp(),
        checksum: fnv1a(&payload),
        payload_bytes: payload.len(),
        file_bytes: bytes.len(),
        crate_version: env!("CARGO_PKG_VERSION").to_string(),
    };
    (bytes, info)
}

/// Load an artifact from `path`, fully validated against `engine`'s
/// session. See the module docs for the validation ladder; every rung
/// has a distinct, actionable error.
pub(crate) fn load(engine: &Engine, path: &Path) -> Result<(CompiledNet, ArtifactInfo)> {
    let bytes = fs::read(path)
        .with_context(|| format!("reading artifact {}", path.display()))?;
    load_bytes(engine, &bytes)
        .with_context(|| format!("loading artifact {}", path.display()))
}

/// [`load`] over an in-memory image.
fn load_bytes(engine: &Engine, bytes: &[u8]) -> Result<(CompiledNet, ArtifactInfo)> {
    // 1. Container shape: magic + manifest length.
    ensure!(
        bytes.len() >= HEADER_LEN,
        "artifact file is {} bytes — too short for the {HEADER_LEN}-byte header",
        bytes.len()
    );
    ensure!(
        &bytes[..MAGIC.len()] == MAGIC,
        "not a CGRA artifact: bad magic {:02x?} (want {:?})",
        &bytes[..MAGIC.len()],
        std::str::from_utf8(MAGIC).unwrap()
    );
    let mlen =
        u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
    ensure!(
        HEADER_LEN + mlen <= bytes.len(),
        "artifact manifest truncated: header promises {mlen} manifest bytes, file holds {}",
        bytes.len() - HEADER_LEN
    );

    // 2. Manifest: parse, then check the version gates before trusting
    //    anything else.
    let mtext = std::str::from_utf8(&bytes[HEADER_LEN..HEADER_LEN + mlen])
        .map_err(|_| anyhow::anyhow!("artifact manifest is not valid UTF-8"))?;
    let m = json::parse(mtext).context("parsing artifact manifest")?;
    let fv = m.req_i64("format_version")?;
    ensure!(
        fv == FORMAT_VERSION as i64,
        "artifact format version {fv}; this build reads version {FORMAT_VERSION} — \
         recompile the artifact with `cgra compile --out`"
    );
    let cv = m.req_str("crate_version")?;
    ensure!(
        cv == env!("CARGO_PKG_VERSION"),
        "artifact written by crate version {cv}; this build is {} — frozen layouts and \
         charges may differ across versions, recompile the artifact",
        env!("CARGO_PKG_VERSION")
    );
    let net_name = m.req_str("net")?.to_string();
    let net_fp = req_hex(&m, "net_fp")?;
    let session_fp = req_hex(&m, "session_fp")?;
    let checksum = req_hex(&m, "checksum")?;
    let payload_len = m.req_i64("payload_len")?;

    // 3. Payload integrity: promised length, then checksum.
    let payload = &bytes[HEADER_LEN + mlen..];
    ensure!(
        payload.len() as i64 == payload_len,
        "artifact payload is {} bytes but the manifest promises {payload_len} — the file \
         is truncated or carries trailing garbage",
        payload.len()
    );
    let computed = fnv1a(payload);
    ensure!(
        computed == checksum,
        "artifact checksum mismatch: manifest says {checksum:016x}, payload hashes to \
         {computed:016x} — the file is corrupted"
    );

    // 4. Session identity: the frozen layouts and charges are only
    //    valid under the config ⊕ energy model they were compiled for.
    let engine_fp = engine.session_fingerprint();
    ensure!(
        session_fp == engine_fp,
        "artifact '{net_name}' was compiled for session fingerprint {session_fp:016x} but \
         this engine's is {engine_fp:016x} — the CGRA config or energy model differs; \
         recompile the artifact for this session"
    );

    // 5. Decode the payload (bounds-checked throughout; zero builds,
    //    zero decodes) and cross-check the graph identity.
    let mut r = Reader::new(payload);
    let cn = CompiledNet::wire_decode_body(&mut r, engine)
        .context("decoding artifact payload")?;
    r.finish()?;
    let got_fp = cn.net().fingerprint();
    ensure!(
        got_fp == net_fp,
        "artifact manifest names net fingerprint {net_fp:016x} but the payload decodes \
         to {got_fp:016x} — manifest and payload disagree"
    );

    let info = ArtifactInfo {
        net: net_name,
        net_fp,
        session_fp,
        checksum,
        payload_bytes: payload.len(),
        file_bytes: bytes.len(),
        crate_version: cv.to_string(),
    };
    Ok((cn, info))
}

/// Render the manifest for a payload.
fn manifest_json(cn: &CompiledNet, payload: &[u8]) -> Json {
    Json::obj(vec![
        ("format_version", (FORMAT_VERSION as i64).into()),
        ("crate_version", env!("CARGO_PKG_VERSION").into()),
        ("net", cn.name().into()),
        // u64 fingerprints travel as 16-hex-digit strings: the JSON
        // number model is f64, which cannot hold them losslessly.
        ("net_fp", hex16(cn.net().fingerprint()).into()),
        ("session_fp", hex16(cn.session_fp()).into()),
        ("checksum", hex16(fnv1a(payload)).into()),
        ("payload_len", payload.len().into()),
    ])
}

/// Format a fingerprint the way the manifest stores it.
fn hex16(v: u64) -> String {
    format!("{v:016x}")
}

/// Read a required 16-hex-digit fingerprint field.
fn req_hex(m: &Json, key: &str) -> Result<u64> {
    let s = m.req_str(key)?;
    u64::from_str_radix(s, 16)
        .map_err(|_| anyhow::anyhow!("manifest field '{key}' is not a hex fingerprint: {s:?}"))
}

// ---------------------------------------------------------------------------
// Net / Layer codec (the payload's source-graph section)
// ---------------------------------------------------------------------------

/// Serialize the source graph (weights included — they are the baked
/// images' ground truth and what golden verification replays).
pub(crate) fn encode_net(w: &mut Writer, net: &Net) {
    w.str(&net.name);
    w.usize(net.input_dims.0);
    w.usize(net.input_dims.1);
    w.usize(net.input_dims.2);
    w.u32(net.layers.len() as u32);
    for layer in &net.layers {
        match layer {
            Layer::Conv { shape, weights, mapping, relu } => {
                w.u8(1);
                encode_gen_shape(w, shape);
                encode_weights(w, weights);
                w.str(mapping.label());
                w.bool(*relu);
            }
            Layer::Depthwise { shape, weights, relu } => {
                w.u8(2);
                encode_gen_shape(w, shape);
                encode_weights(w, weights);
                w.bool(*relu);
            }
            Layer::Pointwise { shape, weights, mapping, relu } => {
                w.u8(3);
                encode_gen_shape(w, shape);
                encode_weights(w, weights);
                w.str(mapping.label());
                w.bool(*relu);
            }
            Layer::MaxPool { size, stride } => {
                w.u8(4);
                w.usize(*size);
                w.usize(*stride);
            }
            Layer::AvgPool { size, stride } => {
                w.u8(5);
                w.usize(*size);
                w.usize(*stride);
            }
        }
    }
}

/// Deserialize the source graph (validated layer by layer; the caller
/// additionally runs [`Net::validate`] over the whole graph).
pub(crate) fn decode_net(r: &mut Reader) -> Result<Net> {
    let name = r.str()?;
    let input_dims = (r.usize()?, r.usize()?, r.usize()?);
    let n = r.u32()? as usize;
    let mut layers = Vec::with_capacity(n.min(4096));
    for i in 0..n {
        let layer = match r.u8()? {
            1 => {
                let shape = decode_gen_shape(r)?;
                let weights = decode_weights(r)?;
                let mapping = Mapping::parse(&r.str()?)?;
                Layer::Conv { shape, weights, mapping, relu: r.bool()? }
            }
            2 => {
                let shape = decode_gen_shape(r)?;
                let weights = decode_weights(r)?;
                Layer::Depthwise { shape, weights, relu: r.bool()? }
            }
            3 => {
                let shape = decode_gen_shape(r)?;
                let weights = decode_weights(r)?;
                let mapping = Mapping::parse(&r.str()?)?;
                Layer::Pointwise { shape, weights, mapping, relu: r.bool()? }
            }
            4 => Layer::MaxPool { size: r.usize()?, stride: r.usize()? },
            5 => Layer::AvgPool { size: r.usize()?, stride: r.usize()? },
            t => bail!("unknown layer tag {t} for layer {i} of '{name}'"),
        };
        layers.push(layer);
    }
    Ok(Net { name, input_dims, layers })
}

/// Serialize a [`GenConvShape`] (9 dims).
fn encode_gen_shape(w: &mut Writer, s: &GenConvShape) {
    for v in [s.c, s.k, s.ih, s.iw, s.fx, s.fy, s.stride, s.pad, s.groups] {
        w.usize(v);
    }
}

/// Deserialize and re-validate a [`GenConvShape`].
fn decode_gen_shape(r: &mut Reader) -> Result<GenConvShape> {
    let s = GenConvShape {
        c: r.usize()?,
        k: r.usize()?,
        ih: r.usize()?,
        iw: r.usize()?,
        fx: r.usize()?,
        fy: r.usize()?,
        stride: r.usize()?,
        pad: r.usize()?,
        groups: r.usize()?,
    };
    s.validate()?;
    Ok(s)
}

/// Serialize a weight tensor (dims + raw bank).
fn encode_weights(w: &mut Writer, ws: &Weights) {
    w.usize(ws.k);
    w.usize(ws.c);
    w.usize(ws.fy);
    w.usize(ws.fx);
    w.vec_i32(&ws.data);
}

/// Deserialize a weight tensor, checking the dims against the bank
/// length (the constructor asserts; a corrupted file must error).
fn decode_weights(r: &mut Reader) -> Result<Weights> {
    let (k, c, fy, fx) = (r.usize()?, r.usize()?, r.usize()?, r.usize()?);
    let data = r.vec_i32()?;
    let want = k
        .checked_mul(c)
        .and_then(|v| v.checked_mul(fy))
        .and_then(|v| v.checked_mul(fx));
    ensure!(
        want == Some(data.len()),
        "weight bank of {} elements does not match dims ({k}, {c}, {fy}, {fx})",
        data.len()
    );
    Ok(Weights { k, c, fy, fx, data })
}
