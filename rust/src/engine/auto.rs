//! Automatic mapping selection — [`Mapping::Auto`]'s policy, with the
//! decision materialized for reporting.
//!
//! Two policies live here:
//!
//! - [`choose`] — the *static threshold* rule ([`Mapping::resolve`],
//!   `kernels::common`): WP whenever the direct working set fits the
//!   512 KiB bound. It lives with the `Mapping` enum so every layer
//!   below the engine (sweep, dispatch) can resolve `Auto` without an
//!   upward dependency, and it is the differential baseline the cost
//!   model is tested against.
//! - [`choose_planned`] — the *cost-model* rule the engine actually
//!   uses since the planner landed: predict every in-bound CGRA
//!   mapping's latency through [`Planner::choose`] and take the
//!   cheapest. On the paper's grid the two policies agree (WP wins
//!   everywhere — enforced by `tests/planner.rs`); the threshold rule
//!   remains the fallback if the planner cannot estimate.

use anyhow::Result;

use crate::cgra::CgraConfig;
use crate::conv::ConvShape;
use crate::kernels::Mapping;
use crate::planner::Planner;

/// A recorded auto-mapping decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AutoDecision {
    /// The concrete strategy chosen.
    pub mapping: Mapping,
    /// Why (one of the policy's fixed reasons).
    pub reason: &'static str,
}

impl std::fmt::Display for AutoDecision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "auto -> {} ({})", self.mapping.label(), self.reason)
    }
}

/// Choose the mapping for a shape per the paper's finding: Conv-WP
/// whenever the direct working set fits the 512 KiB bound, Im2col-OP
/// when only the im2col route fits, an actionable error when nothing
/// does. See [`Mapping::resolve`] for the full policy text.
pub fn choose(shape: &ConvShape, cfg: &CgraConfig) -> Result<AutoDecision> {
    let (mapping, reason) = Mapping::Auto.resolve(shape, cfg)?;
    Ok(AutoDecision { mapping, reason })
}

/// Why the cost model picked its mapping (see [`choose_planned`];
/// `pub(crate)` so the artifact codec can round-trip the `&'static str`
/// by tag).
pub(crate) const AUTO_REASON_COST: &str =
    "cost model predicts the lowest latency among mappings that fit the memory bound";

/// Cost-model-backed strategy choice — the upgraded `Mapping::Auto`
/// policy the engine uses: predict every in-bound CGRA mapping via the
/// planner and take the lowest predicted latency. Falls back to the
/// static threshold rule ([`choose`]) if the planner cannot estimate;
/// when nothing fits the memory bound, the resolver's actionable
/// dual-route error is propagated.
pub fn choose_planned(planner: &Planner, shape: &ConvShape, cfg: &CgraConfig) -> Result<AutoDecision> {
    if let Ok(est) = planner.choose(shape) {
        return Ok(AutoDecision { mapping: est.mapping, reason: AUTO_REASON_COST });
    }
    // Differential fallback: the pre-planner threshold policy (also the
    // path that reports the over-bound error).
    let (mapping, reason) = Mapping::Auto.resolve(shape, cfg)?;
    Ok(AutoDecision { mapping, reason })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chooses_wp_for_paper_grid_shapes() {
        let cfg = CgraConfig::default();
        // In-bound shapes across the paper's Fig. 5 axes: Auto must
        // follow the paper's "WP wins everywhere" conclusion. (The
        // spatial extreme 64×64 at C=K=16 exceeds the 512 KiB bound —
        // the sweep records it as skipped, and `choose` errors on it.)
        for (c, k, o) in [(16, 16, 16), (144, 16, 16), (16, 144, 16), (16, 16, 48)] {
            let d = choose(&ConvShape::new3x3(c, k, o, o), &cfg).unwrap();
            assert_eq!(d.mapping, Mapping::Wp, "C={c} K={k} O={o}");
        }
    }

    #[test]
    fn errors_past_the_memory_bound() {
        let err = choose(&ConvShape::new3x3(144, 144, 64, 64), &CgraConfig::default())
            .unwrap_err();
        assert!(format!("{err:#}").contains("512"));
    }

    #[test]
    fn decision_displays_reason() {
        let d = choose(&ConvShape::baseline(), &CgraConfig::default()).unwrap();
        let s = d.to_string();
        assert!(s.contains("Conv-WP") && s.contains("auto ->"), "{s}");
    }

    #[test]
    fn planned_choice_matches_threshold_on_paper_shapes() {
        let cfg = CgraConfig::default();
        let planner = Planner::new(&cfg, &crate::energy::EnergyModel::default()).unwrap();
        for (c, k, o) in [(16, 16, 16), (32, 16, 16), (16, 48, 16)] {
            let shape = ConvShape::new3x3(c, k, o, o);
            let planned = choose_planned(&planner, &shape, &cfg).unwrap();
            let threshold = choose(&shape, &cfg).unwrap();
            assert_eq!(planned.mapping, threshold.mapping, "C={c} K={k} O={o}");
            assert_eq!(planned.mapping, Mapping::Wp, "C={c} K={k} O={o}");
            assert!(planned.reason.contains("cost model"), "{}", planned.reason);
        }
    }

    #[test]
    fn planned_choice_propagates_the_bound_error() {
        let cfg = CgraConfig::default();
        let planner = Planner::new(&cfg, &crate::energy::EnergyModel::default()).unwrap();
        let err = choose_planned(&planner, &ConvShape::new3x3(144, 144, 64, 64), &cfg)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("KiB") && msg.contains("im2col route"), "{msg}");
    }
}
