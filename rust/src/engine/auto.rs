//! Automatic mapping selection — [`Mapping::Auto`]'s policy, with the
//! decision materialized for reporting.
//!
//! The policy itself lives with the `Mapping` enum
//! ([`Mapping::resolve`], `kernels::common`) so every layer below the
//! engine can resolve `Auto` without an upward dependency; this module
//! is the engine-level front door that callers and results speak.

use anyhow::Result;

use crate::cgra::CgraConfig;
use crate::conv::ConvShape;
use crate::kernels::Mapping;

/// A recorded auto-mapping decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AutoDecision {
    /// The concrete strategy chosen.
    pub mapping: Mapping,
    /// Why (one of the policy's fixed reasons).
    pub reason: &'static str,
}

impl std::fmt::Display for AutoDecision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "auto -> {} ({})", self.mapping.label(), self.reason)
    }
}

/// Choose the mapping for a shape per the paper's finding: Conv-WP
/// whenever the direct working set fits the 512 KiB bound, Im2col-OP
/// when only the im2col route fits, an actionable error when nothing
/// does. See [`Mapping::resolve`] for the full policy text.
pub fn choose(shape: &ConvShape, cfg: &CgraConfig) -> Result<AutoDecision> {
    let (mapping, reason) = Mapping::Auto.resolve(shape, cfg)?;
    Ok(AutoDecision { mapping, reason })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chooses_wp_for_paper_grid_shapes() {
        let cfg = CgraConfig::default();
        // In-bound shapes across the paper's Fig. 5 axes: Auto must
        // follow the paper's "WP wins everywhere" conclusion. (The
        // spatial extreme 64×64 at C=K=16 exceeds the 512 KiB bound —
        // the sweep records it as skipped, and `choose` errors on it.)
        for (c, k, o) in [(16, 16, 16), (144, 16, 16), (16, 144, 16), (16, 16, 48)] {
            let d = choose(&ConvShape::new3x3(c, k, o, o), &cfg).unwrap();
            assert_eq!(d.mapping, Mapping::Wp, "C={c} K={k} O={o}");
        }
    }

    #[test]
    fn errors_past_the_memory_bound() {
        let err = choose(&ConvShape::new3x3(144, 144, 64, 64), &CgraConfig::default())
            .unwrap_err();
        assert!(format!("{err:#}").contains("512"));
    }

    #[test]
    fn decision_displays_reason() {
        let d = choose(&ConvShape::baseline(), &CgraConfig::default()).unwrap();
        let s = d.to_string();
        assert!(s.contains("Conv-WP") && s.contains("auto ->"), "{s}");
    }
}
