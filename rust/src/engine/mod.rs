//! The session-based front door of the crate.
//!
//! Everything the pre-0.2 free-function entry points re-threaded by
//! hand — simulator config, energy model, worker pool width, the
//! sweep-point cache — is owned once by an [`Engine`], built via
//! [`EngineBuilder`]:
//!
//! ```no_run
//! use openedge_cgra::conv::ConvShape;
//! use openedge_cgra::engine::{ConvRequest, EngineBuilder};
//! use openedge_cgra::kernels::Mapping;
//!
//! # fn main() -> anyhow::Result<()> {
//! let engine = EngineBuilder::new().build()?;
//! let req = ConvRequest::seeded(ConvShape::baseline(), Mapping::Auto, 42);
//! let res = engine.submit(&req)?;
//! println!(
//!     "{} in {} cycles ({}){}",
//!     res.mapping,
//!     res.report.latency_cycles,
//!     res.auto.unwrap(),
//!     if res.cache_hit { " [cache hit]" } else { "" },
//! );
//! # Ok(())
//! # }
//! ```
//!
//! The request/response surface is typed: a [`ConvRequest`] names the
//! shape, the strategy (concrete or [`Mapping::Auto`]), the data source
//! (deterministic seed or caller tensors) and an optional host-side
//! ReLU; a [`ConvResult`] carries the output tensor, the paper's
//! [`MappingReport`] metric row, the cache-hit flag and the recorded
//! auto-mapping decision. [`Engine::submit_batch`] fans a slice of
//! requests over the worker pool, order-preserving and
//! cache-consulting; [`Engine::run_network`] chains a [`ConvNet`]
//! layer-by-layer; [`Engine::sweep`] and [`Engine::run_all_mappings`]
//! drive the figure protocols.
//!
//! For repeated inference traffic, [`Engine::compile`] turns a network
//! into a reusable [`CompiledNet`] artifact — mappings frozen,
//! programs pre-decoded, arena pre-sized — whose warm
//! [`CompiledNet::run`] does zero compile-side work (see
//! [`compiled`]). `run_network` and the `nn` executor route through
//! the same compiled steps, so the crate has exactly one lowering
//! path. For bulk traffic, [`CompiledNet::run_batch`] replays one
//! shared µop walk across up to `B` independent inference lanes in a
//! [`BatchCtx`] (DESIGN.md §9) — same modeled numbers per inference,
//! a fraction of the host replay cost.

pub mod artifact;
pub mod auto;
pub mod compiled;
mod request;

pub use artifact::ArtifactInfo;
pub use auto::{choose, choose_planned, AutoDecision};
pub use compiled::{
    BatchCtx, CompiledNet, InferRun, LayerInfo, LayerRun, NetCtx, RunCounters,
};
pub use request::{
    ConvRequest, ConvResult, PlannedResult, RequestData, DEFAULT_INPUT_MAG, DEFAULT_WEIGHT_MAG,
};

use anyhow::{bail, ensure, Result};

use crate::cgra::{Cgra, CgraConfig};
use crate::conv::{
    conv2d, depthwise2d, random_depthwise_weights, random_input, random_weights, ConvShape,
    TensorChw, Weights,
};
use crate::coordinator::cache::{self, CacheStats, CachedOutcome, PointCache, PointKey};
use crate::coordinator::network::{ConvNet, NetworkOutcome};
use crate::coordinator::pool::{default_workers, run_jobs};
use crate::coordinator::sweep::{run_sweep_with_model, SweepRow, SweepSpec};
use crate::energy::EnergyModel;
use crate::kernels::{dispatch, Mapping};
use crate::metrics::MappingReport;
use crate::planner::{CostEstimate, NetworkPlan, PlanObjective, Planner};
use crate::prop::Rng;

/// Host-side ReLU cost: one load + compare + store per element.
const RELU_CYCLES_PER_ELEM: u64 = 3;

/// Which point cache an engine consults.
enum CacheChoice {
    /// The process-wide cache shared with every other engine (the
    /// default).
    Global,
    /// An engine-private cache (isolation for tests and benches).
    Private(PointCache),
}

/// Builder for [`Engine`] — every knob has the calibrated default.
pub struct EngineBuilder {
    cfg: CgraConfig,
    model: EnergyModel,
    workers: usize,
    private_cache: bool,
}

impl EngineBuilder {
    /// Defaults: calibrated [`CgraConfig`], calibrated [`EnergyModel`],
    /// [`default_workers`] threads, the process-wide point cache.
    pub fn new() -> EngineBuilder {
        EngineBuilder {
            cfg: CgraConfig::default(),
            model: EnergyModel::default(),
            workers: default_workers(),
            private_cache: false,
        }
    }

    /// Use a specific simulator configuration (ablations, tiny-memory
    /// tests). The cache key fingerprints both the config and the
    /// energy model, so engines with different configs or models never
    /// cross-contaminate even on the shared global cache.
    pub fn config(mut self, cfg: CgraConfig) -> EngineBuilder {
        self.cfg = cfg;
        self
    }

    /// Use a specific energy model.
    pub fn energy_model(mut self, model: EnergyModel) -> EngineBuilder {
        self.model = model;
        self
    }

    /// Worker threads for `submit_batch` / `sweep` (clamped to ≥ 1).
    pub fn workers(mut self, workers: usize) -> EngineBuilder {
        self.workers = workers;
        self
    }

    /// Give the engine its own isolated point cache instead of the
    /// process-wide one.
    pub fn private_cache(mut self) -> EngineBuilder {
        self.private_cache = true;
        self
    }

    /// Validate the configuration and build the engine.
    pub fn build(self) -> Result<Engine> {
        let key_fp = cache::cfg_fingerprint(&self.cfg) ^ cache::energy_fingerprint(&self.model);
        let planner = Planner::new(&self.cfg, &self.model)?;
        let cgra = Cgra::new(self.cfg)?;
        Ok(Engine {
            key_fp,
            cgra,
            planner,
            model: self.model,
            workers: self.workers.max(1),
            cache: if self.private_cache {
                CacheChoice::Private(PointCache::default())
            } else {
                CacheChoice::Global
            },
        })
    }
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder::new()
    }
}

/// A convolution-execution session: owns the simulator, energy model,
/// worker-pool width and point cache, and serves typed requests.
///
/// `Engine` is `Sync` — one instance is shared by every pool worker —
/// and all methods take `&self`, so a single engine can back an entire
/// process (CLI run, figure regeneration, benches) at once.
pub struct Engine {
    /// Combined config + energy-model fingerprint for cache keys.
    key_fp: u64,
    cgra: Cgra,
    /// The analytical cost model sharing this session's config and
    /// energy model: backs `Mapping::Auto` decisions and the
    /// metrics-only `plan`/`submit_planned` surface.
    planner: Planner,
    model: EnergyModel,
    workers: usize,
    cache: CacheChoice,
}

impl Engine {
    /// Start building an engine.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// The simulator configuration this session runs under (the one
    /// source of truth lives inside the simulator).
    pub fn config(&self) -> &CgraConfig {
        self.cgra.config()
    }

    /// The underlying simulator (for program-level work, e.g. the `asm`
    /// subcommand).
    pub fn cgra(&self) -> &Cgra {
        &self.cgra
    }

    /// The energy model applied to every outcome.
    pub fn energy_model(&self) -> &EnergyModel {
        &self.model
    }

    /// The combined config ⊕ energy-model fingerprint identifying this
    /// session for cache keys
    /// ([`cache::cfg_fingerprint`] `^` [`cache::energy_fingerprint`] —
    /// the same isolation machinery the point cache uses). Two engines
    /// may share compiled artifacts iff their fingerprints are equal;
    /// the serving daemon's artifact registry keys on this so tenants
    /// with different energy models never cross-hit.
    pub fn session_fingerprint(&self) -> u64 {
        self.key_fp
    }

    /// Worker threads used by the batched entry points.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The point cache this engine consults (global by default).
    pub fn cache(&self) -> &PointCache {
        match &self.cache {
            CacheChoice::Global => cache::global(),
            CacheChoice::Private(pc) => pc,
        }
    }

    /// Counter snapshot of the engine's point cache.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache().stats()
    }

    /// Execute one convolution request.
    ///
    /// Seeded requests consult the point cache first: on a hit the
    /// metrics come from the cache and the output tensor is
    /// reconstructed through the golden model (bit-exact vs the
    /// simulator — the invariant every kernel test enforces), so a hit
    /// costs one CPU convolution instead of a cycle-level simulation.
    /// Requests over caller tensors always simulate.
    pub fn submit(&self, req: &ConvRequest) -> Result<ConvResult> {
        match &req.data {
            RequestData::Tensors { input, weights } => {
                self.run_one(&req.shape, req.mapping, req.relu, input, weights)
            }
            RequestData::Seed { seed, in_mag, w_mag } => {
                let auto = self.auto_for(&req.shape, req.mapping)?;
                let mapping = auto.map(|d| d.mapping).unwrap_or(req.mapping);
                let (report, cache_hit, simulated) =
                    self.seeded_exec(&req.shape, mapping, *seed, *in_mag, *w_mag)?;
                let mut output = match simulated {
                    Some(out) => out,
                    // Cache hit: reconstruct the output through the
                    // golden model (bit-exact vs the simulator — the
                    // invariant every kernel test enforces), one CPU
                    // convolution instead of a cycle-level simulation.
                    None => {
                        let (input, weights) =
                            seeded_tensors(&req.shape, mapping, *seed, *in_mag, *w_mag);
                        if mapping == Mapping::DwWp {
                            depthwise2d(&req.shape, &input, &weights)
                        } else {
                            conv2d(&req.shape, &input, &weights)
                        }
                    }
                };
                let (relu_cycles, relu_energy_uj) = self.apply_relu(req.relu, &mut output);
                Ok(ConvResult {
                    output,
                    report,
                    cache_hit,
                    mapping,
                    auto,
                    relu_cycles,
                    relu_energy_uj,
                })
            }
        }
    }

    /// Metrics-only submission: like [`Engine::submit`] but never
    /// materializes the output tensor, so a cache hit is a pure lookup.
    /// The figure drivers ([`Engine::run_all_mappings`]) use this.
    /// Returns the metric row and the cache-hit flag.
    pub fn submit_report(&self, req: &ConvRequest) -> Result<(MappingReport, bool)> {
        match &req.data {
            RequestData::Tensors { .. } => self.submit(req).map(|res| (res.report, false)),
            RequestData::Seed { seed, in_mag, w_mag } => {
                let auto = self.auto_for(&req.shape, req.mapping)?;
                let mapping = auto.map(|d| d.mapping).unwrap_or(req.mapping);
                let (report, cache_hit, _simulated) =
                    self.seeded_exec(&req.shape, mapping, *seed, *in_mag, *w_mag)?;
                Ok((report, cache_hit))
            }
        }
    }

    /// Resolve the auto-mapping decision for a submission (`None` for
    /// concrete mappings), after validating the shape. The single
    /// resolve-then-record sequence shared by every execution path.
    /// Since the planner landed, `Auto` is decided by predicted cost
    /// ([`auto::choose_planned`]); the static threshold rule remains
    /// the differential fallback.
    fn auto_for(&self, shape: &ConvShape, mapping: Mapping) -> Result<Option<AutoDecision>> {
        shape.validate()?;
        if mapping.is_auto() {
            Ok(Some(auto::choose_planned(&self.planner, shape, self.config())?))
        } else {
            Ok(None)
        }
    }

    /// Seed-protocol core shared by [`Engine::submit`] and
    /// [`Engine::submit_report`]: consult the point cache under the
    /// concrete mapping's key, simulate on a miss, memoize the result
    /// (skips included). Returns the metric row, the cache-hit flag,
    /// and the simulated output when a simulation actually ran.
    fn seeded_exec(
        &self,
        shape: &ConvShape,
        mapping: Mapping,
        seed: u64,
        in_mag: i32,
        w_mag: i32,
    ) -> Result<(MappingReport, bool, Option<TensorChw>)> {
        let key = PointKey { mapping, shape: *shape, in_mag, w_mag, seed, cfg_fp: self.key_fp };
        if let Some(hit) = self.cache().get(&key) {
            return match hit {
                CachedOutcome::Report(report) => Ok((report, true, None)),
                CachedOutcome::Skipped(s) => bail!("{s}"),
            };
        }
        let (input, weights) = seeded_tensors(shape, mapping, seed, in_mag, w_mag);
        match dispatch(&self.cgra, mapping, shape, &input, &weights) {
            Ok(out) => {
                let report = MappingReport::from_outcome(&out, &self.model);
                self.cache().insert(key, CachedOutcome::Report(report.clone()));
                Ok((report, false, Some(out.output)))
            }
            Err(e) => {
                // Deterministic failure (memory bound / invalid
                // config): cache the skip like the sweep does.
                self.cache().insert(key, CachedOutcome::Skipped(format!("{e:#}")));
                Err(e)
            }
        }
    }

    /// The uncached borrow-based execution path behind the `Tensors`
    /// arm of [`Engine::submit`] (network execution routes through
    /// [`CompiledNet`] instead since the compile-once refactor).
    pub(crate) fn run_one(
        &self,
        shape: &ConvShape,
        mapping: Mapping,
        relu: bool,
        input: &TensorChw,
        weights: &crate::conv::Weights,
    ) -> Result<ConvResult> {
        let auto = self.auto_for(shape, mapping)?;
        let mapping = auto.map(|d| d.mapping).unwrap_or(mapping);
        ensure!(
            input.data.len() == shape.input_elems(),
            "input tensor has {} elements, shape {} needs {}",
            input.data.len(),
            shape,
            shape.input_elems()
        );
        // The depthwise operator carries one single-channel filter per
        // channel; the dense mappings carry the full K×C filter bank.
        let expected_w = if mapping == Mapping::DwWp {
            shape.k * shape.fx * shape.fy
        } else {
            shape.weight_elems()
        };
        ensure!(
            weights.data.len() == expected_w,
            "weight tensor has {} elements, {} on shape {} needs {}",
            weights.data.len(),
            mapping,
            shape,
            expected_w
        );
        let out = dispatch(&self.cgra, mapping, shape, input, weights)?;
        let report = MappingReport::from_outcome(&out, &self.model);
        let mut output = out.output;
        let (relu_cycles, relu_energy_uj) = self.apply_relu(relu, &mut output);
        Ok(ConvResult {
            output,
            report,
            cache_hit: false,
            mapping,
            auto,
            relu_cycles,
            relu_energy_uj,
        })
    }

    /// Execute a batch of requests across the worker pool.
    ///
    /// Order-preserving (results come back in request order regardless
    /// of worker count) and cache-consulting (each request goes through
    /// the same lookup as [`Engine::submit`]); per-request failures do
    /// not abort the rest of the batch.
    pub fn submit_batch(&self, reqs: &[ConvRequest]) -> Vec<Result<ConvResult>> {
        let jobs: Vec<_> = reqs.iter().map(|req| move || self.submit(req)).collect();
        run_jobs(self.workers, jobs)
    }

    /// Run a feed-forward CNN layer by layer, chaining activations and
    /// charging host-side ReLUs, exactly like the paper's end-to-end
    /// experiment (E7).
    ///
    /// Since the compile-once refactor this routes through the same
    /// compiled steps as everything else: the network is compiled
    /// ([`Engine::compile_conv_net`]) and run once. Callers serving
    /// repeated traffic should hold the [`CompiledNet`] themselves and
    /// amortize the compile across inferences — parallelism now lives
    /// *across* inferences (one `Arc<CompiledNet>`, one [`NetCtx`] per
    /// worker), not inside one.
    pub fn run_network(&self, net: &ConvNet, input: &TensorChw) -> Result<NetworkOutcome> {
        let compiled = self.compile_conv_net(net)?;
        let mut ctx = compiled.new_ctx();
        ctx.collect_reports(true);
        let run = compiled.run(&mut ctx, input)?;
        let layers = run
            .layers
            .into_iter()
            .map(|l| l.report.expect("ConvNet layers are single-group convolutions"))
            .collect();
        Ok(NetworkOutcome {
            layers,
            output: ctx.output().clone(),
            total_cycles: run.total_cycles,
            total_energy_uj: run.total_energy_uj,
            relu_cycles: run.relu_cycles,
        })
    }

    /// Run all five strategies on one shape (batched over the pool) and
    /// return the metric rows in [`Mapping::ALL`] order — the Fig. 3/4
    /// protocol (seeded data at the figure magnitudes). Metrics-only:
    /// warm-cache regenerations are pure lookups
    /// (see [`Engine::submit_report`]).
    pub fn run_all_mappings(&self, shape: &ConvShape, seed: u64) -> Result<Vec<MappingReport>> {
        let reqs: Vec<ConvRequest> =
            Mapping::ALL.into_iter().map(|m| ConvRequest::seeded(*shape, m, seed)).collect();
        let jobs: Vec<_> = reqs.iter().map(|req| move || self.submit_report(req)).collect();
        run_jobs(self.workers, jobs).into_iter().map(|r| r.map(|(report, _)| report)).collect()
    }

    /// Run a Figure-5 hyper-parameter sweep through this session's
    /// config, workers and cache (rows in `spec.points()` order,
    /// memory-bound points recorded as skips).
    ///
    /// `Mapping::Auto` points resolve through the *static threshold*
    /// rule ([`Mapping::resolve`]), not the cost model — deliberately,
    /// so the sweep that generates the planner's validation ground
    /// truth never depends on the model it validates. Off the paper's
    /// grid the two policies can differ; `submit` executes the
    /// cost-based choice.
    pub fn sweep(&self, spec: &SweepSpec) -> Result<Vec<SweepRow>> {
        run_sweep_with_model(spec, self.config(), &self.model, self.workers, self.cache())
    }

    /// Apply the host-side ReLU in place and return its (cycles, µJ)
    /// accounting — the CNN runner's cost model.
    fn apply_relu(&self, on: bool, t: &mut TensorChw) -> (u64, f64) {
        if !on {
            return (0, 0.0);
        }
        for v in t.data.iter_mut() {
            *v = (*v).max(0);
        }
        relu_cost(&self.model, t.data.len())
    }

    /// The cost-model planner sharing this session's configuration and
    /// energy model (estimates are memoized per shape × mapping).
    pub fn planner(&self) -> &Planner {
        &self.planner
    }

    /// Predict one `(shape, mapping)` cost point without simulating the
    /// convolution. `Mapping::Auto` is resolved by predicted cost
    /// ([`Planner::choose`]); concrete mappings estimate directly.
    /// First call per point runs microsecond calibration probes;
    /// repeats are nanosecond memo lookups.
    pub fn plan(&self, shape: &ConvShape, mapping: Mapping) -> Result<CostEstimate> {
        if mapping.is_auto() {
            self.planner.choose(shape)
        } else {
            self.planner.estimate(shape, mapping)
        }
    }

    /// Metrics-only sibling of [`Engine::submit`]: answer a request
    /// from the cost model instead of the simulator — same auto-mapping
    /// resolution, decision recording and host-ReLU charging, no
    /// simulation, no output tensor. The request's data source is
    /// irrelevant (kernel timing is data-independent), so seeded and
    /// tensor requests plan alike.
    pub fn submit_planned(&self, req: &ConvRequest) -> Result<PlannedResult> {
        let auto = self.auto_for(&req.shape, req.mapping)?;
        let mapping = auto.map(|d| d.mapping).unwrap_or(req.mapping);
        let estimate = self.planner.estimate(&req.shape, mapping)?;
        let (relu_cycles, relu_energy_uj) = if req.relu {
            relu_cost(&self.model, req.shape.output_elems())
        } else {
            (0, 0.0)
        };
        Ok(PlannedResult { mapping, auto, estimate, relu_cycles, relu_energy_uj })
    }

    /// Choose a mapping per layer of `net` by predicted cost under the
    /// memory bound and return the plan (apply it with
    /// [`NetworkPlan::apply`], then execute via
    /// [`Engine::run_network`]).
    pub fn plan_network(&self, net: &ConvNet, objective: PlanObjective) -> Result<NetworkPlan> {
        crate::planner::plan_network(&self.planner, net, objective)
    }
}

/// The deterministic seeded tensors of a request: input then weights
/// drawn from one `Rng::new(seed)` stream. Depthwise submissions draw
/// the `(K, 1, 3, 3)` filter bank the Dw-WP kernel consumes; every
/// other mapping draws the dense `(K, C, 3, 3)` bank. Shared by the
/// simulate path and the cache-hit golden reconstruction so both see
/// identical data.
fn seeded_tensors(
    shape: &ConvShape,
    mapping: Mapping,
    seed: u64,
    in_mag: i32,
    w_mag: i32,
) -> (TensorChw, Weights) {
    let mut rng = Rng::new(seed);
    let input = random_input(shape, in_mag, &mut rng);
    let weights = if mapping == Mapping::DwWp {
        random_depthwise_weights(shape, w_mag, &mut rng)
    } else {
        random_weights(shape, w_mag, &mut rng)
    };
    (input, weights)
}

/// Host-side ReLU cost — one load + compare + store per element at
/// [`RELU_CYCLES_PER_ELEM`], CPU-active + memory power over that time
/// plus two memory accesses per element. Shared by the execution path
/// ([`Engine::run_network`]) and the planner so predicted and simulated
/// network totals use the identical formula.
pub(crate) fn relu_cost(model: &EnergyModel, elems: usize) -> (u64, f64) {
    let cycles = RELU_CYCLES_PER_ELEM * elems as u64;
    let t_s = cycles as f64 / model.clock_hz;
    let uj = (model.p_cpu_active_mw + model.p_mem_static_mw) * t_s * 1e3
        + 2.0 * elems as f64 * model.e_mem_access_pj * 1e-6;
    (cycles, uj)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_engine() -> Engine {
        EngineBuilder::new().workers(2).private_cache().build().unwrap()
    }

    #[test]
    fn session_fingerprint_tracks_config_and_model() {
        let a = EngineBuilder::new().build().unwrap();
        let b = EngineBuilder::new().build().unwrap();
        assert_eq!(a.session_fingerprint(), b.session_fingerprint());
        let mut hot = EnergyModel::default();
        hot.e_mem_access_pj *= 2.0;
        let c = EngineBuilder::new().energy_model(hot).build().unwrap();
        assert_ne!(a.session_fingerprint(), c.session_fingerprint());
        let mut cfg = CgraConfig::default();
        cfg.mem_latency += 1;
        let d = EngineBuilder::new().config(cfg).build().unwrap();
        assert_ne!(a.session_fingerprint(), d.session_fingerprint());
    }

    #[test]
    fn builder_defaults_and_accessors() {
        let e = EngineBuilder::new().build().unwrap();
        assert!(e.workers() >= 1);
        assert_eq!(e.config().mem_words, CgraConfig::default().mem_words);
        // Zero workers clamp to one.
        let e1 = EngineBuilder::new().workers(0).build().unwrap();
        assert_eq!(e1.workers(), 1);
    }

    #[test]
    fn seeded_submit_caches_and_flags_hits() {
        let e = quick_engine();
        let req = ConvRequest::seeded(ConvShape::new3x3(3, 4, 5, 5), Mapping::Wp, 7);
        let a = e.submit(&req).unwrap();
        assert!(!a.cache_hit);
        let b = e.submit(&req).unwrap();
        assert!(b.cache_hit, "second submission must hit the cache");
        // Cached metrics and golden-reconstructed output are identical
        // to the simulated ones.
        assert_eq!(a.output.data, b.output.data);
        assert_eq!(a.report.latency_cycles, b.report.latency_cycles);
        assert_eq!(a.report.energy_uj.to_bits(), b.report.energy_uj.to_bits());
        let s = e.cache_stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn tensor_requests_are_never_cached() {
        let e = quick_engine();
        let shape = ConvShape::new3x3(2, 2, 3, 3);
        let mut rng = Rng::new(5);
        let input = random_input(&shape, 10, &mut rng);
        let weights = random_weights(&shape, 5, &mut rng);
        let req = ConvRequest::with_data(shape, Mapping::Wp, input, weights);
        assert!(!e.submit(&req).unwrap().cache_hit);
        assert!(!e.submit(&req).unwrap().cache_hit);
        assert_eq!(e.cache_stats().entries, 0);
    }

    #[test]
    fn auto_decision_is_recorded() {
        let e = quick_engine();
        let res =
            e.submit(&ConvRequest::seeded(ConvShape::baseline(), Mapping::Auto, 3)).unwrap();
        assert_eq!(res.mapping, Mapping::Wp);
        let d = res.auto.expect("auto decision recorded");
        assert_eq!(d.mapping, Mapping::Wp);
        assert_eq!(res.report.mapping, Mapping::Wp, "report names the concrete strategy");
        // An explicit request records no decision.
        let res2 = e.submit(&ConvRequest::seeded(ConvShape::baseline(), Mapping::Wp, 3)).unwrap();
        assert!(res2.auto.is_none());
        assert!(res2.cache_hit, "auto and explicit WP share one cache entry");
    }

    #[test]
    fn relu_is_applied_and_charged() {
        let e = quick_engine();
        let shape = ConvShape::new3x3(2, 2, 3, 3);
        let mut rng = Rng::new(6);
        // All-one input with all-negative weights forces every
        // pre-activation negative.
        let input = TensorChw::from_vec(
            shape.c,
            shape.ih(),
            shape.iw(),
            vec![1; shape.input_elems()],
        );
        let mut weights = random_weights(&shape, 5, &mut rng);
        for w in weights.data.iter_mut() {
            *w = -(w.abs() + 1);
        }
        let base = ConvRequest::with_data(shape, Mapping::Wp, input.clone(), weights.clone());
        let plain = e.submit(&base).unwrap();
        let relued = e.submit(&base.clone().relu(true)).unwrap();
        assert!(plain.output.data.iter().any(|&v| v < 0));
        assert!(relued.output.data.iter().all(|&v| v >= 0));
        assert_eq!(relued.relu_cycles, 3 * shape.output_elems() as u64);
        assert!(relued.relu_energy_uj > 0.0);
        assert_eq!(plain.relu_cycles, 0);
        assert_eq!(relued.total_cycles(), relued.report.latency_cycles + relued.relu_cycles);
    }

    /// Seeded depthwise submissions simulate the Dw-WP kernel, cache
    /// under the DwWp key, and reconstruct cache-hit outputs through
    /// the depthwise golden model bit-exactly.
    #[test]
    fn seeded_depthwise_submits_cache_and_reconstruct() {
        let e = quick_engine();
        let shape = ConvShape::new3x3(5, 5, 6, 6);
        let req = ConvRequest::seeded(shape, Mapping::DwWp, 13);
        let a = e.submit(&req).unwrap();
        assert!(!a.cache_hit);
        assert_eq!(a.mapping, Mapping::DwWp);
        assert_eq!(a.report.launches, 5, "one launch per channel");
        let b = e.submit(&req).unwrap();
        assert!(b.cache_hit);
        assert_eq!(a.output.data, b.output.data, "golden reconstruction must match the sim");
        // A dense WP request on the same shape/seed is a distinct
        // cache entry (different operator, different key).
        let dense = e.submit(&ConvRequest::seeded(shape, Mapping::Wp, 13)).unwrap();
        assert!(!dense.cache_hit);
        assert_ne!(dense.output.data, a.output.data);
    }

    /// Depthwise tensor requests enforce the (K, 1, 3, 3) weight bank.
    #[test]
    fn depthwise_tensor_request_checks_weight_dims() {
        let e = quick_engine();
        let shape = ConvShape::new3x3(4, 4, 5, 5);
        let mut rng = Rng::new(3);
        let input = random_input(&shape, 10, &mut rng);
        let dw = crate::conv::random_depthwise_weights(&shape, 5, &mut rng);
        let golden = depthwise2d(&shape, &input, &dw);
        let res = e
            .submit(&ConvRequest::with_data(shape, Mapping::DwWp, input.clone(), dw))
            .unwrap();
        assert_eq!(res.output.data, golden.data);
        // Dense weights are rejected with the expected count named.
        let dense_w = random_weights(&shape, 5, &mut rng);
        let err = format!(
            "{:#}",
            e.submit(&ConvRequest::with_data(shape, Mapping::DwWp, input, dense_w))
                .unwrap_err()
        );
        assert!(err.contains("needs 36"), "{err}");
    }

    #[test]
    fn mismatched_tensor_sizes_rejected() {
        let e = quick_engine();
        let shape = ConvShape::new3x3(2, 2, 3, 3);
        let mut rng = Rng::new(8);
        let input = random_input(&ConvShape::new3x3(3, 2, 3, 3), 5, &mut rng); // wrong C
        let weights = random_weights(&shape, 5, &mut rng);
        let err = e.submit(&ConvRequest::with_data(shape, Mapping::Wp, input, weights));
        assert!(err.is_err());
    }

    #[test]
    fn oversized_seeded_request_errors_and_caches_the_skip() {
        let e = quick_engine();
        let req = ConvRequest::seeded(ConvShape::new3x3(16, 16, 64, 64), Mapping::Wp, 1);
        let e1 = format!("{:#}", e.submit(&req).unwrap_err());
        assert!(e1.contains("512"));
        // Second attempt is served from the cached skip.
        let e2 = format!("{:#}", e.submit(&req).unwrap_err());
        assert_eq!(e1, e2);
        assert_eq!(e.cache_stats().hits, 1);
    }

    #[test]
    fn empty_batch_is_empty() {
        assert!(quick_engine().submit_batch(&[]).is_empty());
    }

    #[test]
    fn batch_preserves_request_order() {
        let e = quick_engine();
        let shapes = [(2, 3), (3, 2), (4, 1), (1, 4)];
        let reqs: Vec<ConvRequest> = shapes
            .iter()
            .map(|&(c, k)| ConvRequest::seeded(ConvShape::new3x3(c, k, 3, 3), Mapping::Wp, 9))
            .collect();
        let results = e.submit_batch(&reqs);
        assert_eq!(results.len(), reqs.len());
        for (res, &(c, k)) in results.iter().zip(shapes.iter()) {
            let r = res.as_ref().unwrap();
            assert_eq!(r.report.shape_id, format!("c{c}k{k}o3x3"));
        }
    }

    #[test]
    fn batch_isolates_per_request_failures() {
        let e = quick_engine();
        let reqs = vec![
            ConvRequest::seeded(ConvShape::new3x3(2, 2, 3, 3), Mapping::Wp, 1),
            ConvRequest::seeded(ConvShape::new3x3(16, 16, 64, 64), Mapping::Wp, 1), // too big
            ConvRequest::seeded(ConvShape::new3x3(2, 2, 4, 4), Mapping::Cpu, 1),
        ];
        let results = e.submit_batch(&reqs);
        assert!(results[0].is_ok());
        assert!(results[1].is_err());
        assert!(results[2].is_ok());
    }

    #[test]
    fn run_all_mappings_covers_all_strategies() {
        let e = quick_engine();
        let rows = e.run_all_mappings(&ConvShape::new3x3(4, 4, 4, 4), 11).unwrap();
        assert_eq!(rows.len(), Mapping::ALL.len());
        for (r, m) in rows.iter().zip(Mapping::ALL) {
            assert_eq!(r.mapping, m);
        }
    }

    #[test]
    fn network_runs_and_matches_golden() {
        let e = quick_engine();
        let net = ConvNet::random(2, 2, 4, 8, 8, 11);
        let mut rng = Rng::new(5);
        let input = random_input(&net.layers[0].shape, 8, &mut rng);
        let out = e.run_network(&net, &input).unwrap();
        let golden = crate::coordinator::golden_network(&net, &input).unwrap();
        assert_eq!(out.output.data, golden.data);
        assert_eq!(out.layers.len(), 2);
        assert!(out.total_cycles > 0 && out.total_energy_uj > 0.0);
        assert!(out.relu_cycles > 0);
    }

    #[test]
    fn plan_tracks_simulation_closely_without_simulating() {
        let e = quick_engine();
        let shape = ConvShape::new3x3(3, 3, 5, 5);
        let est = e.plan(&shape, Mapping::Wp).unwrap();
        assert!(est.probe_launches > 0 && est.probe_launches < 9, "probes, not a full sim");
        let (report, _) = e.submit_report(&ConvRequest::seeded(shape, Mapping::Wp, 2)).unwrap();
        let (p, s) = (est.report.latency_cycles as f64, report.latency_cycles as f64);
        assert!(((p - s) / s).abs() <= 0.05, "planned {p} vs simulated {s}");
        assert_eq!(est.mapping, Mapping::Wp);
    }

    #[test]
    fn submit_planned_records_cost_based_auto_decisions() {
        let e = quick_engine();
        let req = ConvRequest::seeded(ConvShape::baseline(), Mapping::Auto, 1);
        let planned = e.submit_planned(&req).unwrap();
        assert_eq!(planned.mapping, Mapping::Wp, "the paper's winner");
        let d = planned.auto.expect("auto decision recorded");
        assert!(d.reason.contains("cost model"), "{}", d.reason);
        // Explicit mappings record no decision and plan directly.
        let explicit = e
            .submit_planned(&ConvRequest::seeded(ConvShape::baseline(), Mapping::Cpu, 1))
            .unwrap();
        assert!(explicit.auto.is_none());
        assert_eq!(explicit.estimate.probe_launches, 0, "CPU estimates are closed form");
        // Memoized repeat: no new probes.
        let probes = e.planner().stats().probe_launches;
        let _ = e.submit_planned(&req).unwrap();
        assert_eq!(e.planner().stats().probe_launches, probes);
    }

    #[test]
    fn plan_network_then_run_network_agree() {
        let e = quick_engine();
        let mut net = ConvNet::random(2, 2, 4, 8, 8, 11);
        let plan = e.plan_network(&net, PlanObjective::Latency).unwrap();
        assert_eq!(plan.layers.len(), 2);
        plan.apply(&mut net).unwrap();
        let mut rng = Rng::new(5);
        let input = random_input(&net.layers[0].shape, 8, &mut rng);
        let out = e.run_network(&net, &input).unwrap();
        let (p, s) = (plan.total_cycles as f64, out.total_cycles as f64);
        assert!(((p - s) / s).abs() <= 0.05, "planned {p} vs simulated {s}");
        assert_eq!(plan.layers[0].relu_cycles, out.relu_cycles - plan.layers[1].relu_cycles);
    }

    #[test]
    fn sweep_routes_through_engine_cache() {
        let e = quick_engine();
        let spec = SweepSpec {
            c_values: vec![4],
            k_values: vec![5],
            spatial_values: vec![],
            mappings: vec![Mapping::Wp],
            mag: 6,
            seed: 21,
        };
        let rows = e.sweep(&spec).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(e.cache_stats().entries, 2, "sweep points land in the engine's cache");
    }
}
