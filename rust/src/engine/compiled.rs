//! **`CompiledNet` — the compile-once / run-many inference artifact.**
//!
//! [`Engine::compile`] turns a [`Net`] (or, via
//! [`Engine::compile_conv_net`], a legacy [`ConvNet`]) into a frozen
//! executable: per-layer mapping resolved by the planner **once**,
//! every CGRA launch program built and pre-decoded into the µop IR,
//! memory layouts fixed, the host-op glue (pad / group-slice /
//! decimate / pool / fused ReLU) specialized into a step list with its
//! closed-form charges precomputed, and a ping-pong scratch arena sized
//! at compile time. Steady-state [`CompiledNet::run`] then performs
//! **zero program building, zero µop decoding, zero planner work and
//! zero activation allocation** — the contract is assertable through
//! [`RunCounters`] and pinned by `tests/compiled_counters.rs`.
//!
//! The artifact is immutable and `Send + Sync`: share one behind an
//! `Arc` across the worker pool, give each worker its own [`NetCtx`]
//! (the mutable arena), and fan inference traffic out.
//!
//! Golden verification — the per-inference tax the interpreted
//! executor used to pay on every layer — is demoted to the opt-in
//! [`CompiledNet::run_verified`] debug mode (`cgra serve --verify`, the
//! CI serving job, and the legacy-compatible `nn::run_network` wrapper
//! use it; the hot path does not).
//!
//! Modeled cycles and energy are **identical** to the interpreted path
//! by construction — same launch schedules, same closed-form glue, same
//! energy integration — so a compiled artifact changes the simulator's
//! serving throughput (host wall-clock), never the paper's numbers.
//!
//! For bulk traffic, [`CompiledNet::run_batch`] runs up to `B`
//! independent inferences through **one shared µop program walk** per
//! launch (DESIGN.md §9): allocate a [`BatchCtx`] once via
//! [`CompiledNet::new_batch_ctx`], hand it a chunk of inputs, and read
//! the per-lane outputs back from [`BatchCtx::outputs`]. Batched runs
//! keep the same warm-path counter contract as scalar runs, and their
//! modeled per-inference cycles/energy are bit-identical — batching
//! amortizes the *simulator's* replay overhead, never the hardware
//! model.

// Every public item here is API surface for embedders; the CI docs job
// (`RUSTDOCFLAGS: -D warnings`) turns a missing doc into a failure.
#![warn(missing_docs)]

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::cgra::{self, Cgra, DecodedProgram, ProgTable};
use crate::conv::{GenConvShape, TensorChw, Weights};
use crate::coordinator::cache;
use crate::coordinator::network::ConvNet;
use crate::energy::EnergyModel;
use crate::kernels::{
    self, BatchKernelScratch, CompiledKernel, ConvOutcome, KernelScratch, Mapping, ScratchNeed,
};
use crate::metrics::MappingReport;
use crate::nn::graph::{golden_layer, Layer, Net};
use crate::nn::lower::{
    cpu_baseline_cycles, decimate_into, glue_spec, host_energy_uj, pad_into, pool_into, HostOp,
};
use crate::obs::{profile, trace};
use crate::util::wire::{Reader, Writer};

use super::artifact::{self, ArtifactInfo};
use super::auto::{self, AutoDecision};
use super::{relu_cost, Engine};

/// How one compiled layer executes at run time.
#[derive(Clone, Debug)]
enum LayerExec {
    /// A conv-like layer: optional host pad, one prebuilt kernel per
    /// group, optional decimation.
    Conv {
        /// Host zero-pad per side (0 = input used as-is).
        pad: usize,
        /// Input dims after the pad `(c, h, w)`.
        padded_dims: (usize, usize, usize),
        /// Full stride-1 output dims `(k, oxf, oyf)` before decimation.
        full_dims: (usize, usize, usize),
        /// Decimation factor (1 = the full output is the layer output).
        stride: usize,
        /// One prebuilt kernel per group, sharing decoded programs.
        kernels: Vec<CompiledKernel>,
    },
    /// Host-side max pooling.
    MaxPool {
        /// Window side.
        size: usize,
        /// Window stride.
        stride: usize,
    },
    /// Host-side average pooling.
    AvgPool {
        /// Window side.
        size: usize,
        /// Window stride.
        stride: usize,
    },
}

/// One frozen layer of the artifact: execution plan plus the static
/// metadata and charges every run reuses.
#[derive(Clone, Debug)]
struct CompiledLayer {
    kind: &'static str,
    desc: String,
    /// Concrete strategy (None for host-only pools).
    mapping: Option<Mapping>,
    /// Recorded planner decision when the layer asked for `Auto`.
    auto: Option<AutoDecision>,
    macs: u64,
    cpu_cycles: u64,
    /// Static host-glue charge of the layer (pad + embed + shuffle +
    /// decimate + pool; excludes the fused ReLU).
    host: HostOp,
    relu: bool,
    relu_elems: usize,
    in_dims: (usize, usize, usize),
    out_dims: (usize, usize, usize),
    exec: LayerExec,
}

/// Compile-time arena sizing: the warm path resizes within these
/// capacities and never allocates.
#[derive(Clone, Copy, Debug, Default)]
struct ArenaSpec {
    /// Ping-pong activation buffers (each this big).
    act_elems: usize,
    /// Padded-input staging buffer.
    stage_elems: usize,
    /// Full stride-1 output staging (strided layers only).
    full_elems: usize,
    /// Per-group input slice buffer (grouped layers only).
    group_elems: usize,
    /// Kernel scratch (HWC conversion, im2col patches).
    scratch: ScratchNeed,
}

/// Per-layer result of one inference through a [`CompiledNet`].
#[derive(Clone, Debug)]
pub struct LayerRun {
    /// End-to-end layer cycles (conv + host glue + ReLU).
    pub cycles: u64,
    /// CGRA convolution cycles (summed over group replays).
    pub conv_cycles: u64,
    /// Host cycles (static glue + fused ReLU).
    pub host_cycles: u64,
    /// Fused-ReLU share of `host_cycles`.
    pub relu_cycles: u64,
    /// Layer energy, µJ.
    pub energy_uj: f64,
    /// CGRA launches replayed.
    pub launches: u64,
    /// Concrete strategy (None for host-only pools).
    pub mapping: Option<Mapping>,
    /// Full metric row of the conv (only when the context collects
    /// reports and the layer is a single-group convolution).
    pub report: Option<MappingReport>,
    /// Golden-exactness of the layer (`Some` only in verified runs).
    pub exact: Option<bool>,
}

/// Aggregate result of one inference.
#[derive(Clone, Debug)]
pub struct InferRun {
    /// Per-layer rows, in execution order.
    pub layers: Vec<LayerRun>,
    /// End-to-end cycles.
    pub total_cycles: u64,
    /// End-to-end energy, µJ.
    pub total_energy_uj: f64,
    /// Total fused-ReLU cycles.
    pub relu_cycles: u64,
    /// Whether every layer matched the golden model (`Some` only in
    /// verified runs).
    pub exact: Option<bool>,
    /// Bottleneck attribution of the inference's CGRA walks (`Some`
    /// only while a profiling session is active, DESIGN.md §12). Walk
    /// cycles only: the modeled launch overhead and host glue are not
    /// step-attributable. For batched runs this is the shared µop
    /// walk's attribution — identical for every lane by construction.
    pub profile: Option<profile::ProfileDelta>,
}

/// Static summary of one compiled layer (CLI `cgra compile` table).
#[derive(Clone, Debug)]
pub struct LayerInfo<'a> {
    /// Layer kind label.
    pub kind: &'static str,
    /// Short shape description.
    pub desc: &'a str,
    /// Concrete frozen strategy.
    pub mapping: Option<Mapping>,
    /// Recorded `Auto` decision, if the layer asked for one.
    pub auto: Option<AutoDecision>,
    /// CGRA launches one inference replays.
    pub launches: u64,
    /// Pre-decoded µops owned for this layer.
    pub uops: usize,
    /// True MACs.
    pub macs: u64,
    /// Scalar-CPU baseline cycles.
    pub cpu_cycles: u64,
}

/// A network compiled into a reusable inference artifact. Build with
/// [`Engine::compile`]; run with [`CompiledNet::run`] /
/// [`CompiledNet::run_verified`] against a [`NetCtx`], or batch
/// independent inferences with [`CompiledNet::run_batch`] against a
/// [`BatchCtx`].
pub struct CompiledNet {
    /// The source graph (kept for golden verification and summaries).
    net: Net,
    layers: Vec<CompiledLayer>,
    cgra: Cgra,
    model: EnergyModel,
    arena: ArenaSpec,
}

/// The mutable side of inference: ping-pong activation buffers, the
/// padded/full/group staging buffers, the kernel scratch (CGRA memory
/// image + host staging) and the output tensor. Allocated once by
/// [`CompiledNet::new_ctx`]; every warm [`CompiledNet::run`] reuses it
/// allocation-free. One context serves one thread; pool workers each
/// build their own and share the `Arc<CompiledNet>`.
pub struct NetCtx {
    bufs: [Vec<i32>; 2],
    stage: Vec<i32>,
    full: Vec<i32>,
    group_in: Vec<i32>,
    scratch: KernelScratch,
    out: TensorChw,
    collect_reports: bool,
}

impl NetCtx {
    /// The final activation of the most recent run.
    pub fn output(&self) -> &TensorChw {
        &self.out
    }

    /// Collect a full [`MappingReport`] per single-group conv layer on
    /// subsequent runs (the legacy `Engine::run_network` surface needs
    /// them; the serving hot path skips the row construction).
    pub fn collect_reports(&mut self, on: bool) {
        self.collect_reports = on;
    }
}

/// The mutable side of **batched** inference (DESIGN.md §9): the same
/// arena as [`NetCtx`], widened to `B` lanes. Activation ping-pong and
/// staging buffers are lane-major flat arrays (lane `l`'s image lives
/// at `l * lane_stride`, one stride per buffer family), and the CGRA
/// memory image is a structure-of-arrays [`BatchKernelScratch`] so one
/// shared µop walk serves every lane.
///
/// Allocated once by [`CompiledNet::new_batch_ctx`]; every warm
/// [`CompiledNet::run_batch`] reuses it allocation-free — buffers are
/// sized to full capacity up front, so even the first batched run
/// never grows them. One context serves one thread; pool workers each
/// build their own and share the `Arc<CompiledNet>`.
pub struct BatchCtx {
    batch: usize,
    served: usize,
    bufs: [Vec<i32>; 2],
    stage: Vec<i32>,
    full: Vec<i32>,
    scratch: BatchKernelScratch,
    outs: Vec<TensorChw>,
}

impl BatchCtx {
    /// The lane capacity this context was allocated for. Runs may
    /// present fewer inputs (a ragged final chunk); never more.
    pub fn batch_capacity(&self) -> usize {
        self.batch
    }

    /// The final activations of the most recent run, one tensor per
    /// input lane, in input order. Empty before the first run; after a
    /// ragged run only the served lanes appear.
    pub fn outputs(&self) -> &[TensorChw] {
        &self.outs[..self.served]
    }
}

/// Resize a buffer, counting any capacity growth as an arena allocation
/// (a correctly sized arena never grows after construction).
fn ensure_len(v: &mut Vec<i32>, len: usize) {
    if len > v.capacity() {
        kernels::common::note_arena_alloc();
    }
    v.resize(len, 0);
}

/// Attach the per-layer span arguments (modeled cycle split, launch
/// count, resolved mapping) once the layer's accounting is final. A
/// no-op — including the `desc` clone — when tracing is off.
fn annotate_layer(
    sp: &mut trace::Span,
    cl: &CompiledLayer,
    cycles: u64,
    conv_cycles: u64,
    relu_cycles: u64,
    launches: u64,
) {
    if !sp.is_recording() {
        return;
    }
    sp.arg("desc", cl.desc.as_str());
    sp.arg("cycles", cycles);
    sp.arg("conv_cycles", conv_cycles);
    sp.arg("host_cycles", cl.host.cycles + relu_cycles);
    sp.arg("relu_cycles", relu_cycles);
    sp.arg("launches", launches);
    if let Some(m) = cl.mapping {
        sp.arg("mapping", m.label());
    }
}

impl Engine {
    /// Compile a layer graph into a [`CompiledNet`]: resolve every
    /// `Auto` mapping through the planner once, build and pre-decode
    /// every launch program, freeze layouts and host-glue charges, and
    /// size the run arena. All compile-side failure modes (memory
    /// bound, weight conventions, graph validation) surface here, with
    /// the failing layer named.
    ///
    /// The artifact keeps the source graph (for the opt-in golden
    /// verification and for summaries) in addition to the weight
    /// images baked into the kernels; this borrowing entry point
    /// clones it — callers that own their `Net` and are done with it
    /// should use [`Engine::compile_owned`] instead.
    pub fn compile(&self, net: &Net) -> Result<CompiledNet> {
        self.compile_owned(net.clone())
    }

    /// [`Engine::compile`] over an owned graph — the artifact absorbs
    /// `net` (weights and all) without cloning it. The CLI
    /// `compile`/`serve` verbs and `compile_conv_net` use this.
    pub fn compile_owned(&self, net: Net) -> Result<CompiledNet> {
        net.validate()?;
        let mut layers = Vec::with_capacity(net.layers.len());
        let mut arena = ArenaSpec::default();
        let mut dims = net.input_dims;
        arena.act_elems = dims.0 * dims.1 * dims.2;
        for (index, layer) in net.layers.iter().enumerate() {
            let ctx = || format!("layer {index} ({}) of '{}'", layer.kind(), net.name);
            let spec = glue_spec(layer, dims).with_context(ctx)?;
            let out_dims = spec.out_dims;
            let relu_elems = if layer.relu() { out_dims.0 * out_dims.1 * out_dims.2 } else { 0 };
            let mut auto_decision = None;
            let exec = match &spec.lowered {
                None => match layer {
                    Layer::MaxPool { size, stride } => {
                        LayerExec::MaxPool { size: *size, stride: *stride }
                    }
                    Layer::AvgPool { size, stride } => {
                        LayerExec::AvgPool { size: *size, stride: *stride }
                    }
                    _ => unreachable!("only pools lower to host-only steps"),
                },
                Some(lc) => {
                    let (ks, decision) =
                        self.build_layer_kernels(layer, lc).with_context(ctx)?;
                    auto_decision = decision;
                    arena.scratch =
                        ks.iter().fold(arena.scratch, |need, k| need.max(k.scratch_need()));
                    let shape = layer.conv_shape().expect("conv-like layer");
                    let full_dims = (shape.k, lc.sub_shape.ox, lc.sub_shape.oy);
                    if lc.host_pad > 0 {
                        arena.stage_elems = arena
                            .stage_elems
                            .max(spec.padded_dims.0 * spec.padded_dims.1 * spec.padded_dims.2);
                    }
                    if lc.stride > 1 {
                        arena.full_elems =
                            arena.full_elems.max(full_dims.0 * full_dims.1 * full_dims.2);
                    }
                    if lc.groups > 1 {
                        arena.group_elems =
                            arena.group_elems.max(lc.sub_shape.input_elems());
                    }
                    LayerExec::Conv {
                        pad: lc.host_pad,
                        padded_dims: spec.padded_dims,
                        full_dims,
                        stride: lc.stride,
                        kernels: ks,
                    }
                }
            };
            let mapping = match &exec {
                LayerExec::Conv { kernels: ks, .. } => Some(ks[0].mapping()),
                _ => None,
            };
            // Activation buffers must hold the layer's input, its full
            // (pre-decimation) output and its final output.
            if let LayerExec::Conv { full_dims, stride, .. } = &exec {
                if *stride == 1 {
                    arena.act_elems =
                        arena.act_elems.max(full_dims.0 * full_dims.1 * full_dims.2);
                }
            }
            arena.act_elems = arena.act_elems.max(out_dims.0 * out_dims.1 * out_dims.2);
            layers.push(CompiledLayer {
                kind: layer.kind(),
                desc: layer.describe(),
                mapping,
                auto: auto_decision,
                macs: layer.macs(),
                cpu_cycles: cpu_baseline_cycles(layer),
                host: spec.host,
                relu: layer.relu(),
                relu_elems,
                in_dims: dims,
                out_dims,
                exec,
            });
            dims = out_dims;
        }
        Ok(CompiledNet {
            net,
            layers,
            cgra: Cgra::new(self.config().clone())?,
            model: self.model,
            arena,
        })
    }

    /// Compile a legacy [`ConvNet`] (plain stride-1 / valid conv stack
    /// with per-layer mappings and ReLU flags) by converting it into
    /// the equivalent layer graph. [`Engine::run_network`] routes
    /// through this, so the legacy surface and the `nn` executor share
    /// one compiled execution path.
    pub fn compile_conv_net(&self, net: &ConvNet) -> Result<CompiledNet> {
        net.validate()?;
        let first = &net.layers[0].shape;
        let nn_net = Net {
            name: "conv-net".into(),
            input_dims: (first.c, first.ih(), first.iw()),
            layers: net
                .layers
                .iter()
                .map(|l| Layer::Conv {
                    shape: GenConvShape::from_basic(&l.shape),
                    weights: l.weights.clone(),
                    mapping: l.mapping,
                    relu: l.relu,
                })
                .collect(),
        };
        self.compile_owned(nn_net)
    }

    /// Build the per-group prebuilt kernels of one conv-like layer:
    /// resolve `Auto` through the planner (recording the decision),
    /// apply the pointwise center-embedding to the weights, slice
    /// per-group filter banks. Group 0 builds (and decodes) the
    /// programs; the siblings share them via `Arc`.
    fn build_layer_kernels(
        &self,
        layer: &Layer,
        lc: &crate::nn::lower::LoweredConv,
    ) -> Result<(Vec<CompiledKernel>, Option<AutoDecision>)> {
        let decision = if lc.mapping.is_auto() {
            Some(auto::choose_planned(&self.planner, &lc.sub_shape, self.config())?)
        } else {
            None
        };
        let mapping = decision.map(|d| d.mapping).unwrap_or(lc.mapping);
        let weights = match layer {
            Layer::Conv { weights, .. }
            | Layer::Depthwise { weights, .. }
            | Layer::Pointwise { weights, .. } => weights,
            _ => unreachable!("conv-like layer carries weights"),
        };
        let w_eff: std::borrow::Cow<'_, Weights> = if lc.embed_pointwise {
            std::borrow::Cow::Owned(crate::nn::lower::embed_pointwise_weights(weights).0)
        } else {
            std::borrow::Cow::Borrowed(weights)
        };
        if lc.groups == 1 {
            let k = CompiledKernel::build(self.config(), &lc.sub_shape, mapping, &w_eff)?;
            return Ok((vec![k], decision));
        }
        let (cg, kg) = (lc.sub_shape.c, lc.sub_shape.k);
        let wpg = kg * cg * 9;
        let slice = |g: usize| {
            Weights::from_vec(kg, cg, 3, 3, w_eff.data[g * wpg..(g + 1) * wpg].to_vec())
        };
        let base = CompiledKernel::build(self.config(), &lc.sub_shape, mapping, &slice(0))?;
        let mut ks = Vec::with_capacity(lc.groups);
        for g in 1..lc.groups {
            ks.push(base.with_weights(&slice(g))?);
        }
        ks.insert(0, base);
        Ok((ks, decision))
    }
}

impl CompiledNet {
    /// The source graph the artifact was compiled from.
    pub fn net(&self) -> &Net {
        &self.net
    }

    /// Network name.
    pub fn name(&self) -> &str {
        &self.net.name
    }

    /// Number of layers.
    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// Static per-layer summary.
    pub fn layer_info(&self, index: usize) -> LayerInfo<'_> {
        let l = &self.layers[index];
        let (launches, uops) = match &l.exec {
            LayerExec::Conv { kernels, .. } => (
                kernels.iter().map(|k| k.launches()).sum(),
                kernels.iter().map(|k| k.total_uops()).sum(),
            ),
            _ => (0, 0),
        };
        LayerInfo {
            kind: l.kind,
            desc: &l.desc,
            mapping: l.mapping,
            auto: l.auto,
            launches,
            uops,
            macs: l.macs,
            cpu_cycles: l.cpu_cycles,
        }
    }

    /// CGRA launches one inference replays.
    pub fn total_launches(&self) -> u64 {
        (0..self.layers.len()).map(|i| self.layer_info(i).launches).sum()
    }

    /// Pre-decoded µops the artifact owns.
    pub fn total_uops(&self) -> usize {
        (0..self.layers.len()).map(|i| self.layer_info(i).uops).sum()
    }

    /// Words the run arena holds (activations ping-pong + staging +
    /// group slices; excludes the fixed-size CGRA memory image).
    pub fn arena_words(&self) -> usize {
        2 * self.arena.act_elems
            + self.arena.stage_elems
            + self.arena.full_elems
            + self.arena.group_elems
            + self.arena.scratch.hwc_elems
            + self.arena.scratch.patch_elems
    }

    /// Allocate a fresh execution context sized for this artifact. The
    /// only allocating step of the warm path — do it once per worker.
    pub fn new_ctx(&self) -> NetCtx {
        kernels::common::note_arena_alloc();
        let (c, h, w) = self.net.input_dims;
        NetCtx {
            bufs: [
                Vec::with_capacity(self.arena.act_elems),
                Vec::with_capacity(self.arena.act_elems),
            ],
            stage: Vec::with_capacity(self.arena.stage_elems),
            full: Vec::with_capacity(self.arena.full_elems),
            group_in: Vec::with_capacity(self.arena.group_elems),
            scratch: KernelScratch::new(self.cgra.config(), self.arena.scratch),
            out: TensorChw { c, h, w, data: Vec::with_capacity(self.arena.act_elems) },
            collect_reports: false,
        }
    }

    /// One inference: replay every compiled step against `ctx`'s arena.
    /// The final activation lands in [`NetCtx::output`]. No golden
    /// verification — use [`CompiledNet::run_verified`] for the debug
    /// mode.
    pub fn run(&self, ctx: &mut NetCtx, input: &TensorChw) -> Result<InferRun> {
        self.run_inner(ctx, input, false)
    }

    /// One inference with the opt-in golden debug check: every layer's
    /// output is compared element-exactly against the generalized
    /// golden model and flagged in the result (this pays the golden
    /// chain's CPU cost and allocates — it is the debug mode, not the
    /// serving path).
    pub fn run_verified(&self, ctx: &mut NetCtx, input: &TensorChw) -> Result<InferRun> {
        self.run_inner(ctx, input, true)
    }

    fn run_inner(&self, ctx: &mut NetCtx, input: &TensorChw, verify: bool) -> Result<InferRun> {
        let (c, h, w) = self.net.input_dims;
        if (input.c, input.h, input.w) != (c, h, w) {
            bail!(
                "network '{}' expects a {c}x{h}x{w} input, got {}x{}x{}",
                self.net.name,
                input.c,
                input.h,
                input.w
            );
        }
        let model = self.model;
        let NetCtx { bufs, stage, full, group_in, scratch, out, collect_reports } = ctx;
        let collect = *collect_reports;
        let [buf_a, buf_b] = bufs;
        let (mut cur, mut nxt) = (buf_a, buf_b);
        ensure_len(cur, input.data.len());
        cur.copy_from_slice(&input.data);

        let mut golden_x = verify.then(|| input.clone());
        let mut layers = Vec::with_capacity(self.layers.len());
        let mut total_cycles = 0u64;
        let mut total_energy = 0.0f64;
        let mut relu_total = 0u64;
        let mut all_exact = true;
        let mut rsp = trace::span_dyn("engine", || format!("infer:{}", self.net.name));
        let pf = profile::frame();

        for (index, cl) in self.layers.iter().enumerate() {
            let lctx =
                || format!("layer {index} ({}) of '{}'", cl.kind, self.net.name);
            let mut lsp = trace::span_dyn("layer", || format!("L{index}:{}", cl.kind));
            let lf = profile::frame();
            let out_elems = cl.out_dims.0 * cl.out_dims.1 * cl.out_dims.2;
            let mut conv_cycles = 0u64;
            let mut conv_energy = 0.0f64;
            let mut launches = 0u64;
            let mut report = None;

            match &cl.exec {
                LayerExec::MaxPool { size, stride } => {
                    ensure_len(nxt, out_elems);
                    pool_into(cur, cl.in_dims, *size, *stride, true, nxt, cl.out_dims);
                }
                LayerExec::AvgPool { size, stride } => {
                    ensure_len(nxt, out_elems);
                    pool_into(cur, cl.in_dims, *size, *stride, false, nxt, cl.out_dims);
                }
                LayerExec::Conv { pad, padded_dims, full_dims, stride, kernels } => {
                    // 1. Host padding into the staging buffer.
                    let conv_in: &[i32] = if *pad > 0 {
                        let (pc, ph, pw) = *padded_dims;
                        ensure_len(stage, pc * ph * pw);
                        pad_into(cur, cl.in_dims, *pad, stage);
                        &stage[..]
                    } else {
                        &cur[..]
                    };
                    // 2. The prebuilt kernel replays, per group, into
                    //    the full stride-1 output.
                    let (fk, fh, fw) = *full_dims;
                    let full_elems = fk * fh * fw;
                    let dst: &mut Vec<i32> =
                        if *stride > 1 { &mut *full } else { &mut *nxt };
                    ensure_len(dst, full_elems);
                    if kernels.len() == 1 {
                        let outcome = kernels[0]
                            .run_into(&self.cgra, conv_in, scratch, dst)
                            .with_context(lctx)?;
                        conv_cycles += outcome.latency.total_cycles();
                        conv_energy += outcome_energy(&outcome, &model);
                        launches += outcome.latency.launches;
                        if collect {
                            report = Some(MappingReport::from_outcome(&outcome, &model));
                        }
                    } else {
                        let sub = kernels[0].shape();
                        let (cg, per_in) = (sub.c, sub.input_elems());
                        let per_out = sub.output_elems();
                        let (_, ph, pw) = *padded_dims;
                        ensure_len(group_in, per_in);
                        for (g, kernel) in kernels.iter().enumerate() {
                            let lo = g * cg * ph * pw;
                            group_in.copy_from_slice(&conv_in[lo..lo + per_in]);
                            let outcome = kernel
                                .run_into(
                                    &self.cgra,
                                    group_in,
                                    scratch,
                                    &mut dst[g * per_out..(g + 1) * per_out],
                                )
                                .with_context(|| format!("group {g}"))
                                .with_context(lctx)?;
                            conv_cycles += outcome.latency.total_cycles();
                            conv_energy += outcome_energy(&outcome, &model);
                            launches += outcome.latency.launches;
                        }
                    }
                    // 3. Decimate the full output down to the layer
                    //    output.
                    if *stride > 1 {
                        ensure_len(nxt, out_elems);
                        decimate_into(full, *full_dims, *stride, nxt, cl.out_dims);
                    }
                }
            }

            // 4. Fused ReLU in place, charged like the engine's.
            let (mut relu_cycles, mut relu_uj) = (0u64, 0.0f64);
            if cl.relu {
                for v in nxt.iter_mut() {
                    *v = (*v).max(0);
                }
                let (rc, re) = relu_cost(&model, cl.relu_elems);
                relu_cycles = rc;
                relu_uj = re;
            }

            // 5. Opt-in golden debug check.
            let exact = match &mut golden_x {
                None => None,
                Some(gx) => {
                    *gx = golden_layer(&self.net.layers[index], gx)?;
                    let ok = gx.data[..] == nxt[..out_elems];
                    all_exact &= ok;
                    Some(ok)
                }
            };

            let cycles = conv_cycles + cl.host.cycles + relu_cycles;
            let energy_uj = conv_energy + host_energy_uj(&model, cl.host) + relu_uj;
            total_cycles += cycles;
            total_energy += energy_uj;
            relu_total += relu_cycles;
            annotate_layer(&mut lsp, cl, cycles, conv_cycles, relu_cycles, launches);
            if let Some(d) = lf.finish() {
                profile::record_layer(format!("L{index:02}:{}", cl.kind), &d);
            }
            layers.push(LayerRun {
                cycles,
                conv_cycles,
                host_cycles: cl.host.cycles + relu_cycles,
                relu_cycles,
                energy_uj,
                launches,
                mapping: cl.mapping,
                report,
                exact,
            });
            std::mem::swap(&mut cur, &mut nxt);
        }
        rsp.arg("modeled_cycles", total_cycles);
        rsp.arg("layers", self.layers.len());

        let (oc, oh, ow) = self.layers.last().map(|l| l.out_dims).unwrap_or((c, h, w));
        ensure_len(&mut out.data, oc * oh * ow);
        out.data.copy_from_slice(&cur[..oc * oh * ow]);
        out.c = oc;
        out.h = oh;
        out.w = ow;

        Ok(InferRun {
            layers,
            total_cycles,
            total_energy_uj: total_energy,
            relu_cycles: relu_total,
            exact: verify.then_some(all_exact),
            profile: pf.finish(),
        })
    }

    /// Allocate a batched execution context with capacity for `batch`
    /// concurrent inference lanes. Like [`CompiledNet::new_ctx`], this
    /// is the only allocating step of the warm batched path — do it
    /// once per worker. Every buffer is sized to full capacity here, so
    /// warm [`CompiledNet::run_batch`] calls (full or ragged) never
    /// grow it.
    ///
    /// # Panics
    ///
    /// Panics if `batch` is zero.
    pub fn new_batch_ctx(&self, batch: usize) -> BatchCtx {
        assert!(batch >= 1, "batch capacity must be at least 1");
        kernels::common::note_arena_alloc();
        let (c, h, w) = self.net.input_dims;
        BatchCtx {
            batch,
            served: 0,
            bufs: [
                vec![0; batch * self.arena.act_elems],
                vec![0; batch * self.arena.act_elems],
            ],
            stage: vec![0; batch * self.arena.stage_elems],
            full: vec![0; batch * self.arena.full_elems],
            scratch: BatchKernelScratch::new(self.cgra.config(), self.arena.scratch, batch),
            outs: (0..batch)
                .map(|_| TensorChw {
                    c,
                    h,
                    w,
                    data: Vec::with_capacity(self.arena.act_elems),
                })
                .collect(),
        }
    }

    /// Run up to `B` independent inferences through **one shared µop
    /// program walk** per launch (DESIGN.md §9). Accepts between 1 and
    /// [`BatchCtx::batch_capacity`] inputs — a short slice is the
    /// ragged final chunk of a stream and is charged/validated exactly
    /// like a full one. Per-lane outputs land in [`BatchCtx::outputs`]
    /// in input order.
    ///
    /// The returned [`InferRun`] is **per inference**, not per batch:
    /// every lane replays the identical launch schedule against the
    /// identical timing model, so modeled cycles and energy are
    /// bit-equal to a scalar [`CompiledNet::run`] of any one input
    /// (`tests/batched.rs` pins this). Batching amortizes the
    /// *simulator's* host-side replay work across lanes; it never
    /// changes the paper's modeled numbers.
    ///
    /// Per-layer [`MappingReport`]s are not collected on this path (it
    /// is the bulk-serving hot path); use the scalar [`NetCtx`] with
    /// [`NetCtx::collect_reports`] for report rows.
    pub fn run_batch(&self, ctx: &mut BatchCtx, inputs: &[TensorChw]) -> Result<InferRun> {
        self.run_batch_inner(ctx, inputs, false)
    }

    /// [`CompiledNet::run_batch`] with the opt-in golden debug check:
    /// every layer's output is compared element-exactly against the
    /// generalized golden model **per lane**. This pays `B` golden
    /// chains on the CPU and allocates — it is the debug mode, not the
    /// serving path.
    pub fn run_batch_verified(
        &self,
        ctx: &mut BatchCtx,
        inputs: &[TensorChw],
    ) -> Result<InferRun> {
        self.run_batch_inner(ctx, inputs, true)
    }

    fn run_batch_inner(
        &self,
        ctx: &mut BatchCtx,
        inputs: &[TensorChw],
        verify: bool,
    ) -> Result<InferRun> {
        let nb = inputs.len();
        if nb == 0 || nb > ctx.batch {
            bail!(
                "run_batch got {} inputs for a context of capacity {} (want 1..={})",
                nb,
                ctx.batch,
                ctx.batch
            );
        }
        let (c, h, w) = self.net.input_dims;
        for (l, input) in inputs.iter().enumerate() {
            if (input.c, input.h, input.w) != (c, h, w) {
                bail!(
                    "network '{}' expects a {c}x{h}x{w} input, got {}x{}x{} (batch lane {l})",
                    self.net.name,
                    input.c,
                    input.h,
                    input.w
                );
            }
        }
        let model = self.model;
        // Lane strides are the *capacity* arena sizes, fixed at context
        // creation — a ragged chunk reuses the same layout and simply
        // leaves the tail lanes untouched.
        let a_str = self.arena.act_elems;
        let s_str = self.arena.stage_elems;
        let f_str = self.arena.full_elems;
        let BatchCtx { batch: _, served, bufs, stage, full, scratch, outs } = ctx;
        *served = 0;
        let [buf_a, buf_b] = bufs;
        let (mut cur, mut nxt) = (&mut buf_a[..], &mut buf_b[..]);
        for (l, input) in inputs.iter().enumerate() {
            cur[l * a_str..l * a_str + input.data.len()].copy_from_slice(&input.data);
        }

        let mut golden_x: Option<Vec<TensorChw>> = verify.then(|| inputs.to_vec());
        let mut layers = Vec::with_capacity(self.layers.len());
        let mut total_cycles = 0u64;
        let mut total_energy = 0.0f64;
        let mut relu_total = 0u64;
        let mut all_exact = true;
        let mut rsp = trace::span_dyn("engine", || format!("infer_batch:{}", self.net.name));
        rsp.arg("lanes", nb);
        let pf = profile::frame();

        for (index, cl) in self.layers.iter().enumerate() {
            let lctx =
                || format!("layer {index} ({}) of '{}'", cl.kind, self.net.name);
            let mut lsp = trace::span_dyn("layer", || format!("L{index}:{}", cl.kind));
            let lf = profile::frame();
            let out_elems = cl.out_dims.0 * cl.out_dims.1 * cl.out_dims.2;
            let in_elems = cl.in_dims.0 * cl.in_dims.1 * cl.in_dims.2;
            let mut conv_cycles = 0u64;
            let mut conv_energy = 0.0f64;
            let mut launches = 0u64;

            match &cl.exec {
                LayerExec::MaxPool { size, stride } => {
                    for l in 0..nb {
                        pool_into(
                            &cur[l * a_str..l * a_str + in_elems],
                            cl.in_dims,
                            *size,
                            *stride,
                            true,
                            &mut nxt[l * a_str..l * a_str + out_elems],
                            cl.out_dims,
                        );
                    }
                }
                LayerExec::AvgPool { size, stride } => {
                    for l in 0..nb {
                        pool_into(
                            &cur[l * a_str..l * a_str + in_elems],
                            cl.in_dims,
                            *size,
                            *stride,
                            false,
                            &mut nxt[l * a_str..l * a_str + out_elems],
                            cl.out_dims,
                        );
                    }
                }
                LayerExec::Conv { pad, padded_dims, full_dims, stride, kernels } => {
                    // 1. Host padding, per lane, into the staging
                    //    buffer. The kernel then reads a strided view:
                    //    lane images at `in_stride` apart.
                    let (conv_in, in_stride): (&[i32], usize) = if *pad > 0 {
                        let (pc, ph, pw) = *padded_dims;
                        let padded_elems = pc * ph * pw;
                        for l in 0..nb {
                            pad_into(
                                &cur[l * a_str..l * a_str + in_elems],
                                cl.in_dims,
                                *pad,
                                &mut stage[l * s_str..l * s_str + padded_elems],
                            );
                        }
                        (&stage[..], s_str)
                    } else {
                        (&cur[..], a_str)
                    };
                    // 2. The prebuilt kernel replays every lane through
                    //    one shared program walk, per group, into the
                    //    full stride-1 output.
                    let (fk, fh, fw) = *full_dims;
                    let full_elems = fk * fh * fw;
                    let (dst, dst_stride): (&mut [i32], usize) = if *stride > 1 {
                        (&mut full[..], f_str)
                    } else {
                        (&mut nxt[..], a_str)
                    };
                    debug_assert!(dst_stride >= full_elems);
                    if kernels.len() == 1 {
                        let outcome = kernels[0]
                            .run_batch_into(
                                &self.cgra,
                                nb,
                                conv_in,
                                in_stride,
                                scratch,
                                dst,
                                dst_stride,
                            )
                            .with_context(lctx)?;
                        conv_cycles += outcome.latency.total_cycles();
                        conv_energy += outcome_energy(&outcome, &model);
                        launches += outcome.latency.launches;
                    } else {
                        // A group's input channels are contiguous
                        // *within each lane's padded image*, so the
                        // group view is just an offset into the same
                        // strided layout — no per-group staging copy.
                        let sub = kernels[0].shape();
                        let cg = sub.c;
                        let per_out = sub.output_elems();
                        let (_, ph, pw) = *padded_dims;
                        for (g, kernel) in kernels.iter().enumerate() {
                            let lo = g * cg * ph * pw;
                            let outcome = kernel
                                .run_batch_into(
                                    &self.cgra,
                                    nb,
                                    &conv_in[lo..],
                                    in_stride,
                                    scratch,
                                    &mut dst[g * per_out..],
                                    dst_stride,
                                )
                                .with_context(|| format!("group {g}"))
                                .with_context(lctx)?;
                            conv_cycles += outcome.latency.total_cycles();
                            conv_energy += outcome_energy(&outcome, &model);
                            launches += outcome.latency.launches;
                        }
                    }
                    // 3. Decimate each lane's full output down to the
                    //    layer output.
                    if *stride > 1 {
                        for l in 0..nb {
                            decimate_into(
                                &full[l * f_str..l * f_str + full_elems],
                                *full_dims,
                                *stride,
                                &mut nxt[l * a_str..l * a_str + out_elems],
                                cl.out_dims,
                            );
                        }
                    }
                }
            }

            // 4. Fused ReLU in place, per lane, charged like the
            //    engine's (once — the run is per-inference).
            let (mut relu_cycles, mut relu_uj) = (0u64, 0.0f64);
            if cl.relu {
                for l in 0..nb {
                    for v in nxt[l * a_str..l * a_str + out_elems].iter_mut() {
                        *v = (*v).max(0);
                    }
                }
                let (rc, re) = relu_cost(&model, cl.relu_elems);
                relu_cycles = rc;
                relu_uj = re;
            }

            // 5. Opt-in golden debug check, per lane.
            let exact = match &mut golden_x {
                None => None,
                Some(gxs) => {
                    let mut ok = true;
                    for (l, gx) in gxs.iter_mut().enumerate() {
                        *gx = golden_layer(&self.net.layers[index], gx)?;
                        ok &= gx.data[..] == nxt[l * a_str..l * a_str + out_elems];
                    }
                    all_exact &= ok;
                    Some(ok)
                }
            };

            let cycles = conv_cycles + cl.host.cycles + relu_cycles;
            let energy_uj = conv_energy + host_energy_uj(&model, cl.host) + relu_uj;
            total_cycles += cycles;
            total_energy += energy_uj;
            relu_total += relu_cycles;
            annotate_layer(&mut lsp, cl, cycles, conv_cycles, relu_cycles, launches);
            if let Some(d) = lf.finish() {
                profile::record_layer(format!("L{index:02}:{}", cl.kind), &d);
            }
            layers.push(LayerRun {
                cycles,
                conv_cycles,
                host_cycles: cl.host.cycles + relu_cycles,
                relu_cycles,
                energy_uj,
                launches,
                mapping: cl.mapping,
                report: None,
                exact,
            });
            std::mem::swap(&mut cur, &mut nxt);
        }
        rsp.arg("modeled_cycles", total_cycles);
        rsp.arg("layers", self.layers.len());

        let (oc, oh, ow) = self.layers.last().map(|l| l.out_dims).unwrap_or((c, h, w));
        let out_elems = oc * oh * ow;
        for (l, t) in outs.iter_mut().take(nb).enumerate() {
            ensure_len(&mut t.data, out_elems);
            t.data.copy_from_slice(&cur[l * a_str..l * a_str + out_elems]);
            t.c = oc;
            t.h = oh;
            t.w = ow;
        }
        *served = nb;

        Ok(InferRun {
            layers,
            total_cycles,
            total_energy_uj: total_energy,
            relu_cycles: relu_total,
            exact: verify.then_some(all_exact),
            profile: pf.finish(),
        })
    }
}

// ---------------------------------------------------------------------------
// AOT artifact codec (DESIGN.md §13)
// ---------------------------------------------------------------------------

/// The `AutoDecision` reason for concrete mappings
/// (`Mapping::resolve`'s literal, re-stated here for the wire codec).
const REASON_EXPLICIT: &str = "requested explicitly";

/// Fallback reason for artifacts written by a build whose reason tag
/// this build does not know (forward-compatibility inside one format
/// version).
const REASON_FROM_ARTIFACT: &str = "auto decision recorded in a compiled artifact";

/// Map an `AutoDecision` reason to its stable wire tag. The reasons are
/// `&'static str`s, so they travel by tag, not by copying the text.
fn encode_reason(reason: &str) -> u8 {
    if reason == REASON_EXPLICIT {
        0
    } else if reason == kernels::common::AUTO_REASON_WP {
        1
    } else if reason == kernels::common::AUTO_REASON_OP_IM2COL {
        2
    } else if reason == auto::AUTO_REASON_COST {
        3
    } else {
        4
    }
}

/// Inverse of [`encode_reason`]; unknown tags degrade to a generic
/// reason instead of failing the load.
fn decode_reason(tag: u8) -> &'static str {
    match tag {
        0 => REASON_EXPLICIT,
        1 => kernels::common::AUTO_REASON_WP,
        2 => kernels::common::AUTO_REASON_OP_IM2COL,
        3 => auto::AUTO_REASON_COST,
        _ => REASON_FROM_ARTIFACT,
    }
}

fn encode_dims(w: &mut Writer, d: (usize, usize, usize)) {
    w.usize(d.0);
    w.usize(d.1);
    w.usize(d.2);
}

fn decode_dims(r: &mut Reader) -> Result<(usize, usize, usize)> {
    Ok((r.usize()?, r.usize()?, r.usize()?))
}

impl CompiledNet {
    /// Serialize the whole artifact (manifest + payload) into the
    /// versioned on-disk format (DESIGN.md §13). [`CompiledNet::save`]
    /// writes these bytes to a file.
    pub fn serialize(&self) -> Vec<u8> {
        artifact::serialize(self)
    }

    /// Serialize to `path`, returning the written artifact's identity
    /// (fingerprints, checksum, size).
    pub fn save(&self, path: &Path) -> Result<ArtifactInfo> {
        artifact::save(self, path)
    }

    /// Load an artifact from `path` into `engine`'s session. The file's
    /// format version, crate version, checksum and session fingerprint
    /// are all validated before any payload is trusted, and the load
    /// path performs **zero program builds, zero µop decodes and zero
    /// planner calls** — `tests/compiled_counters.rs` pins this with
    /// [`RunCounters`].
    pub fn load(engine: &Engine, path: &Path) -> Result<(CompiledNet, ArtifactInfo)> {
        artifact::load(engine, path)
    }

    /// The config ⊕ energy-model fingerprint this artifact was compiled
    /// under — must equal the loading engine's
    /// [`Engine::session_fingerprint`].
    pub(crate) fn session_fp(&self) -> u64 {
        cache::cfg_fingerprint(self.cgra.config()) ^ cache::energy_fingerprint(&self.model)
    }

    /// Encode the binary payload: the deduplicated program table first,
    /// then the source graph, the compiled layers (kernels referencing
    /// programs by table index), and the arena sizing.
    pub(crate) fn wire_encode_body(&self, w: &mut Writer) {
        // Intern every kernel's programs up front so the table is
        // complete before it is written; kernel encoding below then
        // resolves to the same indices (shared `Arc`s dedupe).
        let mut table = ProgTable::new();
        for cl in &self.layers {
            if let LayerExec::Conv { kernels, .. } = &cl.exec {
                for k in kernels {
                    k.collect_progs(&mut table);
                }
            }
        }
        let progs: Vec<Arc<DecodedProgram>> = table.progs().to_vec();
        w.u32(progs.len() as u32);
        for p in &progs {
            p.wire_encode(w);
        }
        artifact::encode_net(w, &self.net);
        w.u32(self.layers.len() as u32);
        for cl in &self.layers {
            match cl.mapping {
                None => w.bool(false),
                Some(m) => {
                    w.bool(true);
                    w.str(m.label());
                }
            }
            match cl.auto {
                None => w.bool(false),
                Some(d) => {
                    w.bool(true);
                    w.str(d.mapping.label());
                    w.u8(encode_reason(d.reason));
                }
            }
            w.u64(cl.macs);
            w.u64(cl.cpu_cycles);
            w.u64(cl.host.cycles);
            w.u64(cl.host.accesses);
            w.bool(cl.relu);
            w.usize(cl.relu_elems);
            encode_dims(w, cl.in_dims);
            encode_dims(w, cl.out_dims);
            match &cl.exec {
                LayerExec::Conv { pad, padded_dims, full_dims, stride, kernels } => {
                    w.u8(0);
                    w.usize(*pad);
                    encode_dims(w, *padded_dims);
                    encode_dims(w, *full_dims);
                    w.usize(*stride);
                    w.u32(kernels.len() as u32);
                    for k in kernels {
                        k.wire_encode(w, &mut table);
                    }
                }
                LayerExec::MaxPool { size, stride } => {
                    w.u8(1);
                    w.usize(*size);
                    w.usize(*stride);
                }
                LayerExec::AvgPool { size, stride } => {
                    w.u8(2);
                    w.usize(*size);
                    w.usize(*stride);
                }
            }
        }
        w.usize(self.arena.act_elems);
        w.usize(self.arena.stage_elems);
        w.usize(self.arena.full_elems);
        w.usize(self.arena.group_elems);
        w.usize(self.arena.scratch.hwc_elems);
        w.usize(self.arena.scratch.patch_elems);
    }

    /// Decode the binary payload into a runnable artifact bound to
    /// `engine`'s session (the caller has already verified the session
    /// fingerprint matches). Reconstructs decoded programs, kernels,
    /// layer plans and the arena **without building or decoding
    /// anything** — `kind`/`desc` metadata is re-derived from the
    /// deserialized graph, which is free.
    pub(crate) fn wire_decode_body(r: &mut Reader, engine: &Engine) -> Result<CompiledNet> {
        let n_progs = r.u32()? as usize;
        let mut progs: Vec<Arc<DecodedProgram>> = Vec::with_capacity(n_progs.min(1 << 16));
        for _ in 0..n_progs {
            progs.push(Arc::new(DecodedProgram::wire_decode(r)?));
        }
        let net = artifact::decode_net(r)?;
        net.validate()?;
        let n_layers = r.u32()? as usize;
        ensure!(
            n_layers == net.layers.len(),
            "artifact carries {n_layers} compiled layers for a {}-layer graph",
            net.layers.len()
        );
        let mem_words = engine.config().mem_words;
        let mut layers = Vec::with_capacity(n_layers);
        for (index, src) in net.layers.iter().enumerate() {
            let lctx = || format!("compiled layer {index} ({})", src.kind());
            let mapping =
                if r.bool()? { Some(Mapping::parse(&r.str()?).with_context(lctx)?) } else { None };
            let auto = if r.bool()? {
                let m = Mapping::parse(&r.str()?).with_context(lctx)?;
                Some(AutoDecision { mapping: m, reason: decode_reason(r.u8()?) })
            } else {
                None
            };
            let macs = r.u64()?;
            let cpu_cycles = r.u64()?;
            let host = HostOp { cycles: r.u64()?, accesses: r.u64()? };
            let relu = r.bool()?;
            let relu_elems = r.usize()?;
            let in_dims = decode_dims(r)?;
            let out_dims = decode_dims(r)?;
            let exec = match r.u8()? {
                0 => {
                    let pad = r.usize()?;
                    let padded_dims = decode_dims(r)?;
                    let full_dims = decode_dims(r)?;
                    let stride = r.usize()?;
                    ensure!(stride >= 1, "compiled layer {index} has stride 0");
                    let nk = r.u32()? as usize;
                    ensure!(nk >= 1, "compiled conv layer {index} has no kernels");
                    let mut ks = Vec::with_capacity(nk);
                    for _ in 0..nk {
                        ks.push(
                            CompiledKernel::wire_decode(r, &progs, mem_words)
                                .with_context(lctx)?,
                        );
                    }
                    LayerExec::Conv { pad, padded_dims, full_dims, stride, kernels: ks }
                }
                1 => LayerExec::MaxPool { size: r.usize()?, stride: r.usize()? },
                2 => LayerExec::AvgPool { size: r.usize()?, stride: r.usize()? },
                t => bail!("unknown layer-exec tag {t} in compiled layer {index}"),
            };
            layers.push(CompiledLayer {
                kind: src.kind(),
                desc: src.describe(),
                mapping,
                auto,
                macs,
                cpu_cycles,
                host,
                relu,
                relu_elems,
                in_dims,
                out_dims,
                exec,
            });
        }
        let arena = ArenaSpec {
            act_elems: r.usize()?,
            stage_elems: r.usize()?,
            full_elems: r.usize()?,
            group_elems: r.usize()?,
            scratch: ScratchNeed { hwc_elems: r.usize()?, patch_elems: r.usize()? },
        };
        Ok(CompiledNet {
            net,
            layers,
            cgra: Cgra::new(engine.config().clone())?,
            model: *engine.energy_model(),
            arena,
        })
    }
}

/// Layer conv energy — the same [`MappingReport::from_outcome`] energy
/// evaluation, without constructing the row (the hot path skips the
/// string work).
fn outcome_energy(outcome: &ConvOutcome, model: &EnergyModel) -> f64 {
    model.evaluate(outcome).total_uj()
}

/// Snapshot of every compile-side work counter the warm path must not
/// move: launch-program builds, µop decodes, planner estimate calls,
/// and arena allocations. `tests/compiled_counters.rs` asserts a warm
/// [`CompiledNet::run`] leaves all four unchanged.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RunCounters {
    /// Launch programs built, process-wide.
    pub program_builds: u64,
    /// µop decodes performed, process-wide.
    pub uop_decodes: u64,
    /// Planner estimates served by this engine's planner (memo hits
    /// included — a warm run must not even consult the memo).
    pub planner_estimates: u64,
    /// Arena allocations (context buffers created or grown),
    /// process-wide.
    pub arena_allocs: u64,
}

impl RunCounters {
    /// Read the current counter values.
    pub fn snapshot(engine: &Engine) -> RunCounters {
        RunCounters {
            program_builds: kernels::program_builds(),
            uop_decodes: cgra::decode_count(),
            planner_estimates: engine.planner().stats().estimates,
            arena_allocs: kernels::arena_allocs(),
        }
    }
}

// Unit tests live in `tests/compiled.rs` (equivalence grid, Arc
// concurrency) and `tests/compiled_counters.rs` (warm-path counters):
// the contract spans the whole stack, so it is pinned at the
// integration level.
