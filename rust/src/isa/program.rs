//! Per-PE and whole-array program containers.

use super::{Instr, PeId, N_PES};

/// Capacity of a PE's private program memory, in instruction words.
/// The paper's OpenEdgeCGRA instance has a 32-word program memory per PE;
/// every kernel generator asserts it fits.
pub const PROG_CAPACITY: usize = 32;

/// The program of a single PE (at most [`PROG_CAPACITY`] words).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PeProgram {
    instrs: Vec<Instr>,
}

impl PeProgram {
    /// Empty program (the PE idles at an implicit `nop` and never
    /// terminates by itself; some other PE must `exit`).
    pub fn new() -> Self {
        PeProgram { instrs: Vec::new() }
    }

    /// Build from a list of instructions. Panics if over capacity.
    pub fn from_instrs(instrs: Vec<Instr>) -> Self {
        assert!(
            instrs.len() <= PROG_CAPACITY,
            "PE program of {} words exceeds the {}-word program memory",
            instrs.len(),
            PROG_CAPACITY
        );
        PeProgram { instrs }
    }

    /// Append one instruction, returning its slot index.
    pub fn push(&mut self, i: Instr) -> usize {
        assert!(
            self.instrs.len() < PROG_CAPACITY,
            "PE program overflows the {PROG_CAPACITY}-word program memory"
        );
        self.instrs.push(i);
        self.instrs.len() - 1
    }

    /// Number of words used.
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    /// True if no instructions were written.
    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Fetch the instruction at `pc`, or `nop` past the end (a PE whose
    /// column PC runs past its program idles).
    pub fn fetch(&self, pc: usize) -> Instr {
        self.instrs.get(pc).copied().unwrap_or_else(Instr::nop)
    }

    /// All instructions.
    pub fn instrs(&self) -> &[Instr] {
        &self.instrs
    }

    /// Mutable access (used by generators to patch branch targets).
    pub fn instrs_mut(&mut self) -> &mut [Instr] {
        &mut self.instrs
    }
}

/// A whole-array program: one [`PeProgram`] per PE plus optional
/// human-readable labels (used by traces and the disassembler).
#[derive(Clone, Debug, Default)]
pub struct Program {
    pes: Vec<PeProgram>,
    /// Free-form name shown in traces/reports.
    pub name: String,
}

impl Program {
    /// All-empty program.
    pub fn new(name: impl Into<String>) -> Self {
        Program { pes: vec![PeProgram::new(); N_PES], name: name.into() }
    }

    /// Access the program of one PE.
    pub fn pe(&self, id: PeId) -> &PeProgram {
        &self.pes[id.index()]
    }

    /// Mutable access to the program of one PE.
    pub fn pe_mut(&mut self, id: PeId) -> &mut PeProgram {
        &mut self.pes[id.index()]
    }

    /// Replace the program of one PE.
    pub fn set_pe(&mut self, id: PeId, p: PeProgram) {
        self.pes[id.index()] = p;
    }

    /// Longest per-PE program length.
    pub fn max_len(&self) -> usize {
        self.pes.iter().map(|p| p.len()).max().unwrap_or(0)
    }

    /// Total instruction words across all PEs.
    pub fn total_words(&self) -> usize {
        self.pes.iter().map(|p| p.len()).sum()
    }

    /// Disassembly listing of the whole array (one section per PE).
    pub fn disassemble(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "; program: {}", self.name);
        for id in PeId::all() {
            let p = self.pe(id);
            if p.is_empty() {
                continue;
            }
            let _ = writeln!(s, ".pe {} {}", id.row, id.col);
            for (slot, i) in p.instrs().iter().enumerate() {
                let _ = writeln!(s, "  @{slot:<2} {i}");
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Dst, Op, Src};

    #[test]
    fn capacity_enforced() {
        let mut p = PeProgram::new();
        for _ in 0..PROG_CAPACITY {
            p.push(Instr::nop());
        }
        assert_eq!(p.len(), PROG_CAPACITY);
        let r = std::panic::catch_unwind(move || {
            let mut p = p;
            p.push(Instr::nop());
        });
        assert!(r.is_err());
    }

    #[test]
    fn fetch_past_end_is_nop() {
        let p = PeProgram::from_instrs(vec![Instr::exit()]);
        assert_eq!(p.fetch(0).op, Op::Exit);
        assert_eq!(p.fetch(1).op, Op::Nop);
        assert_eq!(p.fetch(100).op, Op::Nop);
    }

    #[test]
    fn disassemble_skips_empty_pes() {
        let mut prog = Program::new("t");
        prog.pe_mut(PeId::new(1, 2)).push(Instr::new(Op::Add, Src::Zero, Src::Imm(3), Dst::Out));
        let d = prog.disassemble();
        assert!(d.contains(".pe 1 2"));
        assert!(d.contains("add out <- zero, #3"));
        assert!(!d.contains(".pe 0 0"));
    }

    #[test]
    fn total_words_counts_all() {
        let mut prog = Program::new("t");
        prog.pe_mut(PeId::new(0, 0)).push(Instr::nop());
        prog.pe_mut(PeId::new(3, 3)).push(Instr::nop());
        prog.pe_mut(PeId::new(3, 3)).push(Instr::exit());
        assert_eq!(prog.total_words(), 3);
        assert_eq!(prog.max_len(), 2);
    }
}
