//! Instruction word: operation, two operand sources, destination.

use super::{Dir, N_REGS};

/// Operand source mux of a PE.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Src {
    /// Constant zero (hardware tie-off).
    Zero,
    /// Sign-extended immediate from the instruction word.
    Imm(i32),
    /// Register-file entry 0..=3.
    Reg(u8),
    /// The PE's own output register (ROUT).
    Own,
    /// A torus neighbour's output register.
    Neigh(Dir),
    /// The PE's DMA address register (useful for address arithmetic).
    Addr,
}

impl Src {
    /// Shorthand for `Src::Reg`, panicking on out-of-range index.
    pub fn reg(i: usize) -> Src {
        assert!(i < N_REGS, "register index {i} out of range");
        Src::Reg(i as u8)
    }
}

/// Destination mux of a PE.
///
/// Divergence from silicon (documented in DESIGN.md §3.1): the real PE
/// always latches results into ROUT; we additionally permit register-only
/// writes (`Reg`), which the mapping schedules use so ROUT can carry a
/// *different* value for the neighbours while a local temporary is
/// updated. Instruction counts — the quantity the paper reports — are
/// unaffected.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Dst {
    /// Latch into the output register only.
    Out,
    /// Latch into a register-file entry only.
    Reg(u8),
    /// Latch into both ROUT and a register-file entry.
    Both(u8),
    /// Discard the result (stores, branches, nop).
    None,
}

impl Dst {
    /// Shorthand for `Dst::Reg`, panicking on out-of-range index.
    pub fn reg(i: usize) -> Dst {
        assert!(i < N_REGS, "register index {i} out of range");
        Dst::Reg(i as u8)
    }

    /// Shorthand for `Dst::Both`, panicking on out-of-range index.
    pub fn both(i: usize) -> Dst {
        assert!(i < N_REGS, "register index {i} out of range");
        Dst::Both(i as u8)
    }
}

/// Operations supported by the PE's ALU / load-store unit / branch unit.
///
/// All arithmetic is wrapping 32-bit integer arithmetic (the paper's
/// kernels use 32-bit integer data). There is deliberately **no MAC**.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Op {
    /// No operation; burns one slot (counted in the utilization stats).
    Nop,
    /// Halt the whole array (any PE issuing `Exit` stops execution at the
    /// end of the current step).
    Exit,
    /// `dst = a` (b ignored).
    Mov,
    /// `dst = a + b` (wrapping).
    Add,
    /// `dst = a - b` (wrapping).
    Sub,
    /// `dst = a * b` (wrapping, low 32 bits). Multi-cycle: see
    /// [`crate::cgra::CgraConfig::mul_latency`].
    Mul,
    /// `dst = a << (b & 31)`.
    Shl,
    /// `dst = a >> (b & 31)` (arithmetic).
    Shr,
    /// `dst = a & b`.
    And,
    /// `dst = a | b`.
    Or,
    /// `dst = a ^ b`.
    Xor,
    /// `dst = min(a, b)` (signed).
    Min,
    /// `dst = max(a, b)` (signed).
    Max,
    /// Set the PE's DMA address register: `addr = a + b`.
    SetAddr,
    /// Load word: `dst = mem[a + b]` (word address). Goes through the
    /// column's DMA port (contention modeled).
    Lw,
    /// Load word via the address register with post-increment:
    /// `dst = mem[addr]; addr += a + b`. This is the paper's
    /// "load with automatic index increment".
    LwInc,
    /// Store word: `mem[addr] = a; addr += b` (post-increment store).
    SwInc,
    /// Store word at computed address: `mem[a + b] = rout` — stores the
    /// PE's current output register at address `a + b`.
    SwAt,
    /// Branch if `a == b` to the absolute slot in the instruction's
    /// `target` field (column PC).
    Beq,
    /// Branch if `a != b`.
    Bne,
    /// Branch if `a < b` (signed).
    Blt,
    /// Branch if `a >= b` (signed).
    Bge,
    /// Unconditional jump.
    Jump,
}

impl Op {
    /// True for loads/stores (they contend for the column DMA port).
    pub fn is_mem(self) -> bool {
        matches!(self, Op::Lw | Op::LwInc | Op::SwInc | Op::SwAt)
    }

    /// True for loads.
    pub fn is_load(self) -> bool {
        matches!(self, Op::Lw | Op::LwInc)
    }

    /// True for stores.
    pub fn is_store(self) -> bool {
        matches!(self, Op::SwInc | Op::SwAt)
    }

    /// True for control-flow operations (they steer the column PC).
    pub fn is_ctrl(self) -> bool {
        matches!(self, Op::Beq | Op::Bne | Op::Blt | Op::Bge | Op::Jump)
    }

    /// True if the slot does useful work (not `Nop`). `Exit` counts as
    /// control. Utilization in Fig. 3 is `active / (active + nop)`.
    pub fn is_active(self) -> bool {
        !matches!(self, Op::Nop)
    }

    /// Mnemonic used by the assembler/disassembler.
    pub fn mnemonic(self) -> &'static str {
        match self {
            Op::Nop => "nop",
            Op::Exit => "exit",
            Op::Mov => "mov",
            Op::Add => "add",
            Op::Sub => "sub",
            Op::Mul => "mul",
            Op::Shl => "shl",
            Op::Shr => "shr",
            Op::And => "and",
            Op::Or => "or",
            Op::Xor => "xor",
            Op::Min => "min",
            Op::Max => "max",
            Op::SetAddr => "setaddr",
            Op::Lw => "lw",
            Op::LwInc => "lwinc",
            Op::SwInc => "swinc",
            Op::SwAt => "swat",
            Op::Beq => "beq",
            Op::Bne => "bne",
            Op::Blt => "blt",
            Op::Bge => "bge",
            Op::Jump => "jump",
        }
    }
}

/// One instruction word.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Instr {
    /// Operation.
    pub op: Op,
    /// First operand source.
    pub a: Src,
    /// Second operand source.
    pub b: Src,
    /// Result destination.
    pub dst: Dst,
    /// Branch target (absolute slot within the 32-word program) for
    /// control-flow ops; ignored otherwise.
    pub target: u8,
}

impl Instr {
    /// Generic constructor.
    pub fn new(op: Op, a: Src, b: Src, dst: Dst) -> Instr {
        Instr { op, a, b, dst, target: 0 }
    }

    /// `nop`.
    pub fn nop() -> Instr {
        Instr::new(Op::Nop, Src::Zero, Src::Zero, Dst::None)
    }

    /// `exit`.
    pub fn exit() -> Instr {
        Instr::new(Op::Exit, Src::Zero, Src::Zero, Dst::None)
    }

    /// `mov dst ← a`.
    pub fn mov(dst: Dst, a: Src) -> Instr {
        Instr::new(Op::Mov, a, Src::Zero, dst)
    }

    /// Branch helper: `op` must be a control op.
    pub fn branch(op: Op, a: Src, b: Src, target: usize) -> Instr {
        assert!(op.is_ctrl(), "{op:?} is not a control op");
        assert!(target < super::PROG_CAPACITY, "branch target {target} out of range");
        Instr { op, a, b, dst: Dst::None, target: target as u8 }
    }

    /// `jump target`.
    pub fn jump(target: usize) -> Instr {
        Instr::branch(Op::Jump, Src::Zero, Src::Zero, target)
    }
}

impl std::fmt::Display for Src {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Src::Zero => write!(f, "zero"),
            Src::Imm(v) => write!(f, "#{v}"),
            Src::Reg(r) => write!(f, "r{r}"),
            Src::Own => write!(f, "own"),
            Src::Neigh(d) => write!(f, "{}", d.to_string().to_lowercase()),
            Src::Addr => write!(f, "addr"),
        }
    }
}

impl std::fmt::Display for Dst {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Dst::Out => write!(f, "out"),
            Dst::Reg(r) => write!(f, "r{r}"),
            Dst::Both(r) => write!(f, "out+r{r}"),
            Dst::None => write!(f, "_"),
        }
    }
}

impl std::fmt::Display for Instr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.op.is_ctrl() {
            write!(f, "{} {}, {} -> @{}", self.op.mnemonic(), self.a, self.b, self.target)
        } else {
            write!(f, "{} {} <- {}, {}", self.op.mnemonic(), self.dst, self.a, self.b)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_classes_are_disjoint_where_expected() {
        for op in [
            Op::Nop,
            Op::Exit,
            Op::Mov,
            Op::Add,
            Op::Sub,
            Op::Mul,
            Op::Shl,
            Op::Shr,
            Op::And,
            Op::Or,
            Op::Xor,
            Op::Min,
            Op::Max,
            Op::SetAddr,
            Op::Lw,
            Op::LwInc,
            Op::SwInc,
            Op::SwAt,
            Op::Beq,
            Op::Bne,
            Op::Blt,
            Op::Bge,
            Op::Jump,
        ] {
            assert!(!(op.is_mem() && op.is_ctrl()), "{op:?} both mem and ctrl");
            assert_eq!(op.is_load() || op.is_store(), op.is_mem(), "{op:?} mem class");
        }
    }

    #[test]
    fn nop_is_inactive_everything_else_active() {
        assert!(!Op::Nop.is_active());
        assert!(Op::Mov.is_active());
        assert!(Op::Exit.is_active());
    }

    #[test]
    fn display_formats() {
        let i = Instr::new(Op::Add, Src::reg(1), Src::Neigh(Dir::East), Dst::Out);
        assert_eq!(i.to_string(), "add out <- r1, e");
        let b = Instr::branch(Op::Bne, Src::reg(3), Src::Zero, 2);
        assert_eq!(b.to_string(), "bne r3, zero -> @2");
    }

    #[test]
    #[should_panic]
    fn branch_with_alu_op_panics() {
        let _ = Instr::branch(Op::Add, Src::Zero, Src::Zero, 0);
    }
}
