//! The OpenEdgeCGRA instruction set.
//!
//! Modeled after the open-source OpenEdgeCGRA PE (CF'23): each processing
//! element executes a private 32-word program of simple 32-bit integer
//! instructions. There is **no multiply-accumulate instruction** — the
//! paper calls this out explicitly, and the mapping kernels work around it
//! with separate `Mul`/`Add` steps.
//!
//! An instruction is `{op, src_a, src_b, dst}`:
//!
//! - sources ([`Src`]) select between an immediate, the register file
//!   (4 entries), the PE's own output register, one of the four torus
//!   neighbours' output registers, or the PE's DMA address register;
//! - the destination ([`Dst`]) latches the result into the output register
//!   (`Out`, the only value neighbours can see), a register-file entry, or
//!   both;
//! - loads/stores go through the *column's* DMA port and support the
//!   auto-increment addressing mode the paper leverages for Im2col
//!   (`LwInc`/`SwInc`);
//! - control flow (`Beq`/`Bne`/`Blt`/`Bge`/`Jump`) retargets the **column**
//!   program counter; the executor enforces that at most one PE per column
//!   issues control flow in a given step.

mod instr;
mod program;

pub use instr::{Dst, Instr, Op, Src};
pub use program::{PeProgram, Program, PROG_CAPACITY};

/// Grid geometry of the simulated OpenEdgeCGRA instance (the paper uses a
/// fixed 4×4 array; the simulator is generic but the kernels target 4×4).
pub const ROWS: usize = 4;
/// Number of PE columns (each column shares one DMA port and one PC).
pub const COLS: usize = 4;
/// Total number of PEs.
pub const N_PES: usize = ROWS * COLS;
/// Register-file entries per PE.
pub const N_REGS: usize = 4;

/// Identifies one processing element by (row, col).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct PeId {
    /// Row index, 0..ROWS.
    pub row: usize,
    /// Column index, 0..COLS.
    pub col: usize,
}

impl PeId {
    /// Construct, panicking on out-of-range coordinates.
    pub fn new(row: usize, col: usize) -> Self {
        assert!(row < ROWS && col < COLS, "PE ({row},{col}) out of range");
        PeId { row, col }
    }

    /// Linear index in row-major order.
    pub fn index(self) -> usize {
        self.row * COLS + self.col
    }

    /// Inverse of [`PeId::index`].
    pub fn from_index(i: usize) -> Self {
        assert!(i < N_PES);
        PeId { row: i / COLS, col: i % COLS }
    }

    /// Torus neighbour in the given direction.
    pub fn neighbour(self, d: Dir) -> PeId {
        match d {
            Dir::North => PeId { row: (self.row + ROWS - 1) % ROWS, col: self.col },
            Dir::South => PeId { row: (self.row + 1) % ROWS, col: self.col },
            Dir::East => PeId { row: self.row, col: (self.col + 1) % COLS },
            Dir::West => PeId { row: self.row, col: (self.col + COLS - 1) % COLS },
        }
    }

    /// All 16 PEs in row-major order.
    pub fn all() -> impl Iterator<Item = PeId> {
        (0..N_PES).map(PeId::from_index)
    }
}

impl std::fmt::Display for PeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PE({},{})", self.row, self.col)
    }
}

/// Torus directions. `North` is row−1 (wrapping), `South` row+1, `East`
/// col+1, `West` col−1 — matching the neighbour-output mux of the PE.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Dir {
    North,
    South,
    East,
    West,
}

impl Dir {
    /// All four directions.
    pub const ALL: [Dir; 4] = [Dir::North, Dir::South, Dir::East, Dir::West];

    /// The opposite direction.
    pub fn opposite(self) -> Dir {
        match self {
            Dir::North => Dir::South,
            Dir::South => Dir::North,
            Dir::East => Dir::West,
            Dir::West => Dir::East,
        }
    }
}

impl std::fmt::Display for Dir {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Dir::North => "N",
            Dir::South => "S",
            Dir::East => "E",
            Dir::West => "W",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pe_id_roundtrip() {
        for i in 0..N_PES {
            assert_eq!(PeId::from_index(i).index(), i);
        }
    }

    #[test]
    fn torus_wraps() {
        let p = PeId::new(0, 0);
        assert_eq!(p.neighbour(Dir::North), PeId::new(3, 0));
        assert_eq!(p.neighbour(Dir::West), PeId::new(0, 3));
        assert_eq!(p.neighbour(Dir::South), PeId::new(1, 0));
        assert_eq!(p.neighbour(Dir::East), PeId::new(0, 1));
    }

    #[test]
    fn neighbour_opposite_is_identity() {
        for p in PeId::all() {
            for d in Dir::ALL {
                assert_eq!(p.neighbour(d).neighbour(d.opposite()), p);
            }
        }
    }

    #[test]
    #[should_panic]
    fn out_of_range_panics() {
        let _ = PeId::new(4, 0);
    }
}
