//! Plain-text table / bar-chart rendering for reports and benches.
//!
//! The paper's figures are regenerated as CSV plus an ASCII rendering so
//! results are inspectable straight from the terminal (no plotting stack
//! in the offline environment).

/// A simple left-aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no rows were added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(c);
                for _ in c.chars().count()..width[i] {
                    out.push(' ');
                }
            }
            while out.ends_with(' ') {
                out.pop();
            }
            out.push('\n');
        };
        line(&self.headers, &mut out);
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            line(r, &mut out);
        }
        out
    }

    /// Render as CSV (headers + rows).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.headers.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Horizontal ASCII bar chart: one labelled bar per entry, scaled to
/// `width` characters at the maximum value.
pub fn bar_chart(entries: &[(String, f64)], width: usize) -> String {
    let max = entries.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max).max(1e-12);
    let label_w = entries.iter().map(|(l, _)| l.chars().count()).max().unwrap_or(0);
    let mut out = String::new();
    for (label, v) in entries {
        let n = ((v / max) * width as f64).round().max(0.0) as usize;
        out.push_str(&format!("{label:<label_w$} |{} {v:.4}\n", "#".repeat(n)));
    }
    out
}

/// Human-formatted quantities.
pub fn si(v: f64) -> String {
    let (scaled, suffix) = if v.abs() >= 1e9 {
        (v / 1e9, "G")
    } else if v.abs() >= 1e6 {
        (v / 1e6, "M")
    } else if v.abs() >= 1e3 {
        (v / 1e3, "k")
    } else {
        (v, "")
    };
    if suffix.is_empty() && scaled.fract() == 0.0 {
        format!("{scaled}")
    } else {
        format!("{scaled:.2}{suffix}")
    }
}

/// Format a byte count in KiB with two decimals (the paper reports
/// memory footprints in KiB against the 512 KiB budget).
pub fn kib(bytes: usize) -> String {
    format!("{:.2} KiB", bytes as f64 / 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "val"]);
        t.row(vec!["wp".into(), "1".into()]);
        t.row(vec!["im2col-ip".into(), "200".into()]);
        let r = t.render();
        assert!(r.contains("name       val"));
        assert!(r.contains("im2col-ip  200"));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["x,y".into()]);
        assert_eq!(t.to_csv(), "a\n\"x,y\"\n");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn bars_scale_to_max() {
        let c = bar_chart(&[("a".into(), 1.0), ("bb".into(), 2.0)], 10);
        let lines: Vec<&str> = c.lines().collect();
        assert!(lines[0].contains("#####"));
        assert!(lines[1].contains("##########"));
    }

    #[test]
    fn si_and_kib() {
        assert_eq!(si(1500.0), "1.50k");
        assert_eq!(si(2_500_000.0), "2.50M");
        assert_eq!(si(3.0), "3");
        assert_eq!(kib(2048), "2.00 KiB");
    }
}
