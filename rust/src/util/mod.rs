//! Offline-friendly infrastructure: CLI parsing, JSON, text rendering.
//!
//! The build environment vendors no `clap`/`serde`; these small modules
//! replace them (see DESIGN.md "Dependency reality").

pub mod cli;
pub mod fmt;
pub mod json;
pub mod wire;

pub use cli::{Args, OptSpec};
pub use json::Json;
