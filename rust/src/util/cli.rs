//! Tiny typed command-line parser (no `clap` in the offline environment).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors, defaults and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Declarative description of one option (for usage output).
#[derive(Clone, Debug)]
pub struct OptSpec {
    /// Long name without dashes, e.g. `"mapping"`.
    pub name: &'static str,
    /// Metavar / value hint; empty for boolean flags.
    pub value: &'static str,
    /// Help text.
    pub help: &'static str,
}

/// Parsed arguments plus the option specs used for `usage()`.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    specs: Vec<OptSpec>,
    used: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (exclude argv[0]).
    /// `boolean` lists the option names that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        boolean: &[&str],
        specs: Vec<OptSpec>,
    ) -> Result<Args> {
        let mut a = Args { specs, ..Default::default() };
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if boolean.contains(&name) {
                    a.flags.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .with_context(|| format!("option --{name} expects a value"))?;
                    a.opts.insert(name.to_string(), v);
                }
            } else {
                a.positional.push(arg);
            }
        }
        Ok(a)
    }

    /// Parse directly from `std::env::args` after skipping `skip` items.
    pub fn from_env(skip: usize, boolean: &[&str], specs: Vec<OptSpec>) -> Result<Args> {
        Args::parse(std::env::args().skip(skip), boolean, specs)
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Whether a boolean flag was given.
    pub fn flag(&self, name: &str) -> bool {
        self.used.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    /// Optional string option.
    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.used.borrow_mut().push(name.to_string());
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.opt_str(name).unwrap_or(default).to_string()
    }

    /// Typed option with default; errors mention the option name.
    pub fn num_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_str(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("option --{name}={s} is invalid: {e}")),
        }
    }

    /// Required typed option.
    pub fn num<T: std::str::FromStr>(&self, name: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let s = self
            .opt_str(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))?;
        s.parse::<T>().map_err(|e| anyhow::anyhow!("option --{name}={s} is invalid: {e}"))
    }

    /// Error out if the user passed options that no accessor consumed —
    /// catches typos like `--mappings`.
    pub fn reject_unknown(&self) -> Result<()> {
        let used = self.used.borrow();
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !used.iter().any(|u| u == k) {
                bail!("unknown option --{k}\n{}", self.usage());
            }
        }
        Ok(())
    }

    /// Render a usage block from the specs.
    pub fn usage(&self) -> String {
        let mut s = String::from("options:\n");
        for spec in &self.specs {
            let head = if spec.value.is_empty() {
                format!("  --{}", spec.name)
            } else {
                format!("  --{} <{}>", spec.name, spec.value)
            };
            s.push_str(&format!("{head:<28} {}\n", spec.help));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "n", value: "INT", help: "count" },
            OptSpec { name: "verbose", value: "", help: "chatty" },
        ]
    }

    #[test]
    fn parse_forms() {
        let a = Args::parse(
            ["--n", "4", "--name=wp", "pos1", "--verbose"].map(String::from),
            &["verbose"],
            sp(),
        )
        .unwrap();
        assert_eq!(a.num::<usize>("n").unwrap(), 4);
        assert_eq!(a.opt_str("name"), Some("wp"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = Args::parse(["--k", "abc"].map(String::from), &[], sp()).unwrap();
        assert_eq!(a.num_or("missing", 7usize).unwrap(), 7);
        assert!(a.num::<usize>("k").is_err());
        assert!(a.num::<usize>("absent").is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(["--n"].map(String::from), &[], sp()).is_err());
    }

    #[test]
    fn unknown_rejected_after_accessors() {
        let a = Args::parse(["--n", "1", "--typo", "x"].map(String::from), &[], sp()).unwrap();
        let _ = a.num::<usize>("n");
        assert!(a.reject_unknown().is_err());
        let b = Args::parse(["--n", "1"].map(String::from), &[], sp()).unwrap();
        let _ = b.num::<usize>("n");
        assert!(b.reject_unknown().is_ok());
    }

    #[test]
    fn usage_renders() {
        let a = Args::parse(std::iter::empty(), &[], sp()).unwrap();
        let u = a.usage();
        assert!(u.contains("--n <INT>"));
        assert!(u.contains("--verbose"));
    }
}
