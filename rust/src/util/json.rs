//! Minimal JSON value model, serializer and parser.
//!
//! The offline build environment has no `serde`; this module covers the
//! crate's needs: the AOT artifact manifest (read), and report/sweep
//! output files (write). It is a complete little JSON implementation —
//! strings with escapes, numbers, arrays, objects — with precise error
//! positions, not a toy.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// A JSON value. Object keys are ordered (BTreeMap) so output is
/// deterministic — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64; integers round-trip up to 2^53).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with deterministic key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Borrow as object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Borrow as array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Borrow as string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As i64 (must be integral).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Required-field helpers with good error messages.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing JSON field '{key}'"))
    }

    /// Required string field.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow::anyhow!("field '{key}' is not a string"))
    }

    /// Required integer field.
    pub fn req_i64(&self, key: &str) -> Result<i64> {
        self.req(key)?.as_i64().ok_or_else(|| anyhow::anyhow!("field '{key}' is not an integer"))
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {} of JSON input", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        match self.bump() {
            Some(x) if x == b => Ok(()),
            other => bail!(
                "expected '{}' at byte {} but found {:?}",
                b as char,
                self.pos.saturating_sub(1),
                other.map(|c| c as char)
            ),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => bail!("expected ',' or ']' but found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => bail!("expected ',' or '}}' but found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => bail!("unterminated string"),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| anyhow::anyhow!("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| {
                                    anyhow::anyhow!("bad hex digit in \\u escape")
                                })?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => bail!("bad escape {:?}", other.map(|c| c as char)),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.bytes.len() {
                        bail!("truncated UTF-8 sequence in string");
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text.parse().map_err(|_| anyhow::anyhow!("bad number '{text}'"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj(vec![
            ("name", "wp".into()),
            ("cycles", 12345i64.into()),
            ("ratio", 0.5.into()),
            ("ok", true.into()),
            ("tags", vec!["a", "b"].into_iter().map(Json::from).collect::<Vec<_>>().into()),
        ]);
        let s = v.to_string_compact();
        let back = parse(&s).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": -1.5e2}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64().unwrap(), -150.0);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = parse(r#""a\nb\t\"q\" A é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A é");
    }

    #[test]
    fn errors_have_positions() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01x").is_err());
        assert!(parse(r#"{"a":1} trailing"#).unwrap_err().to_string().contains("trailing"));
    }

    #[test]
    fn pretty_output_is_stable() {
        let v = Json::obj(vec![("b", 1i64.into()), ("a", 2i64.into())]);
        // BTreeMap ordering: keys sorted.
        assert_eq!(v.to_string_compact(), r#"{"a":2,"b":1}"#);
        assert!(v.to_string_pretty().contains("\n  \"a\": 2,"));
    }

    /// Serialize → parse must be the identity for any string content —
    /// the daemon emits tenant and preset names verbatim inside JSON
    /// responses, so a hostile name must never produce malformed
    /// output. Covers every escape class the writer handles: the short
    /// escapes, raw control characters (`\u` form), and multi-byte
    /// UTF-8 up to astral-plane codepoints.
    #[test]
    fn string_escaping_round_trips() {
        let cases: Vec<String> = vec![
            String::new(),
            "plain ascii".into(),
            "quote \" inside".into(),
            "back\\slash and \\\" both".into(),
            "newline\nand\rreturn\tand tab".into(),
            "\u{0}\u{1}\u{8}\u{b}\u{c}\u{1f}".into(), // raw control chars
            "mixed \u{7} bell in text".into(),
            "non-ascii: é ß Ω 日本語".into(),
            "astral: \u{1F600} \u{10348}".into(),
            "json-ish: {\"k\": [1, 2]}".into(),
            "trailing backslash \\".into(),
            (0u32..0x20).filter_map(char::from_u32).collect(), // every control char
        ];
        for s in &cases {
            let compact = Json::Str(s.clone()).to_string_compact();
            let back = parse(&compact).unwrap();
            assert_eq!(back.as_str().unwrap(), s, "round-trip of {s:?} via {compact}");
            // Escaped output must itself be pure ASCII-safe JSON: no
            // raw control bytes survive the writer.
            assert!(
                compact.bytes().all(|b| b >= 0x20),
                "raw control byte leaked into {compact:?}"
            );
        }
    }

    /// Escaping applies to object *keys* too (tenant names key the
    /// daemon's per-tenant stats map), and survives pretty-printing.
    #[test]
    fn weird_object_keys_round_trip() {
        let keys = ["a\"b", "tab\tkey", "uni é", "ctl\u{1}", "\\esc\\"];
        let mut obj = std::collections::BTreeMap::new();
        for (i, k) in keys.iter().enumerate() {
            obj.insert(k.to_string(), Json::Num(i as f64));
        }
        let v = Json::Obj(obj);
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            let back = parse(&text).unwrap();
            assert_eq!(back, v, "via {text}");
            for (i, k) in keys.iter().enumerate() {
                assert_eq!(back.get(k).unwrap().as_i64().unwrap(), i as i64);
            }
        }
    }

    #[test]
    fn as_bool_accessor() {
        assert_eq!(parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(parse("false").unwrap().as_bool(), Some(false));
        assert_eq!(parse("1").unwrap().as_bool(), None);
        assert_eq!(parse("\"true\"").unwrap().as_bool(), None);
    }

    #[test]
    fn req_helpers() {
        let v = parse(r#"{"s":"x","n":3}"#).unwrap();
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_i64("n").unwrap(), 3);
        assert!(v.req("missing").is_err());
        assert!(v.req_i64("s").is_err());
    }
}
