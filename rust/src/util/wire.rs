//! Little-endian binary codec for the AOT artifact payload.
//!
//! The offline build environment has no `serde`/`bincode`; this module
//! is the binary sibling of [`super::json`]: a [`Writer`] that appends
//! fixed-width little-endian scalars and length-prefixed strings and
//! vectors, and a bounds-checked [`Reader`] that can never panic on
//! hostile input — every read is validated against the remaining bytes
//! and failures name the offset and the wanted width, so a truncated or
//! corrupted artifact is rejected with an actionable error instead of
//! an out-of-bounds access.
//!
//! Format conventions (DESIGN.md §13): all scalars little-endian;
//! `usize` travels as `u64`; `bool` as one byte (`0`/`1`, anything else
//! is an error); strings and `i32` vectors as a `u32` element count
//! followed by the elements. Length prefixes are validated against the
//! bytes actually remaining *before* any allocation, so a corrupted
//! length cannot trigger a huge allocation.

use anyhow::{bail, ensure, Result};

/// Append-only little-endian byte sink for artifact payloads.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// An empty writer.
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been written yet.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Consume the writer, returning the encoded bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Append one byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a little-endian `u16`.
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian `u32`.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian `u64`.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian `i32`.
    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `usize` as a `u64` (the wire format is
    /// pointer-width-independent).
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Append a `bool` as one `0`/`1` byte.
    pub fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    /// Append a length-prefixed UTF-8 string (`u32` byte count + bytes).
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Append a length-prefixed `i32` vector (`u32` element count +
    /// little-endian elements).
    pub fn vec_i32(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.i32(x);
        }
    }
}

/// Bounds-checked reader over an artifact payload. Never panics:
/// every accessor validates the remaining length first and reports the
/// byte offset on failure.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// A reader positioned at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    /// Current byte offset.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Take `n` raw bytes, or fail naming the offset and shortfall.
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.remaining(),
            "artifact payload truncated: wanted {n} bytes at offset {} but only {} remain \
             (payload is {} bytes)",
            self.pos,
            self.remaining(),
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian `u16`.
    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Read a little-endian `i32`.
    pub fn i32(&mut self) -> Result<i32> {
        let b = self.take(4)?;
        Ok(i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a `usize` (stored as `u64`; rejected if it does not fit the
    /// host pointer width).
    pub fn usize(&mut self) -> Result<usize> {
        let at = self.pos;
        let v = self.u64()?;
        usize::try_from(v)
            .map_err(|_| anyhow::anyhow!("value {v} at offset {at} does not fit usize"))
    }

    /// Read a `bool` (one byte; anything but `0`/`1` is corruption).
    pub fn bool(&mut self) -> Result<bool> {
        let at = self.pos;
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("invalid bool byte {b:#04x} at offset {at}"),
        }
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string ending at offset {}", self.pos))
    }

    /// Read a length-prefixed `i32` vector. The element count is
    /// validated against the remaining bytes before any allocation, so
    /// a corrupted prefix cannot trigger a huge allocation.
    pub fn vec_i32(&mut self) -> Result<Vec<i32>> {
        let at = self.pos;
        let n = self.u32()? as usize;
        ensure!(
            n.checked_mul(4).is_some_and(|bytes| bytes <= self.remaining()),
            "i32 vector at offset {at} claims {n} elements but only {} bytes remain",
            self.remaining()
        );
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.i32()?);
        }
        Ok(v)
    }

    /// Require that the payload was consumed exactly.
    pub fn finish(&self) -> Result<()> {
        ensure!(
            self.remaining() == 0,
            "artifact payload has {} trailing bytes after offset {}",
            self.remaining(),
            self.pos
        );
        Ok(())
    }
}

/// FNV-1a over a byte slice — the artifact checksum (same constants as
/// every other fingerprint in the crate; this one folds raw bytes, so
/// any single-bit payload corruption changes it).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u16(513);
        w.u32(70_000);
        w.u64(u64::MAX - 1);
        w.i32(-42);
        w.usize(12345);
        w.bool(true);
        w.bool(false);
        w.str("hello µop");
        w.vec_i32(&[1, -2, 3]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 513);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.i32().unwrap(), -42);
        assert_eq!(r.usize().unwrap(), 12345);
        assert!(r.bool().unwrap());
        assert!(!r.bool().unwrap());
        assert_eq!(r.str().unwrap(), "hello µop");
        assert_eq!(r.vec_i32().unwrap(), vec![1, -2, 3]);
        r.finish().unwrap();
    }

    #[test]
    fn truncation_names_offset_and_width() {
        let mut w = Writer::new();
        w.u32(9);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes[..2]);
        let err = format!("{:#}", r.u32().unwrap_err());
        assert!(err.contains("truncated") && err.contains("offset 0"), "{err}");
        assert!(err.contains("wanted 4"), "{err}");
    }

    #[test]
    fn hostile_lengths_are_rejected_before_allocation() {
        // A vec_i32 claiming u32::MAX elements with a 4-byte body.
        let mut w = Writer::new();
        w.u32(u32::MAX);
        w.i32(1);
        let bytes = w.into_bytes();
        let err = format!("{:#}", Reader::new(&bytes).vec_i32().unwrap_err());
        assert!(err.contains("claims"), "{err}");
        // A string overrunning the buffer.
        let mut w = Writer::new();
        w.u32(100);
        w.u8(b'x');
        let bytes = w.into_bytes();
        assert!(Reader::new(&bytes).str().is_err());
    }

    #[test]
    fn invalid_bool_and_trailing_bytes_are_errors() {
        let err = format!("{:#}", Reader::new(&[2]).bool().unwrap_err());
        assert!(err.contains("bool"), "{err}");
        let r = Reader::new(&[0, 0]);
        let err = format!("{:#}", r.finish().unwrap_err());
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn checksum_is_bit_sensitive() {
        let a = fnv1a(b"compiled artifact");
        let mut flipped = b"compiled artifact".to_vec();
        flipped[3] ^= 1;
        assert_ne!(a, fnv1a(&flipped));
        assert_eq!(a, fnv1a(b"compiled artifact"));
    }
}
