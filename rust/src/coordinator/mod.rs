//! Orchestration layer: worker pool, the sharded Figure-5 sweep with its
//! cross-driver point cache, and the layer-wise CNN runner.

pub mod cache;
pub mod network;
pub mod pool;
pub mod sweep;

pub use cache::{cfg_fingerprint, CacheStats, CachedOutcome, PointCache, PointKey};
pub use network::{golden_network, run_network, ConvLayer, ConvNet, NetworkOutcome};
pub use pool::{default_workers, run_jobs};
pub use sweep::{
    auto_mapping, paper_axis_values, run_sweep, run_sweep_cached, Axis, SweepPoint, SweepRow,
    SweepSpec,
};
