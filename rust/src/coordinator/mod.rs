//! Orchestration layer: worker pool, the sharded Figure-5 sweep with its
//! cross-driver point cache, and the layer-wise CNN data model.
//!
//! Session-level execution — one object owning config, energy model,
//! workers and caches — lives in [`crate::engine`]; the deprecated free
//! functions re-exported here (`run_sweep`, `run_network`,
//! `auto_mapping`) are thin wrappers over it.

pub mod cache;
pub mod network;
pub mod pool;
pub mod sweep;

pub use cache::{
    cfg_fingerprint, energy_fingerprint, CacheStats, CachedOutcome, PointCache, PointKey,
};
pub use network::{golden_network, ConvLayer, ConvNet, NetworkOutcome};
pub use pool::{default_workers, run_jobs};
pub use sweep::{
    paper_axis_values, run_sweep_cached, run_sweep_with_model, Axis, SweepPoint, SweepRow,
    SweepSpec,
};

// Deprecated entry points, re-exported for source compatibility.
#[allow(deprecated)]
pub use network::run_network;
#[allow(deprecated)]
pub use sweep::{auto_mapping, run_sweep};
