//! Orchestration layer: worker pool, the Figure-5 sweep, and the
//! layer-wise CNN runner.

pub mod network;
pub mod pool;
pub mod sweep;

pub use network::{golden_network, run_network, ConvLayer, ConvNet, NetworkOutcome};
pub use pool::{default_workers, run_jobs};
pub use sweep::{auto_mapping, paper_axis_values, run_sweep, Axis, SweepPoint, SweepRow, SweepSpec};
