//! Orchestration layer: worker pool, the sharded Figure-5 sweep with its
//! cross-driver point cache, and the layer-wise CNN data model.
//!
//! Session-level execution — one object owning config, energy model,
//! workers and caches — lives in [`crate::engine`] (the pre-0.2 free
//! functions were removed in 0.5 once every consumer had migrated).

pub mod cache;
pub mod network;
pub mod pool;
pub mod sweep;

pub use cache::{
    cfg_fingerprint, energy_fingerprint, CacheStats, CachedOutcome, PointCache, PointKey,
};
pub use network::{golden_network, ConvLayer, ConvNet, NetworkOutcome};
pub use pool::{default_workers, run_jobs};
pub use sweep::{
    paper_axis_values, run_sweep_cached, run_sweep_with_model, Axis, SweepPoint, SweepRow,
    SweepSpec,
};
