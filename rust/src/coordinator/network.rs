//! Layer-wise CNN data model: a feed-forward stack of conv layers with
//! host-side ReLU between them, plus the golden CPU reference — the
//! network behind `examples/cnn_inference.rs`.
//!
//! Execution lives in `engine::Engine::run_network`: every conv layer
//! runs on the simulated CGRA with its chosen mapping (by default
//! [`Mapping::Auto`], which resolves to the paper's WP); activations
//! run on the CPU cost model. The runtime verifier can replay the same
//! network through the AOT-compiled JAX/Pallas artifact and compare
//! bit-exactly.

use anyhow::{ensure, Result};

use crate::conv::{ConvShape, TensorChw, Weights};
use crate::kernels::Mapping;
use crate::metrics::MappingReport;
use crate::prop::Rng;

/// One convolutional layer of the network.
#[derive(Clone, Debug)]
pub struct ConvLayer {
    /// Layer shape (input channels must match the previous layer's K).
    pub shape: ConvShape,
    /// Mapping strategy for this layer (may be [`Mapping::Auto`]).
    pub mapping: Mapping,
    /// Layer weights.
    pub weights: Weights,
    /// Apply ReLU (host-side) after the convolution.
    pub relu: bool,
}

/// A feed-forward stack of conv layers.
#[derive(Clone, Debug)]
pub struct ConvNet {
    /// Layers, in execution order.
    pub layers: Vec<ConvLayer>,
}

impl ConvNet {
    /// Validate inter-layer shape compatibility.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.layers.is_empty(), "network has no layers");
        for w in self.layers.windows(2) {
            let (a, b) = (&w[0].shape, &w[1].shape);
            ensure!(
                a.k == b.c,
                "layer output channels K={} do not match next layer C={}",
                a.k,
                b.c
            );
            ensure!(
                a.ox == b.ih() && a.oy == b.iw(),
                "layer output {}x{} does not match next layer input {}x{}",
                a.ox,
                a.oy,
                b.ih(),
                b.iw()
            );
        }
        Ok(())
    }

    /// Build a small random CNN: `depth` 3×3 conv+ReLU layers, starting
    /// from a `c0 × (h, w)` input, all with `k` output channels.
    /// Deterministic in `seed`. Layers use [`Mapping::Auto`], so the
    /// engine picks the strategy (WP on every shape of the paper's
    /// grid) and records the decision per layer.
    pub fn random(depth: usize, c0: usize, k: usize, h: usize, w: usize, seed: u64) -> ConvNet {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        let (mut c, mut ih, mut iw) = (c0, h, w);
        for d in 0..depth {
            let shape = ConvShape::new3x3(c, k, ih - 2, iw - 2);
            let weights = crate::conv::random_weights(&shape, 4, &mut rng);
            layers.push(ConvLayer {
                shape,
                mapping: Mapping::Auto,
                weights,
                relu: d + 1 < depth, // no activation after the last layer
            });
            c = k;
            ih = shape.ox;
            iw = shape.oy;
        }
        ConvNet { layers }
    }

    /// Total MACs across layers.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.shape.macs()).sum()
    }

    /// Set each layer's mapping from a per-layer list (the planner's
    /// [`crate::planner::NetworkPlan::apply`] writes its choices back
    /// through this).
    pub fn apply_mappings(&mut self, mappings: &[Mapping]) -> Result<()> {
        ensure!(
            mappings.len() == self.layers.len(),
            "got {} mappings for {} layers",
            mappings.len(),
            self.layers.len()
        );
        for (layer, &m) in self.layers.iter_mut().zip(mappings) {
            layer.mapping = m;
        }
        Ok(())
    }
}

/// Per-layer and aggregate results of one network inference.
#[derive(Clone, Debug)]
pub struct NetworkOutcome {
    /// Per-layer metric rows.
    pub layers: Vec<MappingReport>,
    /// Final feature map.
    pub output: TensorChw,
    /// Total latency in cycles (conv + host ReLU).
    pub total_cycles: u64,
    /// Total energy, µJ.
    pub total_energy_uj: f64,
    /// Cycles spent in host-side activations.
    pub relu_cycles: u64,
}

impl NetworkOutcome {
    /// Aggregate MAC/cycle of the whole network.
    pub fn mac_per_cycle(&self, net: &ConvNet) -> f64 {
        net.macs() as f64 / self.total_cycles.max(1) as f64
    }
}

/// Golden CPU reference of the same network (wrapping int32 + ReLU),
/// for verification.
pub fn golden_network(net: &ConvNet, input: &TensorChw) -> Result<TensorChw> {
    net.validate()?;
    let mut x = input.clone();
    for layer in &net.layers {
        x = crate::conv::conv2d(&layer.shape, &x, &layer.weights);
        if layer.relu {
            for v in x.data.iter_mut() {
                *v = (*v).max(0);
            }
        }
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::random_input;
    use crate::engine::EngineBuilder;

    #[test]
    fn random_net_validates_and_chains() {
        let net = ConvNet::random(3, 3, 8, 12, 12, 7);
        net.validate().unwrap();
        assert_eq!(net.layers.len(), 3);
        assert_eq!(net.layers[0].shape.c, 3);
        assert_eq!(net.layers[1].shape.c, 8);
        assert_eq!(net.layers[1].shape.ih(), net.layers[0].shape.ox);
        assert!(net.layers[0].relu && !net.layers[2].relu);
        assert!(net.layers.iter().all(|l| l.mapping.is_auto()));
    }

    #[test]
    fn engine_network_matches_golden() {
        let net = ConvNet::random(2, 2, 4, 8, 8, 11);
        let mut rng = Rng::new(5);
        let input = random_input(&net.layers[0].shape, 8, &mut rng);
        let engine = EngineBuilder::new().build().unwrap();
        let out = engine.run_network(&net, &input).unwrap();
        let golden = golden_network(&net, &input).unwrap();
        assert_eq!(out.output.data, golden.data);
        assert_eq!(out.layers.len(), 2);
        assert!(out.total_cycles > 0 && out.total_energy_uj > 0.0);
        assert!(out.relu_cycles > 0);
    }

    #[test]
    fn apply_mappings_sets_layers_and_checks_length() {
        let mut net = ConvNet::random(2, 2, 4, 8, 8, 1);
        net.apply_mappings(&[Mapping::Wp, Mapping::Cpu]).unwrap();
        assert_eq!(net.layers[0].mapping, Mapping::Wp);
        assert_eq!(net.layers[1].mapping, Mapping::Cpu);
        assert!(net.apply_mappings(&[Mapping::Wp]).is_err());
    }

    #[test]
    fn mismatched_layers_rejected() {
        let mut net = ConvNet::random(2, 2, 4, 8, 8, 1);
        net.layers[1].shape.c = 5; // break the channel chain
        assert!(net.validate().is_err());
    }

    #[test]
    fn relu_clamps_negatives() {
        let mut net = ConvNet::random(1, 1, 1, 4, 4, 2);
        net.layers[0].relu = true;
        // All-negative weights force negative pre-activations.
        for w in net.layers[0].weights.data.iter_mut() {
            *w = -3;
        }
        let shape = net.layers[0].shape;
        let input = TensorChw::from_vec(1, 4, 4, vec![1; 16]);
        assert_eq!(shape.ih(), 4);
        let engine = EngineBuilder::new().build().unwrap();
        let out = engine.run_network(&net, &input).unwrap();
        assert!(out.output.data.iter().all(|&v| v == 0));
    }
}
