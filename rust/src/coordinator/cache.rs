//! Sharded cross-driver sweep cache.
//!
//! The Fig. 3/4/5 drivers, the CLI subcommands and the benches evaluate
//! overlapping (mapping, shape, data seed, config) points over and over
//! — every bench sample re-runs the whole grid, and the baseline layer
//! appears on all three sweep axes at once. A sweep *point* is fully
//! determined by its [`PointKey`] (the data RNG is seeded from the shape
//! and the spec seed, and the simulator is deterministic), so completed
//! points can be memoized safely.
//!
//! The cache is sharded: workers from [`super::pool::run_jobs`] hit
//! different locks, so the memo never serializes the sweep. The decoded
//! *program* memo lives one layer down in [`crate::cgra::decode_cached`]
//! (kernels own program construction); [`CacheStats`] here and
//! [`crate::cgra::decode_cache_stats`] together describe both stages.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::cgra::CgraConfig;
use crate::conv::ConvShape;
use crate::energy::EnergyModel;
use crate::kernels::Mapping;
use crate::metrics::MappingReport;

/// Everything that determines a sweep point's result.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct PointKey {
    /// Strategy.
    pub mapping: Mapping,
    /// Layer shape.
    pub shape: ConvShape,
    /// Input-data magnitude (Fig. 5 sweeps use one magnitude for both
    /// tensors; the Fig. 3/4 drivers draw weights at a different one).
    pub in_mag: i32,
    /// Weight-data magnitude.
    pub w_mag: i32,
    /// Derived per-point data seed.
    pub seed: u64,
    /// Fingerprint of everything else that determines the cached
    /// [`MappingReport`]: the full simulator configuration *and* the
    /// energy model ([`cfg_fingerprint`]` ^ `[`energy_fingerprint`]),
    /// so sessions with different configs or models never serve each
    /// other's rows.
    pub cfg_fp: u64,
}

/// A completed sweep evaluation.
#[derive(Clone, Debug)]
pub enum CachedOutcome {
    /// Metrics of a successful run.
    Report(MappingReport),
    /// The point was skipped (memory bound / invalid config), with the
    /// reason string exactly as the sweep row reports it.
    Skipped(String),
}

/// Cache counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to simulate.
    pub misses: u64,
    /// Entries dropped by shard eviction.
    pub evictions: u64,
    /// Points currently resident.
    pub entries: usize,
}

/// Fingerprint of every [`CgraConfig`] field that can influence a run.
pub fn cfg_fingerprint(cfg: &CgraConfig) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in [
        cfg.alu_latency,
        cfg.mul_latency,
        cfg.mem_latency,
        cfg.bank_penalty,
        cfg.n_banks as u64,
        cfg.mem_words as u64,
        cfg.launch_overhead,
        cfg.instruction_load_overhead,
        cfg.max_steps,
    ] {
        h = (h ^ v).wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Fingerprint of every [`EnergyModel`] field. Cached rows embed
/// evaluated energy/power numbers, so the model is part of the key
/// (combined with [`cfg_fingerprint`] in [`PointKey::cfg_fp`]).
pub fn energy_fingerprint(model: &EnergyModel) -> u64 {
    let mut h = 0x84222325_cbf29ce4u64;
    for v in [
        model.clock_hz,
        model.p_cgra_leak_mw,
        model.p_pe_active_mw,
        model.p_cpu_active_mw,
        model.p_cpu_idle_mw,
        model.p_mem_static_mw,
        model.e_mem_access_pj,
    ] {
        h = (h ^ v.to_bits()).wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Entries per shard before the shard is wholesale evicted — the same
/// epoch-eviction bound as the decode cache, so a long-running process
/// sweeping many distinct grids/configs cannot grow the memo without
/// limit. The full paper grid is ~300 points, far under one epoch.
const POINT_SHARD_CAP: usize = 512;

/// Sharded memo of completed sweep points.
pub struct PointCache {
    shards: Vec<Mutex<HashMap<PointKey, CachedOutcome>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl PointCache {
    /// Cache with `shards` independent lock shards (≥ 1).
    pub fn new(shards: usize) -> PointCache {
        let shards = shards.max(1);
        PointCache {
            shards: (0..shards).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &PointKey) -> &Mutex<HashMap<PointKey, CachedOutcome>> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[h.finish() as usize % self.shards.len()]
    }

    /// Look up a completed point (counted as hit or miss).
    pub fn get(&self, key: &PointKey) -> Option<CachedOutcome> {
        let found = self.shard(key).lock().unwrap().get(key).cloned();
        if found.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        found
    }

    /// Record a completed point. When a shard reaches its cap the whole
    /// shard is evicted (epoch eviction — cheap, and re-misses are just
    /// re-simulations).
    pub fn insert(&self, key: PointKey, outcome: CachedOutcome) {
        let mut map = self.shard(&key).lock().unwrap();
        if map.len() >= POINT_SHARD_CAP && !map.contains_key(&key) {
            self.evictions.fetch_add(map.len() as u64, Ordering::Relaxed);
            map.clear();
        }
        map.insert(key, outcome);
    }

    /// Number of cached points.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached point (counters are preserved).
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }
}

impl Default for PointCache {
    fn default() -> Self {
        PointCache::new(8)
    }
}

/// The process-wide point cache shared by every sweep/figure driver.
pub fn global() -> &'static PointCache {
    static GLOBAL: OnceLock<PointCache> = OnceLock::new();
    GLOBAL.get_or_init(PointCache::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(mag: i32) -> PointKey {
        PointKey {
            mapping: Mapping::Wp,
            shape: ConvShape::baseline(),
            in_mag: mag,
            w_mag: mag,
            seed: 7,
            cfg_fp: cfg_fingerprint(&CgraConfig::default()),
        }
    }

    #[test]
    fn insert_get_roundtrip() {
        let c = PointCache::new(4);
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), CachedOutcome::Skipped("because".into()));
        match c.get(&key(1)) {
            Some(CachedOutcome::Skipped(s)) => assert_eq!(s, "because"),
            other => panic!("unexpected {other:?}"),
        }
        assert!(c.get(&key(2)).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 1));
    }

    #[test]
    fn clear_empties_but_keeps_counters() {
        let c = PointCache::new(2);
        c.insert(key(1), CachedOutcome::Skipped("x".into()));
        assert!(!c.is_empty());
        let _ = c.get(&key(1));
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats().hits, 1);
    }

    #[test]
    fn cfg_fingerprint_separates_configs() {
        let a = CgraConfig::default();
        let b = CgraConfig { mem_words: 2048, ..CgraConfig::default() };
        let c = CgraConfig { mul_latency: 3, ..CgraConfig::default() };
        assert_ne!(cfg_fingerprint(&a), cfg_fingerprint(&b));
        assert_ne!(cfg_fingerprint(&a), cfg_fingerprint(&c));
        assert_eq!(cfg_fingerprint(&a), cfg_fingerprint(&a.clone()));
    }

    #[test]
    fn energy_fingerprint_separates_models() {
        let a = EnergyModel::default();
        let mut b = EnergyModel::default();
        b.e_mem_access_pj *= 2.0;
        let mut c = EnergyModel::default();
        c.clock_hz += 1.0;
        assert_ne!(energy_fingerprint(&a), energy_fingerprint(&b));
        assert_ne!(energy_fingerprint(&a), energy_fingerprint(&c));
        assert_eq!(energy_fingerprint(&a), energy_fingerprint(&a));
    }

    #[test]
    fn zero_shards_clamped() {
        let c = PointCache::new(0);
        c.insert(key(3), CachedOutcome::Skipped("s".into()));
        assert_eq!(c.len(), 1);
    }

    /// Concurrent get-then-insert traffic (the `submit_batch` access
    /// pattern) keeps the counters coherent and still triggers epoch
    /// eviction once a shard passes its cap: with 2 shards and more
    /// than 2× the cap in distinct keys, some shard must overflow.
    #[test]
    fn concurrent_traffic_keeps_counters_coherent_and_evicts() {
        const THREADS: u64 = 4;
        let c = PointCache::new(2);
        let distinct = (2 * POINT_SHARD_CAP + 64) as u64;
        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let c = &c;
                scope.spawn(move || {
                    for seed in 0..distinct {
                        let mut k = key(1);
                        k.seed = seed;
                        if c.get(&k).is_none() {
                            c.insert(k, CachedOutcome::Skipped(format!("t{t}")));
                        }
                    }
                });
            }
        });
        let s = c.stats();
        // Every get is counted exactly once, hit or miss.
        assert_eq!(s.hits + s.misses, THREADS * distinct);
        assert!(s.misses >= distinct, "each distinct key misses at least once");
        assert!(s.evictions > 0, "a shard past its cap must epoch-evict");
        assert!(s.entries <= 2 * POINT_SHARD_CAP);
        // The cache still serves after eviction.
        let mut k = key(1);
        k.seed = u64::MAX;
        c.insert(k, CachedOutcome::Skipped("fresh".into()));
        assert!(c.get(&k).is_some());
    }

    #[test]
    fn shard_cap_evicts_by_epoch() {
        let c = PointCache::new(1);
        for seed in 0..(POINT_SHARD_CAP as u64 + 1) {
            let mut k = key(1);
            k.seed = seed;
            c.insert(k, CachedOutcome::Skipped("x".into()));
        }
        let s = c.stats();
        assert!(s.evictions >= POINT_SHARD_CAP as u64, "evictions {}", s.evictions);
        assert!(s.entries <= POINT_SHARD_CAP);
        // Cache still functions after eviction.
        let mut k = key(1);
        k.seed = 9_999_999;
        c.insert(k, CachedOutcome::Skipped("y".into()));
        assert!(c.get(&k).is_some());
    }
}
