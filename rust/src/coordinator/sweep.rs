//! The Figure-5 hyper-parameter sweep, parallelized over a worker pool.
//!
//! Paper §3.2: "We vary Ox and Oy in [16, 64], C and K in [16, 144],
//! increasing by 1 the dimension of each parameter until 32, and then in
//! steps of 16 … We limit our search to the maximum memory available in
//! the system (512 kiB)." Each axis is varied from the baseline
//! C = K = Ox = Oy = 16; every point runs every mapping; oversized
//! points are recorded as skipped, exactly like the paper's bound.

use anyhow::Result;

use crate::cgra::{Cgra, CgraConfig};
use crate::conv::{random_input, random_weights, ConvShape};
use crate::energy::EnergyModel;
use crate::kernels::{dispatch, Mapping};
use crate::metrics::MappingReport;
use crate::prop::Rng;

use super::cache::{self, CachedOutcome, PointCache, PointKey};
use super::pool::run_jobs;

/// Which hyper-parameter an axis point varies.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Axis {
    /// Input channels C.
    C,
    /// Output channels K.
    K,
    /// Spatial size (Ox = Oy varied together, as in Fig. 5's plots).
    Spatial,
}

impl Axis {
    /// Axis label for reports.
    pub fn label(self) -> &'static str {
        match self {
            Axis::C => "C",
            Axis::K => "K",
            Axis::Spatial => "OxOy",
        }
    }
}

/// The paper's sweep values for one axis: step 1 up to 32, then step 16.
pub fn paper_axis_values(lo: usize, mid: usize, hi: usize, step: usize) -> Vec<usize> {
    let mut v: Vec<usize> = (lo..=mid).collect();
    let mut x = mid + step;
    while x <= hi {
        v.push(x);
        x += step;
    }
    v
}

/// Sweep specification.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Values taken by C (other params at baseline).
    pub c_values: Vec<usize>,
    /// Values taken by K.
    pub k_values: Vec<usize>,
    /// Values taken by Ox = Oy.
    pub spatial_values: Vec<usize>,
    /// Mappings to run at every point.
    pub mappings: Vec<Mapping>,
    /// Input-data magnitude (values in [-mag, mag]).
    pub mag: i32,
    /// Base RNG seed; each point derives its own deterministic seed.
    pub seed: u64,
}

impl SweepSpec {
    /// The paper's full Figure-5 sweep.
    pub fn paper() -> SweepSpec {
        SweepSpec {
            c_values: paper_axis_values(16, 32, 144, 16),
            k_values: paper_axis_values(16, 32, 144, 16),
            spatial_values: paper_axis_values(16, 32, 64, 16),
            mappings: Mapping::ALL.to_vec(),
            mag: 20,
            seed: 0xf15_5eed,
        }
    }

    /// A reduced sweep for quick runs/tests: the interesting points only
    /// (baseline, the ±1 imbalance points, tile multiples, extremes).
    pub fn quick() -> SweepSpec {
        SweepSpec {
            c_values: vec![16, 17, 32, 48],
            k_values: vec![16, 17, 32, 48],
            spatial_values: vec![16, 32],
            mappings: Mapping::ALL.to_vec(),
            mag: 20,
            seed: 0xf15_5eed,
        }
    }

    /// The planner-accuracy validation grid (`cgra plan --validate`,
    /// CI's planner smoke job): small enough to simulate in seconds,
    /// but covering both the paper's baseline-aligned points and the
    /// odd-valued shapes where bank-alignment jitter — the planner's
    /// only residual error source — is worst.
    pub fn validation() -> SweepSpec {
        SweepSpec {
            c_values: vec![16, 17, 48],
            k_values: vec![16, 17, 48],
            spatial_values: vec![16, 17, 32],
            mappings: Mapping::ALL.to_vec(),
            mag: 20,
            seed: 0xf15_5eed,
        }
    }

    /// All (axis, value, shape, mapping) points.
    pub fn points(&self) -> Vec<SweepPoint> {
        let base = ConvShape::baseline();
        let mut shapes: Vec<(Axis, usize, ConvShape)> = Vec::new();
        for &c in &self.c_values {
            shapes.push((Axis::C, c, ConvShape { c, ..base }));
        }
        for &k in &self.k_values {
            shapes.push((Axis::K, k, ConvShape { k, ..base }));
        }
        for &s in &self.spatial_values {
            shapes.push((Axis::Spatial, s, ConvShape { ox: s, oy: s, ..base }));
        }
        let mut points = Vec::new();
        for (axis, value, shape) in shapes {
            for &mapping in &self.mappings {
                points.push(SweepPoint { axis, value, shape, mapping });
            }
        }
        points
    }
}

/// One sweep point.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    /// Varied axis.
    pub axis: Axis,
    /// Axis value.
    pub value: usize,
    /// Full layer shape.
    pub shape: ConvShape,
    /// Strategy.
    pub mapping: Mapping,
}

/// One sweep result row.
#[derive(Clone, Debug)]
pub struct SweepRow {
    /// The point.
    pub point: SweepPoint,
    /// Metrics, or `None` with a reason when skipped/failed.
    pub report: Option<MappingReport>,
    /// Why the point was skipped (memory bound), if it was.
    pub skipped: Option<String>,
}

/// Per-point data seed: depends only on the spec seed and the shape, so
/// results are identical across worker counts, shard sizes and runs.
fn point_seed(spec_seed: u64, shape: &ConvShape) -> u64 {
    spec_seed
        ^ (shape.c as u64) << 32
        ^ (shape.k as u64) << 16
        ^ (shape.ox as u64) << 8
        ^ shape.oy as u64
}

/// Work-shard granularity: aim for this many shards per worker so the
/// pool load-balances without paying one closure/lock round-trip per
/// point (sweep points vary in cost by orders of magnitude).
const SHARDS_PER_WORKER: usize = 4;

/// Evaluate one point, consulting `pc` first and recording the outcome.
fn eval_point(
    spec: &SweepSpec,
    cfg: &CgraConfig,
    cfg_fp: u64,
    model: &EnergyModel,
    pc: &PointCache,
    point: SweepPoint,
) -> SweepRow {
    let shape = point.shape;
    // Resolve `Auto` up front so the cache key names the concrete
    // strategy (an Auto point and its resolved mapping share an entry).
    let mapping = match point.mapping.resolve(&shape, cfg) {
        Ok((m, _reason)) => m,
        Err(e) => return SweepRow { point, report: None, skipped: Some(e.to_string()) },
    };
    let key = PointKey {
        mapping,
        shape,
        in_mag: spec.mag,
        w_mag: spec.mag,
        seed: point_seed(spec.seed, &shape),
        cfg_fp,
    };
    if let Some(hit) = pc.get(&key) {
        return match hit {
            CachedOutcome::Report(r) => SweepRow { point, report: Some(r), skipped: None },
            CachedOutcome::Skipped(s) => SweepRow { point, report: None, skipped: Some(s) },
        };
    }
    let mut rng = Rng::new(key.seed);
    let input = random_input(&shape, spec.mag, &mut rng);
    let weights = random_weights(&shape, spec.mag, &mut rng);
    let row = match Cgra::new(cfg.clone()) {
        Err(e) => SweepRow { point, report: None, skipped: Some(e.to_string()) },
        Ok(cgra) => match dispatch(&cgra, mapping, &shape, &input, &weights) {
            Ok(out) => SweepRow {
                point,
                report: Some(MappingReport::from_outcome(&out, model)),
                skipped: None,
            },
            // Memory-bound points are the expected skip class (the
            // paper's 512 KiB limit).
            Err(e) => SweepRow { point, report: None, skipped: Some(e.to_string()) },
        },
    };
    let outcome = match (&row.report, &row.skipped) {
        (Some(r), _) => CachedOutcome::Report(r.clone()),
        (None, Some(s)) => CachedOutcome::Skipped(s.clone()),
        (None, None) => unreachable!("sweep row must report or skip"),
    };
    pc.insert(key, outcome);
    row
}

/// Run the sweep against an explicit cache (tests; isolated sweeps),
/// with the calibrated default energy model. Session-level sweeps go
/// through `engine::Engine::sweep`, which owns the config, worker
/// width and cache.
pub fn run_sweep_cached(
    spec: &SweepSpec,
    cfg: &CgraConfig,
    workers: usize,
    pc: &PointCache,
) -> Result<Vec<SweepRow>> {
    run_sweep_with_model(spec, cfg, &EnergyModel::default(), workers, pc)
}

/// [`run_sweep_cached`] with an explicit energy model (the engine's
/// entry point — `engine::Engine::sweep` passes its session model).
///
/// Points are sharded into contiguous chunks — several per worker — and
/// the chunks are distributed over [`run_jobs`]; flattening the ordered
/// chunk results preserves point order exactly. The cache key combines
/// the config and energy-model fingerprints, so rows evaluated under
/// one model are never served to a sweep under another.
pub fn run_sweep_with_model(
    spec: &SweepSpec,
    cfg: &CgraConfig,
    model: &EnergyModel,
    workers: usize,
    pc: &PointCache,
) -> Result<Vec<SweepRow>> {
    let model = *model;
    let cfg_fp = cache::cfg_fingerprint(cfg) ^ cache::energy_fingerprint(&model);
    let points = spec.points();
    if points.is_empty() {
        return Ok(Vec::new());
    }
    let shard_len = points.len().div_ceil(workers.max(1) * SHARDS_PER_WORKER).max(1);
    let jobs: Vec<_> = points
        .chunks(shard_len)
        .map(|chunk| {
            let chunk: Vec<SweepPoint> = chunk.to_vec();
            let cfg = cfg.clone();
            move || -> Vec<SweepRow> {
                chunk
                    .into_iter()
                    .map(|point| eval_point(spec, &cfg, cfg_fp, &model, pc, point))
                    .collect()
            }
        })
        .collect();
    Ok(run_jobs(workers, jobs).into_iter().flatten().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_axis_values_match_protocol() {
        let v = paper_axis_values(16, 32, 144, 16);
        assert_eq!(v[0], 16);
        assert!(v.contains(&17) && v.contains(&31) && v.contains(&32));
        assert!(v.contains(&48) && v.contains(&144));
        assert!(!v.contains(&33) && !v.contains(&145));
        // 16..=32 step 1 (17 values) + 48..=144 step 16 (7 values).
        assert_eq!(v.len(), 17 + 7);
    }

    #[test]
    fn validation_grid_is_a_subset_of_the_paper_grid() {
        let v = SweepSpec::validation();
        let paper = SweepSpec::paper();
        for (vals, pvals) in [
            (&v.c_values, &paper.c_values),
            (&v.k_values, &paper.k_values),
            (&v.spatial_values, &paper.spatial_values),
        ] {
            assert!(vals.iter().all(|x| pvals.contains(x)), "{vals:?} not in paper grid");
        }
        assert_eq!(v.mappings, Mapping::ALL.to_vec());
        // Odd values present: the planner's worst alignment case.
        assert!(v.c_values.contains(&17) && v.spatial_values.contains(&17));
    }

    #[test]
    fn points_cover_axes_and_mappings() {
        let spec = SweepSpec::quick();
        let pts = spec.points();
        assert_eq!(
            pts.len(),
            (spec.c_values.len() + spec.k_values.len() + spec.spatial_values.len())
                * spec.mappings.len()
        );
        assert!(pts.iter().any(|p| p.axis == Axis::C && p.value == 17));
    }

    #[test]
    fn small_sweep_runs_and_is_deterministic() {
        let spec = SweepSpec {
            c_values: vec![4],
            k_values: vec![5],
            spatial_values: vec![4],
            mappings: vec![Mapping::Wp, Mapping::Cpu],
            mag: 10,
            seed: 1,
        };
        let cfg = CgraConfig::default();
        let a = run_sweep_cached(&spec, &cfg, 2, cache::global()).unwrap();
        let b = run_sweep_cached(&spec, &cfg, 4, cache::global()).unwrap();
        assert_eq!(a.len(), 6);
        for (x, y) in a.iter().zip(b.iter()) {
            let (rx, ry) = (x.report.as_ref().unwrap(), y.report.as_ref().unwrap());
            assert_eq!(rx.latency_cycles, ry.latency_cycles);
            assert_eq!(rx.cgra_accesses, ry.cgra_accesses);
        }
    }

    #[test]
    fn oversized_points_are_skipped_not_fatal() {
        let spec = SweepSpec {
            c_values: vec![144],
            k_values: vec![],
            spatial_values: vec![],
            mappings: vec![Mapping::Ip],
            mag: 5,
            seed: 2,
        };
        // Tiny memory to force the skip.
        let mut cfg = CgraConfig::default();
        cfg.mem_words = 2048;
        let rows = run_sweep_cached(&spec, &cfg, 1, &PointCache::new(2)).unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].report.is_none());
        assert!(rows[0].skipped.as_ref().unwrap().contains("words"));
    }

    /// The paper's conclusion as a resolver check: `Mapping::Auto`
    /// resolves to WP on the baseline layer.
    #[test]
    fn auto_resolves_to_wp_on_baseline() {
        let (m, _) = Mapping::Auto.resolve(&ConvShape::baseline(), &CgraConfig::default()).unwrap();
        assert_eq!(m, Mapping::Wp);
    }

    /// An `Auto` sweep point resolves to WP and shares its cache entry
    /// with an explicit WP point.
    #[test]
    fn auto_points_share_cache_with_resolved_mapping() {
        let spec = SweepSpec {
            c_values: vec![4],
            k_values: vec![],
            spatial_values: vec![],
            mappings: vec![Mapping::Wp, Mapping::Auto],
            mag: 6,
            seed: 3,
        };
        let pc = PointCache::new(2);
        let rows = run_sweep_cached(&spec, &CgraConfig::default(), 1, &pc).unwrap();
        assert_eq!(rows.len(), 2);
        let s = pc.stats();
        assert_eq!(s.entries, 1, "Auto and WP must dedup to one cached point");
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(
            rows[0].report.as_ref().unwrap().latency_cycles,
            rows[1].report.as_ref().unwrap().latency_cycles
        );
    }

    #[test]
    fn second_sweep_is_served_from_the_cache() {
        let spec = SweepSpec {
            c_values: vec![4],
            k_values: vec![5],
            spatial_values: vec![],
            mappings: vec![Mapping::Wp],
            mag: 6,
            seed: 21,
        };
        let cfg = CgraConfig::default();
        let pc = PointCache::new(4);
        let a = run_sweep_cached(&spec, &cfg, 2, &pc).unwrap();
        let s0 = pc.stats();
        assert_eq!(s0.hits, 0);
        assert_eq!(s0.misses, 2);
        assert_eq!(s0.entries, 2);
        let b = run_sweep_cached(&spec, &cfg, 3, &pc).unwrap();
        let s1 = pc.stats();
        assert_eq!(s1.hits, 2);
        assert_eq!(s1.misses, 2);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(
                x.report.as_ref().unwrap().latency_cycles,
                y.report.as_ref().unwrap().latency_cycles
            );
            assert_eq!(x.point.mapping, y.point.mapping);
        }
    }

    #[test]
    fn cache_does_not_leak_across_configs() {
        let spec = SweepSpec {
            c_values: vec![144],
            k_values: vec![],
            spatial_values: vec![],
            mappings: vec![Mapping::Wp],
            mag: 3,
            seed: 2,
        };
        let pc = PointCache::new(2);
        // Tiny memory: the point skips, and the skip is cached.
        let small = CgraConfig { mem_words: 2048, ..CgraConfig::default() };
        let rows = run_sweep_cached(&spec, &small, 1, &pc).unwrap();
        assert!(rows[0].skipped.is_some());
        // Default memory: the same (mapping, shape) must MISS and run.
        let rows2 = run_sweep_cached(&spec, &CgraConfig::default(), 1, &pc).unwrap();
        assert!(rows2[0].report.is_some(), "cfg change must invalidate the cached skip");
        assert_eq!(pc.stats().entries, 2);
    }

    #[test]
    fn sharding_preserves_point_order() {
        // More points than one shard so chunking actually kicks in.
        let spec = SweepSpec {
            c_values: (1..=6).collect(),
            k_values: vec![2, 3],
            spatial_values: vec![2],
            mappings: vec![Mapping::Wp, Mapping::Cpu],
            mag: 4,
            seed: 9,
        };
        let cfg = CgraConfig::default();
        let rows = run_sweep_cached(&spec, &cfg, 3, &PointCache::new(4)).unwrap();
        let points = spec.points();
        assert_eq!(rows.len(), points.len());
        for (r, p) in rows.iter().zip(points.iter()) {
            assert_eq!(r.point.axis, p.axis);
            assert_eq!(r.point.value, p.value);
            assert_eq!(r.point.mapping, p.mapping);
        }
    }

    #[test]
    fn empty_spec_yields_no_rows() {
        let spec = SweepSpec {
            c_values: vec![],
            k_values: vec![],
            spatial_values: vec![],
            mappings: vec![Mapping::Wp],
            mag: 1,
            seed: 0,
        };
        let rows = run_sweep_cached(
            &spec,
            &CgraConfig::default(),
            4,
            &PointCache::new(1),
        )
        .unwrap();
        assert!(rows.is_empty());
    }
}
