//! The Figure-5 hyper-parameter sweep, parallelized over a worker pool.
//!
//! Paper §3.2: "We vary Ox and Oy in [16, 64], C and K in [16, 144],
//! increasing by 1 the dimension of each parameter until 32, and then in
//! steps of 16 … We limit our search to the maximum memory available in
//! the system (512 kiB)." Each axis is varied from the baseline
//! C = K = Ox = Oy = 16; every point runs every mapping; oversized
//! points are recorded as skipped, exactly like the paper's bound.

use anyhow::Result;

use crate::cgra::{Cgra, CgraConfig};
use crate::conv::{random_input, random_weights, ConvShape};
use crate::energy::EnergyModel;
use crate::kernels::{run_mapping, Mapping};
use crate::metrics::MappingReport;
use crate::prop::Rng;

use super::pool::run_jobs;

/// Which hyper-parameter an axis point varies.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Axis {
    /// Input channels C.
    C,
    /// Output channels K.
    K,
    /// Spatial size (Ox = Oy varied together, as in Fig. 5's plots).
    Spatial,
}

impl Axis {
    /// Axis label for reports.
    pub fn label(self) -> &'static str {
        match self {
            Axis::C => "C",
            Axis::K => "K",
            Axis::Spatial => "OxOy",
        }
    }
}

/// The paper's sweep values for one axis: step 1 up to 32, then step 16.
pub fn paper_axis_values(lo: usize, mid: usize, hi: usize, step: usize) -> Vec<usize> {
    let mut v: Vec<usize> = (lo..=mid).collect();
    let mut x = mid + step;
    while x <= hi {
        v.push(x);
        x += step;
    }
    v
}

/// Sweep specification.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Values taken by C (other params at baseline).
    pub c_values: Vec<usize>,
    /// Values taken by K.
    pub k_values: Vec<usize>,
    /// Values taken by Ox = Oy.
    pub spatial_values: Vec<usize>,
    /// Mappings to run at every point.
    pub mappings: Vec<Mapping>,
    /// Input-data magnitude (values in [-mag, mag]).
    pub mag: i32,
    /// Base RNG seed; each point derives its own deterministic seed.
    pub seed: u64,
}

impl SweepSpec {
    /// The paper's full Figure-5 sweep.
    pub fn paper() -> SweepSpec {
        SweepSpec {
            c_values: paper_axis_values(16, 32, 144, 16),
            k_values: paper_axis_values(16, 32, 144, 16),
            spatial_values: paper_axis_values(16, 32, 64, 16),
            mappings: Mapping::ALL.to_vec(),
            mag: 20,
            seed: 0xf15_5eed,
        }
    }

    /// A reduced sweep for quick runs/tests: the interesting points only
    /// (baseline, the ±1 imbalance points, tile multiples, extremes).
    pub fn quick() -> SweepSpec {
        SweepSpec {
            c_values: vec![16, 17, 32, 48],
            k_values: vec![16, 17, 32, 48],
            spatial_values: vec![16, 32],
            mappings: Mapping::ALL.to_vec(),
            mag: 20,
            seed: 0xf15_5eed,
        }
    }

    /// All (axis, value, shape, mapping) points.
    pub fn points(&self) -> Vec<SweepPoint> {
        let base = ConvShape::baseline();
        let mut shapes: Vec<(Axis, usize, ConvShape)> = Vec::new();
        for &c in &self.c_values {
            shapes.push((Axis::C, c, ConvShape { c, ..base }));
        }
        for &k in &self.k_values {
            shapes.push((Axis::K, k, ConvShape { k, ..base }));
        }
        for &s in &self.spatial_values {
            shapes.push((Axis::Spatial, s, ConvShape { ox: s, oy: s, ..base }));
        }
        let mut points = Vec::new();
        for (axis, value, shape) in shapes {
            for &mapping in &self.mappings {
                points.push(SweepPoint { axis, value, shape, mapping });
            }
        }
        points
    }
}

/// One sweep point.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    /// Varied axis.
    pub axis: Axis,
    /// Axis value.
    pub value: usize,
    /// Full layer shape.
    pub shape: ConvShape,
    /// Strategy.
    pub mapping: Mapping,
}

/// One sweep result row.
#[derive(Clone, Debug)]
pub struct SweepRow {
    /// The point.
    pub point: SweepPoint,
    /// Metrics, or `None` with a reason when skipped/failed.
    pub report: Option<MappingReport>,
    /// Why the point was skipped (memory bound), if it was.
    pub skipped: Option<String>,
}

/// Run the sweep on `workers` threads. Deterministic: the per-point data
/// seed depends only on the shape.
pub fn run_sweep(spec: &SweepSpec, cfg: &CgraConfig, workers: usize) -> Result<Vec<SweepRow>> {
    let model = EnergyModel::default();
    let points = spec.points();
    let jobs: Vec<_> = points
        .into_iter()
        .map(|point| {
            let cfg = cfg.clone();
            move || -> SweepRow {
                let shape = point.shape;
                let mut rng = Rng::new(
                    spec.seed ^ (shape.c as u64) << 32
                        ^ (shape.k as u64) << 16
                        ^ (shape.ox as u64) << 8
                        ^ shape.oy as u64,
                );
                let input = random_input(&shape, spec.mag, &mut rng);
                let weights = random_weights(&shape, spec.mag, &mut rng);
                let cgra = match Cgra::new(cfg) {
                    Ok(c) => c,
                    Err(e) => {
                        return SweepRow { point, report: None, skipped: Some(e.to_string()) }
                    }
                };
                match run_mapping(&cgra, point.mapping, &shape, &input, &weights) {
                    Ok(out) => SweepRow {
                        point,
                        report: Some(MappingReport::from_outcome(&out, &model)),
                        skipped: None,
                    },
                    Err(e) => {
                        // Memory-bound points are the expected skip class
                        // (the paper's 512 KiB limit).
                        SweepRow { point, report: None, skipped: Some(e.to_string()) }
                    }
                }
            }
        })
        .collect();
    Ok(run_jobs(workers, jobs))
}

/// The paper's conclusion as an operator: pick the mapping for a shape.
/// WP dominates every hyper-parameter combination in the paper ("WP
/// remains the best approach for any hyperparameter combination"), so
/// the chooser returns WP; the Fig. 5 sweep bench re-verifies that claim
/// against the simulator on every run.
pub fn auto_mapping(_shape: &ConvShape) -> Mapping {
    Mapping::Wp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_axis_values_match_protocol() {
        let v = paper_axis_values(16, 32, 144, 16);
        assert_eq!(v[0], 16);
        assert!(v.contains(&17) && v.contains(&31) && v.contains(&32));
        assert!(v.contains(&48) && v.contains(&144));
        assert!(!v.contains(&33) && !v.contains(&145));
        // 16..=32 step 1 (17 values) + 48..=144 step 16 (7 values).
        assert_eq!(v.len(), 17 + 7);
    }

    #[test]
    fn points_cover_axes_and_mappings() {
        let spec = SweepSpec::quick();
        let pts = spec.points();
        assert_eq!(
            pts.len(),
            (spec.c_values.len() + spec.k_values.len() + spec.spatial_values.len())
                * spec.mappings.len()
        );
        assert!(pts.iter().any(|p| p.axis == Axis::C && p.value == 17));
    }

    #[test]
    fn small_sweep_runs_and_is_deterministic() {
        let spec = SweepSpec {
            c_values: vec![4],
            k_values: vec![5],
            spatial_values: vec![4],
            mappings: vec![Mapping::Wp, Mapping::Cpu],
            mag: 10,
            seed: 1,
        };
        let cfg = CgraConfig::default();
        let a = run_sweep(&spec, &cfg, 2).unwrap();
        let b = run_sweep(&spec, &cfg, 4).unwrap();
        assert_eq!(a.len(), 6);
        for (x, y) in a.iter().zip(b.iter()) {
            let (rx, ry) = (x.report.as_ref().unwrap(), y.report.as_ref().unwrap());
            assert_eq!(rx.latency_cycles, ry.latency_cycles);
            assert_eq!(rx.cgra_accesses, ry.cgra_accesses);
        }
    }

    #[test]
    fn oversized_points_are_skipped_not_fatal() {
        let spec = SweepSpec {
            c_values: vec![144],
            k_values: vec![],
            spatial_values: vec![],
            mappings: vec![Mapping::Ip],
            mag: 5,
            seed: 2,
        };
        // Tiny memory to force the skip.
        let mut cfg = CgraConfig::default();
        cfg.mem_words = 2048;
        let rows = run_sweep(&spec, &cfg, 1).unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].report.is_none());
        assert!(rows[0].skipped.as_ref().unwrap().contains("words"));
    }

    #[test]
    fn auto_mapping_is_wp() {
        assert_eq!(auto_mapping(&ConvShape::baseline()), Mapping::Wp);
    }
}
