//! Scoped worker pool over `std::thread` (no tokio in the offline
//! environment — the workload is CPU-bound simulation, so OS threads are
//! the right tool regardless).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `jobs` across up to `workers` threads, preserving result order.
///
/// Each job runs at most once; panics inside jobs propagate after all
/// workers finish (fail-fast is deliberately avoided so sweep results
/// stay complete).
pub fn run_jobs<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }

    let next = AtomicUsize::new(0);
    // Jobs behind a mutex of Options: each is taken exactly once.
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = jobs[i].lock().unwrap().take().expect("job taken twice");
                let out = job();
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not complete"))
        .collect()
}

/// Default worker count: available parallelism, capped to keep the
/// memory footprint of concurrent simulations reasonable.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..100).map(|i| move || i * 2).collect();
        let out = run_jobs(8, jobs);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_job_exactly_once() {
        use std::sync::atomic::AtomicU32;
        static COUNT: AtomicU32 = AtomicU32::new(0);
        let jobs: Vec<_> = (0..50)
            .map(|_| {
                || {
                    COUNT.fetch_add(1, Ordering::SeqCst);
                    ()
                }
            })
            .collect();
        run_jobs(4, jobs);
        assert_eq!(COUNT.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(run_jobs(1, vec![|| 7]), vec![7]);
        assert!(run_jobs::<i32, fn() -> i32>(4, vec![]).is_empty());
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = run_jobs(64, vec![|| 1, || 2]);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn zero_jobs_with_many_workers() {
        // Must not spawn anything or hang; returns immediately.
        let out: Vec<u8> = run_jobs(32, Vec::<fn() -> u8>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn one_worker_runs_in_submission_order() {
        use std::sync::atomic::AtomicUsize;
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..10usize)
            .map(|i| move || (i, SEQ.fetch_add(1, Ordering::SeqCst)))
            .collect();
        let out = run_jobs(1, jobs);
        for (i, (job, seq)) in out.into_iter().enumerate() {
            assert_eq!(job, i);
            assert_eq!(seq, i, "single worker must execute sequentially");
        }
    }

    #[test]
    fn zero_workers_clamped_to_one() {
        let out = run_jobs(0, vec![|| 5, || 6]);
        assert_eq!(out, vec![5, 6]);
    }

    /// A panicking job propagates only after the surviving workers have
    /// drained every remaining job (fail-fast is deliberately avoided so
    /// sweep results stay complete).
    #[test]
    fn panic_propagates_after_other_workers_finish() {
        use std::sync::atomic::AtomicU32;
        static COMPLETED: AtomicU32 = AtomicU32::new(0);
        let result = std::panic::catch_unwind(|| {
            let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..12)
                .map(|i| -> Box<dyn FnOnce() -> usize + Send> {
                    if i == 2 {
                        Box::new(|| panic!("job 2 exploded"))
                    } else {
                        Box::new(move || {
                            COMPLETED.fetch_add(1, Ordering::SeqCst);
                            i
                        })
                    }
                })
                .collect();
            run_jobs(3, jobs)
        });
        assert!(result.is_err(), "panic must propagate out of run_jobs");
        // All 11 non-panicking jobs still ran: the panicking worker dies,
        // the other workers keep draining the queue.
        assert_eq!(COMPLETED.load(Ordering::SeqCst), 11);
    }
}
