//! Minimal benchmarking harness (no `criterion` offline).
//!
//! Warmup + fixed-sample measurement with median / MAD / min reporting,
//! plus optional throughput units. Used by the `rust/benches/*.rs`
//! targets (built with `harness = false`).

use std::time::Instant;

/// One measured series.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark label.
    pub name: String,
    /// Seconds per iteration, sorted ascending.
    pub samples: Vec<f64>,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl Measurement {
    /// Median seconds/iteration.
    pub fn median(&self) -> f64 {
        percentile(&self.samples, 0.5)
    }

    /// Median absolute deviation (robust spread).
    pub fn mad(&self) -> f64 {
        let m = self.median();
        let mut d: Vec<f64> = self.samples.iter().map(|s| (s - m).abs()).collect();
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile(&d, 0.5)
    }

    /// Fastest sample.
    pub fn min(&self) -> f64 {
        self.samples.first().copied().unwrap_or(f64::NAN)
    }

    /// criterion-like one-line report.
    pub fn report(&self) -> String {
        let med = self.median();
        let mut line = format!(
            "{:<40} time: [{} {} {}]",
            self.name,
            fmt_time(self.min()),
            fmt_time(med),
            fmt_time(percentile(&self.samples, 0.95)),
        );
        if let Some(items) = self.items_per_iter {
            line.push_str(&format!("  thrpt: {}/s", crate::util::fmt::si(items / med)));
        }
        line
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner: warms up then measures `samples` timed iterations.
pub struct Bench {
    warmup_iters: usize,
    samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        // Env overrides let `make bench` trade accuracy for speed.
        let warmup = env_usize("BENCH_WARMUP", 3);
        let samples = env_usize("BENCH_SAMPLES", 10);
        Bench { warmup_iters: warmup, samples }
    }
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

impl Bench {
    /// Runner with explicit warmup/sample counts.
    pub fn new(warmup_iters: usize, samples: usize) -> Bench {
        Bench { warmup_iters, samples }
    }

    /// Measure `f`, printing the report line. `items` (optional) enables
    /// throughput output. Returns the measurement for further use.
    pub fn run<T>(
        &self,
        name: &str,
        items: Option<f64>,
        mut f: impl FnMut() -> T,
    ) -> Measurement {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = Measurement { name: name.to_string(), samples, items_per_iter: items };
        println!("{}", m.report());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::new(0, 3);
        let m = b.run("spin", Some(1000.0), || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(m.samples.len(), 3);
        assert!(m.median() >= 0.0);
        assert!(m.report().contains("spin"));
        assert!(m.report().contains("thrpt"));
    }

    #[test]
    fn percentile_interpolates() {
        let v = vec![1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&v, 0.5) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
    }

    #[test]
    fn time_formats() {
        assert!(fmt_time(2.0).contains('s'));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-9).contains("ns"));
    }
}
