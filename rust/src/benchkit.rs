//! Minimal benchmarking harness (no `criterion` offline).
//!
//! Warmup + fixed-sample measurement with median / MAD / min reporting,
//! plus optional throughput units. Used by the `rust/benches/*.rs`
//! targets (built with `harness = false`). Benches additionally append
//! machine-readable `{bench, metric, value}` rows to
//! `BENCH_RESULTS.json` via [`ResultsWriter`], so CI and scripts can
//! diff numbers across runs without scraping report lines.

use std::time::Instant;

use crate::util::json::{self, Json};

/// One measured series.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark label.
    pub name: String,
    /// Seconds per iteration, sorted ascending.
    pub samples: Vec<f64>,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl Measurement {
    /// Median seconds/iteration.
    pub fn median(&self) -> f64 {
        percentile(&self.samples, 0.5)
    }

    /// Median absolute deviation (robust spread).
    pub fn mad(&self) -> f64 {
        let m = self.median();
        let mut d: Vec<f64> = self.samples.iter().map(|s| (s - m).abs()).collect();
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile(&d, 0.5)
    }

    /// Fastest sample.
    pub fn min(&self) -> f64 {
        self.samples.first().copied().unwrap_or(f64::NAN)
    }

    /// criterion-like one-line report.
    pub fn report(&self) -> String {
        let med = self.median();
        let mut line = format!(
            "{:<40} time: [{} {} {}]",
            self.name,
            fmt_time(self.min()),
            fmt_time(med),
            fmt_time(percentile(&self.samples, 0.95)),
        );
        if let Some(items) = self.items_per_iter {
            line.push_str(&format!("  thrpt: {}/s", crate::util::fmt::si(items / med)));
        }
        line
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner: warms up then measures `samples` timed iterations.
pub struct Bench {
    warmup_iters: usize,
    samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        // Env overrides let `make bench` trade accuracy for speed.
        let warmup = env_usize("BENCH_WARMUP", 3);
        let samples = env_usize("BENCH_SAMPLES", 10);
        Bench { warmup_iters: warmup, samples }
    }
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

impl Bench {
    /// Runner with explicit warmup/sample counts.
    pub fn new(warmup_iters: usize, samples: usize) -> Bench {
        Bench { warmup_iters, samples }
    }

    /// Measure `f`, printing the report line. `items` (optional) enables
    /// throughput output. Returns the measurement for further use.
    pub fn run<T>(
        &self,
        name: &str,
        items: Option<f64>,
        mut f: impl FnMut() -> T,
    ) -> Measurement {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = Measurement { name: name.to_string(), samples, items_per_iter: items };
        println!("{}", m.report());
        m
    }
}

/// Default file machine-readable bench rows append to (repo root when
/// benches run via `cargo bench` from `rust/`, overridable with the
/// `BENCH_RESULTS` env var).
pub const RESULTS_PATH: &str = "../BENCH_RESULTS.json";

/// Accumulates `{bench, metric, value}` rows and appends them to the
/// results file on [`ResultsWriter::flush`]. The file holds one JSON
/// array; flushing parses the existing document and extends it, so
/// successive bench binaries in one `cargo bench` run all land in the
/// same file. IO or parse trouble never fails a bench — the writer
/// warns on stderr and starts a fresh array instead.
#[derive(Debug, Default)]
pub struct ResultsWriter {
    bench: String,
    rows: Vec<(String, f64)>,
}

impl ResultsWriter {
    /// A writer for one bench binary (`bench` names the source, e.g.
    /// `sim_throughput`).
    pub fn new(bench: &str) -> ResultsWriter {
        ResultsWriter { bench: bench.to_string(), rows: Vec::new() }
    }

    /// Queue one metric row.
    pub fn row(&mut self, metric: &str, value: f64) {
        self.rows.push((metric.to_string(), value));
    }

    /// Append the queued rows to the results file (path from the
    /// `BENCH_RESULTS` env var, default [`RESULTS_PATH`]). Returns the
    /// rows written; never panics.
    pub fn flush(&mut self) -> usize {
        let path = std::env::var("BENCH_RESULTS").unwrap_or_else(|_| RESULTS_PATH.to_string());
        self.flush_to(&path)
    }

    /// [`ResultsWriter::flush`] to an explicit path.
    pub fn flush_to(&mut self, path: &str) -> usize {
        let mut all: Vec<Json> = match std::fs::read_to_string(path) {
            Ok(text) => match json::parse(&text) {
                Ok(Json::Arr(rows)) => rows,
                Ok(_) | Err(_) => {
                    eprintln!("benchkit: {path} is not a JSON array; starting fresh");
                    Vec::new()
                }
            },
            Err(_) => Vec::new(), // first run: no file yet
        };
        let n = self.rows.len();
        for (metric, value) in self.rows.drain(..) {
            all.push(Json::obj(vec![
                ("bench", self.bench.as_str().into()),
                ("metric", metric.as_str().into()),
                ("value", value.into()),
            ]));
        }
        let doc = Json::Arr(all).to_string_pretty();
        if let Err(e) = std::fs::write(path, doc) {
            eprintln!("benchkit: cannot write {path}: {e}");
            return 0;
        }
        println!("wrote {n} result rows to {path}");
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::new(0, 3);
        let m = b.run("spin", Some(1000.0), || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(m.samples.len(), 3);
        assert!(m.median() >= 0.0);
        assert!(m.report().contains("spin"));
        assert!(m.report().contains("thrpt"));
    }

    #[test]
    fn percentile_interpolates() {
        let v = vec![1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&v, 0.5) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
    }

    #[test]
    fn time_formats() {
        assert!(fmt_time(2.0).contains('s'));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-9).contains("ns"));
    }

    #[test]
    fn results_writer_appends_and_survives_garbage() {
        let path =
            std::env::temp_dir().join(format!("cgra_bench_rows_{}.json", std::process::id()));
        let path = path.to_string_lossy().to_string();
        let _ = std::fs::remove_file(&path);

        let mut w = ResultsWriter::new("unit");
        w.row("inf_per_s", 123.5);
        assert_eq!(w.flush_to(&path), 1);
        // A second flush appends rather than truncating.
        let mut w2 = ResultsWriter::new("unit2");
        w2.row("slots_per_s", 9.0);
        assert_eq!(w2.flush_to(&path), 1);
        let doc = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        match &doc {
            Json::Arr(rows) => {
                assert_eq!(rows.len(), 2);
                assert_eq!(rows[0].req_str("bench").unwrap(), "unit");
                assert_eq!(rows[0].req_str("metric").unwrap(), "inf_per_s");
                assert_eq!(rows[0].get("value").unwrap().as_f64(), Some(123.5));
                assert_eq!(rows[1].req_str("bench").unwrap(), "unit2");
            }
            other => panic!("expected array, got {other:?}"),
        }
        // A corrupted file is replaced, not fatal.
        std::fs::write(&path, "not json").unwrap();
        let mut w3 = ResultsWriter::new("unit");
        w3.row("x", 1.0);
        assert_eq!(w3.flush_to(&path), 1);
        let doc = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(matches!(doc, Json::Arr(rows) if rows.len() == 1));
        let _ = std::fs::remove_file(&path);
    }
}
