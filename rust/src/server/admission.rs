//! Deadline admission control: price a request through the analytical
//! planner *before* it executes and decide — admit, degrade, or reject
//! — without simulating a single convolution.
//!
//! Soundness: the planner's per-layer predictions are CI-gated to ≤ 5 %
//! MAE against the cycle-level simulator (DESIGN.md §7), and
//! [`crate::nn::plan_network`] prices whole graphs with the *same*
//! closed-form host glue the executor charges — so a modeled-latency
//! admission decision is wrong only within that validated band.
//! Callers with hard SLOs should pad deadlines by the bound; the
//! daemon itself never runs work it already priced over budget.
//!
//! The **degradation ladder** (policy [`AdmissionPolicy::Degrade`])
//! tries, in order, before rejecting:
//! 1. *latency-remap* — an energy-objective request is re-priced under
//!    the latency objective (the paper's shapes usually agree, but
//!    off-grid the energy choice can be slower);
//! 2. *batch-1* — a multi-inference request is cut to a single
//!    inference.
//!
//! Every applied step is recorded in [`Admitted::degrade_steps`] and
//! echoed in the response, so a degraded request is never silent.

use anyhow::Result;

use crate::nn::{plan_network, Net};
use crate::obs::trace;
use crate::planner::{PlanObjective, Planner};

/// What the daemon does with a request whose modeled latency (queue
/// wait + execution) blows its deadline.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AdmissionPolicy {
    /// Reject with a structured error.
    Reject,
    /// Walk the degradation ladder first; reject only if no rung fits.
    Degrade,
}

impl AdmissionPolicy {
    /// Parse a user-facing name, case-insensitively.
    pub fn parse(s: &str) -> Result<AdmissionPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "reject" => Ok(AdmissionPolicy::Reject),
            "degrade" => Ok(AdmissionPolicy::Degrade),
            other => anyhow::bail!("unknown admission policy '{other}' (valid: reject, degrade)"),
        }
    }

    /// Display label.
    pub fn label(self) -> &'static str {
        match self {
            AdmissionPolicy::Reject => "reject",
            AdmissionPolicy::Degrade => "degrade",
        }
    }
}

/// An admitted (possibly degraded) request, fully priced.
#[derive(Clone, Debug)]
pub struct Admitted {
    /// The objective the admitted plan minimized (post-ladder).
    pub objective: PlanObjective,
    /// Inferences to run (post-ladder).
    pub count: usize,
    /// Planner-modeled cycles per inference.
    pub cycles_per_inf: u64,
    /// Planner-modeled energy per inference, µJ.
    pub uj_per_inf: f64,
    /// Modeled execution time of the whole request, µs.
    pub modeled_us: f64,
    /// Modeled queue wait at admission time, µs (backlog cycles over
    /// the worker pool).
    pub wait_us: f64,
    /// Degradation-ladder rungs applied, in order (empty = as asked).
    pub degrade_steps: Vec<&'static str>,
}

/// A structured rejection (a *normal* outcome, not an internal error).
#[derive(Clone, Debug)]
pub struct Rejection {
    /// `"deadline"` (priced over budget) or `"infeasible"` (the net
    /// cannot run under the memory bound at all).
    pub kind: &'static str,
    /// Human-readable detail.
    pub detail: String,
    /// Modeled execution time of the cheapest attempted variant, µs
    /// (0 for infeasible nets).
    pub modeled_us: f64,
    /// Modeled queue wait at admission time, µs.
    pub wait_us: f64,
    /// The deadline the request carried, µs (`f64::INFINITY` if none —
    /// only infeasible requests reject without one).
    pub deadline_us: f64,
}

/// The admission decision.
#[derive(Clone, Debug)]
pub enum Decision {
    /// Run it (terms in the payload).
    Admitted(Admitted),
    /// Don't (reason in the payload).
    Rejected(Rejection),
}

/// Price `count` inferences of `net` under `objective` against
/// `deadline_us` and decide. `backlog_cycles` is the modeled-cycle sum
/// of already-admitted, unfinished work; `workers` divides it into an
/// expected wait. Metrics-only: the only machinery consulted is the
/// planner (memoized per shape × mapping), never the simulator —
/// `tests/daemon_admission.rs` pins that with [`crate::engine::RunCounters`].
pub fn admit(
    planner: &Planner,
    net: &Net,
    objective: PlanObjective,
    count: usize,
    deadline_us: Option<f64>,
    backlog_cycles: u64,
    workers: usize,
    policy: AdmissionPolicy,
) -> Result<Decision> {
    let mut asp = trace::span("admission", "admit");
    let clock_hz = planner.energy_model().clock_hz;
    let us_per_cycle = 1e6 / clock_hz;
    let wait_us = backlog_cycles as f64 * us_per_cycle / workers.max(1) as f64;
    let mut steps: Vec<&'static str> = Vec::new();
    let (mut obj, mut cnt) = (objective, count);
    loop {
        let plan = match plan_network(planner, net, obj) {
            Ok(p) => p,
            Err(e) => {
                // Infeasible under the memory bound (or an invalid
                // graph): no objective or batch change can fix it.
                asp.arg("outcome", "infeasible");
                return Ok(Decision::Rejected(Rejection {
                    kind: "infeasible",
                    detail: format!("{e:#}"),
                    modeled_us: 0.0,
                    wait_us,
                    deadline_us: deadline_us.unwrap_or(f64::INFINITY),
                }));
            }
        };
        let modeled_us = cnt as f64 * plan.total_cycles as f64 * us_per_cycle;
        let fits = match deadline_us {
            None => true,
            Some(d) => wait_us + modeled_us <= d,
        };
        if fits {
            asp.arg("outcome", "admitted");
            asp.arg("degrade_steps", steps.len());
            return Ok(Decision::Admitted(Admitted {
                objective: obj,
                count: cnt,
                cycles_per_inf: plan.total_cycles,
                uj_per_inf: plan.total_energy_uj,
                modeled_us,
                wait_us,
                degrade_steps: steps,
            }));
        }
        if policy == AdmissionPolicy::Degrade {
            if obj == PlanObjective::Energy {
                obj = PlanObjective::Latency;
                steps.push("latency-remap");
                continue;
            }
            if cnt > 1 {
                cnt = 1;
                steps.push("batch-1");
                continue;
            }
        }
        let deadline = deadline_us.unwrap_or(f64::INFINITY);
        asp.arg("outcome", "rejected");
        return Ok(Decision::Rejected(Rejection {
            kind: "deadline",
            detail: format!(
                "modeled {modeled_us:.1} us + queue wait {wait_us:.1} us exceeds the \
                 {deadline:.1} us deadline{}",
                if steps.is_empty() {
                    String::new()
                } else {
                    format!(" (after degradation: {})", steps.join(", "))
                }
            ),
            modeled_us,
            wait_us,
            deadline_us: deadline,
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::CgraConfig;
    use crate::energy::EnergyModel;

    fn planner() -> Planner {
        Planner::new(&CgraConfig::default(), &EnergyModel::default()).unwrap()
    }

    fn tiny() -> Net {
        Net::plain_stack(1, 2, 2, 6, 3).unwrap()
    }

    #[test]
    fn no_deadline_always_admits() {
        let p = planner();
        let d = admit(&p, &tiny(), PlanObjective::Latency, 3, None, 0, 1, AdmissionPolicy::Reject)
            .unwrap();
        match d {
            Decision::Admitted(a) => {
                assert_eq!(a.count, 3);
                assert!(a.cycles_per_inf > 0 && a.uj_per_inf > 0.0);
                assert!(a.degrade_steps.is_empty());
                assert_eq!(a.wait_us, 0.0);
            }
            Decision::Rejected(r) => panic!("rejected: {}", r.detail),
        }
    }

    #[test]
    fn impossible_deadline_rejects_with_terms() {
        let p = planner();
        let d = admit(
            &p,
            &tiny(),
            PlanObjective::Latency,
            1,
            Some(0.001),
            0,
            1,
            AdmissionPolicy::Reject,
        )
        .unwrap();
        match d {
            Decision::Rejected(r) => {
                assert_eq!(r.kind, "deadline");
                assert!(r.modeled_us > r.deadline_us);
                assert!(r.detail.contains("deadline"), "{}", r.detail);
            }
            Decision::Admitted(_) => panic!("admitted past an impossible deadline"),
        }
    }

    #[test]
    fn degrade_ladder_cuts_batch_then_rejects() {
        let p = planner();
        let net = tiny();
        // Price one latency-objective inference to craft a deadline
        // that fits exactly one.
        let one = plan_network(&p, &net, PlanObjective::Latency).unwrap();
        let one_us = one.total_cycles as f64 / p.energy_model().clock_hz * 1e6;
        let d = admit(
            &p,
            &net,
            PlanObjective::Energy,
            4,
            Some(1.5 * one_us),
            0,
            1,
            AdmissionPolicy::Degrade,
        )
        .unwrap();
        match d {
            Decision::Admitted(a) => {
                assert_eq!(a.count, 1);
                assert!(a.degrade_steps.contains(&"batch-1"), "{:?}", a.degrade_steps);
                assert_eq!(a.objective, PlanObjective::Latency);
                assert!(a.modeled_us <= 1.5 * one_us);
            }
            Decision::Rejected(r) => panic!("ladder should have fit batch-1: {}", r.detail),
        }
        // The same request under Reject fails outright.
        let d = admit(
            &p,
            &net,
            PlanObjective::Energy,
            4,
            Some(1.5 * one_us),
            0,
            1,
            AdmissionPolicy::Reject,
        )
        .unwrap();
        assert!(matches!(d, Decision::Rejected(_)));
        // A deadline under even one inference exhausts the ladder.
        let d = admit(
            &p,
            &net,
            PlanObjective::Energy,
            4,
            Some(0.5 * one_us),
            0,
            1,
            AdmissionPolicy::Degrade,
        )
        .unwrap();
        match d {
            Decision::Rejected(r) => {
                assert_eq!(r.kind, "deadline");
                assert!(r.detail.contains("batch-1"), "{}", r.detail);
            }
            Decision::Admitted(a) => panic!("admitted {:?} past the ladder", a.degrade_steps),
        }
    }

    #[test]
    fn backlog_counts_against_the_deadline() {
        let p = planner();
        let net = tiny();
        let one = plan_network(&p, &net, PlanObjective::Latency).unwrap();
        let one_us = one.total_cycles as f64 / p.energy_model().clock_hz * 1e6;
        // Fits with an empty queue...
        let empty = admit(
            &p,
            &net,
            PlanObjective::Latency,
            1,
            Some(1.5 * one_us),
            0,
            1,
            AdmissionPolicy::Reject,
        )
        .unwrap();
        assert!(matches!(empty, Decision::Admitted(_)));
        // ...but not behind a backlog worth two inferences.
        let backlog = 2 * one.total_cycles;
        let busy = admit(
            &p,
            &net,
            PlanObjective::Latency,
            1,
            Some(1.5 * one_us),
            backlog,
            1,
            AdmissionPolicy::Reject,
        )
        .unwrap();
        match busy {
            Decision::Rejected(r) => assert!(r.wait_us > 0.0),
            Decision::Admitted(_) => panic!("queue wait ignored"),
        }
        // More workers drain the same backlog faster: admits again.
        let wide = admit(
            &p,
            &net,
            PlanObjective::Latency,
            1,
            Some(1.5 * one_us),
            backlog,
            8,
            AdmissionPolicy::Reject,
        )
        .unwrap();
        assert!(matches!(wide, Decision::Admitted(_)));
    }

    #[test]
    fn infeasible_net_rejects_structurally() {
        let p = planner();
        // 16ch 64x64 stride-1 valid conv blows the 4 KiB memory bound
        // (the same shape engine tests use for over-bound errors).
        let net = Net::plain_stack(1, 16, 16, 66, 1).unwrap();
        let d = admit(&p, &net, PlanObjective::Latency, 1, None, 0, 1, AdmissionPolicy::Degrade)
            .unwrap();
        match d {
            Decision::Rejected(r) => assert_eq!(r.kind, "infeasible"),
            Decision::Admitted(_) => panic!("a memory-bound net was admitted"),
        }
    }
}
