//! The daemon's newline-delimited JSON wire protocol: one request
//! object per line in, one response object per line out, parsed and
//! rendered through [`crate::util::json`] (no `serde`).
//!
//! Requests (`"op"` selects):
//!
//! ```json
//! {"op":"infer","tenant":"edge","preset":"paper-baseline","count":4,
//!  "deadline_us":900.0,"objective":"latency","seed":3,
//!  "return_output":false,"admission":"degrade"}
//! {"op":"infer","depth":2,"c0":3,"k":8,"hw":16,"net_seed":7}
//! {"op":"register","tenant":"edge","e_mem_access_pj":42.0}
//! {"op":"stats"}
//! {"op":"shutdown"}
//! ```
//!
//! Every response carries `"ok"`; failures are
//! `{"ok":false,"error":{"kind":...,"detail":...}}` with admission
//! rejections adding their priced terms. `register` starts from the
//! calibrated [`EnergyModel`] and overrides any field named in the
//! request, so a tenant's pricing session is declared entirely on the
//! wire.

use anyhow::{bail, Result};

use crate::energy::EnergyModel;
use crate::planner::PlanObjective;
use crate::util::json::{self, Json};

use super::admission::{AdmissionPolicy, Rejection};
use super::{InferRequest, NetSpec, Served};

/// A parsed wire request.
#[derive(Debug)]
pub enum Request {
    /// Run inferences.
    Infer(InferRequest),
    /// Snapshot the stats surface.
    Stats,
    /// Declare a tenant's energy model up front.
    Register {
        /// Tenant name.
        tenant: String,
        /// The tenant's pricing model.
        model: EnergyModel,
    },
    /// Drain and stop the daemon.
    Shutdown,
}

impl Request {
    /// The wire `op` this request arrived under (trace-span label).
    pub fn op(&self) -> &'static str {
        match self {
            Request::Infer(_) => "infer",
            Request::Stats => "stats",
            Request::Register { .. } => "register",
            Request::Shutdown => "shutdown",
        }
    }
}

fn opt_u64(v: &Json, key: &str) -> Result<Option<u64>> {
    match v.get(key) {
        None => Ok(None),
        Some(f) => match f.as_i64() {
            Some(n) if n >= 0 => Ok(Some(n as u64)),
            _ => bail!("field '{key}' is not a non-negative integer"),
        },
    }
}

fn opt_usize(v: &Json, key: &str) -> Result<Option<usize>> {
    Ok(opt_u64(v, key)?.map(|n| n as usize))
}

fn opt_f64(v: &Json, key: &str) -> Result<Option<f64>> {
    match v.get(key) {
        None => Ok(None),
        Some(f) => match f.as_f64() {
            Some(x) => Ok(Some(x)),
            None => bail!("field '{key}' is not a number"),
        },
    }
}

fn opt_bool(v: &Json, key: &str) -> Result<Option<bool>> {
    match v.get(key) {
        None => Ok(None),
        Some(f) => match f.as_bool() {
            Some(b) => Ok(Some(b)),
            None => bail!("field '{key}' is not a boolean"),
        },
    }
}

fn opt_str<'a>(v: &'a Json, key: &str) -> Result<Option<&'a str>> {
    match v.get(key) {
        None => Ok(None),
        Some(f) => match f.as_str() {
            Some(s) => Ok(Some(s)),
            None => bail!("field '{key}' is not a string"),
        },
    }
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = json::parse(line)?;
    let op = v.req_str("op")?;
    match op {
        "infer" => {
            let net_seed = opt_u64(&v, "net_seed")?.unwrap_or(7);
            let net = match opt_str(&v, "preset")? {
                Some(name) => NetSpec::Preset { name: name.to_string(), seed: net_seed },
                None => NetSpec::Stack {
                    depth: opt_usize(&v, "depth")?.unwrap_or(4),
                    c0: opt_usize(&v, "c0")?.unwrap_or(3),
                    k: opt_usize(&v, "k")?.unwrap_or(16),
                    hw: opt_usize(&v, "hw")?.unwrap_or(32),
                    seed: net_seed,
                },
            };
            Ok(Request::Infer(InferRequest {
                tenant: opt_str(&v, "tenant")?.unwrap_or("default").to_string(),
                net,
                count: opt_usize(&v, "count")?.unwrap_or(1),
                input_seed: opt_u64(&v, "seed")?.unwrap_or(0),
                deadline_us: opt_f64(&v, "deadline_us")?,
                objective: match opt_str(&v, "objective")? {
                    Some(s) => PlanObjective::parse(s)?,
                    None => PlanObjective::Latency,
                },
                collect_outputs: opt_bool(&v, "return_output")?.unwrap_or(false),
                admission: match opt_str(&v, "admission")? {
                    Some(s) => Some(AdmissionPolicy::parse(s)?),
                    None => None,
                },
            }))
        }
        "stats" => Ok(Request::Stats),
        "register" => {
            let tenant = v.req_str("tenant")?.to_string();
            let mut model = EnergyModel::default();
            for (field, slot) in [
                ("clock_hz", &mut model.clock_hz as &mut f64),
                ("p_cgra_leak_mw", &mut model.p_cgra_leak_mw),
                ("p_pe_active_mw", &mut model.p_pe_active_mw),
                ("p_cpu_active_mw", &mut model.p_cpu_active_mw),
                ("p_cpu_idle_mw", &mut model.p_cpu_idle_mw),
                ("p_mem_static_mw", &mut model.p_mem_static_mw),
                ("e_mem_access_pj", &mut model.e_mem_access_pj),
            ] {
                if let Some(x) = opt_f64(&v, field)? {
                    *slot = x;
                }
            }
            Ok(Request::Register { tenant, model })
        }
        "shutdown" => Ok(Request::Shutdown),
        other => bail!("unknown op '{other}' (valid: infer, stats, register, shutdown)"),
    }
}

/// Render a served inference response.
pub fn served_json(s: &Served) -> Json {
    let mut fields = vec![
        ("ok", true.into()),
        ("op", "infer".into()),
        ("tenant", s.tenant.as_str().into()),
        ("net", s.net.as_str().into()),
        ("cache", if s.cache_hit { "hit" } else { "miss" }.into()),
        ("count", s.count.into()),
        ("objective", s.objective.label().into()),
        (
            "degraded",
            Json::Arr(s.degrade_steps.iter().map(|&st| Json::Str(st.to_string())).collect()),
        ),
        (
            "priced",
            Json::obj(vec![
                ("cycles_per_inf", s.priced_cycles_per_inf.into()),
                ("uj_per_inf", s.priced_uj_per_inf.into()),
                ("modeled_us", s.modeled_us.into()),
                ("wait_us", s.wait_us.into()),
            ]),
        ),
        (
            "run",
            Json::obj(vec![
                ("cycles_per_inf", s.run_cycles_per_inf.into()),
                ("uj_per_inf", s.run_uj_per_inf.into()),
            ]),
        ),
        ("walk_lanes", s.walk_lanes.into()),
    ];
    if !s.outputs.is_empty() {
        let outs: Vec<Json> = s
            .outputs
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("c", t.c.into()),
                    ("h", t.h.into()),
                    ("w", t.w.into()),
                    ("checksum", checksum_hex(t).into()),
                    ("data", Json::Arr(t.data.iter().map(|&x| Json::Num(x as f64)).collect())),
                ])
            })
            .collect();
        fields.push(("outputs", Json::Arr(outs)));
    }
    Json::obj(fields)
}

/// FNV checksum of an output tensor, rendered as hex (u64-safe in
/// JSON's f64 number space only up to 2^53, so a string it is).
pub fn checksum_hex(t: &crate::conv::TensorChw) -> String {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |v: u64| h = (h ^ v).wrapping_mul(0x1000_0000_01b3);
    for v in [t.c, t.h, t.w] {
        mix(v as u64);
    }
    for &x in &t.data {
        mix(x as u32 as u64);
    }
    format!("{h:#018x}")
}

/// Render an admission rejection.
pub fn rejection_json(r: &Rejection) -> Json {
    Json::obj(vec![
        ("ok", false.into()),
        (
            "error",
            Json::obj(vec![
                ("kind", r.kind.into()),
                ("detail", r.detail.as_str().into()),
                ("modeled_us", r.modeled_us.into()),
                ("wait_us", r.wait_us.into()),
                (
                    "deadline_us",
                    if r.deadline_us.is_finite() { r.deadline_us.into() } else { Json::Null },
                ),
            ]),
        ),
    ])
}

/// Render a generic failure (`bad-request`, `internal`, ...).
pub fn error_json(kind: &str, detail: &str) -> Json {
    Json::obj(vec![
        ("ok", false.into()),
        ("error", Json::obj(vec![("kind", kind.into()), ("detail", detail.into())])),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_defaults_and_overrides() {
        let r = parse_request(r#"{"op":"infer"}"#).unwrap();
        match r {
            Request::Infer(req) => {
                assert_eq!(req.tenant, "default");
                assert_eq!(req.count, 1);
                assert!(matches!(req.net, NetSpec::Stack { depth: 4, c0: 3, k: 16, hw: 32, .. }));
                assert_eq!(req.objective, PlanObjective::Latency);
                assert!(req.deadline_us.is_none() && req.admission.is_none());
                assert!(!req.collect_outputs);
            }
            other => panic!("{other:?}"),
        }
        let r = parse_request(
            r#"{"op":"infer","tenant":"t","preset":"paper-baseline","net_seed":9,
                "count":3,"seed":5,"deadline_us":12.5,"objective":"energy",
                "return_output":true,"admission":"reject"}"#,
        )
        .unwrap();
        match r {
            Request::Infer(req) => {
                assert_eq!(req.tenant, "t");
                assert!(
                    matches!(req.net, NetSpec::Preset { ref name, seed: 9 } if name == "paper-baseline")
                );
                assert_eq!((req.count, req.input_seed), (3, 5));
                assert_eq!(req.deadline_us, Some(12.5));
                assert_eq!(req.objective, PlanObjective::Energy);
                assert!(req.collect_outputs);
                assert_eq!(req.admission, Some(AdmissionPolicy::Reject));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn register_overrides_model_fields() {
        let r = parse_request(r#"{"op":"register","tenant":"hot","e_mem_access_pj":99.0}"#)
            .unwrap();
        match r {
            Request::Register { tenant, model } => {
                assert_eq!(tenant, "hot");
                assert_eq!(model.e_mem_access_pj, 99.0);
                assert_eq!(model.clock_hz, EnergyModel::default().clock_hz);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bad_requests_error_cleanly() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"op":"nope"}"#).is_err());
        assert!(parse_request(r#"{"tenant":"x"}"#).is_err()); // no op
        assert!(parse_request(r#"{"op":"infer","count":-1}"#).is_err());
        assert!(parse_request(r#"{"op":"infer","deadline_us":"soon"}"#).is_err());
        assert!(parse_request(r#"{"op":"register"}"#).is_err()); // tenant required
        // Error responses render with kind + detail.
        let e = error_json("bad-request", "oops");
        assert_eq!(e.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(e.get("error").unwrap().req_str("kind").unwrap(), "bad-request");
    }

    #[test]
    fn checksum_is_content_sensitive() {
        use crate::conv::TensorChw;
        let a = TensorChw::from_vec(1, 1, 2, vec![1, 2]);
        let b = TensorChw::from_vec(1, 1, 2, vec![2, 1]);
        let c = TensorChw::from_vec(1, 2, 1, vec![1, 2]);
        assert_ne!(checksum_hex(&a), checksum_hex(&b));
        assert_ne!(checksum_hex(&a), checksum_hex(&c));
        assert_eq!(checksum_hex(&a), checksum_hex(&a.clone()));
    }
}
