//! # The `cgra daemon` serving subsystem
//!
//! Everything before this module answers one question per process run:
//! compile a net, execute it, print the numbers. This subsystem keeps
//! the process *alive* and serves inference requests continuously —
//! the deployment shape an edge accelerator actually runs in — while
//! preserving the crate's two core contracts:
//!
//! - **compile-once / run-many** — an [`ArtifactRegistry`] (bounded,
//!   sharded, LRU) caches `Arc<CompiledNet>` artifacts keyed by
//!   *network fingerprint ⊕ session fingerprint*, so tenants with
//!   different energy models never share pricing state, while repeat
//!   traffic pays zero compile or program-build work (pinned by the
//!   same [`crate::engine::RunCounters`] discipline as the engine
//!   tests);
//! - **metrics-only admission** — every request is priced through the
//!   analytical planner *before* execution ([`admission`]); a request
//!   whose modeled wait + execution blows its deadline is rejected or
//!   degraded without simulating a single convolution.
//!
//! The daemon is usable two ways: in-process ([`Daemon::submit`],
//! what the integration tests and benches drive) and over NDJSON/TCP
//! ([`tcp::serve`], what `cgra daemon` runs). Both paths share one
//! code body; the transport only parses and prints.
//!
//! ```text
//!   TCP line ─▶ protocol::parse ─▶ Daemon::submit ─▶ admission (planner)
//!                                        │                │ admit/degrade
//!                                        ▼                ▼
//!                                  ArtifactRegistry ─▶ queue ─▶ workers
//!                                  (Arc<CompiledNet>)   (batched µop walks)
//! ```

pub mod admission;
pub mod protocol;
mod queue;
pub mod registry;
pub mod stats;
pub mod tcp;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::cgra::CgraConfig;
use crate::energy::EnergyModel;
use crate::engine::{CompiledNet, Engine};
use crate::nn::{build_preset, Net};
use crate::obs::trace;
use crate::planner::PlanObjective;

pub use admission::{admit, Admitted, AdmissionPolicy, Decision, Rejection};
pub use registry::{ArtifactKey, ArtifactRegistry, RegistryStats};
pub use stats::{DaemonStats, TenantCounters, TenantStats};

use queue::{Job, Shared};

/// Input magnitude for daemon-generated request inputs (the CLI
/// serve/net default).
pub const DAEMON_INPUT_MAG: i32 = 8;

/// Upper bound on inferences per request — keeps a single request from
/// monopolizing the queue (admission already bounds modeled time, this
/// bounds memory for the pre-generated inputs).
pub const MAX_REQUEST_COUNT: usize = 1024;

/// How a request names the network to run.
#[derive(Clone, Debug)]
pub enum NetSpec {
    /// A named preset from [`crate::nn::presets`].
    Preset {
        /// Preset name (e.g. `paper-baseline`).
        name: String,
        /// Weight-generation seed.
        seed: u64,
    },
    /// A plain conv stack ([`Net::plain_stack`]).
    Stack {
        /// Conv layers.
        depth: usize,
        /// Input channels.
        c0: usize,
        /// Output channels per layer.
        k: usize,
        /// Square input size.
        hw: usize,
        /// Weight-generation seed.
        seed: u64,
    },
    /// An already-built graph (in-process callers only; not on the
    /// wire).
    Inline(Net),
}

impl NetSpec {
    /// Materialize the graph.
    pub fn build(&self) -> Result<Net> {
        match self {
            NetSpec::Preset { name, seed } => build_preset(name, *seed),
            NetSpec::Stack { depth, c0, k, hw, seed } => {
                Net::plain_stack(*depth, *c0, *k, *hw, *seed)
            }
            NetSpec::Inline(net) => Ok(net.clone()),
        }
    }
}

/// One inference request, transport-independent.
#[derive(Clone, Debug)]
pub struct InferRequest {
    /// Tenant name (auto-registered with the calibrated energy model on
    /// first use).
    pub tenant: String,
    /// The network to run.
    pub net: NetSpec,
    /// Inferences to run (1..=[`MAX_REQUEST_COUNT`]).
    pub count: usize,
    /// Seed of the first input; lane `i` uses `input_seed + i`.
    pub input_seed: u64,
    /// Deadline over modeled queue wait + execution, µs. `None` always
    /// admits.
    pub deadline_us: Option<f64>,
    /// Mapping objective for planning.
    pub objective: PlanObjective,
    /// Return the output tensors in the response.
    pub collect_outputs: bool,
    /// Per-request admission policy override (`None` = daemon default).
    pub admission: Option<AdmissionPolicy>,
}

impl InferRequest {
    /// A minimal request: one inference of `net` for `tenant`, no
    /// deadline, latency objective, outputs not returned.
    pub fn new(tenant: &str, net: NetSpec) -> InferRequest {
        InferRequest {
            tenant: tenant.to_string(),
            net,
            count: 1,
            input_seed: 0,
            deadline_us: None,
            objective: PlanObjective::Latency,
            collect_outputs: false,
            admission: None,
        }
    }
}

/// A served request: admission terms, execution figures, and outputs
/// if requested.
#[derive(Clone, Debug)]
pub struct Served {
    /// Tenant that ran it.
    pub tenant: String,
    /// Name of the compiled network.
    pub net: String,
    /// Whether the artifact came from the registry (true) or was
    /// compiled for this request (false).
    pub cache_hit: bool,
    /// Inferences executed (post-degradation).
    pub count: usize,
    /// The objective the admitted plan minimized (post-degradation).
    pub objective: PlanObjective,
    /// Degradation-ladder rungs applied, in order (empty = as asked).
    pub degrade_steps: Vec<&'static str>,
    /// Admission-planner cycles per inference.
    pub priced_cycles_per_inf: u64,
    /// Admission-planner energy per inference, µJ.
    pub priced_uj_per_inf: f64,
    /// Modeled execution time of the request, µs.
    pub modeled_us: f64,
    /// Modeled queue wait at admission, µs.
    pub wait_us: f64,
    /// Replay-modeled cycles per inference.
    pub run_cycles_per_inf: u64,
    /// Replay-modeled energy per inference, µJ.
    pub run_uj_per_inf: f64,
    /// Lanes of the µop walk group this request rode (> `count` when
    /// co-batched with other requests).
    pub walk_lanes: usize,
    /// Output tensors, one per inference (empty unless
    /// [`InferRequest::collect_outputs`]).
    pub outputs: Vec<crate::conv::TensorChw>,
}

/// What `submit` produced: a served request or a structured rejection.
/// Rejections are normal admission outcomes, not errors — `Err` from
/// [`Daemon::submit`] means the request itself was malformed or the
/// daemon is shutting down.
#[derive(Clone, Debug)]
pub enum Outcome {
    /// Executed; figures in the payload.
    Served(Served),
    /// Refused by admission control; terms in the payload.
    Rejected(Rejection),
}

/// One tenant: a name bound to an [`Engine`] (and therefore to a
/// pricing session — config ⊕ energy model) plus its counters.
pub struct Tenant {
    name: String,
    engine: Engine,
    session_fp: u64,
    counters: Mutex<TenantCounters>,
}

impl Tenant {
    /// Tenant name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The tenant's session fingerprint (config ⊕ energy model) — the
    /// registry-isolation half of its [`ArtifactKey`]s.
    pub fn session_fp(&self) -> u64 {
        self.session_fp
    }

    /// The tenant's engine (its planner prices this tenant's
    /// admissions).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Counter snapshot.
    pub fn counters_snapshot(&self) -> TenantCounters {
        *self.counters.lock().unwrap()
    }

    pub(crate) fn counters(&self) -> &Mutex<TenantCounters> {
        &self.counters
    }
}

/// Builder for [`Daemon`] — every knob has a serving-sized default.
pub struct DaemonBuilder {
    cfg: CgraConfig,
    workers: usize,
    batch: usize,
    capacity: usize,
    shards: usize,
    policy: AdmissionPolicy,
    artifact_dir: Option<PathBuf>,
}

impl Default for DaemonBuilder {
    fn default() -> DaemonBuilder {
        DaemonBuilder::new()
    }
}

impl DaemonBuilder {
    /// Defaults: calibrated config, 2 workers, batch 4, a 32-artifact
    /// registry over 4 shards, degrade-first admission.
    pub fn new() -> DaemonBuilder {
        DaemonBuilder {
            cfg: CgraConfig::default(),
            workers: 2,
            batch: 4,
            capacity: 32,
            shards: 4,
            policy: AdmissionPolicy::Degrade,
            artifact_dir: None,
        }
    }

    /// CGRA configuration shared by every tenant engine.
    pub fn config(mut self, cfg: CgraConfig) -> DaemonBuilder {
        self.cfg = cfg;
        self
    }

    /// Worker threads (clamped to ≥ 1).
    pub fn workers(mut self, workers: usize) -> DaemonBuilder {
        self.workers = workers.max(1);
        self
    }

    /// Max inference lanes per shared µop walk (clamped to ≥ 1; 1
    /// disables batching).
    pub fn batch(mut self, batch: usize) -> DaemonBuilder {
        self.batch = batch.max(1);
        self
    }

    /// Artifact-registry capacity (clamped to ≥ 1).
    pub fn capacity(mut self, capacity: usize) -> DaemonBuilder {
        self.capacity = capacity.max(1);
        self
    }

    /// Registry lock shards (clamped to ≥ 1; use 1 for deterministic
    /// global LRU order).
    pub fn shards(mut self, shards: usize) -> DaemonBuilder {
        self.shards = shards.max(1);
        self
    }

    /// Default admission policy (requests may override per-request).
    pub fn admission(mut self, policy: AdmissionPolicy) -> DaemonBuilder {
        self.policy = policy;
        self
    }

    /// Enable the registry's disk tier: serialized artifacts
    /// (DESIGN.md §13) are loaded from — and freshly compiled ones
    /// persisted to — this directory, keyed by net ⊕ session
    /// fingerprint. A restarted daemon warms its registry from here
    /// instead of recompiling.
    pub fn artifact_dir(mut self, dir: impl Into<PathBuf>) -> DaemonBuilder {
        self.artifact_dir = Some(dir.into());
        self
    }

    /// Spawn the worker pool and return the daemon.
    pub fn build(self) -> Daemon {
        if let Some(dir) = &self.artifact_dir {
            // Best-effort: a missing or unwritable directory degrades
            // the disk tier to a no-op (every load misses, every
            // persist reports false), it never breaks serving.
            let _ = std::fs::create_dir_all(dir);
        }
        let shared = Arc::new(Shared::new());
        let handles = (0..self.workers)
            .map(|_| {
                let shared = shared.clone();
                let batch = self.batch;
                std::thread::spawn(move || queue::worker_loop(shared, batch))
            })
            .collect();
        Daemon {
            cfg: self.cfg,
            policy: self.policy,
            batch: self.batch,
            workers: self.workers,
            registry: ArtifactRegistry::new(self.capacity, self.shards),
            tenants: Mutex::new(HashMap::new()),
            shared,
            handles: Mutex::new(handles),
            started: Instant::now(),
            artifact_dir: self.artifact_dir,
        }
    }
}

/// A persistent serving instance: tenants, artifact registry, admission
/// control, worker pool. See the [module docs](self) for the shape.
pub struct Daemon {
    cfg: CgraConfig,
    policy: AdmissionPolicy,
    batch: usize,
    workers: usize,
    registry: ArtifactRegistry,
    tenants: Mutex<HashMap<String, Arc<Tenant>>>,
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    started: Instant,
    artifact_dir: Option<PathBuf>,
}

impl Daemon {
    /// A builder with serving-sized defaults.
    pub fn builder() -> DaemonBuilder {
        DaemonBuilder::new()
    }

    /// The artifact registry (counter inspection; entries are managed
    /// internally).
    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// The disk-tier directory, if the registry has one.
    pub fn artifact_dir(&self) -> Option<&std::path::Path> {
        self.artifact_dir.as_deref()
    }

    /// Max inference lanes per shared µop walk.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Jobs queued and not yet picked up by a worker.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Declare `name`'s energy model. Idempotent for an identical
    /// pricing session; changing a live tenant's model is refused (it
    /// would silently re-key the tenant's registry entries).
    pub fn register_tenant(&self, name: &str, model: EnergyModel) -> Result<Arc<Tenant>> {
        ensure!(!name.is_empty(), "tenant name must not be empty");
        let engine = Engine::builder()
            .config(self.cfg.clone())
            .energy_model(model)
            .workers(1)
            .build()
            .with_context(|| format!("building engine for tenant '{name}'"))?;
        let session_fp = engine.session_fingerprint();
        let mut tenants = self.tenants.lock().unwrap();
        if let Some(existing) = tenants.get(name) {
            if existing.session_fp == session_fp {
                return Ok(existing.clone());
            }
            bail!(
                "tenant '{name}' is already registered with a different energy model \
                 (session {:#018x} vs {:#018x})",
                existing.session_fp,
                session_fp
            );
        }
        let tenant = Arc::new(Tenant {
            name: name.to_string(),
            engine,
            session_fp,
            counters: Mutex::new(TenantCounters::default()),
        });
        tenants.insert(name.to_string(), tenant.clone());
        Ok(tenant)
    }

    /// Fetch `name`, auto-registering it with the calibrated
    /// [`EnergyModel`] on first use.
    pub fn tenant(&self, name: &str) -> Result<Arc<Tenant>> {
        if let Some(t) = self.tenants.lock().unwrap().get(name) {
            return Ok(t.clone());
        }
        self.register_tenant(name, EnergyModel::default())
    }

    /// Serve one request end to end: admission (planner pricing against
    /// the deadline), registry fetch-or-compile, queued execution on
    /// the worker pool. Blocks until the request is served or rejected.
    ///
    /// `Ok(Outcome::Rejected(..))` is a *normal* outcome; `Err` means a
    /// malformed request, a failed compile, or a daemon shutting down.
    pub fn submit(&self, req: InferRequest) -> Result<Outcome> {
        let t_submit = Instant::now();
        let mut rsp = trace::span_dyn("daemon", || format!("submit:{}", req.tenant));
        ensure!(
            !self.shared.stop.load(Ordering::Acquire),
            "daemon is shutting down; request refused"
        );
        ensure!(
            (1..=MAX_REQUEST_COUNT).contains(&req.count),
            "count must be in 1..={MAX_REQUEST_COUNT}, got {}",
            req.count
        );
        let tenant = self.tenant(&req.tenant)?;
        let net = req.net.build()?;
        let policy = req.admission.unwrap_or(self.policy);
        let decision = admit(
            tenant.engine.planner(),
            &net,
            req.objective,
            req.count,
            req.deadline_us,
            self.shared.backlog_cycles.load(Ordering::Relaxed),
            self.workers,
            policy,
        )?;
        let admitted = match decision {
            Decision::Admitted(a) => a,
            Decision::Rejected(r) => {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                tenant.counters.lock().unwrap().rejected += 1;
                rsp.arg("outcome", "rejected");
                return Ok(Outcome::Rejected(r));
            }
        };
        if !admitted.degrade_steps.is_empty() {
            self.shared.degraded.fetch_add(1, Ordering::Relaxed);
            tenant.counters.lock().unwrap().degraded += 1;
        }

        let key = ArtifactKey { net_fp: net.fingerprint(), session_fp: tenant.session_fp };
        let mut gsp = trace::span("registry", "get_or_compile");
        let (artifact, cache_hit) = match &self.artifact_dir {
            None => self.registry.get_or_compile(key, || tenant.engine.compile_owned(net))?,
            Some(dir) => {
                // Disk tier: fingerprint-named file per artifact. The
                // load is fully validated (checksum, format, session
                // fingerprint — see `engine::artifact`); any mismatch
                // falls back to a fresh compile, which then overwrites
                // the stale file via `persist`.
                let path =
                    dir.join(format!("{:016x}-{:016x}.cgrart", key.net_fp, key.session_fp));
                let engine = tenant.engine();
                self.registry.get_or_compile_tiered(
                    key,
                    || {
                        if !path.exists() {
                            return None;
                        }
                        let mut lsp = trace::span("registry", "disk_load");
                        match CompiledNet::load(engine, &path) {
                            Ok((cn, _)) => Some(cn),
                            Err(e) => {
                                lsp.arg("invalid", format!("{e:#}"));
                                None
                            }
                        }
                    },
                    || engine.compile_owned(net),
                    |cn| cn.save(&path).is_ok(),
                )?
            }
        };
        gsp.arg("hit", cache_hit);
        drop(gsp);

        let inputs: Vec<_> = (0..admitted.count)
            .map(|i| {
                artifact
                    .net()
                    .random_input(DAEMON_INPUT_MAG, req.input_seed.wrapping_add(i as u64))
            })
            .collect();

        // Charge the backlog for exactly what admission priced; the
        // worker retires the same amount before replying.
        self.shared
            .backlog_cycles
            .fetch_add(admitted.cycles_per_inf * admitted.count as u64, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Job {
                tenant: tenant.clone(),
                artifact: artifact.clone(),
                key,
                inputs,
                priced_cycles_per_inf: admitted.cycles_per_inf,
                priced_uj_per_inf: admitted.uj_per_inf,
                collect_outputs: req.collect_outputs,
                enqueued: Instant::now(),
                reply: tx,
            });
        }
        self.shared.cv.notify_one();
        let done = rx
            .recv()
            .context("worker pool dropped the request (daemon stopped?)")?
            .map_err(|msg| anyhow::anyhow!("execution failed: {msg}"))?;
        self.shared.e2e_us.record(t_submit.elapsed().as_micros() as u64);
        rsp.arg("outcome", "served");
        rsp.arg("lanes", admitted.count);
        Ok(Outcome::Served(Served {
            tenant: tenant.name.clone(),
            net: artifact.name().to_string(),
            cache_hit,
            count: admitted.count,
            objective: admitted.objective,
            degrade_steps: admitted.degrade_steps,
            priced_cycles_per_inf: admitted.cycles_per_inf,
            priced_uj_per_inf: admitted.uj_per_inf,
            modeled_us: admitted.modeled_us,
            wait_us: admitted.wait_us,
            run_cycles_per_inf: done.run_cycles_per_inf,
            run_uj_per_inf: done.run_uj_per_inf,
            walk_lanes: done.walk_lanes,
            outputs: done.outputs,
        }))
    }

    /// Point-in-time stats snapshot.
    pub fn stats(&self) -> DaemonStats {
        let mut tenants: Vec<TenantStats> = self
            .tenants
            .lock()
            .unwrap()
            .values()
            .map(|t| TenantStats {
                name: t.name.clone(),
                session_fp: t.session_fp,
                counters: *t.counters.lock().unwrap(),
            })
            .collect();
        tenants.sort_by(|a, b| a.name.cmp(&b.name));
        DaemonStats {
            version: env!("CARGO_PKG_VERSION").to_string(),
            uptime_s: self.started.elapsed().as_secs_f64(),
            workers: self.workers,
            batch: self.batch,
            queue_depth: self.queue_depth(),
            backlog_cycles: self.shared.backlog_cycles.load(Ordering::Relaxed),
            served_requests: self.shared.served_requests.load(Ordering::Relaxed),
            served_inferences: self.shared.served_inferences.load(Ordering::Relaxed),
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            degraded: self.shared.degraded.load(Ordering::Relaxed),
            walks: self.shared.walks.load(Ordering::Relaxed),
            walk_lanes: self.shared.walk_lanes.load(Ordering::Relaxed),
            registry: self.registry.stats(),
            queue_wait_us: self.shared.queue_wait_us.summary(),
            exec_us: self.shared.exec_us.summary(),
            e2e_us: self.shared.e2e_us.summary(),
            tenants,
        }
    }

    /// Stop accepting work, drain the queue, join the workers.
    /// Idempotent; called by `Drop` as a backstop.
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::Release);
        self.shared.cv.notify_all();
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> NetSpec {
        NetSpec::Stack { depth: 1, c0: 2, k: 2, hw: 6, seed: 3 }
    }

    #[test]
    fn builder_clamps_and_defaults() {
        let d = Daemon::builder().workers(0).batch(0).capacity(0).shards(0).build();
        assert_eq!(d.workers(), 1);
        assert_eq!(d.batch(), 1);
        assert!(d.registry().stats().capacity >= 1);
        d.shutdown();
    }

    #[test]
    fn count_bounds_are_enforced() {
        let d = Daemon::builder().workers(1).build();
        let mut req = InferRequest::new("t", tiny_spec());
        req.count = 0;
        assert!(d.submit(req.clone()).is_err());
        req.count = MAX_REQUEST_COUNT + 1;
        assert!(d.submit(req).is_err());
        d.shutdown();
    }

    #[test]
    fn register_is_idempotent_but_model_changes_are_refused() {
        let d = Daemon::builder().workers(1).build();
        let a = d.register_tenant("t", EnergyModel::default()).unwrap();
        let b = d.register_tenant("t", EnergyModel::default()).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let mut hot = EnergyModel::default();
        hot.e_mem_access_pj *= 2.0;
        assert!(d.register_tenant("t", hot).is_err());
        assert!(d.register_tenant("", EnergyModel::default()).is_err());
        d.shutdown();
    }

    #[test]
    fn submit_after_shutdown_is_refused() {
        let d = Daemon::builder().workers(1).build();
        d.shutdown();
        let err = d.submit(InferRequest::new("t", tiny_spec())).unwrap_err();
        assert!(err.to_string().contains("shutting down"), "{err:#}");
    }
}
