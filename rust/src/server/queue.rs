//! The daemon's request queue and worker loop.
//!
//! A single `Mutex<VecDeque<Job>>` + condvar feeds `--workers` plain
//! `std::thread` workers (the same no-dependency threading style as
//! [`crate::coordinator::pool`], but long-lived). Each worker:
//!
//! - pops the front job, then **opportunistically gathers** queued jobs
//!   for the *same artifact key* until the walk holds up to `batch`
//!   lanes — so bursts of same-net traffic ride one shared µop walk
//!   (DESIGN.md §9) without any client-side coordination;
//! - reuses per-artifact contexts from a small per-worker cache (a
//!   [`NetCtx`] for scalar walks, a [`BatchCtx`] for batched ones) —
//!   warm replays allocate nothing, preserving the compile-once
//!   counter contract end to end;
//! - updates tenant/global counters and retires the admission backlog
//!   **before** replying, so the moment a `submit` returns, the
//!   daemon's stats are quiescent for that request.
//!
//! Context reuse across recompiles is sound: an evicted-and-recompiled
//! key denotes a bit-identical artifact (the key covers weights,
//! config and energy model), so arena sizes match and a cached context
//! replays the new `Arc` exactly as it did the old one.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::conv::TensorChw;
use crate::engine::{BatchCtx, CompiledNet, NetCtx};
use crate::obs::metrics::{Histogram, Registry};
use crate::obs::profile::BnClass;
use crate::obs::trace;

use super::registry::ArtifactKey;
use super::Tenant;

/// Per-worker cached contexts one artifact key (bounded per worker by
/// [`WORKER_CTX_CAP`]).
#[derive(Default)]
struct WorkerCtx {
    scalar: Option<NetCtx>,
    batched: Option<BatchCtx>,
}

/// Distinct artifacts a worker keeps warm contexts for before it
/// resets the cache (arena reuse vs unbounded growth under many
/// tenants/nets).
const WORKER_CTX_CAP: usize = 8;

/// One admitted request, ready to execute.
pub(super) struct Job {
    pub tenant: Arc<Tenant>,
    pub artifact: Arc<CompiledNet>,
    pub key: ArtifactKey,
    /// Pre-generated inputs, one per inference lane.
    pub inputs: Vec<TensorChw>,
    /// Admission-planner cycles per inference (backlog retirement +
    /// priced stats).
    pub priced_cycles_per_inf: u64,
    /// Admission-planner energy per inference, µJ.
    pub priced_uj_per_inf: f64,
    /// Clone the output tensors into the reply.
    pub collect_outputs: bool,
    /// When the job entered the queue (feeds the queue-wait histogram).
    pub enqueued: Instant,
    pub reply: Sender<std::result::Result<JobDone, String>>,
}

/// What the worker hands back per job.
pub(super) struct JobDone {
    /// Output tensors in input order (empty unless requested).
    pub outputs: Vec<TensorChw>,
    /// Replay-modeled cycles per inference.
    pub run_cycles_per_inf: u64,
    /// Replay-modeled energy per inference, µJ.
    pub run_uj_per_inf: f64,
    /// Total lanes of the walk group this job rode (its own plus
    /// co-batched jobs') — the observable batching factor.
    pub walk_lanes: usize,
}

/// State shared between the daemon front end and its workers.
pub(super) struct Shared {
    pub queue: Mutex<VecDeque<Job>>,
    pub cv: Condvar,
    pub stop: AtomicBool,
    /// Modeled cycles admitted but not yet executed.
    pub backlog_cycles: AtomicU64,
    pub served_requests: AtomicU64,
    pub served_inferences: AtomicU64,
    pub rejected: AtomicU64,
    pub degraded: AtomicU64,
    /// µop walks executed (scalar runs count as 1-lane walks).
    pub walks: AtomicU64,
    /// Lanes summed over walks.
    pub walk_lanes: AtomicU64,
    /// The daemon's metrics registry (DESIGN.md §11); the histograms
    /// below are cached handles into it.
    pub metrics: Registry,
    /// Per-job time from enqueue to worker pickup, µs.
    pub queue_wait_us: Arc<Histogram>,
    /// Per-walk-group execution wall time, µs.
    pub exec_us: Arc<Histogram>,
    /// Per-request end-to-end latency (submit to reply), µs.
    pub e2e_us: Arc<Histogram>,
}

impl Shared {
    pub fn new() -> Shared {
        let metrics = Registry::new();
        let queue_wait_us = metrics.histogram("queue_wait_us");
        let exec_us = metrics.histogram("exec_us");
        let e2e_us = metrics.histogram("e2e_us");
        Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            backlog_cycles: AtomicU64::new(0),
            served_requests: AtomicU64::new(0),
            served_inferences: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            walks: AtomicU64::new(0),
            walk_lanes: AtomicU64::new(0),
            metrics,
            queue_wait_us,
            exec_us,
            e2e_us,
        }
    }
}

/// The worker thread body: drain jobs until stopped *and* the queue is
/// empty (shutdown completes in-flight work rather than dropping it).
pub(super) fn worker_loop(shared: Arc<Shared>, batch: usize) {
    let mut ctxs: HashMap<ArtifactKey, WorkerCtx> = HashMap::new();
    loop {
        let group = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(first) = q.pop_front() {
                    break gather(first, &mut q, batch);
                }
                if shared.stop.load(Ordering::Acquire) {
                    return;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        execute(&shared, &mut ctxs, group, batch);
    }
}

/// Pull queued same-key jobs behind `first` until the walk group holds
/// up to `batch` lanes. Other keys are left in place, order preserved.
fn gather(first: Job, q: &mut VecDeque<Job>, batch: usize) -> Vec<Job> {
    let mut gsp = trace::span("queue", "gather");
    let mut lanes = first.inputs.len();
    let mut group = vec![first];
    let mut i = 0;
    while i < q.len() && lanes < batch {
        let fits = q[i].key == group[0].key && lanes + q[i].inputs.len() <= batch;
        if fits {
            let job = q.remove(i).expect("index checked");
            lanes += job.inputs.len();
            group.push(job);
        } else {
            i += 1;
        }
    }
    gsp.arg("jobs", group.len());
    gsp.arg("lanes", lanes);
    group
}

/// Run one walk group: all jobs share one artifact; lanes are chunked
/// by the batch limit through one reused context.
fn execute(
    shared: &Shared,
    ctxs: &mut HashMap<ArtifactKey, WorkerCtx>,
    mut group: Vec<Job>,
    batch: usize,
) {
    let artifact = group[0].artifact.clone();
    let key = group[0].key;
    let collect = group.iter().any(|j| j.collect_outputs);
    let mut inputs: Vec<TensorChw> = Vec::new();
    let mut lane_counts = Vec::with_capacity(group.len());
    for job in &mut group {
        shared.queue_wait_us.record(job.enqueued.elapsed().as_micros() as u64);
        lane_counts.push(job.inputs.len());
        inputs.append(&mut job.inputs);
    }
    let total = inputs.len();
    let mut xsp = trace::span("queue", "exec");
    xsp.arg("jobs", group.len());
    xsp.arg("lanes", total);
    let exec_start = Instant::now();

    if ctxs.len() >= WORKER_CTX_CAP && !ctxs.contains_key(&key) {
        ctxs.clear();
    }
    let ctx = ctxs.entry(key).or_default();

    let mut outputs: Vec<TensorChw> = Vec::new();
    let mut run_cycles = 0u64;
    let mut run_uj = 0.0f64;
    let mut run_bn = [0u64; BnClass::COUNT];
    let mut failure: Option<String> = None;
    if batch > 1 && total > 1 {
        let bctx = ctx.batched.get_or_insert_with(|| artifact.new_batch_ctx(batch));
        for chunk in inputs.chunks(batch) {
            match artifact.run_batch(bctx, chunk) {
                Ok(run) => {
                    // Per-inference figures are chunk-invariant by
                    // construction (DESIGN.md §9).
                    run_cycles = run.total_cycles;
                    run_uj = run.total_energy_uj;
                    if let Some(p) = &run.profile {
                        run_bn = p.class_cycles;
                    }
                    shared.walks.fetch_add(1, Ordering::Relaxed);
                    shared.walk_lanes.fetch_add(chunk.len() as u64, Ordering::Relaxed);
                    if collect {
                        outputs.extend(bctx.outputs().iter().cloned());
                    }
                }
                Err(e) => {
                    failure = Some(format!("{e:#}"));
                    break;
                }
            }
        }
    } else {
        let sctx = ctx.scalar.get_or_insert_with(|| artifact.new_ctx());
        for input in &inputs {
            match artifact.run(sctx, input) {
                Ok(run) => {
                    run_cycles = run.total_cycles;
                    run_uj = run.total_energy_uj;
                    if let Some(p) = &run.profile {
                        run_bn = p.class_cycles;
                    }
                    shared.walks.fetch_add(1, Ordering::Relaxed);
                    shared.walk_lanes.fetch_add(1, Ordering::Relaxed);
                    if collect {
                        outputs.push(sctx.output().clone());
                    }
                }
                Err(e) => {
                    failure = Some(format!("{e:#}"));
                    break;
                }
            }
        }
    }
    shared.exec_us.record(exec_start.elapsed().as_micros() as u64);
    drop(xsp);

    // Distribute results, settle counters *before* each reply.
    let mut offset = 0usize;
    for (job, lanes) in group.into_iter().zip(lane_counts) {
        let result = match &failure {
            Some(msg) => Err(msg.clone()),
            None => Ok(JobDone {
                outputs: if job.collect_outputs {
                    outputs[offset..offset + lanes].to_vec()
                } else {
                    Vec::new()
                },
                run_cycles_per_inf: run_cycles,
                run_uj_per_inf: run_uj,
                walk_lanes: total,
            }),
        };
        offset += lanes;
        // Retire exactly what admission charged for these lanes.
        let priced_total = job.priced_cycles_per_inf * lanes as u64;
        shared.backlog_cycles.fetch_sub(priced_total, Ordering::Relaxed);
        if failure.is_none() {
            shared.served_requests.fetch_add(1, Ordering::Relaxed);
            shared.served_inferences.fetch_add(lanes as u64, Ordering::Relaxed);
            let mut stats = job.tenant.counters().lock().unwrap();
            stats.requests += 1;
            stats.inferences += lanes as u64;
            stats.priced_cycles += priced_total;
            stats.priced_uj += job.priced_uj_per_inf * lanes as f64;
            stats.run_cycles += run_cycles * lanes as u64;
            stats.run_uj += run_uj * lanes as f64;
            // Walk-cycle bottleneck attribution is per-inference like
            // run_cycles; all-zero when the daemon isn't profiling.
            for (acc, v) in stats.bottleneck_cycles.iter_mut().zip(run_bn) {
                *acc += v * lanes as u64;
            }
        }
        // A dropped receiver (client gone) is fine; the work is done
        // and accounted either way.
        let _ = job.reply.send(result);
    }
}
