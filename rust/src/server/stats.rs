//! The daemon's stats surface: per-tenant and global counters, plus
//! their JSON rendering through the crate's hand-rolled
//! [`crate::util::json`] (no `serde`, per the repo's ADR stance).
//!
//! Two families of figures coexist deliberately:
//! - **priced** — what the admission planner modeled when it admitted
//!   the request (cycles/µJ per inference × inferences);
//! - **run** — what the compiled artifact's replay actually modeled.
//!
//! The two agree within the planner's validated ≤ 5 % band; reporting
//! both makes the admission error observable in production instead of
//! assumed. Counters accumulate under a per-tenant mutex, updated by
//! the worker *before* the reply is sent, so once a `submit` returns,
//! a `stats` read is quiescent with respect to that request.

use std::collections::BTreeMap;

use crate::obs::metrics::HistogramSummary;
use crate::obs::profile::BnClass;
use crate::util::json::Json;

use super::registry::RegistryStats;

/// Monotonic per-tenant counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TenantCounters {
    /// Requests served to completion.
    pub requests: u64,
    /// Inferences executed (post-degradation counts).
    pub inferences: u64,
    /// Requests rejected by admission control.
    pub rejected: u64,
    /// Requests served after walking the degradation ladder.
    pub degraded: u64,
    /// Admission-planner cycles, summed over served inferences.
    pub priced_cycles: u64,
    /// Admission-planner energy, µJ, summed over served inferences.
    pub priced_uj: f64,
    /// Replay-modeled cycles, summed over served inferences.
    pub run_cycles: u64,
    /// Replay-modeled energy, µJ, summed over served inferences.
    pub run_uj: f64,
    /// Bottleneck-class walk-cycle attribution (DESIGN.md §12), summed
    /// over served inferences, indexed by [`BnClass::idx`]. All-zero
    /// unless the daemon runs with `--profile` — the profiler is
    /// free-when-off, so the daemon only pays for attribution when
    /// asked to.
    pub bottleneck_cycles: [u64; BnClass::COUNT],
}

/// One tenant's row of a [`DaemonStats`] snapshot.
#[derive(Clone, Debug)]
pub struct TenantStats {
    /// Tenant name.
    pub name: String,
    /// The tenant's session fingerprint (config ⊕ energy model).
    pub session_fp: u64,
    /// Counter values.
    pub counters: TenantCounters,
}

/// A full point-in-time snapshot of a daemon.
#[derive(Clone, Debug)]
pub struct DaemonStats {
    /// Crate version serving this snapshot (`CARGO_PKG_VERSION`).
    pub version: String,
    /// Seconds since the daemon started.
    pub uptime_s: f64,
    /// Worker threads.
    pub workers: usize,
    /// Max inference lanes per shared µop walk.
    pub batch: usize,
    /// Jobs queued and not yet picked up.
    pub queue_depth: usize,
    /// Modeled cycles admitted but not yet executed (the admission
    /// backlog term). Cycles, not time: tenants may model different
    /// clocks, so the time conversion happens per request.
    pub backlog_cycles: u64,
    /// Requests served to completion, all tenants.
    pub served_requests: u64,
    /// Inferences executed, all tenants.
    pub served_inferences: u64,
    /// Requests rejected by admission control.
    pub rejected: u64,
    /// Requests served degraded.
    pub degraded: u64,
    /// µop program walks executed (a batched walk carries many lanes).
    pub walks: u64,
    /// Inference lanes summed over walks (`walk_lanes / walks` = the
    /// achieved batching factor).
    pub walk_lanes: u64,
    /// Artifact-registry counters.
    pub registry: RegistryStats,
    /// Observed enqueue-to-pickup wait per job, µs.
    pub queue_wait_us: HistogramSummary,
    /// Observed walk-group execution wall time, µs.
    pub exec_us: HistogramSummary,
    /// Observed end-to-end latency per served request (submit entry to
    /// reply), µs.
    pub e2e_us: HistogramSummary,
    /// Per-tenant rows, name-sorted.
    pub tenants: Vec<TenantStats>,
}

impl DaemonStats {
    /// Throughput over the daemon's lifetime, inferences per second of
    /// wall clock.
    pub fn throughput_inf_per_s(&self) -> f64 {
        self.served_inferences as f64 / self.uptime_s.max(1e-9)
    }

    /// Render the snapshot as the `stats` response body (`ok: true`
    /// included, so the wire shape is uniform with other responses).
    pub fn to_json(&self) -> Json {
        let reg = Json::obj(vec![
            ("hits", self.registry.hits.into()),
            ("misses", self.registry.misses.into()),
            ("evictions", self.registry.evictions.into()),
            ("compiles", self.registry.compiles.into()),
            ("disk_hits", self.registry.disk_hits.into()),
            ("disk_writes", self.registry.disk_writes.into()),
            ("entries", self.registry.entries.into()),
            ("capacity", self.registry.capacity.into()),
        ]);
        let mut tenants = BTreeMap::new();
        for t in &self.tenants {
            let c = t.counters;
            let bottleneck = Json::obj(
                BnClass::ALL
                    .iter()
                    .map(|b| (b.key(), c.bottleneck_cycles[b.idx()].into()))
                    .collect(),
            );
            tenants.insert(
                t.name.clone(),
                Json::obj(vec![
                    ("session_fp", format!("{:#018x}", t.session_fp).into()),
                    ("requests", c.requests.into()),
                    ("inferences", c.inferences.into()),
                    ("rejected", c.rejected.into()),
                    ("degraded", c.degraded.into()),
                    ("priced_cycles", c.priced_cycles.into()),
                    ("priced_uj", c.priced_uj.into()),
                    ("run_cycles", c.run_cycles.into()),
                    ("run_uj", c.run_uj.into()),
                    ("bottleneck", bottleneck),
                ]),
            );
        }
        Json::obj(vec![
            ("ok", true.into()),
            ("op", "stats".into()),
            ("version", self.version.as_str().into()),
            ("uptime_s", self.uptime_s.into()),
            ("workers", self.workers.into()),
            ("batch", self.batch.into()),
            ("queue_depth", self.queue_depth.into()),
            ("backlog_cycles", self.backlog_cycles.into()),
            ("served_requests", self.served_requests.into()),
            ("served_inferences", self.served_inferences.into()),
            ("rejected", self.rejected.into()),
            ("degraded", self.degraded.into()),
            ("throughput_inf_per_s", self.throughput_inf_per_s().into()),
            ("walks", self.walks.into()),
            ("walk_lanes", self.walk_lanes.into()),
            ("registry", reg),
            ("queue_wait_us", self.queue_wait_us.to_json()),
            ("exec_us", self.exec_us.to_json()),
            ("e2e_us", self.e2e_us.to_json()),
            ("tenants", Json::Obj(tenants)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_render_includes_every_surface() {
        let lat = {
            let h = crate::obs::metrics::Histogram::new();
            h.record(40);
            h.record(90);
            h.summary()
        };
        let s = DaemonStats {
            version: env!("CARGO_PKG_VERSION").to_string(),
            uptime_s: 2.0,
            workers: 2,
            batch: 4,
            queue_depth: 1,
            backlog_cycles: 500,
            served_requests: 3,
            served_inferences: 6,
            rejected: 1,
            degraded: 1,
            walks: 2,
            walk_lanes: 6,
            registry: RegistryStats {
                hits: 2,
                misses: 1,
                compiles: 1,
                disk_hits: 1,
                disk_writes: 1,
                entries: 1,
                capacity: 8,
                ..Default::default()
            },
            queue_wait_us: HistogramSummary::default(),
            exec_us: lat,
            e2e_us: lat,
            tenants: vec![TenantStats {
                name: "edge\"box".into(), // hostile name: escaping matters
                session_fp: 0xdead_beef,
                counters: TenantCounters {
                    requests: 3,
                    inferences: 6,
                    priced_uj: 1.25,
                    run_uj: 1.3,
                    bottleneck_cycles: [10, 4, 3, 2, 1],
                    ..Default::default()
                },
            }],
        };
        assert_eq!(s.throughput_inf_per_s(), 3.0);
        let j = s.to_json();
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(j.req_str("version").unwrap(), env!("CARGO_PKG_VERSION"));
        assert_eq!(j.req_i64("served_inferences").unwrap(), 6);
        assert_eq!(j.get("registry").unwrap().req_i64("hits").unwrap(), 2);
        assert_eq!(j.get("registry").unwrap().req_i64("disk_hits").unwrap(), 1);
        assert_eq!(j.get("registry").unwrap().req_i64("disk_writes").unwrap(), 1);
        let e2e = j.get("e2e_us").unwrap();
        assert_eq!(e2e.req_i64("count").unwrap(), 2);
        assert_eq!(e2e.req_i64("min").unwrap(), 40);
        assert_eq!(e2e.req_i64("p99").unwrap(), 90);
        assert_eq!(j.get("queue_wait_us").unwrap().req_i64("count").unwrap(), 0);
        let t = j.get("tenants").unwrap().get("edge\"box").unwrap();
        assert_eq!(t.req_str("session_fp").unwrap(), "0x00000000deadbeef");
        assert_eq!(t.get("priced_uj").unwrap().as_f64().unwrap(), 1.25);
        let bn = t.get("bottleneck").unwrap();
        assert_eq!(bn.req_i64("alu").unwrap(), 10);
        assert_eq!(bn.req_i64("dma_port").unwrap(), 4);
        assert_eq!(bn.req_i64("bank_conflict").unwrap(), 3);
        assert_eq!(bn.req_i64("control").unwrap(), 2);
        assert_eq!(bn.req_i64("floor").unwrap(), 1);
        // The rendered document survives a parse round-trip despite
        // the quote in the tenant name.
        let text = j.to_string_compact();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back, j);
    }
}
