//! The artifact registry: a bounded, sharded, LRU cache of compiled
//! inference artifacts shared by every tenant of a [`super::Daemon`].
//!
//! Keys combine the *network* fingerprint ([`crate::nn::Net::fingerprint`])
//! with the *session* fingerprint
//! ([`crate::engine::Engine::session_fingerprint`], the PR-2 config ⊕
//! energy-model machinery), so two tenants share one `Arc<CompiledNet>`
//! iff both the graph (weights included) and the pricing session are
//! identical — tenants with different energy models never cross-hit,
//! which `tests/registry.rs` and the end-to-end daemon test pin.
//!
//! Concurrency: each shard is a `Mutex<HashMap>` whose values hold an
//! `Arc<OnceLock<..>>` cell. `get_or_compile` finds-or-inserts the cell
//! *under* the shard lock (constant-time bookkeeping only), then runs
//! the compile through [`OnceLock::get_or_init`] *outside* it — so one
//! thread compiles while concurrent requesters for the same key block
//! on the cell rather than thundering-herd compiling, and requests for
//! other keys proceed untouched. Deterministic compile failures
//! (memory-bound nets) are cached as errors like the point cache's
//! skip entries, so a doomed net is priced exactly once.
//!
//! An optional *disk tier* sits between the memory cache and the
//! compiler ([`ArtifactRegistry::get_or_compile_tiered`]): a memory
//! miss first tries to load a serialized artifact (DESIGN.md §13)
//! before compiling, and freshly compiled artifacts are persisted for
//! the next process. The daemon enables it with `--artifact-dir`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, Result};

use crate::engine::CompiledNet;
use crate::obs::trace;

/// Identity of one registry entry: network ⊕ session fingerprints.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct ArtifactKey {
    /// [`crate::nn::Net::fingerprint`] of the compiled graph.
    pub net_fp: u64,
    /// [`crate::engine::Engine::session_fingerprint`] of the compiling
    /// tenant's engine (config ⊕ energy model).
    pub session_fp: u64,
}

/// The compile-once cell: ready artifact, or the cached deterministic
/// failure.
type Cell = Arc<OnceLock<std::result::Result<Arc<CompiledNet>, String>>>;

struct Entry {
    cell: Cell,
    /// Global LRU tick of the last touch (insert or hit).
    last_used: u64,
}

/// Counter snapshot of a registry (all counters monotonic since
/// construction).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RegistryStats {
    /// Lookups that found an existing cell (in-flight compiles count:
    /// the requester joins the compile instead of duplicating it).
    pub hits: u64,
    /// Lookups that created a new cell.
    pub misses: u64,
    /// Entries evicted to make room.
    pub evictions: u64,
    /// Compiles actually executed (≤ misses: evicted-and-refetched
    /// keys recompile, concurrent same-key requests do not).
    pub compiles: u64,
    /// Memory misses satisfied by loading a disk artifact instead of
    /// compiling ([`ArtifactRegistry::get_or_compile_tiered`]).
    pub disk_hits: u64,
    /// Freshly compiled artifacts persisted to the disk tier.
    pub disk_writes: u64,
    /// Live entries right now.
    pub entries: usize,
    /// Total capacity (shards × per-shard cap).
    pub capacity: usize,
}

/// Bounded, sharded LRU cache of `Arc<CompiledNet>` artifacts.
pub struct ArtifactRegistry {
    shards: Vec<Mutex<HashMap<ArtifactKey, Entry>>>,
    shard_cap: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    compiles: AtomicU64,
    disk_hits: AtomicU64,
    disk_writes: AtomicU64,
}

impl ArtifactRegistry {
    /// A registry holding at most `capacity` artifacts across `shards`
    /// lock shards (both clamped to ≥ 1). Per-shard capacity is
    /// `ceil(capacity / shards)`; eviction is true LRU within a shard.
    /// Tests that need deterministic global LRU order use one shard.
    pub fn new(capacity: usize, shards: usize) -> ArtifactRegistry {
        let shards = shards.max(1);
        let shard_cap = capacity.max(1).div_ceil(shards);
        ArtifactRegistry {
            shards: (0..shards).map(|_| Mutex::new(HashMap::new())).collect(),
            shard_cap,
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            compiles: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            disk_writes: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &ArtifactKey) -> &Mutex<HashMap<ArtifactKey, Entry>> {
        // Fold both fingerprints; the FNV step decorrelates the low
        // bits the modulo consumes.
        let h = (key.net_fp ^ key.session_fp.rotate_left(17)).wrapping_mul(0x1000_0000_01b3);
        &self.shards[(h % self.shards.len() as u64) as usize]
    }

    /// Find-or-insert the single-flight cell for `key` under the shard
    /// lock (constant-time bookkeeping only), evicting the shard's LRU
    /// entry when full. Returns the cell and whether it already existed.
    fn cell_for(&self, key: ArtifactKey) -> (Cell, bool) {
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        let (cell, hit) = {
            let mut shard = self.shard(&key).lock().unwrap();
            if let Some(entry) = shard.get_mut(&key) {
                entry.last_used = tick;
                (entry.cell.clone(), true)
            } else {
                if shard.len() >= self.shard_cap {
                    // True LRU within the shard: evict the least
                    // recently touched entry.
                    let victim =
                        shard.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| *k);
                    if let Some(victim) = victim {
                        shard.remove(&victim);
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                }
                let cell: Cell = Arc::new(OnceLock::new());
                shard.insert(key, Entry { cell: cell.clone(), last_used: tick });
                (cell, false)
            }
        };
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        (cell, hit)
    }

    /// Fetch the artifact for `key`, compiling it via `compile` on a
    /// miss. Returns the shared artifact and whether the lookup was a
    /// registry hit (an in-flight compile by another thread counts as
    /// a hit — the work is shared, not repeated). Deterministic compile
    /// failures are cached and replayed as errors.
    pub fn get_or_compile(
        &self,
        key: ArtifactKey,
        compile: impl FnOnce() -> Result<CompiledNet>,
    ) -> Result<(Arc<CompiledNet>, bool)> {
        self.get_or_compile_tiered(key, || None, compile, |_| false)
    }

    /// [`ArtifactRegistry::get_or_compile`] with a disk tier between
    /// the memory cache and the compiler. On a memory miss the
    /// single-flight winner first tries `load` (a validated
    /// deserialization of a previously persisted artifact — counted as
    /// a disk hit); only if that yields nothing does it `compile`, and
    /// a successful compile is offered to `persist` (return `true` when
    /// a file was actually written — counted as a disk write). Both
    /// closures run inside the single-flight cell, so concurrent
    /// same-key requests never duplicate a load, a compile, or a write.
    pub fn get_or_compile_tiered(
        &self,
        key: ArtifactKey,
        load: impl FnOnce() -> Option<CompiledNet>,
        compile: impl FnOnce() -> Result<CompiledNet>,
        persist: impl FnOnce(&CompiledNet) -> bool,
    ) -> Result<(Arc<CompiledNet>, bool)> {
        let (cell, hit) = self.cell_for(key);
        // Single-flight fill outside the shard lock: the first caller
        // initializes, concurrent same-key callers block here,
        // different keys never contend.
        let outcome = cell.get_or_init(|| {
            if let Some(cn) = load() {
                self.disk_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Arc::new(cn));
            }
            self.compiles.fetch_add(1, Ordering::Relaxed);
            let mut csp = trace::span("registry", "compile");
            csp.arg("net_fp", format!("{:#018x}", key.net_fp));
            match compile() {
                Ok(cn) => {
                    if persist(&cn) {
                        self.disk_writes.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(Arc::new(cn))
                }
                Err(e) => Err(format!("{e:#}")),
            }
        });
        match outcome {
            Ok(artifact) => Ok((artifact.clone(), hit)),
            Err(msg) => Err(anyhow!("{msg}")),
        }
    }

    /// Whether `key` is currently resident (no counter movement, no
    /// LRU touch) — a test/introspection peek.
    pub fn contains(&self, key: &ArtifactKey) -> bool {
        self.shard(key).lock().unwrap().contains_key(key)
    }

    /// Live entry count.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Whether the registry is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counter snapshot.
    pub fn stats(&self) -> RegistryStats {
        RegistryStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            compiles: self.compiles.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            disk_writes: self.disk_writes.load(Ordering::Relaxed),
            entries: self.len(),
            capacity: self.shard_cap * self.shards.len(),
        }
    }
}

// Behavioral tests (isolation, LRU, single-flight) live in
// `tests/registry.rs`: they exercise real compiles through an Engine,
// which is integration-level machinery.
