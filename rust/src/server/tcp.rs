//! NDJSON-over-TCP transport for the daemon.
//!
//! One `std::net::TcpListener`, one detached handler thread per
//! connection, one JSON request object per line in, one JSON response
//! object per line out. The transport is a thin shell: every request is
//! parsed by [`super::protocol`] and dispatched through [`handle_line`],
//! which is a plain function over an in-process [`Daemon`] — the
//! protocol tests drive it without opening a socket, and the CI smoke
//! script drives the same code over bash's `/dev/tcp`.
//!
//! Shutdown: a `{"op":"shutdown"}` request is answered first, then the
//! accept loop is released by a self-connection and [`Daemon::shutdown`]
//! drains the worker pool — in-flight requests finish, new ones are
//! refused.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use anyhow::{Context, Result};

use crate::obs::trace;
use crate::util::json::Json;

use super::protocol::{self, Request};
use super::{Daemon, Outcome};

/// Dispatch one request line against `daemon`. Returns the response
/// document and whether the caller should begin daemon shutdown.
///
/// Never panics on hostile input: parse and execution failures render
/// as `{"ok":false,"error":{...}}` responses.
pub fn handle_line(daemon: &Daemon, line: &str) -> (Json, bool) {
    let req = match protocol::parse_request(line) {
        Ok(r) => r,
        Err(e) => return (protocol::error_json("bad-request", &format!("{e:#}")), false),
    };
    let mut rsp = trace::span("rpc", "handle_line");
    rsp.arg("op", req.op());
    match req {
        Request::Infer(req) => match daemon.submit(req) {
            Ok(Outcome::Served(s)) => (protocol::served_json(&s), false),
            Ok(Outcome::Rejected(r)) => (protocol::rejection_json(&r), false),
            Err(e) => (protocol::error_json("internal", &format!("{e:#}")), false),
        },
        Request::Stats => (daemon.stats().to_json(), false),
        Request::Register { tenant, model } => match daemon.register_tenant(&tenant, model) {
            Ok(t) => (
                Json::obj(vec![
                    ("ok", true.into()),
                    ("op", "register".into()),
                    ("tenant", t.name().into()),
                    ("session_fp", format!("{:#018x}", t.session_fp()).into()),
                ]),
                false,
            ),
            Err(e) => (protocol::error_json("bad-request", &format!("{e:#}")), false),
        },
        Request::Shutdown => {
            (Json::obj(vec![("ok", true.into()), ("op", "shutdown".into())]), true)
        }
    }
}

/// Serve NDJSON requests on `listener` until a shutdown request
/// arrives, then drain the daemon's workers and return. Blocks the
/// calling thread for the daemon's lifetime; per-connection handlers
/// run on detached threads.
pub fn serve(daemon: Arc<Daemon>, listener: TcpListener) -> Result<()> {
    let addr = listener.local_addr().context("listener has no local address")?;
    let stop = Arc::new(AtomicBool::new(false));
    for stream in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            // Transient accept errors (EMFILE, aborted handshakes)
            // shouldn't kill the daemon.
            Err(_) => continue,
        };
        let daemon = daemon.clone();
        let stop = stop.clone();
        thread::spawn(move || {
            let _ = handle_conn(&daemon, stream, &stop, addr);
        });
    }
    daemon.shutdown();
    Ok(())
}

fn handle_conn(
    daemon: &Daemon,
    stream: TcpStream,
    stop: &AtomicBool,
    addr: std::net::SocketAddr,
) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (resp, shutdown) = handle_line(daemon, &line);
        writeln!(writer, "{}", resp.to_string_compact())?;
        writer.flush()?;
        if shutdown {
            stop.store(true, Ordering::Release);
            // Unblock the accept loop so `serve` can observe the flag.
            let _ = TcpStream::connect(addr);
            break;
        }
    }
    Ok(())
}
