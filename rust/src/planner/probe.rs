//! Micro-probe calibration: simulate one or two representative launches
//! per [`LaunchClass`](super::model::LaunchClass) and scale by the
//! closed-form counts.
//!
//! Why this is sound: launch timing in this simulator is
//! *data-independent* — every branch counter, address register and
//! auto-increment is driven by immediates derived from the shape, never
//! by tensor values — so a class representative executed against a
//! zero-filled memory takes exactly the cycles the real launch takes.
//! Members of a class can differ only in the bank alignment of their
//! address immediates (a ±`bank_penalty` ripple on a minority of
//! steps); probing the first and last member of each class and
//! averaging bounds that residual well under the 5 % acceptance bar.
//! Where the representatives *are* the whole class (small C/K, few
//! pixels) the prediction is cycle-exact — the unit tests in
//! `planner::tests` pin that down.

use anyhow::{ensure, Context, Result};

use crate::cgra::{decode, Cgra, Memory, RunStats};
use crate::conv::{ConvShape, TensorChw};
use crate::energy::EnergyModel;
use crate::kernels::{ConvOutcome, LatencyBreakdown};
use crate::metrics::MappingReport;

use super::model::{KernelModel, LaunchClass};
use super::CostEstimate;

/// Measured cost of one launch class.
struct ClassProbe {
    /// Number of probe launches simulated (1–2).
    n: u64,
    /// Summed cycles over the probes.
    cycles_sum: u64,
    /// Summed `min(cycles, hidden_cap)` over the probes — the im2col
    /// overlap term of the drivers.
    hidden_sum: u64,
    /// Per-launch statistics (steps, op mix, memory traffic) — identical
    /// for every member of the class, taken from the first probe.
    stats: RunStats,
}

/// `count × (sum / n)`, rounded to nearest, without u64 overflow.
fn scale(count: u64, sum: u64, n: u64) -> u64 {
    ((count as u128 * sum as u128 + n as u128 / 2) / n as u128) as u64
}

/// Accumulate `count` copies of a per-launch `RunStats` (everything but
/// `cycles`, which the caller sets from the averaged probe cycles).
fn merge_scaled(dst: &mut RunStats, src: &RunStats, count: u64) {
    dst.steps += src.steps * count;
    dst.contention_cycles += src.contention_cycles * count;
    if dst.op_mix.len() < src.op_mix.len() {
        dst.op_mix.resize(src.op_mix.len(), [0; crate::cgra::OpClass::COUNT]);
    }
    for (a, b) in dst.op_mix.iter_mut().zip(src.op_mix.iter()) {
        for (x, y) in a.iter_mut().zip(b.iter()) {
            *x += y * count;
        }
    }
    dst.mem.loads += src.mem.loads * count;
    dst.mem.stores += src.mem.stores * count;
    dst.exited &= src.exited;
}

/// Run one class's representative launches against `mem`.
fn probe_class(cgra: &Cgra, mem: &mut Memory, class: &LaunchClass, cap: u64) -> Result<ClassProbe> {
    ensure!(!class.probes.is_empty(), "launch class '{}' has no probe", class.label);
    let mut cycles_sum = 0u64;
    let mut hidden_sum = 0u64;
    let mut stats: Option<RunStats> = None;
    for prog in &class.probes {
        let s = cgra
            .run_decoded(&decode(prog), mem)
            .with_context(|| format!("planner probe '{}'", class.label))?;
        cycles_sum += s.cycles;
        hidden_sum += s.cycles.min(cap);
        if stats.is_none() {
            stats = Some(s);
        }
    }
    Ok(ClassProbe { n: class.probes.len() as u64, cycles_sum, hidden_sum, stats: stats.unwrap() })
}

/// Calibrate `km`'s classes against the simulator and assemble the full
/// cost estimate (latency breakdown, run statistics, metric row).
pub(crate) fn assemble(
    cgra: &Cgra,
    emodel: &EnergyModel,
    shape: &ConvShape,
    km: KernelModel,
) -> Result<CostEstimate> {
    let cfg = cgra.config();
    let mut stats = RunStats::new();
    stats.exited = true;
    let mut cgra_cycles = 0u64;
    let mut hidden = 0u64;
    let mut probe_launches = 0u64;
    if !km.classes.is_empty() {
        // One zeroed memory serves every probe: values never influence
        // timing, and the probe programs only touch in-layout addresses.
        let mut mem = Memory::new(cfg.mem_words, cfg.n_banks);
        for class in &km.classes {
            let p = probe_class(cgra, &mut mem, class, km.hidden_cap_per_launch)?;
            cgra_cycles += scale(class.count, p.cycles_sum, p.n);
            hidden += scale(class.count, p.hidden_sum, p.n);
            merge_scaled(&mut stats, &p.stats, class.count);
            probe_launches += p.n;
        }
    }
    stats.cycles = cgra_cycles;
    let latency = LatencyBreakdown {
        cgra_cycles,
        // Same charging as every kernel driver (the instruction-load
        // term applies once per convolution, CGRA mappings only).
        launch_cycles: if km.launches > 0 {
            km.launches * cfg.launch_overhead + cfg.instruction_load_overhead
        } else {
            0
        },
        cpu_im2col_cycles: km.cpu_im2col_cycles,
        cpu_hidden_cycles: hidden,
        cpu_compute_cycles: km.cpu_compute_cycles,
        launches: km.launches,
    };
    // A metric row is evaluated exactly like a simulated outcome's —
    // same energy integration, same derived metrics — over the
    // predicted breakdown and statistics. The output tensor is never
    // materialized (this is the whole point of the planner).
    let outcome = ConvOutcome {
        mapping: km.mapping,
        shape: *shape,
        output: TensorChw::zeros(0, 0, 0),
        latency,
        cgra_stats: stats,
        cpu_mem: km.cpu_mem,
        footprint_bytes: km.footprint_bytes,
    };
    let report = MappingReport::from_outcome(&outcome, emodel);
    Ok(CostEstimate { mapping: km.mapping, shape: *shape, latency, report, probe_launches })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_rounds_to_nearest_and_is_exact_on_full_coverage() {
        // count == n: the probes ARE the class — exact sum.
        assert_eq!(scale(2, 101 + 99, 2), 200);
        // Averaging: 3 launches at (10+12)/2 each.
        assert_eq!(scale(3, 22, 2), 33);
        // Rounding to nearest.
        assert_eq!(scale(1, 3, 2), 2); // 1.5 -> 2 (half away from zero)
        // Intermediate products beyond u32 ranges stay exact (u128 math).
        assert_eq!(scale(1 << 32, (1 << 20) + 2, 2), (1u64 << 51) + (1 << 32));
    }

    #[test]
    fn merge_scaled_multiplies_everything() {
        let mut a = RunStats::new();
        a.exited = true;
        let mut b = RunStats::new();
        b.exited = true;
        b.steps = 7;
        b.mem.loads = 3;
        b.op_mix[5][0] = 2;
        merge_scaled(&mut a, &b, 4);
        assert_eq!(a.steps, 28);
        assert_eq!(a.mem.loads, 12);
        assert_eq!(a.op_mix[5][0], 8);
        assert!(a.exited);
    }
}
