//! Network-level planning: choose a mapping per [`ConvNet`] layer by
//! predicted cost under the 512 KiB working-set constraint.
//!
//! The per-layer candidate set is every concrete strategy (the four
//! CGRA mappings *and* the CPU baseline — a layer too big for any CGRA
//! route can still run on the host if its tensors fit); candidates
//! whose working set exceeds the memory bound are excluded by the same
//! layout checks the kernels enforce. Host-side ReLU cycles/energy are
//! charged exactly as `engine::Engine::run_network` charges them, so a
//! plan's totals are directly comparable to a simulated inference.

use anyhow::{Context, Result};

use crate::conv::ConvShape;
use crate::coordinator::network::ConvNet;
use crate::kernels::Mapping;

use super::{CostEstimate, Planner};

/// What a plan optimizes per layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanObjective {
    /// Minimize predicted end-to-end cycles (the paper's Fig. 4 x-axis).
    Latency,
    /// Minimize predicted total energy in µJ (the Fig. 4 y-axis).
    Energy,
}

impl PlanObjective {
    /// Parse a user-facing name, case-insensitively.
    pub fn parse(s: &str) -> Result<PlanObjective> {
        match s.to_ascii_lowercase().as_str() {
            "latency" | "cycles" => Ok(PlanObjective::Latency),
            "energy" | "uj" => Ok(PlanObjective::Energy),
            other => anyhow::bail!("unknown objective '{other}' (valid: latency, energy)"),
        }
    }

    /// Display label.
    pub fn label(self) -> &'static str {
        match self {
            PlanObjective::Latency => "latency",
            PlanObjective::Energy => "energy",
        }
    }
}

/// The chosen strategy and predicted cost of one layer.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    /// Layer index in execution order.
    pub index: usize,
    /// Layer shape.
    pub shape: ConvShape,
    /// The winning mapping under the objective.
    pub mapping: Mapping,
    /// Its full predicted cost point.
    pub estimate: CostEstimate,
    /// Host ReLU cycles (0 when the layer has no activation).
    pub relu_cycles: u64,
    /// Host ReLU energy, µJ.
    pub relu_energy_uj: f64,
}

impl LayerPlan {
    /// Predicted layer latency including the activation, cycles.
    pub fn total_cycles(&self) -> u64 {
        self.estimate.cycles() + self.relu_cycles
    }

    /// Predicted layer energy including the activation, µJ.
    pub fn total_energy_uj(&self) -> f64 {
        self.estimate.energy_uj() + self.relu_energy_uj
    }
}

/// A whole-network plan: per-layer choices plus predicted totals.
#[derive(Clone, Debug)]
pub struct NetworkPlan {
    /// The objective the plan minimized.
    pub objective: PlanObjective,
    /// Per-layer choices, in execution order.
    pub layers: Vec<LayerPlan>,
    /// Predicted end-to-end cycles (convolutions + ReLUs).
    pub total_cycles: u64,
    /// Predicted end-to-end energy, µJ.
    pub total_energy_uj: f64,
}

impl NetworkPlan {
    /// The chosen mapping per layer.
    pub fn mappings(&self) -> Vec<Mapping> {
        self.layers.iter().map(|l| l.mapping).collect()
    }

    /// Write the chosen mappings back into a network, so a subsequent
    /// `Engine::run_network` executes the plan.
    pub fn apply(&self, net: &mut ConvNet) -> Result<()> {
        net.apply_mappings(&self.mappings())
    }
}

/// Plan every layer of `net`: predict each candidate mapping's cost and
/// keep the best under `objective`. Ties break in [`Mapping::ALL`]
/// order (WP first), keeping plans deterministic.
pub fn plan_network(
    planner: &Planner,
    net: &ConvNet,
    objective: PlanObjective,
) -> Result<NetworkPlan> {
    net.validate()?;
    let mut layers = Vec::with_capacity(net.layers.len());
    let mut total_cycles = 0u64;
    let mut total_energy_uj = 0.0f64;
    for (index, layer) in net.layers.iter().enumerate() {
        let estimate = planner
            .best_of(&layer.shape, &Mapping::ALL, objective)
            .with_context(|| format!("planning layer {index} ({})", layer.shape))?;
        let (relu_cycles, relu_energy_uj) = if layer.relu {
            crate::engine::relu_cost(planner.energy_model(), layer.shape.output_elems())
        } else {
            (0, 0.0)
        };
        total_cycles += estimate.cycles() + relu_cycles;
        total_energy_uj += estimate.energy_uj() + relu_energy_uj;
        layers.push(LayerPlan {
            index,
            shape: layer.shape,
            mapping: estimate.mapping,
            estimate,
            relu_cycles,
            relu_energy_uj,
        });
    }
    Ok(NetworkPlan { objective, layers, total_cycles, total_energy_uj })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::CgraConfig;
    use crate::energy::EnergyModel;

    fn planner() -> Planner {
        Planner::new(&CgraConfig::default(), &EnergyModel::default()).unwrap()
    }

    #[test]
    fn plans_every_layer_and_totals_add_up() {
        let p = planner();
        let net = ConvNet::random(3, 2, 5, 9, 9, 4);
        let plan = plan_network(&p, &net, PlanObjective::Latency).unwrap();
        assert_eq!(plan.layers.len(), 3);
        let cycles: u64 = plan.layers.iter().map(|l| l.total_cycles()).sum();
        assert_eq!(cycles, plan.total_cycles);
        let uj: f64 = plan.layers.iter().map(|l| l.total_energy_uj()).sum();
        assert!((uj - plan.total_energy_uj).abs() < 1e-9);
        // ReLU charged on every layer but the last (ConvNet::random).
        assert!(plan.layers[0].relu_cycles > 0);
        assert_eq!(plan.layers[2].relu_cycles, 0);
        assert!(plan.layers.iter().all(|l| !l.mapping.is_auto()));
    }

    #[test]
    fn apply_writes_concrete_mappings_back() {
        let p = planner();
        let mut net = ConvNet::random(2, 2, 4, 8, 8, 9);
        assert!(net.layers.iter().all(|l| l.mapping.is_auto()));
        let plan = plan_network(&p, &net, PlanObjective::Energy).unwrap();
        plan.apply(&mut net).unwrap();
        assert_eq!(
            net.layers.iter().map(|l| l.mapping).collect::<Vec<_>>(),
            plan.mappings()
        );
    }

    #[test]
    fn objective_parsing() {
        assert_eq!(PlanObjective::parse("Latency").unwrap(), PlanObjective::Latency);
        assert_eq!(PlanObjective::parse("ENERGY").unwrap(), PlanObjective::Energy);
        assert!(PlanObjective::parse("speed").is_err());
        assert_eq!(PlanObjective::Latency.label(), "latency");
    }
}
