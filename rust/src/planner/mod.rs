//! Analytical cost-model planner: predict latency (cycles) and energy
//! (µJ) of any `(ConvShape, Mapping)` point **without simulating the
//! convolution**, and plan whole networks per layer.
//!
//! # How it works
//!
//! A full simulation of one sweep point costs milliseconds — every
//! launch of the kernel's loop nest is executed cycle by cycle. But the
//! loop nests themselves are closed-form in the shape
//! ([`model`](self)): WP runs exactly `K·C` launches of two structural
//! kinds, Conv-OP `⌈K/16⌉·9·Ox`, Im2col-OP `⌈K/16⌉·Ox·Oy`, Im2col-IP
//! `Ox·Oy·K`, and within a kind every launch executes the same step
//! sequence (timing in this simulator is data-independent; members of a
//! kind differ only in address immediates). So the planner:
//!
//! 1. decomposes the kernel into launch classes with closed-form counts
//!    (`model.rs`),
//! 2. *calibrates* each class by simulating one or two representative
//!    launches against a zeroed memory (`probe.rs`) — microseconds, not
//!    milliseconds — and
//! 3. scales by the counts, adds the drivers' closed-form host-side
//!    terms (launch overhead, im2col copy cycles, overlap hiding, CPU
//!    baseline cycles) and evaluates the session energy model over the
//!    predicted breakdown.
//!
//! Estimates are memoized per `(mapping, shape)`, so repeated queries —
//! the `Engine::submit_planned` fast path — are nanosecond lookups.
//! The CPU baseline needs no probes at all ([`CpuModel`] is already
//! closed-form), and where the representatives cover the whole class
//! the prediction is cycle-exact (pinned by the tests below).
//!
//! [`validate`] measures the residual against the decoded simulator
//! over a sweep grid (the `cgra plan --validate` protocol; CI enforces
//! the ≤ 5 % mean-absolute-latency-error bound), and [`plan_network`]
//! picks a mapping per CNN layer by predicted cost under the 512 KiB
//! working-set constraint.
//!
//! [`CpuModel`]: crate::cpu_ref::CpuModel

mod model;
mod network;
mod probe;
mod validate;

pub use network::{plan_network, LayerPlan, NetworkPlan, PlanObjective};
pub use validate::{
    bottleneck_check, validate, validate_extended, BottleneckCheck, ValidationReport,
    ValidationRow,
};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, ensure, Result};

use crate::cgra::{Cgra, CgraConfig};
use crate::conv::ConvShape;
use crate::energy::EnergyModel;
use crate::kernels::{LatencyBreakdown, Mapping};
use crate::metrics::MappingReport;

/// One predicted cost point: everything a simulation would report about
/// `(shape, mapping)` except the output tensor.
#[derive(Clone, Debug)]
pub struct CostEstimate {
    /// The concrete strategy modeled.
    pub mapping: Mapping,
    /// The layer shape.
    pub shape: ConvShape,
    /// Predicted latency decomposition (same fields the kernels fill).
    pub latency: LatencyBreakdown,
    /// Predicted metric row — evaluated by the same
    /// [`MappingReport::from_outcome`] path as simulated rows, so every
    /// derived metric (energy split, MAC/cycle, utilization, op mix)
    /// is available.
    pub report: MappingReport,
    /// Probe launches simulated to calibrate this estimate (0 when the
    /// estimate is pure closed form, e.g. the CPU baseline).
    pub probe_launches: u64,
}

impl CostEstimate {
    /// Predicted end-to-end latency, cycles.
    pub fn cycles(&self) -> u64 {
        self.latency.total_cycles()
    }

    /// Predicted total energy, µJ.
    pub fn energy_uj(&self) -> f64 {
        self.report.energy_uj
    }
}

/// Counter snapshot of a [`Planner`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlannerStats {
    /// Estimate requests served (including memo hits).
    pub estimates: u64,
    /// Requests served from the memo without touching the simulator.
    pub memo_hits: u64,
    /// Probe launches simulated for calibration, in total.
    pub probe_launches: u64,
}

/// The cost-model planner: owns a simulator instance for calibration
/// probes, the session energy model, and a memo of completed estimates.
///
/// `Planner` is `Sync` — `engine::Engine` shares one across its worker
/// pool — and deterministic: the same `(config, model, shape, mapping)`
/// always yields the same estimate.
pub struct Planner {
    cgra: Cgra,
    model: EnergyModel,
    memo: Mutex<HashMap<(Mapping, ConvShape), CostEstimate>>,
    estimates: AtomicU64,
    memo_hits: AtomicU64,
    probe_launches: AtomicU64,
}

impl Planner {
    /// Build a planner for a simulator configuration and energy model
    /// (an `Engine` builds one with its own session pair).
    pub fn new(cfg: &CgraConfig, model: &EnergyModel) -> Result<Planner> {
        Ok(Planner {
            cgra: Cgra::new(cfg.clone())?,
            model: *model,
            memo: Mutex::new(HashMap::new()),
            estimates: AtomicU64::new(0),
            memo_hits: AtomicU64::new(0),
            probe_launches: AtomicU64::new(0),
        })
    }

    /// The simulator configuration the predictions are calibrated to.
    pub fn config(&self) -> &CgraConfig {
        self.cgra.config()
    }

    /// The energy model applied to every estimate.
    pub fn energy_model(&self) -> &EnergyModel {
        &self.model
    }

    /// Counter snapshot.
    pub fn stats(&self) -> PlannerStats {
        PlannerStats {
            estimates: self.estimates.load(Ordering::Relaxed),
            memo_hits: self.memo_hits.load(Ordering::Relaxed),
            probe_launches: self.probe_launches.load(Ordering::Relaxed),
        }
    }

    /// Predict the cost of one concrete `(shape, mapping)` point.
    ///
    /// Memoized: the first call per point runs the calibration probes
    /// (microseconds); repeats are pure lookups. Fails with the same
    /// actionable memory-bound error as the kernel would.
    ///
    /// The memo check and insert are separate critical sections, so
    /// concurrent *first* calls for one point may each run the probes;
    /// that is deliberate (probing is deterministic and cheap, and
    /// holding the lock across a probe would serialize estimates of
    /// unrelated shapes) — the only visible effect is a higher
    /// [`PlannerStats::probe_launches`] count.
    pub fn estimate(&self, shape: &ConvShape, mapping: Mapping) -> Result<CostEstimate> {
        ensure!(
            !mapping.is_auto(),
            "estimate() needs a concrete mapping — use Planner::choose for Auto"
        );
        shape.validate()?;
        self.estimates.fetch_add(1, Ordering::Relaxed);
        let key = (mapping, *shape);
        if let Some(hit) = self.memo.lock().unwrap().get(&key) {
            self.memo_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(hit.clone());
        }
        let km = model::KernelModel::for_mapping(mapping, shape, self.cgra.config())?;
        let est = probe::assemble(&self.cgra, &self.model, shape, km)?;
        self.probe_launches.fetch_add(est.probe_launches, Ordering::Relaxed);
        self.memo.lock().unwrap().insert(key, est.clone());
        Ok(est)
    }

    /// Estimate every candidate mapping and keep the cheapest under
    /// `objective` (ties break in candidate order). The single
    /// select-best policy shared by [`Planner::choose`] and
    /// [`plan_network`]. When no candidate fits the memory bound, the
    /// last estimation error is returned.
    pub fn best_of(
        &self,
        shape: &ConvShape,
        candidates: &[Mapping],
        objective: PlanObjective,
    ) -> Result<CostEstimate> {
        let mut best: Option<CostEstimate> = None;
        let mut last_err = None;
        for &m in candidates {
            match self.estimate(shape, m) {
                Ok(est) => {
                    let better = match (&best, objective) {
                        (None, _) => true,
                        (Some(b), PlanObjective::Latency) => est.cycles() < b.cycles(),
                        (Some(b), PlanObjective::Energy) => est.energy_uj() < b.energy_uj(),
                    };
                    if better {
                        best = Some(est);
                    }
                }
                Err(e) => last_err = Some(e),
            }
        }
        best.ok_or_else(|| last_err.unwrap_or_else(|| anyhow!("no candidate mappings given")))
    }

    /// Pick the CGRA mapping with the lowest predicted latency for a
    /// shape — the cost-model backing of `Mapping::Auto` (ties break in
    /// [`Mapping::CGRA`] order, WP first). The CPU baseline is never
    /// chosen, matching the static policy it upgrades.
    ///
    /// When no mapping fits the memory bound, the error is the
    /// actionable dual-route message of [`Mapping::resolve`].
    pub fn choose(&self, shape: &ConvShape) -> Result<CostEstimate> {
        shape.validate()?;
        match self.best_of(shape, &Mapping::CGRA, PlanObjective::Latency) {
            Ok(est) => Ok(est),
            // Nothing fits: prefer the resolver's dual-route bound
            // message; surface the estimate error only if the resolver
            // unexpectedly thinks a route exists.
            Err(est_err) => match Mapping::Auto.resolve(shape, self.cgra.config()) {
                Err(e) => Err(e),
                Ok(_) => Err(est_err),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{random_input, random_weights};
    use crate::kernels::{dispatch, ConvOutcome};
    use crate::prop::Rng;

    fn planner() -> Planner {
        Planner::new(&CgraConfig::default(), &EnergyModel::default()).unwrap()
    }

    fn simulate(shape: &ConvShape, mapping: Mapping) -> ConvOutcome {
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let mut rng = Rng::new(7);
        let input = random_input(shape, 12, &mut rng);
        let weights = random_weights(shape, 7, &mut rng);
        dispatch(&cgra, mapping, shape, &input, &weights).unwrap()
    }

    /// With K ≤ 2 and C ≤ 2 the WP probes (first/last launch of each
    /// class) ARE the full launch set, so the prediction must equal the
    /// simulation cycle for cycle — and, the breakdown and statistics
    /// being identical, energy bit for bit.
    #[test]
    fn wp_prediction_exact_when_probes_cover_all_launches() {
        let p = planner();
        let shape = ConvShape::new3x3(2, 2, 5, 4);
        let est = p.estimate(&shape, Mapping::Wp).unwrap();
        let out = simulate(&shape, Mapping::Wp);
        assert_eq!(est.latency.cgra_cycles, out.latency.cgra_cycles);
        assert_eq!(est.cycles(), out.latency.total_cycles());
        assert_eq!(est.report.launches, out.latency.launches);
        let sim = MappingReport::from_outcome(&out, &EnergyModel::default());
        assert_eq!(est.report.energy_uj.to_bits(), sim.energy_uj.to_bits());
        assert_eq!(est.report.cgra_accesses, sim.cgra_accesses);
        assert_eq!(est.report.utilization.to_bits(), sim.utilization.to_bits());
        assert_eq!(est.report.footprint_bytes, sim.footprint_bytes);
    }

    /// Full-coverage shapes for the im2col mappings (≤ 2 pixels, one
    /// k-tile / K = 1): predictions exact including the CPU-overlap
    /// accounting.
    #[test]
    fn im2col_mappings_exact_on_full_coverage_shapes() {
        let p = planner();
        for (shape, mapping) in [
            (ConvShape::new3x3(3, 4, 1, 2), Mapping::OpIm2col),
            (ConvShape::new3x3(3, 1, 1, 2), Mapping::Ip),
        ] {
            let est = p.estimate(&shape, mapping).unwrap();
            let out = simulate(&shape, mapping);
            assert_eq!(est.latency.cgra_cycles, out.latency.cgra_cycles, "{mapping} {shape}");
            assert_eq!(
                est.latency.cpu_im2col_cycles, out.latency.cpu_im2col_cycles,
                "{mapping} {shape}"
            );
            assert_eq!(
                est.latency.cpu_hidden_cycles, out.latency.cpu_hidden_cycles,
                "{mapping} {shape}"
            );
            assert_eq!(est.cycles(), out.latency.total_cycles(), "{mapping} {shape}");
        }
    }

    /// Conv-OP samples 2 of the 8 accumulation taps, so it is only
    /// alignment-close, not exact — within 2 % on a small shape.
    #[test]
    fn op_direct_prediction_close() {
        let p = planner();
        let shape = ConvShape::new3x3(3, 5, 4, 4);
        let est = p.estimate(&shape, Mapping::OpDirect).unwrap();
        let out = simulate(&shape, Mapping::OpDirect);
        let (a, b) = (est.cycles() as f64, out.latency.total_cycles() as f64);
        assert!(((a - b) / b).abs() < 0.02, "predicted {a} vs simulated {b}");
        assert_eq!(est.report.launches, out.latency.launches);
    }

    /// The CPU baseline is pure closed form: zero probes, exact cycles,
    /// bit-identical energy.
    #[test]
    fn cpu_prediction_is_closed_form_and_exact() {
        let p = planner();
        let shape = ConvShape::new3x3(3, 2, 4, 5);
        let est = p.estimate(&shape, Mapping::Cpu).unwrap();
        assert_eq!(est.probe_launches, 0);
        let out = simulate(&shape, Mapping::Cpu);
        assert_eq!(est.cycles(), out.latency.total_cycles());
        let sim = MappingReport::from_outcome(&out, &EnergyModel::default());
        assert_eq!(est.report.energy_uj.to_bits(), sim.energy_uj.to_bits());
    }

    #[test]
    fn memo_serves_repeats_without_new_probes() {
        let p = planner();
        let shape = ConvShape::new3x3(4, 4, 6, 6);
        let a = p.estimate(&shape, Mapping::Wp).unwrap();
        let s0 = p.stats();
        assert!(s0.probe_launches > 0);
        assert_eq!(s0.memo_hits, 0);
        let b = p.estimate(&shape, Mapping::Wp).unwrap();
        let s1 = p.stats();
        assert_eq!(s1.probe_launches, s0.probe_launches, "repeat must not probe");
        assert_eq!(s1.memo_hits, 1);
        assert_eq!(a.cycles(), b.cycles());
        assert_eq!(a.report.energy_uj.to_bits(), b.report.energy_uj.to_bits());
    }

    #[test]
    fn choose_picks_wp_on_the_baseline_layer() {
        let p = planner();
        let est = p.choose(&ConvShape::baseline()).unwrap();
        assert_eq!(est.mapping, Mapping::Wp, "the paper's winner");
    }

    #[test]
    fn choose_errors_actionably_past_the_bound() {
        let err = planner().choose(&ConvShape::new3x3(144, 144, 64, 64)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("KiB"), "{msg}");
    }

    #[test]
    fn estimate_rejects_auto_and_oversized_shapes() {
        let p = planner();
        assert!(p.estimate(&ConvShape::baseline(), Mapping::Auto).is_err());
        assert!(p.estimate(&ConvShape::new3x3(144, 144, 64, 64), Mapping::Wp).is_err());
    }
}
