//! Predicted-vs-simulated validation of the cost model.
//!
//! Runs a sweep grid through the engine (ground truth: the decoded
//! simulator, cache-assisted) and the planner (cost model only), and
//! reports per-point and aggregate error. This is the calibration
//! protocol of DESIGN.md §6 and the `cgra plan --validate` CLI path;
//! CI runs it on [`SweepSpec::validation`] and fails the build when the
//! mean absolute latency error exceeds the checked-in bound (the
//! tentpole's ≤ 5 % acceptance criterion).

use anyhow::{ensure, Context, Result};

use crate::cgra::Memory;
use crate::conv::ConvShape;
use crate::coordinator::sweep::SweepSpec;
use crate::engine::Engine;
use crate::kernels::Mapping;
use crate::obs::profile::{self, BnClass};
use crate::util::fmt::Table;
use crate::util::Json;

use super::model::KernelModel;

/// One validated point.
#[derive(Clone, Debug)]
pub struct ValidationRow {
    /// Varied sweep axis label (`C` / `K` / `OxOy`).
    pub axis: &'static str,
    /// Axis value.
    pub value: usize,
    /// The concrete mapping compared.
    pub mapping: Mapping,
    /// Full layer shape.
    pub shape: ConvShape,
    /// Ground-truth cycles from the decoded simulator.
    pub simulated_cycles: u64,
    /// Cost-model cycles.
    pub predicted_cycles: u64,
    /// Signed latency error, percent of the simulated value.
    pub latency_err_pct: f64,
    /// Ground-truth energy, µJ.
    pub simulated_uj: f64,
    /// Cost-model energy, µJ.
    pub predicted_uj: f64,
    /// Signed energy error, percent.
    pub energy_err_pct: f64,
}

/// Aggregate validation results.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    /// Every compared point.
    pub rows: Vec<ValidationRow>,
    /// Points both sides refuse (memory bound) — expected skips.
    pub skipped: usize,
    /// Points where simulator and planner disagree on feasibility
    /// (must be 0: both consult the same layout bounds).
    pub bound_mismatches: usize,
    /// One line per feasibility mismatch naming the point, the side
    /// that disagreed and why — so the CI hard gate is debuggable from
    /// the log alone.
    pub mismatch_details: Vec<String>,
    /// Mean of |latency error| over the rows, percent.
    pub mean_abs_latency_err_pct: f64,
    /// Worst |latency error|, percent.
    pub max_abs_latency_err_pct: f64,
    /// Mean of |energy error|, percent.
    pub mean_abs_energy_err_pct: f64,
    /// Worst |energy error|, percent.
    pub max_abs_energy_err_pct: f64,
    /// Probe launches the planner simulated to calibrate, in total.
    pub probe_launches: u64,
    /// Launches the ground-truth simulations executed, in total.
    pub simulated_launches: u64,
}

/// Validate the planner against the simulator over `spec`'s grid.
///
/// `Mapping::Auto` points are resolved through the same static policy
/// the sweep uses, so both sides compare the identical concrete kernel.
pub fn validate(engine: &Engine, spec: &SweepSpec) -> Result<ValidationReport> {
    let sweep_rows = engine.sweep(spec)?;
    let planner = engine.planner();
    let probes_before = planner.stats().probe_launches;
    let mut rows = Vec::new();
    let mut skipped = 0usize;
    let mut mismatch_details: Vec<String> = Vec::new();
    let mut simulated_launches = 0u64;
    for r in &sweep_rows {
        let mapping = match r.point.mapping.resolve(&r.point.shape, engine.config()) {
            Ok((m, _reason)) => m,
            // Auto past the bound: the sweep recorded a skip; the
            // planner refuses too — counted below via the Err arm.
            Err(_) => r.point.mapping,
        };
        let est = if mapping.is_auto() {
            Err(anyhow::anyhow!("unresolvable Auto point"))
        } else {
            planner.estimate(&r.point.shape, mapping)
        };
        match (&r.report, est) {
            (Some(sim), Ok(est)) => {
                simulated_launches += sim.launches;
                let (sc, pc) = (sim.latency_cycles, est.cycles());
                let latency_err_pct = err_pct(pc as f64, sc as f64);
                let energy_err_pct = err_pct(est.energy_uj(), sim.energy_uj);
                rows.push(ValidationRow {
                    axis: r.point.axis.label(),
                    value: r.point.value,
                    mapping,
                    shape: r.point.shape,
                    simulated_cycles: sc,
                    predicted_cycles: pc,
                    latency_err_pct,
                    simulated_uj: sim.energy_uj,
                    predicted_uj: est.energy_uj(),
                    energy_err_pct,
                });
            }
            (None, Err(_)) => skipped += 1,
            (Some(_), Err(e)) => mismatch_details.push(format!(
                "{}={} {} ({}): simulator produced a row but the planner refused: {e:#}",
                r.point.axis.label(),
                r.point.value,
                mapping,
                r.point.shape,
            )),
            (None, Ok(_)) => mismatch_details.push(format!(
                "{}={} {} ({}): planner produced an estimate but the simulator skipped: {}",
                r.point.axis.label(),
                r.point.value,
                mapping,
                r.point.shape,
                r.skipped.as_deref().unwrap_or("no reason recorded"),
            )),
        }
    }
    let mut report = ValidationReport {
        mean_abs_latency_err_pct: 0.0,
        max_abs_latency_err_pct: 0.0,
        mean_abs_energy_err_pct: 0.0,
        max_abs_energy_err_pct: 0.0,
        probe_launches: planner.stats().probe_launches - probes_before,
        simulated_launches,
        rows,
        skipped,
        bound_mismatches: mismatch_details.len(),
        mismatch_details,
    };
    recompute_aggregates(&mut report);
    Ok(report)
}

/// Signed percentage error of `pred` against `sim`.
fn err_pct(pred: f64, sim: f64) -> f64 {
    (pred - sim) / sim.max(1e-12) * 100.0
}

/// Recompute the aggregate error statistics from the current rows
/// (used after [`validate_extended`] appends its extension points).
fn recompute_aggregates(report: &mut ValidationReport) {
    let n = report.rows.len().max(1) as f64;
    report.mean_abs_latency_err_pct =
        report.rows.iter().map(|r| r.latency_err_pct.abs()).sum::<f64>() / n;
    report.max_abs_latency_err_pct =
        report.rows.iter().map(|r| r.latency_err_pct.abs()).fold(0.0f64, f64::max);
    report.mean_abs_energy_err_pct =
        report.rows.iter().map(|r| r.energy_err_pct.abs()).sum::<f64>() / n;
    report.max_abs_energy_err_pct =
        report.rows.iter().map(|r| r.energy_err_pct.abs()).fold(0.0f64, f64::max);
}

/// The `cgra plan --validate` protocol since the `nn` subsystem landed:
/// the [`validate`] grid **plus two generalized-layer points** —
///
/// - a **depthwise** shape (`axis "DW"`): the planner's `Dw-WP` launch
///   class vs the simulated `kernels::dw` run, and
/// - a **strided** layer (`axis "stride"`): the nn plan (conv estimate
///   + closed-form host glue) vs the executed nn lowering of a
///   stride-2 / pad-1 convolution, end to end.
///
/// Both rows enter the same aggregate error statistics, so the CI MAE
/// gate covers the new layer classes too.
pub fn validate_extended(engine: &Engine, spec: &SweepSpec) -> Result<ValidationReport> {
    let mut report = validate(engine, spec)?;
    let planner = engine.planner();
    let probes_before = planner.stats().probe_launches;

    // Depthwise point: predicted vs simulated Dw-WP.
    let dw_shape = ConvShape::new3x3(16, 16, 16, 16);
    let est = planner.estimate(&dw_shape, Mapping::DwWp)?;
    let req = crate::engine::ConvRequest::seeded_with_mags(
        dw_shape,
        Mapping::DwWp,
        spec.seed,
        spec.mag,
        spec.mag,
    );
    let (sim, _) = engine.submit_report(&req)?;
    report.simulated_launches += sim.launches;
    report.rows.push(ValidationRow {
        axis: "DW",
        value: dw_shape.c,
        mapping: Mapping::DwWp,
        shape: dw_shape,
        simulated_cycles: sim.latency_cycles,
        predicted_cycles: est.cycles(),
        latency_err_pct: err_pct(est.cycles() as f64, sim.latency_cycles as f64),
        simulated_uj: sim.energy_uj,
        predicted_uj: est.energy_uj(),
        energy_err_pct: err_pct(est.energy_uj(), sim.energy_uj),
    });

    // Strided point: nn plan vs nn execution of one stride-2 / pad-1
    // convolution (conv estimate plus identical closed-form glue).
    let gen = crate::conv::GenConvShape::new(8, 8, 18, 18, 3, 3, 2, 1, 1)?;
    let mut rng = crate::prop::Rng::new(spec.seed ^ 0x57de);
    let layer = crate::nn::Layer::conv(gen, false, spec.mag.min(9), &mut rng)?;
    let net = crate::nn::Net {
        name: "validate-strided".into(),
        input_dims: (gen.c, gen.ih, gen.iw),
        layers: vec![layer],
    };
    let plan = crate::nn::plan_network(planner, &net, crate::planner::PlanObjective::Latency)?;
    let input = net.random_input(spec.mag, spec.seed);
    let exec = crate::nn::run_network(engine, &net, &input)?;
    anyhow::ensure!(
        exec.exact,
        "strided validation layer diverged from the generalized golden model"
    );
    report.simulated_launches += exec.layers[0].launches;
    let lowered = crate::nn::lower::lower_conv(&gen, Mapping::Auto, false)?;
    report.rows.push(ValidationRow {
        axis: "stride",
        value: gen.stride,
        mapping: plan.layers[0].mapping.expect("conv layer has a mapping"),
        shape: lowered.sub_shape,
        simulated_cycles: exec.total_cycles,
        predicted_cycles: plan.total_cycles,
        latency_err_pct: err_pct(plan.total_cycles as f64, exec.total_cycles as f64),
        simulated_uj: exec.total_energy_uj,
        predicted_uj: plan.total_energy_uj,
        energy_err_pct: err_pct(plan.total_energy_uj, exec.total_energy_uj),
    });

    report.probe_launches += planner.stats().probe_launches - probes_before;
    recompute_aggregates(&mut report);
    Ok(report)
}

/// Result of one [`bottleneck_check`]: predicted vs attributed
/// bottleneck composition of a kernel execution.
#[derive(Clone, Debug)]
pub struct BottleneckCheck {
    /// The concrete strategy checked.
    pub mapping: Mapping,
    /// The layer shape.
    pub shape: ConvShape,
    /// Predicted walk cycles (probe attribution scaled by class
    /// counts; fractional because classes average over their probes).
    pub predicted_cycles: f64,
    /// Attributed walk cycles from profiling the real kernel run.
    pub attributed_cycles: u64,
    /// Predicted bottleneck shares, indexed by [`BnClass::idx`].
    pub predicted_shares: [f64; BnClass::COUNT],
    /// Attributed bottleneck shares.
    pub attributed_shares: [f64; BnClass::COUNT],
    /// Worst per-class share disagreement, percentage points.
    pub max_share_err_pp: f64,
}

/// Cross-check the planner's launch-class decomposition against the
/// profiler (DESIGN.md §12): does the cost model predict *where* the
/// cycles go, not just how many there are?
///
/// The launch classes' representative probe programs are replayed under
/// a profiling session and their attribution scaled by the class counts
/// — exactly the calibration protocol of `planner::probe`, keeping the
/// bottleneck split instead of just the cycle total. The full kernel is
/// then dispatched under a second session and the attributed shares
/// compared class by class. Where the probes cover the whole launch set
/// (small shapes) the two sides agree to rounding; elsewhere the
/// residual is the same bank-alignment jitter the latency validation
/// bounds.
pub fn bottleneck_check(
    engine: &Engine,
    shape: &ConvShape,
    mapping: Mapping,
    seed: u64,
) -> Result<BottleneckCheck> {
    let model = KernelModel::for_mapping(mapping, shape, engine.config())?;
    ensure!(
        model.launches > 0,
        "bottleneck check needs a CGRA mapping with launches, {mapping} has none"
    );

    // Predicted side: replay each class's probes, average, scale.
    let mut predicted = [0.0f64; BnClass::COUNT];
    let mut predicted_cycles = 0.0f64;
    {
        let session = profile::session();
        for class in &model.classes {
            let mut sum = [0u64; BnClass::COUNT];
            let mut cycles = 0u64;
            for prog in &class.probes {
                let cfg = engine.config();
                let mut mem = Memory::new(cfg.mem_words, cfg.n_banks);
                engine.cgra().run(prog, &mut mem)?;
                let d = profile::take_last_walk()
                    .context("probe walk left no profile delta — profiler hook missing?")?;
                for k in 0..BnClass::COUNT {
                    sum[k] += d.class_cycles[k];
                }
                cycles += d.cycles;
            }
            let n = class.probes.len().max(1) as f64;
            for k in 0..BnClass::COUNT {
                predicted[k] += class.count as f64 * sum[k] as f64 / n;
            }
            predicted_cycles += class.count as f64 * cycles as f64 / n;
        }
        drop(session.finish());
    }

    // Attributed side: profile the real kernel dispatch.
    let mut rng = crate::prop::Rng::new(seed);
    let input = crate::conv::random_input(shape, 6, &mut rng);
    let weights = if mapping == Mapping::DwWp {
        ensure!(shape.k == shape.c, "depthwise needs K == C");
        crate::conv::random_depthwise_weights(shape, 6, &mut rng)
    } else {
        crate::conv::random_weights(shape, 6, &mut rng)
    };
    // A thread-local Frame (not the session totals) collects the
    // attribution: dispatch runs on this thread, so walks from any
    // concurrent simulations elsewhere in the process cannot leak in.
    let session = profile::session();
    let fr = profile::frame();
    crate::kernels::dispatch(engine.cgra(), mapping, shape, &input, &weights)?;
    let attributed =
        fr.finish().context("kernel dispatch recorded no profiled walks")?;
    drop(session.finish());
    let attributed_cycles = attributed.cycles;
    let attributed_shares = attributed.class_shares();

    let mut predicted_shares = [0.0f64; BnClass::COUNT];
    if predicted_cycles > 0.0 {
        for k in 0..BnClass::COUNT {
            predicted_shares[k] = predicted[k] / predicted_cycles;
        }
    }
    let max_share_err_pp = (0..BnClass::COUNT)
        .map(|k| (predicted_shares[k] - attributed_shares[k]).abs() * 100.0)
        .fold(0.0f64, f64::max);
    Ok(BottleneckCheck {
        mapping,
        shape: *shape,
        predicted_cycles,
        attributed_cycles,
        predicted_shares,
        attributed_shares,
        max_share_err_pp,
    })
}

impl BottleneckCheck {
    /// Human-readable comparison table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["class", "predicted%", "attributed%", "delta_pp"]);
        for b in BnClass::ALL {
            t.row(vec![
                b.label().into(),
                format!("{:.3}", self.predicted_shares[b.idx()] * 100.0),
                format!("{:.3}", self.attributed_shares[b.idx()] * 100.0),
                format!(
                    "{:+.3}",
                    (self.predicted_shares[b.idx()] - self.attributed_shares[b.idx()]) * 100.0
                ),
            ]);
        }
        format!(
            "Bottleneck cross-check — {} on {} \
             (predicted {:.0} vs attributed {} walk cycles)\n{}max share error: {:.3} pp\n",
            self.mapping.label(),
            self.shape,
            self.predicted_cycles,
            self.attributed_cycles,
            t.render(),
            self.max_share_err_pp,
        )
    }
}

impl ValidationReport {
    /// The per-point comparison as a table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&[
            "axis",
            "value",
            "mapping",
            "sim_cycles",
            "pred_cycles",
            "lat_err%",
            "sim_uJ",
            "pred_uJ",
            "energy_err%",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.axis.into(),
                r.value.to_string(),
                r.mapping.label().into(),
                r.simulated_cycles.to_string(),
                r.predicted_cycles.to_string(),
                format!("{:+.3}", r.latency_err_pct),
                format!("{:.3}", r.simulated_uj),
                format!("{:.3}", r.predicted_uj),
                format!("{:+.3}", r.energy_err_pct),
            ]);
        }
        t
    }

    /// Human-readable report: table + aggregate summary.
    pub fn render(&self) -> String {
        let mut out = String::from(
            "Planner validation — cost model vs decoded simulator\n\
             (per point: predicted closed-form+probe cost vs full simulation)\n\n",
        );
        out.push_str(&self.table().render());
        out.push_str(&format!(
            "\n{} points compared, {} skipped (memory bound), {} feasibility mismatches\n\
             latency: mean |err| {:.3}%  max |err| {:.3}%\n\
             energy:  mean |err| {:.3}%  max |err| {:.3}%\n\
             calibration: {} probe launches vs {} simulated launches ({}x fewer)\n",
            self.rows.len(),
            self.skipped,
            self.bound_mismatches,
            self.mean_abs_latency_err_pct,
            self.max_abs_latency_err_pct,
            self.mean_abs_energy_err_pct,
            self.max_abs_energy_err_pct,
            self.probe_launches,
            self.simulated_launches,
            self.simulated_launches / self.probe_launches.max(1),
        ));
        for m in &self.mismatch_details {
            out.push_str(&format!("MISMATCH: {m}\n"));
        }
        out
    }

    /// JSON form (persisted by `cgra plan --validate --out DIR`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("points", (self.rows.len() as u64).into()),
            ("skipped", (self.skipped as u64).into()),
            ("bound_mismatches", (self.bound_mismatches as u64).into()),
            (
                "mismatch_details",
                Json::Arr(self.mismatch_details.iter().map(|m| m.clone().into()).collect()),
            ),
            ("mean_abs_latency_err_pct", self.mean_abs_latency_err_pct.into()),
            ("max_abs_latency_err_pct", self.max_abs_latency_err_pct.into()),
            ("mean_abs_energy_err_pct", self.mean_abs_energy_err_pct.into()),
            ("max_abs_energy_err_pct", self.max_abs_energy_err_pct.into()),
            ("probe_launches", self.probe_launches.into()),
            ("simulated_launches", self.simulated_launches.into()),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("axis", r.axis.into()),
                                ("value", (r.value as u64).into()),
                                ("mapping", r.mapping.label().into()),
                                ("shape", r.shape.id().into()),
                                ("simulated_cycles", r.simulated_cycles.into()),
                                ("predicted_cycles", r.predicted_cycles.into()),
                                ("latency_err_pct", r.latency_err_pct.into()),
                                ("simulated_uj", r.simulated_uj.into()),
                                ("predicted_uj", r.predicted_uj.into()),
                                ("energy_err_pct", r.energy_err_pct.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineBuilder;

    /// A two-point grid end to end: CPU rows are closed-form exact, WP
    /// rows probe-calibrated; the report renders and serializes.
    #[test]
    fn tiny_grid_validates_exactly_for_cpu_and_tightly_for_wp() {
        let engine = EngineBuilder::new().workers(2).private_cache().build().unwrap();
        let spec = SweepSpec {
            c_values: vec![2],
            k_values: vec![],
            spatial_values: vec![],
            mappings: vec![Mapping::Wp, Mapping::Cpu],
            mag: 6,
            seed: 5,
        };
        let report = validate(&engine, &spec).unwrap();
        assert_eq!(report.rows.len(), 2);
        assert_eq!(report.bound_mismatches, 0);
        let cpu = report.rows.iter().find(|r| r.mapping == Mapping::Cpu).unwrap();
        assert_eq!(cpu.latency_err_pct, 0.0, "CPU baseline is closed form");
        let wp = report.rows.iter().find(|r| r.mapping == Mapping::Wp).unwrap();
        assert!(wp.latency_err_pct.abs() <= 5.0, "WP err {}%", wp.latency_err_pct);
        let text = report.render();
        assert!(text.contains("mean |err|"));
        let json = report.to_json();
        assert_eq!(json.req("rows").unwrap().as_arr().unwrap().len(), 2);
    }

    /// The extended protocol appends exactly the depthwise and strided
    /// rows, both inside the 5% bound, and keeps the aggregates
    /// consistent with the row set.
    #[test]
    fn extended_validation_adds_dw_and_stride_rows_within_bound() {
        let engine = EngineBuilder::new().workers(2).private_cache().build().unwrap();
        let spec = SweepSpec {
            c_values: vec![2],
            k_values: vec![],
            spatial_values: vec![],
            mappings: vec![Mapping::Cpu],
            mag: 6,
            seed: 5,
        };
        let report = validate_extended(&engine, &spec).unwrap();
        assert_eq!(report.rows.len(), 3, "grid row + DW + stride");
        let dw = report.rows.iter().find(|r| r.axis == "DW").unwrap();
        assert_eq!(dw.mapping, Mapping::DwWp);
        assert!(dw.latency_err_pct.abs() <= 5.0, "DW err {}%", dw.latency_err_pct);
        let st = report.rows.iter().find(|r| r.axis == "stride").unwrap();
        assert_eq!(st.value, 2);
        assert!(st.latency_err_pct.abs() <= 5.0, "stride err {}%", st.latency_err_pct);
        // Aggregates reflect the appended rows.
        let mean = report.rows.iter().map(|r| r.latency_err_pct.abs()).sum::<f64>()
            / report.rows.len() as f64;
        assert!((report.mean_abs_latency_err_pct - mean).abs() < 1e-12);
        assert!(report.simulated_launches > 0);
    }

    /// With K ≤ 2 and C ≤ 2 the WP probes ARE the full launch set, so
    /// the predicted bottleneck composition matches the attributed one
    /// exactly (up to f64 share rounding) — the composition analogue of
    /// `wp_prediction_exact_when_probes_cover_all_launches`.
    #[test]
    fn bottleneck_check_exact_when_probes_cover_all_launches() {
        let engine = EngineBuilder::new().workers(1).private_cache().build().unwrap();
        let shape = ConvShape::new3x3(2, 2, 5, 4);
        let bc = bottleneck_check(&engine, &shape, Mapping::Wp, 7).unwrap();
        assert!(bc.attributed_cycles > 0);
        assert!(
            (bc.predicted_cycles - bc.attributed_cycles as f64).abs() < 1e-6,
            "predicted {} vs attributed {}",
            bc.predicted_cycles,
            bc.attributed_cycles
        );
        assert!(bc.max_share_err_pp < 1e-6, "max share err {} pp", bc.max_share_err_pp);
        let sum: f64 = bc.attributed_shares.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "shares sum to 1, got {sum}");
        let text = bc.render();
        assert!(text.contains("max share error"));
        assert!(text.contains("dma-port"));
    }

    /// On a bigger shape the probes sample the classes instead of
    /// covering them; composition must still agree within a few
    /// percentage points (the same jitter the latency MAE bounds).
    #[test]
    fn bottleneck_check_close_on_sampled_classes() {
        let engine = EngineBuilder::new().workers(1).private_cache().build().unwrap();
        let shape = ConvShape::new3x3(4, 4, 6, 6);
        let bc = bottleneck_check(&engine, &shape, Mapping::Wp, 11).unwrap();
        assert!(bc.max_share_err_pp <= 5.0, "max share err {} pp", bc.max_share_err_pp);
        // CPU has no launches to attribute; the check refuses it.
        assert!(bottleneck_check(&engine, &shape, Mapping::Cpu, 11).is_err());
    }

    /// Memory-bound points must be refused by both sides.
    #[test]
    fn over_bound_points_skip_on_both_sides() {
        let engine = EngineBuilder::new().workers(1).private_cache().build().unwrap();
        let spec = SweepSpec {
            c_values: vec![],
            k_values: vec![],
            spatial_values: vec![64],
            mappings: vec![Mapping::Ip],
            mag: 4,
            seed: 6,
        };
        // Ox=Oy=64 at C=K=16: the IP aux buffers blow the 512 KiB bound
        // (the paper's sweep skips this point too).
        let report = validate(&engine, &spec).unwrap();
        assert_eq!(report.bound_mismatches, 0);
        assert_eq!(report.rows.len() + report.skipped, 1);
    }
}
