//! Closed-form launch decomposition of the four mapping kernels.
//!
//! Every kernel driver in `kernels/` executes a statically known loop
//! nest of CGRA launches; the programs of a launch differ only in
//! address immediates, never in structure, so the *step sequence* of a
//! launch — and therefore everything about its cost except ±1-cycle
//! bank-alignment jitter — is fixed by a small launch *class*:
//!
//! - **WP** (`kernels::wp::run`): one launch per `(k, ci)`; two classes
//!   — the `ci == 0` initialisation launches (no previous-partial
//!   prefetch) and the `ci > 0` accumulation launches.
//! - **Conv-OP** (`kernels::op_direct::run`): one launch per
//!   `(k-tile, filter tap, output row)`; classes split by tap kind
//!   (`(0,0)` initialises the in-memory partials, the other eight
//!   read-modify-write) × tile kind (full 16-lane tiles vs the
//!   imbalanced last tile when `K % 16 != 0`).
//! - **Im2col-OP** (`kernels::op_im2col::run`): one launch per
//!   `(k-tile, pixel)`; classes split by tile kind × the ping-pong
//!   patch-slot parity (`pixel % 2` picks the staging buffer, which is
//!   the only address difference between consecutive pixels).
//! - **Im2col-IP** (`kernels::ip::run`): one launch per `(pixel, k)`;
//!   classes split by patch-slot parity.
//! - **Dw-WP** (`kernels::dw::run`): one launch per channel — a single
//!   class, structurally the WP `ci == 0` class on a `C = K = 1` shape
//!   (the depthwise kernel reuses the WP generator).
//! - **CPU**: no launches — the scalar cost model
//!   ([`CpuModel::conv_cycles`]) is already closed-form.
//!
//! For each class this module emits the exact per-launch [`Program`]s
//! the kernel would build (one or two representatives, deduplicated),
//! plus the closed-form launch counts and the host-side accounting
//! (im2col copy cycles, overlap caps, CPU memory traffic, footprint)
//! lifted verbatim from the drivers. `planner::probe` simulates the
//! representatives once and scales by the counts.

use anyhow::{bail, Result};

use crate::cgra::{CgraConfig, MemStats};
use crate::conv::{patch_len, ConvShape};
use crate::cpu_ref::CpuModel;
use crate::isa::{Program, N_PES};
use crate::kernels::op_direct::{self, OpDirectLaunch};
use crate::kernels::wp::{self, WpLaunch};
use crate::kernels::{ip, op_im2col, HostCostModel, Mapping, MemLayout};

/// One structurally uniform group of launches: every member executes
/// the same step sequence; members differ only in address immediates.
pub(crate) struct LaunchClass {
    /// Diagnostic label, e.g. `wp/acc` or `op-direct/partial/first-tap`.
    pub label: String,
    /// How many launches of the full convolution belong to this class.
    pub count: u64,
    /// Representative launch programs (1–2, deduplicated); their
    /// simulated cost is averaged and scaled by `count`.
    pub probes: Vec<Program>,
}

/// The closed-form skeleton of one kernel execution: launch classes
/// plus every cost term the driver computes outside the simulator.
pub(crate) struct KernelModel {
    /// The concrete strategy modeled.
    pub mapping: Mapping,
    /// Total CGRA launches (0 for the CPU baseline).
    pub launches: u64,
    /// Launch classes; counts sum to `launches`.
    pub classes: Vec<LaunchClass>,
    /// Host cycles building im2col patches / prepared buffers
    /// (closed-form; 0 for the direct mappings).
    pub cpu_im2col_cycles: u64,
    /// Per-launch cap on im2col cycles hidden under the CGRA run
    /// (`copied × im2col_cycles_per_elem`, as in the drivers).
    pub hidden_cap_per_launch: u64,
    /// CPU-side memory traffic (im2col copies / CPU-baseline accesses).
    pub cpu_mem: MemStats,
    /// Memory footprint in bytes (the paper's "memory usage" metric).
    pub footprint_bytes: usize,
    /// Pure-CPU compute cycles (CPU baseline only).
    pub cpu_compute_cycles: u64,
}

impl KernelModel {
    /// Decompose `mapping` on `shape` under `cfg`. Fails with the same
    /// actionable memory-bound errors as the kernels themselves (the
    /// planner must refuse exactly the shapes the simulator refuses).
    pub fn for_mapping(
        mapping: Mapping,
        shape: &ConvShape,
        cfg: &CgraConfig,
    ) -> Result<KernelModel> {
        shape.validate()?;
        match mapping {
            Mapping::Wp => wp_model(shape, cfg),
            Mapping::OpDirect => op_direct_model(shape, cfg),
            Mapping::OpIm2col => op_im2col_model(shape, cfg),
            Mapping::Ip => ip_model(shape, cfg),
            Mapping::DwWp => dw_model(shape, cfg),
            Mapping::Cpu => cpu_baseline_model(shape, cfg),
            Mapping::Auto => bail!(
                "the cost model needs a concrete mapping — resolve Auto first \
                 (Planner::choose / Mapping::resolve)"
            ),
        }
    }
}

/// Keep the first occurrence of each probe parameter tuple (tiny shapes
/// collapse the "first" and "last" representatives onto one launch).
fn uniq<T: PartialEq>(v: Vec<T>) -> Vec<T> {
    let mut out: Vec<T> = Vec::new();
    for x in v {
        if !out.contains(&x) {
            out.push(x);
        }
    }
    out
}

fn wp_model(shape: &ConvShape, cfg: &CgraConfig) -> Result<KernelModel> {
    let layout = MemLayout::new(shape, 0, cfg)?;
    let (k, c) = (shape.k, shape.c);
    let mut classes = vec![LaunchClass {
        label: "wp/ci0".into(),
        count: k as u64,
        probes: uniq(vec![0, k - 1])
            .into_iter()
            .map(|kk| wp::build_program(shape, &layout, WpLaunch { k: kk, ci: 0, acc: false }))
            .collect(),
    }];
    if c > 1 {
        classes.push(LaunchClass {
            label: "wp/acc".into(),
            count: (k * (c - 1)) as u64,
            probes: uniq(vec![(0, 1), (k - 1, c - 1)])
                .into_iter()
                .map(|(kk, ci)| {
                    wp::build_program(shape, &layout, WpLaunch { k: kk, ci, acc: true })
                })
                .collect(),
        });
    }
    Ok(KernelModel {
        mapping: Mapping::Wp,
        launches: (k * c) as u64,
        classes,
        cpu_im2col_cycles: 0,
        hidden_cap_per_launch: 0,
        cpu_mem: MemStats::default(),
        footprint_bytes: shape.base_bytes(),
        cpu_compute_cycles: 0,
    })
}

/// One launch per channel, all of one structural kind — the WP `ci == 0`
/// class on the per-channel `C = K = 1` shape (see `kernels::dw`).
fn dw_model(shape: &ConvShape, cfg: &CgraConfig) -> Result<KernelModel> {
    use crate::kernels::dw;
    let lay = dw::layout(shape, cfg)?;
    let c = shape.c;
    let classes = vec![LaunchClass {
        label: "dw/ch".into(),
        count: c as u64,
        probes: uniq(vec![0, c - 1])
            .into_iter()
            .map(|g| dw::build_channel_program(shape, &lay, g))
            .collect(),
    }];
    Ok(KernelModel {
        mapping: Mapping::DwWp,
        launches: c as u64,
        classes,
        cpu_im2col_cycles: 0,
        hidden_cap_per_launch: 0,
        cpu_mem: MemStats::default(),
        footprint_bytes: dw::footprint_bytes(shape),
        cpu_compute_cycles: 0,
    })
}

/// Tile kinds of the output-channel mappings: `(label, representative
/// k-tile index, number of tiles of that kind)`.
fn tile_kinds(k: usize) -> Vec<(&'static str, usize, u64)> {
    let tiles = k.div_ceil(N_PES);
    let full = k / N_PES;
    let mut kinds = Vec::new();
    if full > 0 {
        kinds.push(("full", 0, full as u64));
    }
    if k % N_PES != 0 {
        kinds.push(("partial", tiles - 1, 1));
    }
    kinds
}

fn op_direct_model(shape: &ConvShape, cfg: &CgraConfig) -> Result<KernelModel> {
    let layout = MemLayout::new(shape, 0, cfg)?;
    let ox = shape.ox;
    let mut classes = Vec::new();
    for (kind, kt, n_tiles) in tile_kinds(shape.k) {
        classes.push(LaunchClass {
            label: format!("op-direct/{kind}/first-tap"),
            count: n_tiles * ox as u64,
            probes: uniq(vec![0, ox - 1])
                .into_iter()
                .map(|y| {
                    op_direct::build_program(shape, &layout, OpDirectLaunch { kt, fy: 0, fx: 0, y })
                })
                .collect(),
        });
        classes.push(LaunchClass {
            label: format!("op-direct/{kind}/acc-tap"),
            count: n_tiles * 8 * ox as u64,
            probes: uniq(vec![(1, 1, 0), (2, 2, ox - 1)])
                .into_iter()
                .map(|(fy, fx, y)| {
                    op_direct::build_program(shape, &layout, OpDirectLaunch { kt, fy, fx, y })
                })
                .collect(),
        });
    }
    Ok(KernelModel {
        mapping: Mapping::OpDirect,
        launches: (shape.k.div_ceil(N_PES) * 9 * ox) as u64,
        classes,
        cpu_im2col_cycles: 0,
        hidden_cap_per_launch: 0,
        cpu_mem: MemStats::default(),
        footprint_bytes: shape.base_bytes(),
        cpu_compute_cycles: 0,
    })
}

/// Representative pixel indices of one ping-pong parity: the first and
/// the last pixel using that patch slot.
fn parity_reps(pixels: usize, parity: usize) -> Vec<usize> {
    let last = if (pixels - 1) % 2 == parity { pixels - 1 } else { pixels - 2 };
    uniq(vec![parity, last])
}

fn op_im2col_model(shape: &ConvShape, cfg: &CgraConfig) -> Result<KernelModel> {
    let host = HostCostModel::default();
    let pl = patch_len(shape);
    let layout = MemLayout::new(shape, 2 * pl, cfg)?;
    let pixels = shape.ox * shape.oy;
    let launches = (shape.k.div_ceil(N_PES) * pixels) as u64;
    // Per-launch program construction lifted verbatim from
    // `op_im2col::run` (ping-pong slot, weight rows, idle-lane scratch).
    let build = |kt: usize, pix: usize| {
        op_im2col::build_program(
            shape,
            (layout.im2col + (pix % 2) * pl) as i32,
            |l| {
                let kp = (kt * N_PES + l).min(shape.k - 1);
                (layout.weights + kp * pl) as i32
            },
            |l| {
                let kp = kt * N_PES + l;
                if kp < shape.k {
                    (layout.output + kp * pixels + pix) as i32
                } else {
                    (layout.scratch + l) as i32
                }
            },
        )
    };
    let mut classes = Vec::new();
    for (kind, kt, n_tiles) in tile_kinds(shape.k) {
        for (parity, name, count) in
            [(0usize, "even", pixels.div_ceil(2)), (1, "odd", pixels / 2)]
        {
            if count == 0 {
                continue;
            }
            classes.push(LaunchClass {
                label: format!("op-im2col/{kind}/pix-{name}"),
                count: n_tiles * count as u64,
                probes: parity_reps(pixels, parity)
                    .into_iter()
                    .map(|pix| build(kt, pix))
                    .collect(),
            });
        }
    }
    // Host accounting, as in the driver: one-time HWC + weight-matrix
    // prep, then one full patch copy per launch (rebuilt per k-tile).
    let prep_elems = (shape.input_elems() + shape.weight_elems()) as u64;
    let cpu_copies = launches * pl as u64;
    Ok(KernelModel {
        mapping: Mapping::OpIm2col,
        launches,
        classes,
        cpu_im2col_cycles: prep_elems * host.prep_cycles_per_elem
            + cpu_copies * host.im2col_cycles_per_elem,
        hidden_cap_per_launch: pl as u64 * host.im2col_cycles_per_elem,
        cpu_mem: MemStats { loads: cpu_copies + prep_elems, stores: cpu_copies + prep_elems },
        footprint_bytes: shape.base_bytes() + 4 * 2 * pl,
        cpu_compute_cycles: 0,
    })
}

fn ip_model(shape: &ConvShape, cfg: &CgraConfig) -> Result<KernelModel> {
    let host = HostCostModel::default();
    let cp = ip::padded_c(shape);
    let patch_words = cp * 9;
    let padded_w = shape.c != cp;
    let aux_words = 2 * patch_words + if padded_w { shape.k * patch_words } else { 0 };
    let layout = MemLayout::new(shape, aux_words, cfg)?;
    let w_image_base =
        if padded_w { layout.im2col + 2 * patch_words } else { layout.weights };
    let pixels = shape.ox * shape.oy;
    let launches = (pixels * shape.k) as u64;
    let build = |pix: usize, kk: usize| {
        ip::build_program(
            shape,
            (layout.im2col + (pix % 2) * patch_words) as i32,
            (w_image_base + kk * patch_words) as i32,
            (layout.output + kk * pixels + pix) as i32,
        )
    };
    let mut classes = Vec::new();
    for (parity, name, count) in [(0usize, "even", pixels.div_ceil(2)), (1, "odd", pixels / 2)]
    {
        if count == 0 {
            continue;
        }
        let reps = parity_reps(pixels, parity);
        // Pair the first/last pixels with the first/last output channels
        // so the probes also sample the weight-row address spread.
        let ks = [0, shape.k - 1];
        classes.push(LaunchClass {
            label: format!("ip/pix-{name}"),
            count: (count * shape.k) as u64,
            probes: uniq(reps.into_iter().zip(ks).collect::<Vec<_>>())
                .into_iter()
                .map(|(pix, kk)| build(pix, kk))
                .collect(),
        });
    }
    // Host accounting from `ip::run`: HWC prep (+ padded weight image),
    // then the paper's per-(pixel, k) patch rebuild.
    let prep_elems =
        (shape.input_elems() + if padded_w { shape.k * shape.c * 9 } else { 0 }) as u64;
    let cpu_copies = launches * patch_words as u64;
    Ok(KernelModel {
        mapping: Mapping::Ip,
        launches,
        classes,
        cpu_im2col_cycles: prep_elems * host.prep_cycles_per_elem
            + cpu_copies * host.im2col_cycles_per_elem,
        hidden_cap_per_launch: patch_words as u64 * host.im2col_cycles_per_elem,
        cpu_mem: MemStats { loads: cpu_copies + prep_elems, stores: cpu_copies + prep_elems },
        footprint_bytes: shape.base_bytes() + 4 * aux_words,
        cpu_compute_cycles: 0,
    })
}

fn cpu_baseline_model(shape: &ConvShape, cfg: &CgraConfig) -> Result<KernelModel> {
    // The CPU shares the same 512 KiB system RAM (see `kernels::dispatch`).
    MemLayout::new(shape, 0, cfg)?;
    Ok(KernelModel {
        mapping: Mapping::Cpu,
        launches: 0,
        classes: Vec::new(),
        cpu_im2col_cycles: 0,
        hidden_cap_per_launch: 0,
        cpu_mem: MemStats { loads: 2 * shape.macs(), stores: shape.output_elems() as u64 },
        footprint_bytes: shape.base_bytes(),
        cpu_compute_cycles: CpuModel::default().conv_cycles(shape),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_counts_sum_to_launch_counts() {
        let cfg = CgraConfig::default();
        for shape in [
            ConvShape::baseline(),
            ConvShape::new3x3(17, 17, 5, 3),
            ConvShape::new3x3(1, 1, 1, 1),
            ConvShape::new3x3(3, 33, 2, 2),
        ] {
            for m in Mapping::CGRA {
                let km = KernelModel::for_mapping(m, &shape, &cfg).unwrap();
                let sum: u64 = km.classes.iter().map(|c| c.count).sum();
                assert_eq!(sum, km.launches, "{m} on {shape}");
                assert!(km.classes.iter().all(|c| !c.probes.is_empty()), "{m} on {shape}");
                assert!(km.classes.iter().all(|c| c.probes.len() <= 2), "{m} on {shape}");
            }
        }
    }

    #[test]
    fn launch_counts_match_the_drivers() {
        let cfg = CgraConfig::default();
        let s = ConvShape::new3x3(17, 17, 3, 4);
        // WP: one launch per (k, ci).
        assert_eq!(
            KernelModel::for_mapping(Mapping::Wp, &s, &cfg).unwrap().launches,
            17 * 17
        );
        // Conv-OP: tiles × 9 taps × output rows.
        assert_eq!(
            KernelModel::for_mapping(Mapping::OpDirect, &s, &cfg).unwrap().launches,
            2 * 9 * 3
        );
        // Im2col-OP: tiles × pixels.
        assert_eq!(
            KernelModel::for_mapping(Mapping::OpIm2col, &s, &cfg).unwrap().launches,
            2 * 12
        );
        // Im2col-IP: pixels × K.
        assert_eq!(KernelModel::for_mapping(Mapping::Ip, &s, &cfg).unwrap().launches, 12 * 17);
        // CPU: no launches, pure cycles.
        let cpu = KernelModel::for_mapping(Mapping::Cpu, &s, &cfg).unwrap();
        assert_eq!(cpu.launches, 0);
        assert_eq!(cpu.cpu_compute_cycles, CpuModel::default().conv_cycles(&s));
    }

    #[test]
    fn dw_model_is_one_class_with_one_launch_per_channel() {
        let cfg = CgraConfig::default();
        for c in [1usize, 2, 16] {
            let s = ConvShape::new3x3(c, c, 8, 8);
            let km = KernelModel::for_mapping(Mapping::DwWp, &s, &cfg).unwrap();
            assert_eq!(km.launches, c as u64);
            assert_eq!(km.classes.len(), 1);
            assert_eq!(km.classes[0].count, c as u64);
            // First/last channel dedup onto one probe when C == 1.
            assert_eq!(km.classes[0].probes.len(), if c == 1 { 1 } else { 2 });
            assert_eq!(km.footprint_bytes, crate::kernels::dw::footprint_bytes(&s));
        }
        // The depthwise convention is enforced.
        assert!(KernelModel::for_mapping(Mapping::DwWp, &ConvShape::new3x3(2, 3, 4, 4), &cfg)
            .is_err());
    }

    #[test]
    fn over_bound_shapes_are_refused_like_the_kernels() {
        let cfg = CgraConfig::default();
        let s = ConvShape::new3x3(144, 144, 64, 64);
        for m in [Mapping::Wp, Mapping::Ip, Mapping::Cpu] {
            assert!(KernelModel::for_mapping(m, &s, &cfg).is_err(), "{m}");
        }
    }

    #[test]
    fn auto_is_rejected() {
        let err = KernelModel::for_mapping(Mapping::Auto, &ConvShape::baseline(), &CgraConfig::default())
            .unwrap_err();
        assert!(err.to_string().contains("concrete"));
    }

    #[test]
    fn parity_reps_pick_first_and_last_of_each_slot() {
        assert_eq!(parity_reps(1, 0), vec![0]);
        assert_eq!(parity_reps(2, 0), vec![0]);
        assert_eq!(parity_reps(2, 1), vec![1]);
        assert_eq!(parity_reps(5, 0), vec![0, 4]);
        assert_eq!(parity_reps(5, 1), vec![1, 3]);
    }
}
