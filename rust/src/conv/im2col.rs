//! The Image-to-Column transformation (paper §2.2).
//!
//! Turns each 3-D input patch (C × Fx × Fy) into a 1-D vector so the
//! convolution becomes a vector–matrix product with sequential memory
//! accesses. Following the paper (citing CMSIS-NN), the reorder buffer is
//! built from the **HWC** layout; the patch vector order is
//! `(fy, fx, c)` — consecutive channels innermost — which makes each
//! patch a gather of `Fx·Fy` contiguous C-element runs.

use super::shape::{ConvShape, GenConvShape};
use super::tensor::TensorHwc;

/// Number of elements in one im2col patch vector: C × Fx × Fy.
pub fn patch_len(shape: &ConvShape) -> usize {
    shape.c * shape.fx * shape.fy
}

/// Write the patch vector for output pixel `(oy_row, ox_col)` —
/// i.e. input window rows `oy_row..oy_row+Fx`, cols `ox_col..ox_col+Fy` —
/// into `out` (must have length [`patch_len`]).
///
/// Returns the number of *CPU element copies* performed (= patch_len);
/// the host cost model charges im2col creation per copied element.
pub fn im2col_patch(
    shape: &ConvShape,
    input: &TensorHwc,
    oy_row: usize,
    ox_col: usize,
    out: &mut [i32],
) -> usize {
    assert_eq!(out.len(), patch_len(shape));
    let mut idx = 0;
    for fy in 0..shape.fx {
        for fx in 0..shape.fy {
            let base = input.offset(oy_row + fy, ox_col + fx, 0);
            out[idx..idx + shape.c].copy_from_slice(&input.data[base..base + shape.c]);
            idx += shape.c;
        }
    }
    idx
}

/// Build the full im2col matrix for all output pixels (row-major over
/// output pixels, each row one patch). Used by tests and the golden
/// im2col matmul; the mapping kernels stage patches incrementally the way
/// the paper describes (per output position for IP, per 16-output strip
/// for OP).
pub fn im2col_full(shape: &ConvShape, input: &TensorHwc) -> Vec<i32> {
    let pl = patch_len(shape);
    let mut m = vec![0i32; shape.ox * shape.oy * pl];
    for y in 0..shape.ox {
        for x in 0..shape.oy {
            let row = y * shape.oy + x;
            im2col_patch(shape, input, y, x, &mut m[row * pl..(row + 1) * pl]);
        }
    }
    m
}

/// Golden im2col convolution: im2col matrix × weight matrix, wrapping
/// int32. Output is CHW-ordered `(K, Ox, Oy)` flattened, matching
/// [`super::golden::conv2d`]'s layout so results compare directly.
pub fn conv2d_im2col(shape: &ConvShape, input: &TensorHwc, w_matrix: &[i32]) -> Vec<i32> {
    let pl = patch_len(shape);
    assert_eq!(w_matrix.len(), shape.k * pl);
    let patches = im2col_full(shape, input);
    let n_pix = shape.ox * shape.oy;
    let mut out = vec![0i32; shape.k * n_pix];
    for k in 0..shape.k {
        let wrow = &w_matrix[k * pl..(k + 1) * pl];
        for p in 0..n_pix {
            let patch = &patches[p * pl..(p + 1) * pl];
            let mut acc = 0i32;
            for i in 0..pl {
                acc = acc.wrapping_add(patch[i].wrapping_mul(wrow[i]));
            }
            out[k * n_pix + p] = acc;
        }
    }
    out
}

/// Patch length of one generalized im2col vector: `C/groups × Fx × Fy`
/// (a grouped layer's reorder buffer only stages its own group's
/// channels).
pub fn patch_len_general(shape: &GenConvShape) -> usize {
    shape.c_per_group() * shape.fx * shape.fy
}

/// Generalized im2col patch: gather the window of output pixel
/// `(oy_row, ox_col)` of `group` under stride/padding into `out`
/// (length [`patch_len_general`]), same `(fy, fx, c)` order as
/// [`im2col_patch`]. Taps that fall into the zero padding write zeros.
/// Returns the CPU element copies performed (= patch length — padding
/// zeros are stores too).
pub fn im2col_patch_general(
    shape: &GenConvShape,
    input: &TensorHwc,
    group: usize,
    oy_row: usize,
    ox_col: usize,
    out: &mut [i32],
) -> usize {
    assert_eq!(out.len(), patch_len_general(shape));
    let cg = shape.c_per_group();
    let (s, p) = (shape.stride, shape.pad as isize);
    let mut idx = 0;
    for fy in 0..shape.fx {
        for fx in 0..shape.fy {
            let iy = (oy_row * s + fy) as isize - p;
            let ix = (ox_col * s + fx) as isize - p;
            if iy < 0 || ix < 0 || iy >= input.h as isize || ix >= input.w as isize {
                out[idx..idx + cg].fill(0);
            } else {
                let base = input.offset(iy as usize, ix as usize, group * cg);
                out[idx..idx + cg].copy_from_slice(&input.data[base..base + cg]);
            }
            idx += cg;
        }
    }
    idx
}

/// Golden generalized im2col convolution: per group, im2col matrix ×
/// weight matrix, wrapping int32. `w_matrix` is the whole layer's
/// im2col weight matrix (`K` rows of [`patch_len_general`] columns, as
/// produced by `Weights::to_im2col_matrix` on `(K, C/groups, Fy, Fx)`
/// weights). Output is CHW-ordered `(K, Ox, Oy)` flattened, matching
/// [`super::golden::conv2d_general`].
pub fn conv2d_im2col_general(
    shape: &GenConvShape,
    input: &TensorHwc,
    w_matrix: &[i32],
) -> Vec<i32> {
    let pl = patch_len_general(shape);
    assert_eq!(w_matrix.len(), shape.k * pl);
    let (ox, oy) = (shape.ox(), shape.oy());
    let n_pix = ox * oy;
    let kg = shape.k_per_group();
    let mut patch = vec![0i32; pl];
    let mut out = vec![0i32; shape.k * n_pix];
    for group in 0..shape.groups {
        for y in 0..ox {
            for x in 0..oy {
                im2col_patch_general(shape, input, group, y, x, &mut patch);
                for k in group * kg..(group + 1) * kg {
                    let wrow = &w_matrix[k * pl..(k + 1) * pl];
                    let mut acc = 0i32;
                    for i in 0..pl {
                        acc = acc.wrapping_add(patch[i].wrapping_mul(wrow[i]));
                    }
                    out[k * n_pix + y * oy + x] = acc;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::golden::conv2d;
    use crate::conv::tensor::{random_input, random_weights};
    use crate::prop::Rng;

    #[test]
    fn patch_is_window_in_hwc_order() {
        let s = ConvShape::new3x3(2, 1, 2, 2);
        let mut input = TensorHwc::zeros(4, 4, 2);
        // Tag every element with a unique value y*100 + x*10 + c.
        for y in 0..4 {
            for x in 0..4 {
                for c in 0..2 {
                    input.set(y, x, c, (y * 100 + x * 10 + c) as i32);
                }
            }
        }
        let mut patch = vec![0; patch_len(&s)];
        im2col_patch(&s, &input, 1, 1, &mut patch);
        // First run: window element (fy=0, fx=0) = input (1,1): 110, 111.
        assert_eq!(&patch[..2], &[110, 111]);
        // Element (fy=2, fx=1) = input (3,2): index (2*3+1)*2 = 14.
        assert_eq!(&patch[14..16], &[320, 321]);
    }

    #[test]
    fn im2col_conv_matches_direct_conv() {
        let s = ConvShape::new3x3(3, 4, 5, 6);
        let mut rng = Rng::new(42);
        let input = random_input(&s, 50, &mut rng);
        let weights = random_weights(&s, 9, &mut rng);
        let direct = conv2d(&s, &input, &weights);
        let via_im2col = conv2d_im2col(&s, &input.to_hwc(), &weights.to_im2col_matrix());
        assert_eq!(direct.data, via_im2col);
    }

    #[test]
    fn full_matrix_dimensions() {
        let s = ConvShape::new3x3(2, 1, 3, 4);
        let input = TensorHwc::zeros(s.ih(), s.iw(), s.c);
        let m = im2col_full(&s, &input);
        assert_eq!(m.len(), 3 * 4 * patch_len(&s));
    }

    #[test]
    fn patch_copy_count_charged() {
        let s = ConvShape::new3x3(4, 1, 2, 2);
        let input = TensorHwc::zeros(s.ih(), s.iw(), s.c);
        let mut patch = vec![0; patch_len(&s)];
        let copied = im2col_patch(&s, &input, 0, 0, &mut patch);
        assert_eq!(copied, 36);
    }

    /// The generalized patch agrees with the basic one on stride-1 /
    /// pad-0 / groups-1 shapes, and pads with zeros otherwise.
    #[test]
    fn general_patch_degenerates_and_zero_pads() {
        let basic = ConvShape::new3x3(2, 1, 2, 2);
        let gen = crate::conv::GenConvShape::from_basic(&basic);
        let mut input = TensorHwc::zeros(4, 4, 2);
        for i in 0..input.data.len() {
            input.data[i] = i as i32 + 1;
        }
        let mut a = vec![0; patch_len(&basic)];
        let mut b = vec![0; patch_len_general(&gen)];
        im2col_patch(&basic, &input, 1, 1, &mut a);
        im2col_patch_general(&gen, &input, 0, 1, 1, &mut b);
        assert_eq!(a, b);
        // With pad 1, the (0,0) patch's first row/col taps are zeros.
        let padded = crate::conv::GenConvShape { pad: 1, ..gen };
        let mut p = vec![-1; patch_len_general(&padded)];
        im2col_patch_general(&padded, &input, 0, 0, 0, &mut p);
        // fy=0 row (3 taps x 2 channels) and the fx=0 taps are zero.
        assert_eq!(&p[..6], &[0; 6]);
        assert_eq!(&p[6..8], &[0, 0]); // (fy=1, fx=0)
        assert_eq!(p[8], input.at(0, 0, 0));
    }

    /// Generalized im2col matmul ≡ generalized direct convolution over
    /// a strided + padded + grouped shape.
    #[test]
    fn general_im2col_matches_general_direct() {
        use crate::conv::golden::conv2d_general;
        use crate::conv::{TensorChw, Weights};
        let shape = crate::conv::GenConvShape::new(4, 6, 9, 8, 3, 3, 2, 1, 2).unwrap();
        let mut rng = Rng::new(44);
        let input = TensorChw::random(shape.c, shape.ih, shape.iw, 50, &mut rng);
        let weights = Weights::random(shape.k, shape.c_per_group(), 3, 3, 9, &mut rng);
        let direct = conv2d_general(&shape, &input, &weights);
        let via = conv2d_im2col_general(&shape, &input.to_hwc(), &weights.to_im2col_matrix());
        assert_eq!(direct.data, via);
    }
}
