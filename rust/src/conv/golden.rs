//! Golden direct convolution — the bit-exact functional reference every
//! mapping kernel and the XLA artifact are checked against — plus its
//! stride/padding/groups generalization ([`conv2d_general`]) and the
//! depthwise special case ([`depthwise2d`]) the `nn` subsystem and the
//! `Dw-WP` kernel are checked against.

use super::shape::{ConvShape, GenConvShape};
use super::tensor::{TensorChw, Weights};

/// Direct 2-D convolution (valid padding, stride 1, groups 1), wrapping
/// int32 arithmetic. Input CHW `(C, ih, iw)`, weights `(K, C, Fy, Fx)`,
/// output CHW `(K, Ox, Oy)`.
pub fn conv2d(shape: &ConvShape, input: &TensorChw, weights: &Weights) -> TensorChw {
    assert_eq!(input.c, shape.c, "input channel mismatch");
    assert_eq!(input.h, shape.ih(), "input height mismatch");
    assert_eq!(input.w, shape.iw(), "input width mismatch");
    assert_eq!(weights.k, shape.k);
    assert_eq!(weights.c, shape.c);
    assert_eq!(weights.fy, shape.fx, "weights fy must equal shape fx (rows)");
    assert_eq!(weights.fx, shape.fy, "weights fx must equal shape fy (cols)");

    let mut out = TensorChw::zeros(shape.k, shape.ox, shape.oy);
    for k in 0..shape.k {
        for y in 0..shape.ox {
            for x in 0..shape.oy {
                let mut acc: i32 = 0;
                for c in 0..shape.c {
                    for fy in 0..shape.fx {
                        for fx in 0..shape.fy {
                            let iv = input.at(c, y + fy, x + fx);
                            let wv = weights.at(k, c, fy, fx);
                            acc = acc.wrapping_add(iv.wrapping_mul(wv));
                        }
                    }
                }
                out.set(k, y, x, acc);
            }
        }
    }
    out
}

/// Generalized direct convolution: stride, symmetric zero padding and
/// channel groups, wrapping int32 — the functional reference of the
/// `nn` subsystem. Input CHW `(C, ih, iw)`, weights `(K, C/groups, Fy,
/// Fx)`, output CHW `(K, Ox, Oy)`.
///
/// On a stride-1 / pad-0 / groups-1 / 3×3 shape this loop nest walks
/// exactly the same (k, y, x, c, fy, fx) order as [`conv2d`] with the
/// same wrapping arithmetic, so the results are bit-identical (pinned
/// by `stride1_pad0_groups1_is_bit_identical_to_conv2d` below).
pub fn conv2d_general(shape: &GenConvShape, input: &TensorChw, weights: &Weights) -> TensorChw {
    assert_eq!(input.c, shape.c, "input channel mismatch");
    assert_eq!(input.h, shape.ih, "input height mismatch");
    assert_eq!(input.w, shape.iw, "input width mismatch");
    assert_eq!(weights.k, shape.k);
    assert_eq!(weights.c, shape.c_per_group(), "weights must hold C/groups channels");
    assert_eq!(weights.fy, shape.fx, "weights fy must equal shape fx (rows)");
    assert_eq!(weights.fx, shape.fy, "weights fx must equal shape fy (cols)");

    let (ox, oy) = (shape.ox(), shape.oy());
    let (cg, kg) = (shape.c_per_group(), shape.k_per_group());
    let (s, p) = (shape.stride, shape.pad as isize);
    let mut out = TensorChw::zeros(shape.k, ox, oy);
    for k in 0..shape.k {
        let group = k / kg;
        for y in 0..ox {
            for x in 0..oy {
                let mut acc: i32 = 0;
                for c in 0..cg {
                    for fy in 0..shape.fx {
                        for fx in 0..shape.fy {
                            let iy = (y * s + fy) as isize - p;
                            let ix = (x * s + fx) as isize - p;
                            // Zero padding: out-of-bounds taps add 0.
                            if iy < 0
                                || ix < 0
                                || iy >= shape.ih as isize
                                || ix >= shape.iw as isize
                            {
                                continue;
                            }
                            let iv = input.at(group * cg + c, iy as usize, ix as usize);
                            let wv = weights.at(k, c, fy, fx);
                            acc = acc.wrapping_add(iv.wrapping_mul(wv));
                        }
                    }
                }
                out.set(k, y, x, acc);
            }
        }
    }
    out
}

/// Golden depthwise convolution (stride 1, valid padding): channel `c`
/// of the output is channel `c` of the input convolved with filter `c`
/// — the functional reference of the `Dw-WP` kernel. `shape` uses the
/// depthwise convention `k == c`; weights are `(C, 1, Fy, Fx)`.
/// Strided/padded depthwise layers are handled by the `nn` lowering
/// (pad the input, decimate the output) around this stride-1 core.
pub fn depthwise2d(shape: &ConvShape, input: &TensorChw, weights: &Weights) -> TensorChw {
    assert_eq!(shape.k, shape.c, "depthwise convention: K == C");
    assert_eq!(input.c, shape.c, "input channel mismatch");
    assert_eq!(input.h, shape.ih(), "input height mismatch");
    assert_eq!(input.w, shape.iw(), "input width mismatch");
    assert_eq!(weights.k, shape.c);
    assert_eq!(weights.c, 1, "depthwise weights hold one channel per filter");
    assert_eq!(weights.fy, shape.fx);
    assert_eq!(weights.fx, shape.fy);

    let mut out = TensorChw::zeros(shape.k, shape.ox, shape.oy);
    for c in 0..shape.c {
        for y in 0..shape.ox {
            for x in 0..shape.oy {
                let mut acc: i32 = 0;
                for fy in 0..shape.fx {
                    for fx in 0..shape.fy {
                        let iv = input.at(c, y + fy, x + fx);
                        let wv = weights.at(c, 0, fy, fx);
                        acc = acc.wrapping_add(iv.wrapping_mul(wv));
                    }
                }
                out.set(c, y, x, acc);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::Rng;

    /// Identity kernel (single 1 at the filter center) copies the
    /// interior of the input.
    #[test]
    fn identity_kernel() {
        let s = ConvShape::new3x3(1, 1, 3, 3);
        let mut rng = Rng::new(1);
        let input = TensorChw::random(1, 5, 5, 50, &mut rng);
        let mut w = Weights::zeros(1, 1, 3, 3);
        w.set(0, 0, 1, 1, 1);
        let out = conv2d(&s, &input, &w);
        for y in 0..3 {
            for x in 0..3 {
                assert_eq!(out.at(0, y, x), input.at(0, y + 1, x + 1));
            }
        }
    }

    /// All-ones kernel computes 3×3 box sums.
    #[test]
    fn box_sum_kernel() {
        let s = ConvShape::new3x3(1, 1, 2, 2);
        let input = TensorChw::from_vec(1, 4, 4, (1..=16).collect());
        let w = Weights::from_vec(1, 1, 3, 3, vec![1; 9]);
        let out = conv2d(&s, &input, &w);
        // Top-left 3x3 sum of 1..=16 grid: rows 1,2,3 / 5,6,7 / 9,10,11.
        assert_eq!(out.at(0, 0, 0), 1 + 2 + 3 + 5 + 6 + 7 + 9 + 10 + 11);
        assert_eq!(out.at(0, 1, 1), 6 + 7 + 8 + 10 + 11 + 12 + 14 + 15 + 16);
    }

    /// Linearity: conv(a+b) = conv(a) + conv(b) (wrapping).
    #[test]
    fn linear_in_input() {
        let s = ConvShape::new3x3(2, 2, 3, 4);
        let mut rng = Rng::new(7);
        let a = TensorChw::random(2, 5, 6, 100, &mut rng);
        let b = TensorChw::random(2, 5, 6, 100, &mut rng);
        let w = Weights::random(2, 2, 3, 3, 10, &mut rng);
        let mut ab = a.clone();
        for (x, y) in ab.data.iter_mut().zip(b.data.iter()) {
            *x = x.wrapping_add(*y);
        }
        let ca = conv2d(&s, &a, &w);
        let cb = conv2d(&s, &b, &w);
        let cab = conv2d(&s, &ab, &w);
        for i in 0..cab.data.len() {
            assert_eq!(cab.data[i], ca.data[i].wrapping_add(cb.data[i]));
        }
    }

    /// Channels accumulate: a 2-channel conv equals the sum of two
    /// 1-channel convs.
    #[test]
    fn channels_accumulate() {
        let s2 = ConvShape::new3x3(2, 1, 3, 3);
        let s1 = ConvShape::new3x3(1, 1, 3, 3);
        let mut rng = Rng::new(9);
        let input = TensorChw::random(2, 5, 5, 20, &mut rng);
        let w = Weights::random(1, 2, 3, 3, 5, &mut rng);
        let full = conv2d(&s2, &input, &w);

        let in0 = TensorChw::from_vec(1, 5, 5, input.data[..25].to_vec());
        let in1 = TensorChw::from_vec(1, 5, 5, input.data[25..].to_vec());
        let w0 = Weights::from_vec(1, 1, 3, 3, w.data[..9].to_vec());
        let w1 = Weights::from_vec(1, 1, 3, 3, w.data[9..].to_vec());
        let c0 = conv2d(&s1, &in0, &w0);
        let c1 = conv2d(&s1, &in1, &w1);
        for i in 0..full.data.len() {
            assert_eq!(full.data[i], c0.data[i].wrapping_add(c1.data[i]));
        }
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let s = ConvShape::new3x3(1, 1, 3, 3);
        let input = TensorChw::zeros(1, 4, 5); // wrong height
        let w = Weights::zeros(1, 1, 3, 3);
        let _ = conv2d(&s, &input, &w);
    }

    /// The generalized model degenerates to the paper's golden model
    /// bit for bit on stride-1 / pad-0 / groups-1 shapes (the key
    /// regression of the generalization).
    #[test]
    fn stride1_pad0_groups1_is_bit_identical_to_conv2d() {
        let basic = ConvShape::new3x3(3, 4, 5, 6);
        let gen = GenConvShape::from_basic(&basic);
        let mut rng = Rng::new(17);
        let input = TensorChw::random(basic.c, basic.ih(), basic.iw(), 80, &mut rng);
        let weights = Weights::random(basic.k, basic.c, 3, 3, 11, &mut rng);
        let a = conv2d(&basic, &input, &weights);
        let b = conv2d_general(&gen, &input, &weights);
        assert_eq!(a, b);
    }

    /// Stride-s output is the stride-1 output sampled every s pixels
    /// (same filter, same data) — the decimation identity the nn
    /// lowering relies on.
    #[test]
    fn strided_output_is_decimated_stride1_output() {
        let mut rng = Rng::new(23);
        let s1 = GenConvShape::new(2, 3, 9, 11, 3, 3, 1, 0, 1).unwrap();
        let s2 = GenConvShape { stride: 2, ..s1 };
        let input = TensorChw::random(2, 9, 11, 50, &mut rng);
        let w = Weights::random(3, 2, 3, 3, 9, &mut rng);
        let full = conv2d_general(&s1, &input, &w);
        let dec = conv2d_general(&s2, &input, &w);
        for k in 0..3 {
            for y in 0..s2.ox() {
                for x in 0..s2.oy() {
                    assert_eq!(dec.at(k, y, x), full.at(k, 2 * y, 2 * x));
                }
            }
        }
    }

    /// Padding by p equals convolving an explicitly zero-bordered input
    /// with no padding.
    #[test]
    fn padding_equals_explicit_zero_border() {
        let mut rng = Rng::new(29);
        let padded = GenConvShape::new(2, 2, 6, 7, 3, 3, 1, 1, 1).unwrap();
        let input = TensorChw::random(2, 6, 7, 40, &mut rng);
        let w = Weights::random(2, 2, 3, 3, 7, &mut rng);
        // Embed into an 8x9 zero tensor.
        let mut big = TensorChw::zeros(2, 8, 9);
        for c in 0..2 {
            for y in 0..6 {
                for x in 0..7 {
                    big.set(c, y + 1, x + 1, input.at(c, y, x));
                }
            }
        }
        let valid = GenConvShape::new(2, 2, 8, 9, 3, 3, 1, 0, 1).unwrap();
        let a = conv2d_general(&padded, &input, &w);
        let b = conv2d_general(&valid, &big, &w);
        assert_eq!(a, b);
    }

    /// A grouped conv is the channel-concatenation of per-group dense
    /// convs over the corresponding input slices.
    #[test]
    fn grouped_conv_is_concatenated_group_convs() {
        let mut rng = Rng::new(31);
        let g = GenConvShape::new(4, 6, 6, 6, 3, 3, 1, 0, 2).unwrap();
        let input = TensorChw::random(4, 6, 6, 30, &mut rng);
        let w = Weights::random(6, 2, 3, 3, 9, &mut rng); // C/groups = 2
        let whole = conv2d_general(&g, &input, &w);
        for group in 0..2usize {
            let sub = GenConvShape::new(2, 3, 6, 6, 3, 3, 1, 0, 1).unwrap();
            let in_slice = TensorChw::from_vec(
                2,
                6,
                6,
                input.data[group * 2 * 36..(group + 1) * 2 * 36].to_vec(),
            );
            let w_slice = Weights::from_vec(
                3,
                2,
                3,
                3,
                w.data[group * 3 * 18..(group + 1) * 3 * 18].to_vec(),
            );
            let part = conv2d_general(&sub, &in_slice, &w_slice);
            let out_base = group * 3 * whole.h * whole.w;
            assert_eq!(
                &whole.data[out_base..out_base + part.data.len()],
                &part.data[..],
                "group {group}"
            );
        }
    }

    /// Depthwise is the groups = C special case of the generalized
    /// model.
    #[test]
    fn depthwise_equals_grouped_conv_with_groups_c() {
        let mut rng = Rng::new(37);
        let basic = ConvShape::new3x3(5, 5, 4, 6);
        let gen = GenConvShape {
            groups: 5,
            ..GenConvShape::from_basic(&basic)
        };
        let input = TensorChw::random(5, 6, 8, 45, &mut rng);
        let w = Weights::random(5, 1, 3, 3, 9, &mut rng);
        let via_groups = conv2d_general(&gen, &input, &w);
        let via_depthwise = depthwise2d(&basic, &input, &w);
        assert_eq!(via_groups, via_depthwise);
    }
}
