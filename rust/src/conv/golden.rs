//! Golden direct convolution — the bit-exact functional reference every
//! mapping kernel and the XLA artifact are checked against.

use super::shape::ConvShape;
use super::tensor::{TensorChw, Weights};

/// Direct 2-D convolution (valid padding, stride 1, groups 1), wrapping
/// int32 arithmetic. Input CHW `(C, ih, iw)`, weights `(K, C, Fy, Fx)`,
/// output CHW `(K, Ox, Oy)`.
pub fn conv2d(shape: &ConvShape, input: &TensorChw, weights: &Weights) -> TensorChw {
    assert_eq!(input.c, shape.c, "input channel mismatch");
    assert_eq!(input.h, shape.ih(), "input height mismatch");
    assert_eq!(input.w, shape.iw(), "input width mismatch");
    assert_eq!(weights.k, shape.k);
    assert_eq!(weights.c, shape.c);
    assert_eq!(weights.fy, shape.fx, "weights fy must equal shape fx (rows)");
    assert_eq!(weights.fx, shape.fy, "weights fx must equal shape fy (cols)");

    let mut out = TensorChw::zeros(shape.k, shape.ox, shape.oy);
    for k in 0..shape.k {
        for y in 0..shape.ox {
            for x in 0..shape.oy {
                let mut acc: i32 = 0;
                for c in 0..shape.c {
                    for fy in 0..shape.fx {
                        for fx in 0..shape.fy {
                            let iv = input.at(c, y + fy, x + fx);
                            let wv = weights.at(k, c, fy, fx);
                            acc = acc.wrapping_add(iv.wrapping_mul(wv));
                        }
                    }
                }
                out.set(k, y, x, acc);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::Rng;

    /// Identity kernel (single 1 at the filter center) copies the
    /// interior of the input.
    #[test]
    fn identity_kernel() {
        let s = ConvShape::new3x3(1, 1, 3, 3);
        let mut rng = Rng::new(1);
        let input = TensorChw::random(1, 5, 5, 50, &mut rng);
        let mut w = Weights::zeros(1, 1, 3, 3);
        w.set(0, 0, 1, 1, 1);
        let out = conv2d(&s, &input, &w);
        for y in 0..3 {
            for x in 0..3 {
                assert_eq!(out.at(0, y, x), input.at(0, y + 1, x + 1));
            }
        }
    }

    /// All-ones kernel computes 3×3 box sums.
    #[test]
    fn box_sum_kernel() {
        let s = ConvShape::new3x3(1, 1, 2, 2);
        let input = TensorChw::from_vec(1, 4, 4, (1..=16).collect());
        let w = Weights::from_vec(1, 1, 3, 3, vec![1; 9]);
        let out = conv2d(&s, &input, &w);
        // Top-left 3x3 sum of 1..=16 grid: rows 1,2,3 / 5,6,7 / 9,10,11.
        assert_eq!(out.at(0, 0, 0), 1 + 2 + 3 + 5 + 6 + 7 + 9 + 10 + 11);
        assert_eq!(out.at(0, 1, 1), 6 + 7 + 8 + 10 + 11 + 12 + 14 + 15 + 16);
    }

    /// Linearity: conv(a+b) = conv(a) + conv(b) (wrapping).
    #[test]
    fn linear_in_input() {
        let s = ConvShape::new3x3(2, 2, 3, 4);
        let mut rng = Rng::new(7);
        let a = TensorChw::random(2, 5, 6, 100, &mut rng);
        let b = TensorChw::random(2, 5, 6, 100, &mut rng);
        let w = Weights::random(2, 2, 3, 3, 10, &mut rng);
        let mut ab = a.clone();
        for (x, y) in ab.data.iter_mut().zip(b.data.iter()) {
            *x = x.wrapping_add(*y);
        }
        let ca = conv2d(&s, &a, &w);
        let cb = conv2d(&s, &b, &w);
        let cab = conv2d(&s, &ab, &w);
        for i in 0..cab.data.len() {
            assert_eq!(cab.data[i], ca.data[i].wrapping_add(cb.data[i]));
        }
    }

    /// Channels accumulate: a 2-channel conv equals the sum of two
    /// 1-channel convs.
    #[test]
    fn channels_accumulate() {
        let s2 = ConvShape::new3x3(2, 1, 3, 3);
        let s1 = ConvShape::new3x3(1, 1, 3, 3);
        let mut rng = Rng::new(9);
        let input = TensorChw::random(2, 5, 5, 20, &mut rng);
        let w = Weights::random(1, 2, 3, 3, 5, &mut rng);
        let full = conv2d(&s2, &input, &w);

        let in0 = TensorChw::from_vec(1, 5, 5, input.data[..25].to_vec());
        let in1 = TensorChw::from_vec(1, 5, 5, input.data[25..].to_vec());
        let w0 = Weights::from_vec(1, 1, 3, 3, w.data[..9].to_vec());
        let w1 = Weights::from_vec(1, 1, 3, 3, w.data[9..].to_vec());
        let c0 = conv2d(&s1, &in0, &w0);
        let c1 = conv2d(&s1, &in1, &w1);
        for i in 0..full.data.len() {
            assert_eq!(full.data[i], c0.data[i].wrapping_add(c1.data[i]));
        }
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let s = ConvShape::new3x3(1, 1, 3, 3);
        let input = TensorChw::zeros(1, 4, 5); // wrong height
        let w = Weights::zeros(1, 1, 3, 3);
        let _ = conv2d(&s, &input, &w);
    }
}
