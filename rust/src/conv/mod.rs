//! Convolution substrate: shapes, tensors/layouts, golden models, im2col.

mod golden;
mod im2col;
mod shape;
mod tensor;

pub use golden::conv2d;
pub use im2col::{conv2d_im2col, im2col_full, im2col_patch, patch_len};
pub use shape::ConvShape;
pub use tensor::{random_input, random_weights, TensorChw, TensorHwc, Weights};
