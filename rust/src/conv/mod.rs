//! Convolution substrate: shapes, tensors/layouts, golden models, im2col
//! — both the paper's stride-1/valid/groups-1 fast path and the
//! generalized (stride / padding / groups / depthwise) forms the `nn`
//! subsystem lowers from.

mod golden;
mod im2col;
mod shape;
mod tensor;

pub use golden::{conv2d, conv2d_general, depthwise2d};
pub use im2col::{
    conv2d_im2col, conv2d_im2col_general, im2col_full, im2col_patch, im2col_patch_general,
    patch_len, patch_len_general,
};
pub use shape::{ConvShape, GenConvShape, MAX_DIM};
pub use tensor::{
    random_depthwise_weights, random_input, random_weights, TensorChw, TensorHwc, Weights,
};
