//! Convolution layer hyper-parameters (the paper's sweep axes).

use anyhow::{ensure, Result};

/// Shape of a 2D convolution, groups = 1, stride 1, no padding, as in
/// the paper (§2.2: "we always consider convolutions with groups = 1 and
/// a filter of dimension Fx × Fy = 3 × 3").
///
/// Naming follows the paper: `C` input channels, `K` output channels,
/// `Ox` output rows, `Oy` output columns, `Fx`/`Fy` filter rows/columns.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct ConvShape {
    /// Input channels (C).
    pub c: usize,
    /// Output channels (K).
    pub k: usize,
    /// Output rows (Ox).
    pub ox: usize,
    /// Output columns (Oy).
    pub oy: usize,
    /// Filter rows (Fx).
    pub fx: usize,
    /// Filter columns (Fy).
    pub fy: usize,
}

impl ConvShape {
    /// The paper's baseline layer: C = K = Ox = Oy = 16, 3×3 filter.
    pub fn baseline() -> ConvShape {
        ConvShape { c: 16, k: 16, ox: 16, oy: 16, fx: 3, fy: 3 }
    }

    /// A 3×3 convolution with the given C/K/Ox/Oy.
    pub fn new3x3(c: usize, k: usize, ox: usize, oy: usize) -> ConvShape {
        ConvShape { c, k, ox, oy, fx: 3, fy: 3 }
    }

    /// Input rows (valid convolution): Ox + Fx − 1.
    pub fn ih(&self) -> usize {
        self.ox + self.fx - 1
    }

    /// Input columns: Oy + Fy − 1.
    pub fn iw(&self) -> usize {
        self.oy + self.fy - 1
    }

    /// Total multiply-accumulate operations of the layer.
    pub fn macs(&self) -> u64 {
        (self.c * self.k * self.ox * self.oy * self.fx * self.fy) as u64
    }

    /// Input tensor elements (C × ih × iw).
    pub fn input_elems(&self) -> usize {
        self.c * self.ih() * self.iw()
    }

    /// Weight tensor elements (K × C × Fx × Fy).
    pub fn weight_elems(&self) -> usize {
        self.k * self.c * self.fx * self.fy
    }

    /// Output tensor elements (K × Ox × Oy).
    pub fn output_elems(&self) -> usize {
        self.k * self.ox * self.oy
    }

    /// Baseline memory footprint in bytes (int32): inputs + weights +
    /// outputs. Mapping strategies add their reorder buffers on top (see
    /// `metrics::memory_footprint`).
    pub fn base_bytes(&self) -> usize {
        4 * (self.input_elems() + self.weight_elems() + self.output_elems())
    }

    /// Validity for the kernels in this repo.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.c >= 1 && self.k >= 1, "need at least one channel");
        ensure!(self.ox >= 1 && self.oy >= 1, "need a non-empty output");
        ensure!(
            self.fx == 3 && self.fy == 3,
            "the paper's kernels target 3x3 filters (got {}x{})",
            self.fx,
            self.fy
        );
        Ok(())
    }

    /// Short display id, e.g. `c16k16o16x16`.
    pub fn id(&self) -> String {
        format!("c{}k{}o{}x{}", self.c, self.k, self.ox, self.oy)
    }
}

impl std::fmt::Display for ConvShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "C={} K={} Ox={} Oy={} F={}x{}",
            self.c, self.k, self.ox, self.oy, self.fx, self.fy
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_matches_paper() {
        let s = ConvShape::baseline();
        assert_eq!((s.c, s.k, s.ox, s.oy), (16, 16, 16, 16));
        assert_eq!(s.ih(), 18);
        assert_eq!(s.iw(), 18);
        assert_eq!(s.macs(), 16 * 16 * 16 * 16 * 9);
    }

    #[test]
    fn element_counts() {
        let s = ConvShape::new3x3(2, 3, 4, 5);
        assert_eq!(s.input_elems(), 2 * 6 * 7);
        assert_eq!(s.weight_elems(), 3 * 2 * 9);
        assert_eq!(s.output_elems(), 3 * 4 * 5);
        assert_eq!(s.base_bytes(), 4 * (84 + 54 + 60));
    }

    #[test]
    fn validation() {
        assert!(ConvShape::baseline().validate().is_ok());
        assert!(ConvShape { fx: 5, ..ConvShape::baseline() }.validate().is_err());
        assert!(ConvShape { c: 0, ..ConvShape::baseline() }.validate().is_err());
    }

    #[test]
    fn display_and_id() {
        let s = ConvShape::baseline();
        assert_eq!(s.id(), "c16k16o16x16");
        assert!(s.to_string().contains("F=3x3"));
    }
}
