//! Convolution layer hyper-parameters: the paper's stride-1 / valid /
//! groups-1 [`ConvShape`] (the sweep axes, and the shape every cache and
//! planner key is built from), plus the generalized [`GenConvShape`]
//! the `nn` layer-graph subsystem lowers from (stride / padding /
//! groups / 1×1 filters).

use anyhow::{ensure, Result};

/// Upper bound on any single shape dimension. Far beyond anything the
/// 512 KiB memory bound admits, but low enough that every derived
/// quantity (`macs`, element counts, byte footprints) fits u64/usize
/// arithmetic with room to spare, so validated shapes can never
/// overflow downstream.
pub const MAX_DIM: usize = 4096;

/// Shape of a 2D convolution, groups = 1, stride 1, no padding, as in
/// the paper (§2.2: "we always consider convolutions with groups = 1 and
/// a filter of dimension Fx × Fy = 3 × 3").
///
/// Naming follows the paper: `C` input channels, `K` output channels,
/// `Ox` output rows, `Oy` output columns, `Fx`/`Fy` filter rows/columns.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct ConvShape {
    /// Input channels (C).
    pub c: usize,
    /// Output channels (K).
    pub k: usize,
    /// Output rows (Ox).
    pub ox: usize,
    /// Output columns (Oy).
    pub oy: usize,
    /// Filter rows (Fx).
    pub fx: usize,
    /// Filter columns (Fy).
    pub fy: usize,
}

impl ConvShape {
    /// The paper's baseline layer: C = K = Ox = Oy = 16, 3×3 filter.
    pub fn baseline() -> ConvShape {
        ConvShape { c: 16, k: 16, ox: 16, oy: 16, fx: 3, fy: 3 }
    }

    /// A 3×3 convolution with the given C/K/Ox/Oy.
    pub fn new3x3(c: usize, k: usize, ox: usize, oy: usize) -> ConvShape {
        ConvShape { c, k, ox, oy, fx: 3, fy: 3 }
    }

    /// The validating constructor: a 3×3 shape, rejected up front when
    /// any dimension is zero or exceeds [`MAX_DIM`] — an actionable
    /// error instead of a downstream panic/overflow in `macs` /
    /// `input_elems`. Paths that take dimensions from outside the crate
    /// (the CLI, the `nn` lowering) build shapes through this.
    pub fn checked(c: usize, k: usize, ox: usize, oy: usize) -> Result<ConvShape> {
        let s = ConvShape::new3x3(c, k, ox, oy);
        s.validate()?;
        Ok(s)
    }

    /// Input rows (valid convolution): Ox + Fx − 1.
    pub fn ih(&self) -> usize {
        self.ox + self.fx - 1
    }

    /// Input columns: Oy + Fy − 1.
    pub fn iw(&self) -> usize {
        self.oy + self.fy - 1
    }

    /// Total multiply-accumulate operations of the layer. Computed in
    /// u64 so even unvalidated (but [`MAX_DIM`]-bounded) shapes cannot
    /// overflow.
    pub fn macs(&self) -> u64 {
        self.c as u64
            * self.k as u64
            * self.ox as u64
            * self.oy as u64
            * self.fx as u64
            * self.fy as u64
    }

    /// Input tensor elements (C × ih × iw).
    pub fn input_elems(&self) -> usize {
        self.c * self.ih() * self.iw()
    }

    /// Weight tensor elements (K × C × Fx × Fy).
    pub fn weight_elems(&self) -> usize {
        self.k * self.c * self.fx * self.fy
    }

    /// Output tensor elements (K × Ox × Oy).
    pub fn output_elems(&self) -> usize {
        self.k * self.ox * self.oy
    }

    /// Baseline memory footprint in bytes (int32): inputs + weights +
    /// outputs. Mapping strategies add their reorder buffers on top (see
    /// `metrics::memory_footprint`).
    pub fn base_bytes(&self) -> usize {
        4 * (self.input_elems() + self.weight_elems() + self.output_elems())
    }

    /// Validity for the kernels in this repo: non-zero channels and
    /// output, 3×3 filter, every dimension within [`MAX_DIM`] (so no
    /// derived count can overflow).
    pub fn validate(&self) -> Result<()> {
        ensure!(self.c >= 1 && self.k >= 1, "need at least one channel");
        ensure!(self.ox >= 1 && self.oy >= 1, "need a non-empty output");
        ensure!(
            self.fx == 3 && self.fy == 3,
            "the paper's kernels target 3x3 filters (got {}x{})",
            self.fx,
            self.fy
        );
        for (name, v) in [("C", self.c), ("K", self.k), ("Ox", self.ox), ("Oy", self.oy)] {
            ensure!(
                v <= MAX_DIM,
                "{name}={v} exceeds the {MAX_DIM} per-dimension limit (any such layer \
                 is far past the 512 KiB memory bound anyway)"
            );
        }
        Ok(())
    }

    /// Short display id, e.g. `c16k16o16x16`.
    pub fn id(&self) -> String {
        format!("c{}k{}o{}x{}", self.c, self.k, self.ox, self.oy)
    }
}

impl std::fmt::Display for ConvShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "C={} K={} Ox={} Oy={} F={}x{}",
            self.c, self.k, self.ox, self.oy, self.fx, self.fy
        )
    }
}

/// A generalized 2-D convolution shape: stride, zero padding, grouped
/// channels, and 3×3 **or 1×1** filters — the layer vocabulary of the
/// `nn` subsystem (MobileNet-style edge networks).
///
/// Unlike [`ConvShape`] (output-driven: `Ox`/`Oy` given, input derived)
/// this is *input-driven*: the input spatial size `ih × iw` is given and
/// the output size follows from stride/padding, the way network layers
/// chain. A `GenConvShape` with stride 1, no padding, one group and a
/// 3×3 filter is exactly a [`ConvShape`] ([`GenConvShape::to_basic`]),
/// and that `ConvShape` is what the lowering hands to the engine — so
/// every cache and planner key of the stride-1 fast path is unchanged.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct GenConvShape {
    /// Input channels (C).
    pub c: usize,
    /// Output channels (K).
    pub k: usize,
    /// Input rows (pre-padding).
    pub ih: usize,
    /// Input columns (pre-padding).
    pub iw: usize,
    /// Filter rows (3 or 1).
    pub fx: usize,
    /// Filter columns (3 or 1).
    pub fy: usize,
    /// Stride (both spatial dimensions).
    pub stride: usize,
    /// Zero padding (both spatial dimensions, symmetric).
    pub pad: usize,
    /// Channel groups: input channels split into `groups` blocks of
    /// `C/groups`, each convolved with its own `K/groups` filters.
    /// `groups == c` (with `k == c`) is depthwise.
    pub groups: usize,
}

impl GenConvShape {
    /// Validating constructor (the only way the `nn` subsystem builds
    /// shapes): rejects zero dimensions, dimensions past [`MAX_DIM`],
    /// filters other than 3×3 / 1×1, groups that do not divide both
    /// channel counts, and windows that do not fit the padded input.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        c: usize,
        k: usize,
        ih: usize,
        iw: usize,
        fx: usize,
        fy: usize,
        stride: usize,
        pad: usize,
        groups: usize,
    ) -> Result<GenConvShape> {
        let s = GenConvShape { c, k, ih, iw, fx, fy, stride, pad, groups };
        s.validate()?;
        Ok(s)
    }

    /// A stride-1 / no-padding / single-group 3×3 shape equivalent to
    /// `basic` (the round trip [`GenConvShape::to_basic`] inverts).
    pub fn from_basic(basic: &ConvShape) -> GenConvShape {
        GenConvShape {
            c: basic.c,
            k: basic.k,
            ih: basic.ih(),
            iw: basic.iw(),
            fx: basic.fx,
            fy: basic.fy,
            stride: 1,
            pad: 0,
            groups: 1,
        }
    }

    /// The exact [`ConvShape`] this layer *is* when it needs no
    /// generalization (stride 1, no padding, one group, 3×3). `None`
    /// otherwise. The lowering uses this so stride-1 layers hit the
    /// same engine/cache/planner keys as before the generalization.
    pub fn to_basic(&self) -> Option<ConvShape> {
        if self.stride == 1 && self.pad == 0 && self.groups == 1 && (self.fx, self.fy) == (3, 3)
        {
            Some(ConvShape {
                c: self.c,
                k: self.k,
                ox: self.ox(),
                oy: self.oy(),
                fx: 3,
                fy: 3,
            })
        } else {
            None
        }
    }

    /// Output rows: `(ih + 2·pad − fx) / stride + 1`.
    pub fn ox(&self) -> usize {
        (self.ih + 2 * self.pad - self.fx) / self.stride + 1
    }

    /// Output columns: `(iw + 2·pad − fy) / stride + 1`.
    pub fn oy(&self) -> usize {
        (self.iw + 2 * self.pad - self.fy) / self.stride + 1
    }

    /// Input channels per group.
    pub fn c_per_group(&self) -> usize {
        self.c / self.groups
    }

    /// Output channels per group.
    pub fn k_per_group(&self) -> usize {
        self.k / self.groups
    }

    /// Whether this is a depthwise layer (one input channel per group,
    /// one filter per channel).
    pub fn is_depthwise(&self) -> bool {
        self.groups == self.c && self.k == self.c && self.groups > 1
    }

    /// True multiply-accumulates of the layer (group-aware — a grouped
    /// layer does `1/groups` the work of its dense counterpart).
    pub fn macs(&self) -> u64 {
        self.c_per_group() as u64
            * self.k as u64
            * self.ox() as u64
            * self.oy() as u64
            * self.fx as u64
            * self.fy as u64
    }

    /// Input tensor elements (pre-padding).
    pub fn input_elems(&self) -> usize {
        self.c * self.ih * self.iw
    }

    /// Weight tensor elements: `K × C/groups × Fx × Fy`.
    pub fn weight_elems(&self) -> usize {
        self.k * self.c_per_group() * self.fx * self.fy
    }

    /// Output tensor elements.
    pub fn output_elems(&self) -> usize {
        self.k * self.ox() * self.oy()
    }

    /// Validity (see [`GenConvShape::new`]).
    pub fn validate(&self) -> Result<()> {
        ensure!(self.c >= 1 && self.k >= 1, "need at least one channel");
        ensure!(self.ih >= 1 && self.iw >= 1, "need a non-empty input");
        ensure!(self.stride >= 1, "stride must be at least 1");
        ensure!(
            (self.fx, self.fy) == (3, 3) || (self.fx, self.fy) == (1, 1),
            "the nn lowering supports 3x3 and 1x1 filters (got {}x{})",
            self.fx,
            self.fy
        );
        ensure!(self.groups >= 1, "need at least one group");
        ensure!(
            self.c % self.groups == 0 && self.k % self.groups == 0,
            "groups={} must divide both C={} and K={}",
            self.groups,
            self.c,
            self.k
        );
        ensure!(
            self.ih + 2 * self.pad >= self.fx && self.iw + 2 * self.pad >= self.fy,
            "padded input {}x{} is smaller than the {}x{} filter",
            self.ih + 2 * self.pad,
            self.iw + 2 * self.pad,
            self.fx,
            self.fy
        );
        for (name, v) in [
            ("C", self.c),
            ("K", self.k),
            ("ih", self.ih),
            ("iw", self.iw),
            ("stride", self.stride),
            ("pad", self.pad),
        ] {
            ensure!(
                v <= MAX_DIM,
                "{name}={v} exceeds the {MAX_DIM} per-dimension limit (any such layer \
                 is far past the 512 KiB memory bound anyway)"
            );
        }
        Ok(())
    }

    /// Short display id, e.g. `c8k16i32x32f3s2p1g1`.
    pub fn id(&self) -> String {
        format!(
            "c{}k{}i{}x{}f{}s{}p{}g{}",
            self.c, self.k, self.ih, self.iw, self.fx, self.stride, self.pad, self.groups
        )
    }
}

impl std::fmt::Display for GenConvShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "C={} K={} in={}x{} F={}x{} s={} p={} g={}",
            self.c, self.k, self.ih, self.iw, self.fx, self.fy, self.stride, self.pad, self.groups
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_matches_paper() {
        let s = ConvShape::baseline();
        assert_eq!((s.c, s.k, s.ox, s.oy), (16, 16, 16, 16));
        assert_eq!(s.ih(), 18);
        assert_eq!(s.iw(), 18);
        assert_eq!(s.macs(), 16 * 16 * 16 * 16 * 9);
    }

    #[test]
    fn element_counts() {
        let s = ConvShape::new3x3(2, 3, 4, 5);
        assert_eq!(s.input_elems(), 2 * 6 * 7);
        assert_eq!(s.weight_elems(), 3 * 2 * 9);
        assert_eq!(s.output_elems(), 3 * 4 * 5);
        assert_eq!(s.base_bytes(), 4 * (84 + 54 + 60));
    }

    #[test]
    fn validation() {
        assert!(ConvShape::baseline().validate().is_ok());
        assert!(ConvShape { fx: 5, ..ConvShape::baseline() }.validate().is_err());
        assert!(ConvShape { c: 0, ..ConvShape::baseline() }.validate().is_err());
    }

    #[test]
    fn display_and_id() {
        let s = ConvShape::baseline();
        assert_eq!(s.id(), "c16k16o16x16");
        assert!(s.to_string().contains("F=3x3"));
    }

    #[test]
    fn checked_constructor_rejects_zero_and_oversized_dims() {
        assert!(ConvShape::checked(16, 16, 16, 16).is_ok());
        for (c, k, ox, oy) in [(0, 1, 1, 1), (1, 0, 1, 1), (1, 1, 0, 1), (1, 1, 1, 0)] {
            let err = format!("{:#}", ConvShape::checked(c, k, ox, oy).unwrap_err());
            assert!(
                err.contains("channel") || err.contains("output"),
                "zero dim must be actionable: {err}"
            );
        }
        let err = format!("{:#}", ConvShape::checked(MAX_DIM + 1, 1, 1, 1).unwrap_err());
        assert!(err.contains("per-dimension limit"), "{err}");
        // The validated bound keeps macs() exact in u64.
        let big = ConvShape::checked(MAX_DIM, MAX_DIM, MAX_DIM, MAX_DIM).unwrap();
        assert_eq!(big.macs(), 9 * (MAX_DIM as u64).pow(4));
    }

    #[test]
    fn gen_shape_output_arithmetic() {
        // 32x32 input, 3x3, stride 2, pad 1 -> 16x16 (the MobileNet rule).
        let g = GenConvShape::new(3, 8, 32, 32, 3, 3, 2, 1, 1).unwrap();
        assert_eq!((g.ox(), g.oy()), (16, 16));
        // Valid stride-1: matches ConvShape's input/output relation.
        let g = GenConvShape::new(2, 4, 18, 18, 3, 3, 1, 0, 1).unwrap();
        assert_eq!((g.ox(), g.oy()), (16, 16));
        // 1x1 pointwise preserves the spatial size.
        let g = GenConvShape::new(8, 16, 7, 9, 1, 1, 1, 0, 1).unwrap();
        assert_eq!((g.ox(), g.oy()), (7, 9));
        assert_eq!(g.weight_elems(), 16 * 8);
    }

    #[test]
    fn gen_shape_round_trips_the_basic_shape() {
        let basic = ConvShape::new3x3(5, 7, 11, 13);
        let g = GenConvShape::from_basic(&basic);
        assert_eq!(g.to_basic(), Some(basic));
        assert_eq!(g.macs(), basic.macs());
        assert_eq!(g.input_elems(), basic.input_elems());
        assert_eq!(g.weight_elems(), basic.weight_elems());
        assert_eq!(g.output_elems(), basic.output_elems());
        // Any generalization breaks the fast path.
        assert_eq!(GenConvShape { stride: 2, ..g }.to_basic(), None);
        assert_eq!(GenConvShape { pad: 1, ..g }.to_basic(), None);
        assert_eq!(GenConvShape { c: 4, k: 4, groups: 2, ..g }.to_basic(), None);
    }

    #[test]
    fn gen_shape_groups_and_depthwise() {
        let g = GenConvShape::new(8, 8, 10, 10, 3, 3, 1, 1, 8).unwrap();
        assert!(g.is_depthwise());
        assert_eq!((g.c_per_group(), g.k_per_group()), (1, 1));
        // Depthwise does C× less work than the dense layer.
        let dense = GenConvShape { groups: 1, ..g };
        assert_eq!(dense.macs(), 8 * g.macs());
        // Groups must divide the channel counts.
        assert!(GenConvShape::new(8, 8, 10, 10, 3, 3, 1, 0, 3).is_err());
        assert!(GenConvShape::new(6, 8, 10, 10, 3, 3, 1, 0, 2).is_ok());
    }

    #[test]
    fn gen_shape_rejects_bad_windows_and_filters() {
        // 2x2 padded input smaller than the 3x3 filter.
        assert!(GenConvShape::new(1, 1, 2, 2, 3, 3, 1, 0, 1).is_err());
        // Padding can rescue it.
        assert!(GenConvShape::new(1, 1, 2, 2, 3, 3, 1, 1, 1).is_ok());
        // Only 3x3 and 1x1 filters lower onto the kernels.
        assert!(GenConvShape::new(1, 1, 8, 8, 5, 5, 1, 0, 1).is_err());
        assert!(GenConvShape::new(1, 1, 8, 8, 3, 3, 0, 0, 1).is_err());
    }

    #[test]
    fn gen_shape_display_and_id() {
        let g = GenConvShape::new(8, 16, 32, 32, 3, 3, 2, 1, 1).unwrap();
        assert_eq!(g.id(), "c8k16i32x32f3s2p1g1");
        assert!(g.to_string().contains("s=2"));
    }
}
