//! Int32 tensors with the two data layouts the paper compares.
//!
//! - **CHW** (channel-major): the layout that minimizes addressing
//!   overhead for *direct* convolution (paper §2.2, citing CMSIS-NN);
//!   used by `WP` and `OP-direct`.
//! - **HWC** (channel-last): the layout the Im2col reorder buffer is
//!   cheapest to build from; used by `IP` and `OP-im2col`.
//!
//! All data is `i32` (the paper's kernels use 32-bit integer data) and
//! all arithmetic downstream is wrapping, so the simulator, the Rust
//! golden model and the XLA artifact agree bit-exactly.

use crate::prop::Rng;

use super::shape::ConvShape;

/// Dense 3-D int32 tensor in **CHW** order: index `(c, y, x)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorChw {
    /// Channels.
    pub c: usize,
    /// Rows.
    pub h: usize,
    /// Columns.
    pub w: usize,
    /// Row-major storage, length `c*h*w`.
    pub data: Vec<i32>,
}

impl TensorChw {
    /// Zero-filled tensor.
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        TensorChw { c, h, w, data: vec![0; c * h * w] }
    }

    /// From existing data (length-checked).
    pub fn from_vec(c: usize, h: usize, w: usize, data: Vec<i32>) -> Self {
        assert_eq!(data.len(), c * h * w, "CHW data length mismatch");
        TensorChw { c, h, w, data }
    }

    /// Linear offset of `(ci, y, x)`.
    #[inline]
    pub fn offset(&self, ci: usize, y: usize, x: usize) -> usize {
        debug_assert!(ci < self.c && y < self.h && x < self.w);
        (ci * self.h + y) * self.w + x
    }

    /// Element accessor.
    #[inline]
    pub fn at(&self, ci: usize, y: usize, x: usize) -> i32 {
        self.data[self.offset(ci, y, x)]
    }

    /// Element setter.
    #[inline]
    pub fn set(&mut self, ci: usize, y: usize, x: usize, v: i32) {
        let o = self.offset(ci, y, x);
        self.data[o] = v;
    }

    /// Convert to HWC.
    pub fn to_hwc(&self) -> TensorHwc {
        let mut out = TensorHwc::zeros(self.h, self.w, self.c);
        for ci in 0..self.c {
            for y in 0..self.h {
                for x in 0..self.w {
                    out.set(y, x, ci, self.at(ci, y, x));
                }
            }
        }
        out
    }

    /// Deterministic random tensor with bounded magnitude (|v| ≤ `mag`).
    pub fn random(c: usize, h: usize, w: usize, mag: i32, rng: &mut Rng) -> Self {
        let data =
            (0..c * h * w).map(|_| rng.range_i64(-mag as i64, mag as i64) as i32).collect();
        TensorChw { c, h, w, data }
    }
}

/// Dense 3-D int32 tensor in **HWC** order: index `(y, x, c)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorHwc {
    /// Rows.
    pub h: usize,
    /// Columns.
    pub w: usize,
    /// Channels.
    pub c: usize,
    /// Storage, length `h*w*c`.
    pub data: Vec<i32>,
}

impl TensorHwc {
    /// Zero-filled tensor.
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        TensorHwc { h, w, c, data: vec![0; h * w * c] }
    }

    /// Linear offset of `(y, x, ci)`.
    #[inline]
    pub fn offset(&self, y: usize, x: usize, ci: usize) -> usize {
        debug_assert!(y < self.h && x < self.w && ci < self.c);
        (y * self.w + x) * self.c + ci
    }

    /// Element accessor.
    #[inline]
    pub fn at(&self, y: usize, x: usize, ci: usize) -> i32 {
        self.data[self.offset(y, x, ci)]
    }

    /// Element setter.
    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ci: usize, v: i32) {
        let o = self.offset(y, x, ci);
        self.data[o] = v;
    }

    /// Convert to CHW.
    pub fn to_chw(&self) -> TensorChw {
        let mut out = TensorChw::zeros(self.c, self.h, self.w);
        for y in 0..self.h {
            for x in 0..self.w {
                for ci in 0..self.c {
                    out.set(ci, y, x, self.at(y, x, ci));
                }
            }
        }
        out
    }
}

/// Convolution weights in **K-C-Fy-Fx** order (the CHW-direct layout):
/// index `(k, c, fy, fx)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Weights {
    /// Output channels.
    pub k: usize,
    /// Input channels.
    pub c: usize,
    /// Filter rows.
    pub fy: usize,
    /// Filter columns.
    pub fx: usize,
    /// Storage, length `k*c*fy*fx`.
    pub data: Vec<i32>,
}

impl Weights {
    /// Zero-filled weights.
    pub fn zeros(k: usize, c: usize, fy: usize, fx: usize) -> Self {
        Weights { k, c, fy, fx, data: vec![0; k * c * fy * fx] }
    }

    /// From existing data (length-checked).
    pub fn from_vec(k: usize, c: usize, fy: usize, fx: usize, data: Vec<i32>) -> Self {
        assert_eq!(data.len(), k * c * fy * fx, "weight data length mismatch");
        Weights { k, c, fy, fx, data }
    }

    /// Linear offset of `(k, c, fy, fx)`.
    #[inline]
    pub fn offset(&self, ki: usize, ci: usize, fyi: usize, fxi: usize) -> usize {
        debug_assert!(ki < self.k && ci < self.c && fyi < self.fy && fxi < self.fx);
        ((ki * self.c + ci) * self.fy + fyi) * self.fx + fxi
    }

    /// Element accessor.
    #[inline]
    pub fn at(&self, ki: usize, ci: usize, fyi: usize, fxi: usize) -> i32 {
        self.data[self.offset(ki, ci, fyi, fxi)]
    }

    /// Element setter.
    #[inline]
    pub fn set(&mut self, ki: usize, ci: usize, fyi: usize, fxi: usize, v: i32) {
        let o = self.offset(ki, ci, fyi, fxi);
        self.data[o] = v;
    }

    /// Deterministic random weights with |v| ≤ `mag`.
    pub fn random(k: usize, c: usize, fy: usize, fx: usize, mag: i32, rng: &mut Rng) -> Self {
        let data =
            (0..k * c * fy * fx).map(|_| rng.range_i64(-mag as i64, mag as i64) as i32).collect();
        Weights { k, c, fy, fx, data }
    }

    /// Re-order into the Im2col weight matrix `[K][(fy*Fx+fx)*C + c]`,
    /// matching the HWC patch vector order of
    /// [`super::im2col::im2col_patch`].
    pub fn to_im2col_matrix(&self) -> Vec<i32> {
        let cols = self.c * self.fy * self.fx;
        let mut m = vec![0i32; self.k * cols];
        for ki in 0..self.k {
            for fyi in 0..self.fy {
                for fxi in 0..self.fx {
                    for ci in 0..self.c {
                        let col = (fyi * self.fx + fxi) * self.c + ci;
                        m[ki * cols + col] = self.at(ki, ci, fyi, fxi);
                    }
                }
            }
        }
        m
    }
}

/// Deterministic random input for a conv shape (CHW). Magnitudes are
/// bounded so that a full 3×3×C accumulation cannot overflow i32 even in
/// the CPU oracle; exactness tests rely on wrapping semantics anyway.
pub fn random_input(shape: &ConvShape, mag: i32, rng: &mut Rng) -> TensorChw {
    TensorChw::random(shape.c, shape.ih(), shape.iw(), mag, rng)
}

/// Deterministic random weights for a conv shape.
pub fn random_weights(shape: &ConvShape, mag: i32, rng: &mut Rng) -> Weights {
    Weights::random(shape.k, shape.c, shape.fy, shape.fx, mag, rng)
}

/// Deterministic random *depthwise* weights for a shape under the
/// depthwise convention (`k == c`, one single-channel filter per
/// channel): dimensions `(K, 1, Fy, Fx)`.
pub fn random_depthwise_weights(shape: &ConvShape, mag: i32, rng: &mut Rng) -> Weights {
    Weights::random(shape.k, 1, shape.fy, shape.fx, mag, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chw_indexing_roundtrip() {
        let mut t = TensorChw::zeros(2, 3, 4);
        t.set(1, 2, 3, 42);
        assert_eq!(t.at(1, 2, 3), 42);
        assert_eq!(t.offset(0, 0, 1), 1);
        assert_eq!(t.offset(1, 0, 0), 12);
    }

    #[test]
    fn hwc_indexing_roundtrip() {
        let mut t = TensorHwc::zeros(3, 4, 2);
        t.set(2, 3, 1, 7);
        assert_eq!(t.at(2, 3, 1), 7);
        assert_eq!(t.offset(0, 0, 1), 1);
        assert_eq!(t.offset(0, 1, 0), 2);
    }

    #[test]
    fn layout_conversion_is_inverse() {
        let mut rng = Rng::new(11);
        let t = TensorChw::random(3, 5, 4, 100, &mut rng);
        assert_eq!(t.to_hwc().to_chw(), t);
    }

    #[test]
    fn weights_offsets() {
        let mut w = Weights::zeros(2, 3, 3, 3);
        w.set(1, 2, 0, 1, 9);
        assert_eq!(w.at(1, 2, 0, 1), 9);
        assert_eq!(w.offset(0, 0, 0, 1), 1);
        assert_eq!(w.offset(0, 1, 0, 0), 9);
        assert_eq!(w.offset(1, 0, 0, 0), 27);
    }

    #[test]
    fn im2col_matrix_order_matches_patch_order() {
        // Weight value at (k=0, c, fy, fx) must land at column
        // (fy*3+fx)*C + c.
        let c = 2;
        let mut w = Weights::zeros(1, c, 3, 3);
        w.set(0, 1, 2, 0, 55); // c=1, fy=2, fx=0 -> col (2*3+0)*2+1 = 13
        let m = w.to_im2col_matrix();
        assert_eq!(m[13], 55);
        assert_eq!(m.len(), 18);
    }

    #[test]
    fn random_is_bounded_and_deterministic() {
        let s = ConvShape::new3x3(2, 2, 4, 4);
        let a = random_input(&s, 8, &mut Rng::new(3));
        let b = random_input(&s, 8, &mut Rng::new(3));
        assert_eq!(a, b);
        assert!(a.data.iter().all(|&v| (-8..=8).contains(&v)));
    }
}
