//! **Im2col-IP — im2col + input-channel parallelism.**
//!
//! Each PE accumulates over a distinct slice of the input channels
//! (C/16 per PE) for one output position and one output channel; the
//! partial sums are then aggregated over the torus and a single result is
//! stored. The im2col patch is built by the host **per output position
//! and per output channel** (the paper: "every call of the Im2col
//! function creates one output position at a time and, additionally,
//! each Im2col input organization has to be repeated for every output
//! channel") — the launch and reorder overhead that makes IP the slowest
//! CGRA mapping in Figure 4.
//!
//! The patch buffer is laid out **channel-major** `(ci, fy, fx)` so each
//! PE's slice is contiguous (sequential DMA bursts); weights in KCFF
//! order are already channel-major per output channel. When C is not a
//! multiple of 16 the patch and weights are zero-padded to `Cp =
//! ceil(C/16)·16` channels so all lanes run the same trip count — the
//! padded lanes do full-cost dummy work, reproducing the paper's
//! collapse at C = 17.

use anyhow::Result;

use crate::cgra::{decode, Cgra, Memory, RunStats};
use crate::conv::{ConvShape, TensorChw, TensorHwc, Weights};
use crate::isa::{Dir, Dst, Instr, Op, PeId, PeProgram, Program, Src, N_PES};

use super::common::{ConvOutcome, HostCostModel, LatencyBreakdown, Mapping, MemLayout};
use super::op_im2col::push_inner_loop;

/// Channels after padding to a multiple of the PE count.
pub fn padded_c(shape: &ConvShape) -> usize {
    shape.c.div_ceil(N_PES) * N_PES
}

/// Build the channel-major patch for output pixel (y, x):
/// `patch[ci*9 + fy*3 + fx] = I[y+fy][x+fx][ci]`, zero-padded to Cp.
pub fn im2col_patch_cm(shape: &ConvShape, input: &TensorHwc, y: usize, x: usize, out: &mut [i32]) {
    let cp = padded_c(shape);
    assert_eq!(out.len(), cp * 9);
    out.fill(0);
    for ci in 0..shape.c {
        for fy in 0..3 {
            for fx in 0..3 {
                out[ci * 9 + fy * 3 + fx] = input.at(y + fy, x + fx, ci);
            }
        }
    }
}

/// Build the program for one (pixel, k) launch.
///
/// `patch_base` — channel-major patch; `w_base` — channel-major weights
/// of output channel k (padded if C % 16 != 0); `out_addr` — the single
/// word receiving the result.
pub fn build_program(
    shape: &ConvShape,
    patch_base: i32,
    w_base: i32,
    out_addr: i32,
) -> Program {
    super::common::note_program_build();
    let slice = (padded_c(shape) / N_PES * 9) as i32;
    let mut prog = Program::new(format!("ip-{}", shape.id()));
    for id in PeId::all() {
        let lane = id.index() as i32;
        let wb = w_base + lane * slice;
        let mut p = Vec::new();
        // INIT: acc = 0, weight slice pointer, input slice pointer.
        p.push(Instr::mov(Dst::Reg(0), Src::Zero));
        p.push(Instr::mov(Dst::Reg(3), Src::Imm(wb)));
        p.push(Instr::new(
            Op::SetAddr,
            Src::Imm(patch_base + lane * slice),
            Src::Zero,
            Dst::None,
        ));
        // Inner loop over the lane's slice (the paper's 8 instructions).
        push_inner_loop(&mut p, id, 1, 1, wb + slice);
        // Aggregation over the torus: row chains flow east into column 3,
        // then down into PE(3,3), which stores the total.
        p.push(Instr::mov(Dst::Out, Src::Reg(0))); // a0: expose partial
        let w = Src::Neigh(Dir::West);
        let n = Src::Neigh(Dir::North);
        // a1..a3: eastward row chain (cols 1, 2, 3 in successive slots).
        for step in 1..=3 {
            if id.col == step {
                p.push(Instr::new(Op::Add, w, Src::Own, Dst::Out));
            } else {
                p.push(Instr::nop());
            }
        }
        // a4..a6: downward chain in column 3.
        for step in 1..=3 {
            if id.col == 3 && id.row == step {
                p.push(Instr::new(Op::Add, n, Src::Own, Dst::Out));
            } else {
                p.push(Instr::nop());
            }
        }
        // a7: store + exit (PE(3,3) holds the grand total).
        if id == PeId::new(3, 3) {
            p.push(Instr::new(Op::SwAt, Src::Imm(out_addr), Src::Zero, Dst::None));
            p.push(Instr::exit());
        }
        prog.set_pe(id, PeProgram::from_instrs(p));
    }
    prog
}

/// Execute the full convolution with the Im2col-IP mapping.
pub fn run(
    cgra: &Cgra,
    shape: &ConvShape,
    input: &TensorChw,
    weights: &Weights,
) -> Result<ConvOutcome> {
    shape.validate()?;
    let cfg = cgra.config();
    let host = HostCostModel::default();
    let cp = padded_c(shape);
    let patch_words = cp * 9;
    let padded_w = shape.c != cp;
    // Aux region: double-buffered patch + (if padding) a padded weight
    // image. The paper notes IP's buffer roughly doubles the memory.
    let aux_words = 2 * patch_words + if padded_w { shape.k * patch_words } else { 0 };
    let layout = MemLayout::new(shape, aux_words, cfg)?;
    let mut mem = Memory::new(cfg.mem_words, cfg.n_banks);
    let input_hwc = input.to_hwc();
    mem.poke_slice(layout.input, &input_hwc.data);
    mem.poke_slice(layout.weights, &weights.data);

    // One-time host prep: HWC conversion (+ padded weight image).
    let w_image_base = if padded_w {
        let base = layout.im2col + 2 * patch_words;
        for k in 0..shape.k {
            let src = &weights.data[k * shape.c * 9..(k + 1) * shape.c * 9];
            mem.poke_slice(base + k * patch_words, src);
            // padding channels stay zero
        }
        base
    } else {
        layout.weights
    };
    let prep_elems =
        (input_hwc.data.len() + if padded_w { shape.k * shape.c * 9 } else { 0 }) as u64;

    let mut stats = RunStats::new();
    stats.exited = true;
    let mut launches = 0u64;
    let mut cpu_im2col = prep_elems * host.prep_cycles_per_elem;
    let mut cpu_hidden = 0u64;
    let mut cpu_copies = 0u64;
    let mut patch = vec![0i32; patch_words];

    for y in 0..shape.ox {
        for x in 0..shape.oy {
            let pix = y * shape.oy + x;
            // The patch content is identical across k, but the paper's
            // implementation rebuilds it per output channel; we charge
            // the CPU for every rebuild and write it once per pixel.
            im2col_patch_cm(shape, &input_hwc, y, x, &mut patch);
            let slot = layout.im2col + (pix % 2) * patch_words;
            mem.poke_slice(slot, &patch);
            for k in 0..shape.k {
                cpu_copies += patch_words as u64;
                cpu_im2col += patch_words as u64 * host.im2col_cycles_per_elem;
                let prog = build_program(
                    shape,
                    slot as i32,
                    (w_image_base + k * patch_words) as i32,
                    (layout.output + k * shape.ox * shape.oy + pix) as i32,
                );
                // Every (pixel, k) launch has unique address immediates,
                // so memoizing decodes would only churn the bounded
                // cache — decode directly (it is cheap vs the run).
                let dp = decode(&prog);
                let s = cgra.run_decoded(&dp, &mut mem)?;
                cpu_hidden += s.cycles.min(patch_words as u64 * host.im2col_cycles_per_elem);
                stats.merge(&s);
                launches += 1;
            }
        }
    }

    let output = TensorChw::from_vec(
        shape.k,
        shape.ox,
        shape.oy,
        mem.peek_slice(layout.output, shape.output_elems()).to_vec(),
    );
    let latency = LatencyBreakdown {
        cgra_cycles: stats.cycles,
        launch_cycles: launches * cfg.launch_overhead + cfg.instruction_load_overhead,
        cpu_im2col_cycles: cpu_im2col,
        cpu_hidden_cycles: cpu_hidden,
        launches,
        ..Default::default()
    };
    Ok(ConvOutcome {
        mapping: Mapping::Ip,
        shape: *shape,
        output,
        latency,
        cgra_stats: stats,
        cpu_mem: crate::cgra::MemStats {
            loads: cpu_copies + prep_elems,
            stores: cpu_copies + prep_elems,
        },
        footprint_bytes: shape.base_bytes() + 4 * aux_words,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::CgraConfig;
    use crate::conv::{conv2d, random_input, random_weights};
    use crate::prop::Rng;

    fn check_shape(shape: ConvShape, seed: u64) -> ConvOutcome {
        let mut rng = Rng::new(seed);
        let input = random_input(&shape, 50, &mut rng);
        let weights = random_weights(&shape, 9, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let out = run(&cgra, &shape, &input, &weights).unwrap();
        let golden = conv2d(&shape, &input, &weights);
        assert_eq!(out.output.data, golden.data, "Im2col-IP mismatch on {shape}");
        out
    }

    #[test]
    fn c_below_16_padded() {
        check_shape(ConvShape::new3x3(3, 2, 3, 3), 1);
    }

    #[test]
    fn c_exactly_16() {
        check_shape(ConvShape::new3x3(16, 2, 3, 3), 2);
    }

    #[test]
    fn c_17_imbalanced() {
        let out = check_shape(ConvShape::new3x3(17, 2, 2, 2), 3);
        // Padded to 32 channels: each lane runs 2*9 inner iterations even
        // though 15 channels are dummies.
        let iters_per_launch = 2 * 9;
        let expected_loads_lower = out.latency.launches * iters_per_launch as u64 * 16;
        assert!(out.cgra_stats.mem.loads >= expected_loads_lower);
    }

    #[test]
    fn c_32_two_channels_per_lane() {
        check_shape(ConvShape::new3x3(32, 2, 2, 3), 4);
    }

    #[test]
    fn launches_scale_with_pixels_times_k() {
        let shape = ConvShape::new3x3(16, 3, 2, 4);
        let out = check_shape(shape, 5);
        assert_eq!(out.latency.launches, (3 * 2 * 4) as u64);
    }

    #[test]
    fn aggregation_program_fits() {
        let shape = ConvShape::new3x3(144, 1, 2, 2);
        let prog = build_program(&shape, 0, 100, 999);
        assert!(prog.max_len() <= 32);
    }

    #[test]
    fn cpu_overhead_dominates_small_layers() {
        // Fig. 4's story: IP pays heavy CPU im2col + launch overheads.
        let shape = ConvShape::new3x3(16, 16, 4, 4);
        let out = check_shape(shape, 6);
        assert!(out.latency.cpu_im2col_cycles > 0);
        assert!(out.latency.launches == (16 * 16) as u64);
    }
}
