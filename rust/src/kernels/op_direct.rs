//! **Conv-OP — direct convolution + output-channel parallelism.**
//!
//! Like Im2col-OP, each PE owns one output channel; unlike it, inputs are
//! fetched straight from the CHW tensor (no reorder buffer), so the input
//! stream is strided and the per-pixel bookkeeping is heavier — the
//! paper's "higher overhead in data addressing" for direct access.
//!
//! Loop nest: the host launches once per (k-tile, filter tap (fy,fx),
//! output row y); the program sweeps the row's Oy pixels, and for each
//! pixel runs the shared 8-instruction inner loop over input channels
//! (input stride = ih·iw, weight stride = 9 — both constant in CHW/KCFF
//! layouts). Partial sums accumulate **in memory** across the 9 tap
//! launches (tap (0,0) initializes, later taps read-modify-write); within
//! a pixel the accumulator stays in the RF.

use anyhow::Result;

use crate::cgra::{decode, Cgra, Memory, RunStats};
use crate::conv::{ConvShape, TensorChw, Weights};
use crate::isa::{Dst, Instr, Op, PeId, PeProgram, Program, Src, N_PES};

use super::common::{ConvOutcome, LatencyBreakdown, Mapping, MemLayout};
use super::op_im2col::push_inner_loop;

/// Parameters of one (k_tile, fy, fx, y) launch.
#[derive(Clone, Copy, Debug)]
pub struct OpDirectLaunch {
    /// Output-channel tile index (16 channels per tile).
    pub kt: usize,
    /// Filter tap row.
    pub fy: usize,
    /// Filter tap column.
    pub fx: usize,
    /// Output row being swept.
    pub y: usize,
}

/// Build the program for one launch.
pub fn build_program(shape: &ConvShape, layout: &MemLayout, l: OpDirectLaunch) -> Program {
    super::common::note_program_build();
    let (c, oy) = (shape.c as i32, shape.oy as i32);
    let (ih, iw) = (shape.ih() as i32, shape.iw() as i32);
    let oxy = (shape.ox * shape.oy) as i32;
    let first_tap = l.fy == 0 && l.fx == 0;
    let mut prog = Program::new(format!(
        "op-direct-{}-kt{}f{}{}y{}",
        shape.id(),
        l.kt,
        l.fy,
        l.fx,
        l.y
    ));

    for id in PeId::all() {
        let lane = id.index();
        let kp = l.kt * N_PES + lane;
        let active = kp < shape.k;
        let kc = kp.min(shape.k - 1); // idle lanes shadow the last channel
        let w_tap =
            layout.weights as i32 + (kc * shape.c * 9) as i32 + (l.fy * 3 + l.fx) as i32;
        // Output pointer: active lanes write their row; idle lanes write
        // into scratch (distinct per lane, see MemLayout's margin).
        let out_row = if active {
            layout.output as i32 + kp as i32 * oxy + l.y as i32 * oy
        } else {
            layout.scratch as i32 + lane as i32
        };

        let mut p = Vec::new();
        // INIT: input pointer at (y+fy, fx) of channel 0; R1 = out ptr.
        p.push(Instr::new(
            Op::SetAddr,
            Src::Imm(layout.input as i32 + (l.y + l.fy) as i32 * iw + l.fx as i32),
            Src::Zero,
            Dst::None,
        ));
        p.push(Instr::mov(Dst::Reg(1), Src::Imm(out_row)));
        let pix_start = p.len();
        // Per-pixel prologue: reset weight pointer; init accumulator.
        p.push(Instr::mov(Dst::Reg(3), Src::Imm(w_tap)));
        if first_tap {
            p.push(Instr::mov(Dst::Reg(0), Src::Zero));
        } else {
            p.push(Instr::new(Op::Lw, Src::Reg(1), Src::Zero, Dst::Reg(0)));
        }
        // Inner loop over input channels.
        push_inner_loop(&mut p, id, ih * iw, 9, w_tap + 9 * c);
        // Per-pixel epilogue: store, advance pointers, pixel loop.
        p.push(Instr::mov(Dst::Out, Src::Reg(0))); // expose acc
        p.push(Instr::new(Op::SwAt, Src::Reg(1), Src::Zero, Dst::None));
        p.push(Instr::new(Op::Sub, Src::Reg(1), Src::Imm(-1), Dst::Reg(1)));
        p.push(Instr::new(Op::SetAddr, Src::Addr, Src::Imm(1 - c * ih * iw), Dst::None));
        if id.row == 0 {
            p.push(Instr::branch(Op::Blt, Src::Reg(1), Src::Imm(out_row + oy), pix_start));
        } else {
            p.push(Instr::nop());
        }
        if id == PeId::new(3, 3) {
            p.push(Instr::exit());
        }
        prog.set_pe(id, PeProgram::from_instrs(p));
    }
    prog
}

/// Execute the full convolution with the Conv-OP mapping.
pub fn run(
    cgra: &Cgra,
    shape: &ConvShape,
    input: &TensorChw,
    weights: &Weights,
) -> Result<ConvOutcome> {
    shape.validate()?;
    let cfg = cgra.config();
    let layout = MemLayout::new(shape, 0, cfg)?;
    let mut mem = Memory::new(cfg.mem_words, cfg.n_banks);
    mem.poke_slice(layout.input, &input.data);
    mem.poke_slice(layout.weights, &weights.data);

    let mut stats = RunStats::new();
    stats.exited = true;
    let mut launches = 0u64;
    for kt in 0..shape.k.div_ceil(N_PES) {
        for fy in 0..3 {
            for fx in 0..3 {
                for y in 0..shape.ox {
                    let prog =
                        build_program(shape, &layout, OpDirectLaunch { kt, fy, fx, y });
                    // Per-(k_tile, tap, row) programs are unique, so
                    // decode directly rather than churn the decode cache.
                    let dp = decode(&prog);
                    let s = cgra.run_decoded(&dp, &mut mem)?;
                    stats.merge(&s);
                    launches += 1;
                }
            }
        }
    }

    let output = TensorChw::from_vec(
        shape.k,
        shape.ox,
        shape.oy,
        mem.peek_slice(layout.output, shape.output_elems()).to_vec(),
    );
    let latency = LatencyBreakdown {
        cgra_cycles: stats.cycles,
        launch_cycles: launches * cfg.launch_overhead + cfg.instruction_load_overhead,
        launches,
        ..Default::default()
    };
    Ok(ConvOutcome {
        mapping: Mapping::OpDirect,
        shape: *shape,
        output,
        latency,
        cgra_stats: stats,
        cpu_mem: Default::default(),
        footprint_bytes: shape.base_bytes(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::CgraConfig;
    use crate::conv::{conv2d, random_input, random_weights};
    use crate::prop::Rng;

    fn check_shape(shape: ConvShape, seed: u64) -> ConvOutcome {
        let mut rng = Rng::new(seed);
        let input = random_input(&shape, 50, &mut rng);
        let weights = random_weights(&shape, 9, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let out = run(&cgra, &shape, &input, &weights).unwrap();
        let golden = conv2d(&shape, &input, &weights);
        assert_eq!(out.output.data, golden.data, "Conv-OP mismatch on {shape}");
        out
    }

    #[test]
    fn tiny() {
        check_shape(ConvShape::new3x3(1, 1, 2, 2), 1);
    }

    #[test]
    fn full_tile() {
        check_shape(ConvShape::new3x3(2, 16, 3, 4), 2);
    }

    #[test]
    fn k_17_spills_to_second_tile() {
        let out = check_shape(ConvShape::new3x3(1, 17, 3, 3), 3);
        assert_eq!(out.latency.launches, 2 * 9 * 3);
    }

    #[test]
    fn rect_shapes() {
        check_shape(ConvShape::new3x3(3, 5, 2, 6), 4);
        check_shape(ConvShape::new3x3(2, 2, 6, 2), 5);
    }

    #[test]
    fn program_fits() {
        let shape = ConvShape::new3x3(144, 144, 64, 64);
        let layout = MemLayout {
            input: 0,
            weights: 10,
            output: 20,
            im2col: 30,
            im2col_words: 0,
            scratch: 30,
            total_words: 40,
        };
        let prog = build_program(
            &shape,
            &layout,
            OpDirectLaunch { kt: 8, fy: 2, fx: 2, y: 63 },
        );
        assert!(prog.max_len() <= 32);
    }

    #[test]
    fn slower_than_wp_on_baseline() {
        // Fig. 4: WP beats Conv-OP in latency.
        let shape = ConvShape::new3x3(8, 16, 8, 8);
        let mut rng = Rng::new(6);
        let input = random_input(&shape, 20, &mut rng);
        let weights = random_weights(&shape, 9, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let op = run(&cgra, &shape, &input, &weights).unwrap();
        let wp = super::super::wp::run(&cgra, &shape, &input, &weights).unwrap();
        assert!(
            op.latency.total_cycles() > wp.latency.total_cycles(),
            "Conv-OP {} should be slower than WP {}",
            op.latency.total_cycles(),
            wp.latency.total_cycles()
        );
    }
}
