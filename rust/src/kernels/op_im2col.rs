//! **Im2col-OP — im2col + output-channel parallelism.**
//!
//! Each PE owns one output channel (16 at a time); the host CPU builds an
//! im2col patch per output position (double-buffered, overlapped with the
//! CGRA run), and all 16 PEs stream the *same* patch sequentially while
//! each walks its own row of the im2col weight matrix. Partial sums stay
//! in the register file until the single store per (k, pixel) — the
//! paper's rationale for OP ("minimize the latency for reading and
//! writing partial sums by keeping them in the RF").
//!
//! Innermost loop — the paper's 8 instructions (Fig. 3), identical for
//! IP / Im2col-OP / Conv-OP:
//!
//! ```text
//!   b0  lwinc r2, #1      ; patch element   (all 16 PEs -> collisions!)
//!   b1  lw    out, r3     ; weight element
//!   b2  mul   r2, r2, own
//!   b3  add   r0, r0, r2  ; accumulate ("sum")
//!   b4  sub   r3, r3, #-1 ; weight index update
//!   b5  nop               ; (input index is auto-increment)
//!   b6  nop               ; (loop bound is a pointer compare)
//!   b7  blt   r3, #bound  ; branch — one PE per column
//! ```
//!
//! Most PEs nop in the tail slots → ≈69% utilization, as the paper
//! reports. When K is not a multiple of 16 the last k-tile runs with
//! idle lanes (they compute into scratch), reproducing the paper's
//! performance collapse at K = 17.

use anyhow::Result;

use crate::cgra::{decode, Cgra, Memory, RunStats};
use crate::conv::{im2col_patch, patch_len, ConvShape, TensorChw, Weights};
use crate::isa::{Dst, Instr, Op, PeId, PeProgram, Program, Src, N_PES};

use super::common::{ConvOutcome, HostCostModel, LatencyBreakdown, Mapping, MemLayout};

/// Lane index (0..15) of a PE: row-major, `kp = k_tile*16 + lane`.
fn lane(id: PeId) -> usize {
    id.index()
}

/// Emit the shared 8-slot inner loop. `input_stride` is the ADDR-register
/// auto-increment; `w_stride` the weight-pointer step; `bound` the weight
/// pointer's end value for the branching PE (row 0 of each column).
pub(super) fn push_inner_loop(
    p: &mut Vec<Instr>,
    id: PeId,
    input_stride: i32,
    w_stride: i32,
    bound: i32,
) {
    let body = p.len();
    p.push(Instr::new(Op::LwInc, Src::Imm(input_stride), Src::Zero, Dst::Reg(2)));
    p.push(Instr::new(Op::Lw, Src::Reg(3), Src::Zero, Dst::Out));
    p.push(Instr::new(Op::Mul, Src::Reg(2), Src::Own, Dst::Reg(2)));
    p.push(Instr::new(Op::Add, Src::Reg(0), Src::Reg(2), Dst::Reg(0)));
    p.push(Instr::new(Op::Sub, Src::Reg(3), Src::Imm(-w_stride), Dst::Reg(3)));
    p.push(Instr::nop());
    p.push(Instr::nop());
    if id.row == 0 {
        p.push(Instr::branch(Op::Blt, Src::Reg(3), Src::Imm(bound), body));
    } else {
        p.push(Instr::nop());
    }
}

/// Build the program for one (k_tile, pixel) launch.
///
/// `patch_base` — address of the current im2col patch;
/// `out_addr(lane)` — where each lane stores (scratch for idle lanes);
/// `w_base(lane)` / `w_bound(lane)` — each lane's weight row.
pub fn build_program(
    shape: &ConvShape,
    patch_base: i32,
    w_base: impl Fn(usize) -> i32,
    out_addr: impl Fn(usize) -> i32,
) -> Program {
    super::common::note_program_build();
    let pl = patch_len(shape) as i32;
    let mut prog = Program::new(format!("op-im2col-{}", shape.id()));
    for id in PeId::all() {
        let l = lane(id);
        let wb = w_base(l);
        let mut p = Vec::new();
        // INIT: acc = 0, weight pointer, input pointer.
        p.push(Instr::mov(Dst::Reg(0), Src::Zero));
        p.push(Instr::mov(Dst::Reg(3), Src::Imm(wb)));
        p.push(Instr::new(Op::SetAddr, Src::Imm(patch_base), Src::Zero, Dst::None));
        // Inner loop over the 9·C patch elements.
        push_inner_loop(&mut p, id, 1, 1, wb + pl);
        // Store: expose acc, store at the lane's output address.
        p.push(Instr::mov(Dst::Out, Src::Reg(0)));
        p.push(Instr::new(Op::SwAt, Src::Imm(out_addr(l)), Src::Zero, Dst::None));
        if id == PeId::new(3, 3) {
            p.push(Instr::exit());
        }
        prog.set_pe(id, PeProgram::from_instrs(p));
    }
    prog
}

/// Execute the full convolution with the Im2col-OP mapping.
pub fn run(
    cgra: &Cgra,
    shape: &ConvShape,
    input: &TensorChw,
    weights: &Weights,
) -> Result<ConvOutcome> {
    shape.validate()?;
    let cfg = cgra.config();
    let host = HostCostModel::default();
    let pl = patch_len(shape);
    // Double-buffered single-patch im2col region.
    let layout = MemLayout::new(shape, 2 * pl, cfg)?;
    let mut mem = Memory::new(cfg.mem_words, cfg.n_banks);
    let input_hwc = input.to_hwc();
    let w_matrix = weights.to_im2col_matrix();
    mem.poke_slice(layout.input, &input_hwc.data);
    mem.poke_slice(layout.weights, &w_matrix);
    // One-time host prep: HWC input + weight-matrix reorder.
    let prep_elems = (input_hwc.data.len() + w_matrix.len()) as u64;

    let mut stats = RunStats::new();
    stats.exited = true;
    let mut launches = 0u64;
    let mut cpu_im2col = prep_elems * host.prep_cycles_per_elem;
    let mut cpu_hidden = 0u64;
    let mut cpu_copies = 0u64;
    let k_tiles = shape.k.div_ceil(N_PES);
    let mut patch = vec![0i32; pl];

    for kt in 0..k_tiles {
        for y in 0..shape.ox {
            for x in 0..shape.oy {
                let pix = y * shape.oy + x;
                // Host: build the patch into the ping-pong slot. Charged
                // to the CPU; hidden under the *previous* launch's CGRA
                // time by the overlap accounting below.
                let slot = layout.im2col + (pix % 2) * pl;
                let copied = im2col_patch(shape, &input_hwc, y, x, &mut patch) as u64;
                mem.poke_slice(slot, &patch);
                cpu_copies += copied;
                cpu_im2col += copied * host.im2col_cycles_per_elem;

                let prog = build_program(
                    shape,
                    slot as i32,
                    |l| {
                        let kp = (kt * N_PES + l).min(shape.k - 1);
                        (layout.weights + kp * pl) as i32
                    },
                    |l| {
                        let kp = kt * N_PES + l;
                        if kp < shape.k {
                            (layout.output + kp * shape.ox * shape.oy + pix) as i32
                        } else {
                            (layout.scratch + l) as i32 // idle lane
                        }
                    },
                );
                // Per-(k_tile, pixel) programs are unique (output
                // addresses + ping-pong patch slot), so decode directly
                // instead of churning the bounded decode cache.
                let dp = decode(&prog);
                let s = cgra.run_decoded(&dp, &mut mem)?;
                // The patch build for the NEXT pixel overlaps this run.
                cpu_hidden += s.cycles.min(copied * host.im2col_cycles_per_elem);
                stats.merge(&s);
                launches += 1;
            }
        }
    }

    let output = TensorChw::from_vec(
        shape.k,
        shape.ox,
        shape.oy,
        mem.peek_slice(layout.output, shape.output_elems()).to_vec(),
    );
    let latency = LatencyBreakdown {
        cgra_cycles: stats.cycles,
        launch_cycles: launches * cfg.launch_overhead + cfg.instruction_load_overhead,
        cpu_im2col_cycles: cpu_im2col,
        cpu_hidden_cycles: cpu_hidden,
        launches,
        ..Default::default()
    };
    Ok(ConvOutcome {
        mapping: Mapping::OpIm2col,
        shape: *shape,
        output,
        latency,
        cgra_stats: stats,
        cpu_mem: crate::cgra::MemStats { loads: cpu_copies + prep_elems, stores: cpu_copies + prep_elems },
        // HWC input + weight matrix + output + double patch buffer.
        footprint_bytes: shape.base_bytes() + 4 * 2 * pl,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::CgraConfig;
    use crate::conv::{conv2d, random_input, random_weights};
    use crate::prop::Rng;

    fn check_shape(shape: ConvShape, seed: u64) -> ConvOutcome {
        let mut rng = Rng::new(seed);
        let input = random_input(&shape, 50, &mut rng);
        let weights = random_weights(&shape, 9, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let out = run(&cgra, &shape, &input, &weights).unwrap();
        let golden = conv2d(&shape, &input, &weights);
        assert_eq!(out.output.data, golden.data, "Im2col-OP mismatch on {shape}");
        out
    }

    #[test]
    fn tiny_full_tile() {
        check_shape(ConvShape::new3x3(1, 16, 2, 2), 1);
    }

    #[test]
    fn k_below_tile_width() {
        check_shape(ConvShape::new3x3(2, 3, 3, 3), 2);
    }

    #[test]
    fn k_17_imbalanced_tile() {
        let out = check_shape(ConvShape::new3x3(1, 17, 3, 3), 3);
        // Two k-tiles: twice the launches of K=16.
        assert_eq!(out.latency.launches, 2 * 9);
    }

    #[test]
    fn multi_channel() {
        check_shape(ConvShape::new3x3(4, 5, 4, 3), 4);
    }

    #[test]
    fn inner_loop_is_eight_instructions() {
        let shape = ConvShape::baseline();
        let prog = build_program(&shape, 0, |_| 100, |l| 200 + l as i32);
        // Body starts after the 3 INIT slots; branch at body+7 -> body.
        let p = prog.pe(PeId::new(0, 1));
        let br = p.fetch(3 + 7);
        assert_eq!(br.op, Op::Blt);
        assert_eq!(br.target as usize, 3);
        assert!(prog.max_len() <= 32);
    }

    #[test]
    fn utilization_near_paper_69_percent() {
        let shape = ConvShape::new3x3(16, 16, 4, 4);
        let out = check_shape(shape, 5);
        let u = out.cgra_stats.utilization();
        assert!((0.55..0.80).contains(&u), "Im2col-OP utilization {u:.3}");
    }

    #[test]
    fn two_loads_per_mac() {
        // The defining cost of the lane mappings: one input + one weight
        // load per MAC (the paper's collision source).
        let shape = ConvShape::new3x3(16, 16, 4, 4);
        let out = check_shape(shape, 6);
        let per_mac = out.cgra_stats.mem.loads as f64 / shape.macs() as f64;
        assert!((1.9..2.2).contains(&per_mac), "loads/MAC {per_mac:.3}");
    }
}
