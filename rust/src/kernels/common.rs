//! Shared infrastructure for the mapping kernels: memory map, host-side
//! driver accounting, and the result bundle every mapping returns.
//!
//! **Op-classification convention** (see `cgra::stats::OpClass`): kernel
//! generators use `Add` *only* for genuine accumulation; index arithmetic
//! uses `Sub` with negative immediates / `SetAddr` / auto-increment
//! addressing, so Figure 3's "sum" vs "other" split falls out of the
//! static op class.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{ensure, Result};

use crate::cgra::{CgraConfig, MemStats, RunStats};
use crate::conv::{ConvShape, TensorChw};

/// Process-wide count of CGRA launch `Program`s built (every
/// `build_program` of every kernel notes one). Together with
/// [`crate::cgra::decode_count`] and [`arena_allocs`] this makes the
/// compile-once / run-many contract *assertable*: a warm
/// `CompiledNet::run` must not move any of these counters
/// (`engine::compiled::RunCounters`).
static PROGRAM_BUILDS: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of execution-arena allocations (context buffers,
/// kernel scratch) — growth after warm-up indicates a sizing bug.
static ARENA_ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Total launch programs built so far in this process.
pub fn program_builds() -> u64 {
    PROGRAM_BUILDS.load(Ordering::Relaxed)
}

/// Record one launch-program construction.
pub(crate) fn note_program_build() {
    PROGRAM_BUILDS.fetch_add(1, Ordering::Relaxed);
}

/// Total arena allocations so far in this process.
pub fn arena_allocs() -> u64 {
    ARENA_ALLOCS.load(Ordering::Relaxed)
}

/// Record one arena (de)allocation-class event: a buffer created or
/// grown on an execution path that promises steady-state zero
/// allocation.
pub(crate) fn note_arena_alloc() {
    ARENA_ALLOCS.fetch_add(1, Ordering::Relaxed);
}

/// Word addresses of each region in CGRA memory.
///
/// Layout: `[input | weights | output | im2col buffer | scratch]`.
/// `scratch` absorbs the WP pipeline's benign one-row overshoot of the
/// output prev-partial stream (see `kernels::wp`); the input overshoot
/// lands in the weights/output regions (reads only, values discarded).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemLayout {
    /// Input tensor base (CHW or HWC depending on the mapping).
    pub input: usize,
    /// Weights base.
    pub weights: usize,
    /// Output tensor base (always CHW `(K, Ox, Oy)`).
    pub output: usize,
    /// Im2col reorder buffer base (0-sized for direct mappings).
    pub im2col: usize,
    /// Im2col buffer length in words.
    pub im2col_words: usize,
    /// Scratch base.
    pub scratch: usize,
    /// Total words used.
    pub total_words: usize,
}

impl MemLayout {
    /// Build the layout for a shape. `im2col_words` is mapping-specific
    /// (0 for direct convolution).
    pub fn new(shape: &ConvShape, im2col_words: usize, cfg: &CgraConfig) -> Result<MemLayout> {
        let input = 0;
        let weights = input + shape.input_elems();
        let output = weights + shape.weight_elems();
        let im2col = output + shape.output_elems();
        let scratch = im2col + im2col_words;
        let total_words = MemLayout::required_words(shape, im2col_words);
        ensure!(
            total_words <= cfg.mem_words,
            "layer {shape} needs {total_words} words but the memory holds {} \
             (the paper bounds its sweep by the 512 KiB HEEPsilon RAM the same way)",
            cfg.mem_words
        );
        Ok(MemLayout {
            input,
            weights,
            output,
            im2col,
            im2col_words,
            scratch,
            total_words,
        })
    }

    /// Words a layout for `shape` requires, independent of any memory
    /// bound: the tensor regions, the mapping's `im2col_words`, and the
    /// scratch margin (one output row of WP pipeline overshoot + a
    /// safety margin). This is exactly what [`MemLayout::new`] checks
    /// against `CgraConfig::mem_words`, exposed so over-bound errors
    /// ([`Mapping::resolve`], the planner) can name the computed
    /// working-set sizes instead of just the bound.
    pub fn required_words(shape: &ConvShape, im2col_words: usize) -> usize {
        shape.input_elems()
            + shape.weight_elems()
            + shape.output_elems()
            + im2col_words
            + shape.oy
            + 2 * shape.iw()
            + 16
    }
}

/// Which of the paper's mapping strategies to run.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Mapping {
    /// Direct convolution, weight parallelism (paper's winner).
    Wp,
    /// Im2col, input-channel parallelism.
    Ip,
    /// Im2col, output-channel parallelism.
    OpIm2col,
    /// Direct convolution, output-channel parallelism.
    OpDirect,
    /// Depthwise convolution with weight parallelism: one WP-style
    /// launch per channel (`kernels::dw`, reusing the WP program
    /// generator). Computes the *depthwise* operator — shape convention
    /// `k == c`, weights `(C, 1, 3, 3)` — so it is not interchangeable
    /// with the dense mappings above and is excluded from
    /// [`Mapping::ALL`] / [`Mapping::CGRA`].
    DwWp,
    /// CPU-only baseline (no CGRA).
    Cpu,
    /// Pick the strategy per shape at submission time (see
    /// [`Mapping::resolve`] / `engine::auto`). Never executes directly:
    /// the dispatcher resolves it to one of the concrete strategies
    /// above, and `engine::Engine` records the decision in the result.
    Auto,
}

/// Why `Auto` picked WP (see [`Mapping::resolve`]; `pub(crate)` so the
/// artifact codec can round-trip the `&'static str` by tag).
pub(crate) const AUTO_REASON_WP: &str = "direct working set fits the memory bound; the paper \
     finds Conv-WP best for any hyperparameter combination";

/// Why `Auto` fell back to OP-im2col (see [`Mapping::resolve`]).
pub(crate) const AUTO_REASON_OP_IM2COL: &str = "direct convolution is unavailable for this \
     shape but the im2col buffer fits the memory bound; Im2col-OP is the best remaining \
     mapping (Fig. 4)";

impl Mapping {
    /// All CGRA mappings (excludes the CPU baseline and `Auto`).
    pub const CGRA: [Mapping; 4] = [Mapping::Wp, Mapping::Ip, Mapping::OpIm2col, Mapping::OpDirect];

    /// All *concrete* dense-convolution strategies including the CPU
    /// baseline (excludes `Auto`, which always resolves to one of
    /// these, and the depthwise-operator mapping [`Mapping::DwWp`],
    /// listed in [`Mapping::DEPTHWISE`]).
    pub const ALL: [Mapping; 5] =
        [Mapping::Wp, Mapping::Ip, Mapping::OpIm2col, Mapping::OpDirect, Mapping::Cpu];

    /// The depthwise-capable CGRA mappings (a different operator —
    /// see [`Mapping::DwWp`]).
    pub const DEPTHWISE: [Mapping; 1] = [Mapping::DwWp];

    /// Paper label.
    pub fn label(self) -> &'static str {
        match self {
            Mapping::Wp => "Conv-WP",
            Mapping::Ip => "Im2col-IP",
            Mapping::OpIm2col => "Im2col-OP",
            Mapping::OpDirect => "Conv-OP",
            Mapping::DwWp => "Dw-WP",
            Mapping::Cpu => "CPU",
            Mapping::Auto => "Auto",
        }
    }

    /// Parse a user-facing name, case-insensitively. Accepts the short
    /// names, the paper labels, `dw` / `depthwise` for the depthwise
    /// kernel, and `auto`. The error lists every accepted name, sorted
    /// by canonical name, so a typo is self-correcting from the message
    /// alone.
    pub fn parse(s: &str) -> Result<Mapping> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "wp" | "conv-wp" => Mapping::Wp,
            "ip" | "im2col-ip" => Mapping::Ip,
            "op-im2col" | "im2col-op" => Mapping::OpIm2col,
            "op-direct" | "conv-op" | "op" => Mapping::OpDirect,
            "dw" | "dw-wp" | "depthwise" => Mapping::DwWp,
            "cpu" => Mapping::Cpu,
            "auto" => Mapping::Auto,
            other => anyhow::bail!(
                "unknown mapping '{other}' (valid, case-insensitive, sorted: \
                 auto; conv-op | op-direct | op; cpu; dw-wp | dw | depthwise; \
                 im2col-ip | ip; im2col-op | op-im2col; wp | conv-wp)"
            ),
        })
    }

    /// Whether this is the `Auto` placeholder (must be resolved before
    /// keying caches or reporting a concrete strategy).
    pub fn is_auto(self) -> bool {
        self == Mapping::Auto
    }

    /// Resolve to the concrete strategy that should execute for `shape`
    /// under `cfg`, with the reason for the choice. Concrete mappings
    /// resolve to themselves.
    ///
    /// The `Auto` policy encodes the paper's conclusion: Conv-WP
    /// whenever the direct-convolution working set fits the 512 KiB
    /// memory bound ("WP remains the best approach for any
    /// hyperparameter combination"), falling back to Im2col-OP when
    /// direct convolution is unavailable but the im2col staging buffer
    /// still fits. With today's layouts the direct working set is the
    /// strict minimum, so the fallback guards shape classes a future
    /// mapping may open rather than a reachable branch of the current
    /// grid; the bound checks keep the policy honest either way.
    pub fn resolve(self, shape: &ConvShape, cfg: &CgraConfig) -> Result<(Mapping, &'static str)> {
        if self != Mapping::Auto {
            return Ok((self, "requested explicitly"));
        }
        shape.validate()?;
        if MemLayout::new(shape, 0, cfg).is_ok() {
            return Ok((Mapping::Wp, AUTO_REASON_WP));
        }
        let im2col_words = 2 * crate::conv::patch_len(shape);
        if MemLayout::new(shape, im2col_words, cfg).is_ok() {
            return Ok((Mapping::OpIm2col, AUTO_REASON_OP_IM2COL));
        }
        // Nothing fits: name both routes' computed working sets so the
        // failure is actionable (which route is closest, by how much),
        // not just the bound.
        let direct_words = MemLayout::required_words(shape, 0);
        let im2col_total = MemLayout::required_words(shape, im2col_words);
        anyhow::bail!(
            "layer {shape} exceeds the {} KiB memory bound on every route: direct \
             convolution needs {direct_words} words ({:.1} KiB), the im2col route needs \
             {im2col_total} words ({:.1} KiB), but the memory holds {} words ({} KiB) — \
             the paper bounds its Fig. 5 sweep by the same limit",
            cfg.mem_words * 4 / 1024,
            direct_words as f64 * 4.0 / 1024.0,
            im2col_total as f64 * 4.0 / 1024.0,
            cfg.mem_words,
            cfg.mem_words * 4 / 1024,
        )
    }

    /// Whether this mapping runs the Im2col transformation on the host
    /// (`Auto` reports `false`; resolve it first for a concrete answer).
    pub fn uses_im2col(self) -> bool {
        matches!(self, Mapping::Ip | Mapping::OpIm2col)
    }
}

impl std::fmt::Display for Mapping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Latency decomposition of one convolution execution.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyBreakdown {
    /// Cycles the CGRA array was executing.
    pub cgra_cycles: u64,
    /// Cycles charged for kernel launches (CPU configuring the CGRA).
    pub launch_cycles: u64,
    /// CPU cycles spent building im2col buffers (0 for direct mappings).
    pub cpu_im2col_cycles: u64,
    /// CPU cycles *hidden* under CGRA execution (the paper overlaps the
    /// MCU's reordering with the CGRA run; only the excess shows up in
    /// latency).
    pub cpu_hidden_cycles: u64,
    /// CPU cycles of a CPU-only execution (only for `Mapping::Cpu`).
    pub cpu_compute_cycles: u64,
    /// Number of CGRA launches.
    pub launches: u64,
}

impl LatencyBreakdown {
    /// End-to-end latency in cycles: CGRA serial path + launches + the
    /// im2col work that could not be hidden + pure-CPU compute.
    pub fn total_cycles(&self) -> u64 {
        self.cgra_cycles
            + self.launch_cycles
            + self.cpu_im2col_cycles.saturating_sub(self.cpu_hidden_cycles)
            + self.cpu_compute_cycles
    }

    /// Cycles during which the CPU was actively working (energy model).
    pub fn cpu_active_cycles(&self) -> u64 {
        self.cpu_im2col_cycles + self.launch_cycles + self.cpu_compute_cycles
    }
}

/// Everything a mapping execution produces.
#[derive(Clone, Debug)]
pub struct ConvOutcome {
    /// Which strategy ran.
    pub mapping: Mapping,
    /// The layer shape.
    pub shape: ConvShape,
    /// Output tensor (K, Ox, Oy), bit-exact wrapping int32.
    pub output: TensorChw,
    /// Latency decomposition.
    pub latency: LatencyBreakdown,
    /// Merged CGRA run statistics (zeroed for the CPU baseline).
    pub cgra_stats: RunStats,
    /// CPU-side memory traffic (im2col copies / CPU-baseline accesses),
    /// charged separately from the CGRA's DMA traffic.
    pub cpu_mem: MemStats,
    /// Memory footprint in bytes (paper's "memory usage" metric).
    pub footprint_bytes: usize,
}

impl ConvOutcome {
    /// MAC/cycle — the paper's headline performance metric.
    pub fn macs_per_cycle(&self) -> f64 {
        self.shape.macs() as f64 / self.latency.total_cycles().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_regions_are_disjoint_and_ordered() {
        let s = ConvShape::baseline();
        let cfg = CgraConfig::default();
        let l = MemLayout::new(&s, 100, &cfg).unwrap();
        assert!(l.input < l.weights);
        assert_eq!(l.weights - l.input, s.input_elems());
        assert_eq!(l.output - l.weights, s.weight_elems());
        assert_eq!(l.im2col - l.output, s.output_elems());
        assert_eq!(l.scratch - l.im2col, 100);
        assert!(l.total_words > l.scratch);
    }

    #[test]
    fn layout_rejects_oversized_layers() {
        let s = ConvShape::new3x3(144, 144, 64, 64);
        let cfg = CgraConfig::default();
        assert!(MemLayout::new(&s, 0, &cfg).is_err());
    }

    #[test]
    fn mapping_parse_roundtrip() {
        for m in Mapping::ALL.into_iter().chain(Mapping::DEPTHWISE) {
            assert_eq!(Mapping::parse(m.label()).unwrap(), m);
        }
        assert_eq!(Mapping::parse(Mapping::Auto.label()).unwrap(), Mapping::Auto);
        assert!(Mapping::parse("bogus").is_err());
    }

    #[test]
    fn mapping_parse_is_case_insensitive() {
        assert_eq!(Mapping::parse("WP").unwrap(), Mapping::Wp);
        assert_eq!(Mapping::parse("Conv-WP").unwrap(), Mapping::Wp);
        assert_eq!(Mapping::parse("IM2COL-OP").unwrap(), Mapping::OpIm2col);
        assert_eq!(Mapping::parse("AuTo").unwrap(), Mapping::Auto);
        assert_eq!(Mapping::parse("CPU").unwrap(), Mapping::Cpu);
        assert_eq!(Mapping::parse("Depthwise").unwrap(), Mapping::DwWp);
        assert_eq!(Mapping::parse("DW").unwrap(), Mapping::DwWp);
    }

    #[test]
    fn mapping_parse_error_lists_valid_values() {
        let err = format!("{:#}", Mapping::parse("bogus").unwrap_err());
        for name in ["wp", "ip", "op-im2col", "op-direct", "cpu", "auto", "dw-wp", "depthwise"]
        {
            assert!(err.contains(name), "error should list '{name}': {err}");
        }
        // The canonical names appear in sorted order.
        let canon = ["auto", "conv-op", "cpu", "dw-wp", "im2col-ip", "im2col-op", "; wp"];
        let pos: Vec<usize> = canon.iter().map(|n| err.find(n).expect(n)).collect();
        assert!(pos.windows(2).all(|w| w[0] < w[1]), "not sorted: {err}");
    }

    #[test]
    fn auto_resolves_to_wp_when_direct_fits() {
        let cfg = CgraConfig::default();
        let (m, reason) = Mapping::Auto.resolve(&ConvShape::baseline(), &cfg).unwrap();
        assert_eq!(m, Mapping::Wp);
        assert!(reason.contains("hyperparameter"), "reason: {reason}");
        // Concrete mappings resolve to themselves.
        for m in Mapping::ALL {
            assert_eq!(m.resolve(&ConvShape::baseline(), &cfg).unwrap().0, m);
        }
    }

    #[test]
    fn auto_resolve_respects_memory_bound() {
        // A layer too big for the 512 KiB bound: Auto must error with
        // the same actionable message the layouts give.
        let s = ConvShape::new3x3(144, 144, 64, 64);
        let err = Mapping::Auto.resolve(&s, &CgraConfig::default()).unwrap_err();
        assert!(format!("{err:#}").contains("512"), "{err:#}");
    }

    #[test]
    fn auto_resolve_over_bound_error_names_both_working_sets() {
        let s = ConvShape::new3x3(144, 144, 64, 64);
        let err = format!("{:#}", Mapping::Auto.resolve(&s, &CgraConfig::default()).unwrap_err());
        assert!(err.contains("direct convolution needs"), "{err}");
        assert!(err.contains("im2col route needs"), "{err}");
        // Both computed sizes appear, in words and KiB.
        let direct = MemLayout::required_words(&s, 0);
        let im2col = MemLayout::required_words(&s, 2 * crate::conv::patch_len(&s));
        assert!(err.contains(&direct.to_string()), "{err}");
        assert!(err.contains(&im2col.to_string()), "{err}");
        assert!(err.contains("KiB"), "{err}");
    }

    #[test]
    fn required_words_matches_layout_total() {
        let cfg = CgraConfig::default();
        for (shape, aux) in [
            (ConvShape::baseline(), 0usize),
            (ConvShape::new3x3(3, 5, 7, 2), 123),
            (ConvShape::new3x3(1, 1, 1, 1), 0),
        ] {
            let l = MemLayout::new(&shape, aux, &cfg).unwrap();
            assert_eq!(l.total_words, MemLayout::required_words(&shape, aux), "{shape}");
        }
    }

    #[test]
    fn latency_totals() {
        let l = LatencyBreakdown {
            cgra_cycles: 100,
            launch_cycles: 10,
            cpu_im2col_cycles: 50,
            cpu_hidden_cycles: 30,
            cpu_compute_cycles: 0,
            launches: 2,
        };
        assert_eq!(l.total_cycles(), 100 + 10 + 20);
        assert_eq!(l.cpu_active_cycles(), 60);
    }

    #[test]
    fn im2col_flag() {
        assert!(Mapping::Ip.uses_im2col());
        assert!(Mapping::OpIm2col.uses_im2col());
        assert!(!Mapping::Wp.uses_im2col());
        assert!(!Mapping::OpDirect.uses_im2col());
    }
}

/// Host (CPU) cost model for work the MCU does around the CGRA:
/// building im2col patches and preparing padded buffers.
///
/// The paper overlaps the MCU's reordering with CGRA execution (§2.3
/// Energy); the drivers charge `im2col_cycles_per_elem × elements`
/// per patch and hide up to the concurrent CGRA run time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HostCostModel {
    /// CPU cycles per element copied into an im2col patch (load + store
    /// + address bookkeeping on an in-order RV32 core).
    pub im2col_cycles_per_elem: u64,
    /// CPU cycles per element of one-time buffer preparation (padded
    /// weight images etc.).
    pub prep_cycles_per_elem: u64,
}

impl Default for HostCostModel {
    fn default() -> Self {
        HostCostModel { im2col_cycles_per_elem: 3, prep_cycles_per_elem: 3 }
    }
}
