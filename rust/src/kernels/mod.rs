//! The paper's four convolution mapping strategies as CGRA program
//! generators, plus the shared host-driver plumbing and a dispatcher.
//!
//! The per-mapping generators (`wp::run`, `ip::run`, …) remain the
//! low-level one-shot API and expose the full [`ConvOutcome`] including
//! raw `RunStats`; [`prebuilt::CompiledKernel`] is their build/run
//! split — programs built and decoded once, replayed many times — for
//! the compile-once / run-many serving path. Session-level execution —
//! config/energy/worker/cache ownership, batching, `Mapping::Auto`
//! decisions — lives one layer up in [`crate::engine`].

pub mod common;
pub mod dw;
pub mod ip;
pub mod op_direct;
pub mod op_im2col;
pub mod prebuilt;
pub mod wp;

pub use common::{
    arena_allocs, program_builds, ConvOutcome, HostCostModel, LatencyBreakdown, Mapping,
    MemLayout,
};
pub use prebuilt::{BatchKernelScratch, CompiledKernel, KernelScratch, ScratchNeed};

use anyhow::Result;

use crate::cgra::Cgra;
use crate::conv::{ConvShape, TensorChw, Weights};
use crate::cpu_ref::CpuModel;

/// Dispatch one convolution to the chosen strategy's generator.
/// `Mapping::Auto` is resolved against the simulator's config first
/// (callers that need the decision recorded resolve it themselves —
/// see `engine::Engine::submit`).
pub(crate) fn dispatch(
    cgra: &Cgra,
    mapping: Mapping,
    shape: &ConvShape,
    input: &TensorChw,
    weights: &Weights,
) -> Result<ConvOutcome> {
    if mapping.is_auto() {
        let (concrete, _reason) = Mapping::Auto.resolve(shape, cgra.config())?;
        return dispatch(cgra, concrete, shape, input, weights);
    }
    // Aggregate the conv's walks under its mapping label when a
    // profiling session is active (DESIGN.md §12). The frame folds
    // into any enclosing frame, so callers that scope their own
    // (e.g. `planner::bottleneck_check`) still see the full delta.
    let fr = crate::obs::profile::frame();
    let out = match mapping {
        Mapping::Auto => unreachable!("resolved above"),
        Mapping::Wp => wp::run(cgra, shape, input, weights),
        Mapping::Ip => ip::run(cgra, shape, input, weights),
        Mapping::OpIm2col => op_im2col::run(cgra, shape, input, weights),
        Mapping::OpDirect => op_direct::run(cgra, shape, input, weights),
        // The depthwise operator: shape convention k == c, weights
        // (C, 1, 3, 3) — callers route depthwise layers here explicitly
        // (the nn lowering, `cgra run --mapping dw`).
        Mapping::DwWp => dw::run(cgra, shape, input, weights),
        Mapping::Cpu => {
            // The CPU shares the same 512 KiB system RAM: the paper's
            // sweep bound applies to it too.
            MemLayout::new(shape, 0, cgra.config())?;
            crate::cpu_ref::run(&CpuModel::default(), shape, input, weights)
        }
    }?;
    if let Some(d) = fr.finish() {
        // The CPU baseline performs no CGRA walks; nothing to file.
        if d.walks > 0 {
            crate::obs::profile::record_walk(mapping.label(), &d);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::CgraConfig;
    use crate::conv::{conv2d, random_input, random_weights};
    use crate::prop::Rng;

    /// All five strategies agree bit-exactly with the golden model on a
    /// shape that exercises padding, imbalance and multi-tile paths.
    #[test]
    fn all_mappings_agree() {
        let shape = ConvShape::new3x3(5, 17, 4, 3);
        let mut rng = Rng::new(33);
        let input = random_input(&shape, 60, &mut rng);
        let weights = random_weights(&shape, 11, &mut rng);
        let golden = conv2d(&shape, &input, &weights);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        for m in Mapping::ALL {
            let out = dispatch(&cgra, m, &shape, &input, &weights).unwrap();
            assert_eq!(out.output.data, golden.data, "{m} disagrees with golden");
            assert!(out.latency.total_cycles() > 0);
        }
    }

    /// `Mapping::Auto` dispatches through the resolver and matches an
    /// explicit WP run bit-for-bit (incl. timing).
    #[test]
    fn auto_dispatch_matches_resolved_mapping() {
        let shape = ConvShape::new3x3(3, 5, 6, 4);
        let mut rng = Rng::new(9);
        let input = random_input(&shape, 25, &mut rng);
        let weights = random_weights(&shape, 9, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let auto = dispatch(&cgra, Mapping::Auto, &shape, &input, &weights).unwrap();
        let wp = dispatch(&cgra, Mapping::Wp, &shape, &input, &weights).unwrap();
        assert_eq!(auto.mapping, Mapping::Wp);
        assert_eq!(auto.output.data, wp.output.data);
        assert_eq!(auto.latency.total_cycles(), wp.latency.total_cycles());
    }

}
