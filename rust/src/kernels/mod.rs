//! The paper's four convolution mapping strategies as CGRA program
//! generators, plus the shared host-driver plumbing and a dispatcher.

pub mod common;
pub mod ip;
pub mod op_direct;
pub mod op_im2col;
pub mod wp;

pub use common::{ConvOutcome, HostCostModel, LatencyBreakdown, Mapping, MemLayout};

use anyhow::Result;

use crate::cgra::Cgra;
use crate::conv::{ConvShape, TensorChw, Weights};
use crate::cpu_ref::CpuModel;

/// Run one convolution with the chosen strategy.
pub fn run_mapping(
    cgra: &Cgra,
    mapping: Mapping,
    shape: &ConvShape,
    input: &TensorChw,
    weights: &Weights,
) -> Result<ConvOutcome> {
    match mapping {
        Mapping::Wp => wp::run(cgra, shape, input, weights),
        Mapping::Ip => ip::run(cgra, shape, input, weights),
        Mapping::OpIm2col => op_im2col::run(cgra, shape, input, weights),
        Mapping::OpDirect => op_direct::run(cgra, shape, input, weights),
        Mapping::Cpu => {
            // The CPU shares the same 512 KiB system RAM: the paper's
            // sweep bound applies to it too.
            MemLayout::new(shape, 0, cgra.config())?;
            crate::cpu_ref::run(&CpuModel::default(), shape, input, weights)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::CgraConfig;
    use crate::conv::{conv2d, random_input, random_weights};
    use crate::prop::Rng;

    /// All five strategies agree bit-exactly with the golden model on a
    /// shape that exercises padding, imbalance and multi-tile paths.
    #[test]
    fn all_mappings_agree() {
        let shape = ConvShape::new3x3(5, 17, 4, 3);
        let mut rng = Rng::new(33);
        let input = random_input(&shape, 60, &mut rng);
        let weights = random_weights(&shape, 11, &mut rng);
        let golden = conv2d(&shape, &input, &weights);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        for m in Mapping::ALL {
            let out = run_mapping(&cgra, m, &shape, &input, &weights).unwrap();
            assert_eq!(out.output.data, golden.data, "{m} disagrees with golden");
            assert!(out.latency.total_cycles() > 0);
        }
    }
}
