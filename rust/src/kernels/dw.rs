//! **Dw-WP — depthwise convolution with weight parallelism.**
//!
//! Depthwise convolution is exactly the WP dataflow with one input
//! channel per output channel: channel `c` of the output is channel `c`
//! of the input convolved with its own 3×3 filter, no cross-channel
//! accumulation. So this kernel *reuses the WP launch machinery* rather
//! than forking it: every launch is [`wp::build_program`] on a
//! `C = K = 1` shape — the `ci == 0` / no-accumulate launch class WP
//! already has — with the per-channel input/weight/output base
//! addresses supplied through the launch's [`MemLayout`]. One memory
//! image holds the whole layer; the layer runs in `C` launches (vs
//! `K·C` for dense WP).
//!
//! Shape convention: `shape.k == shape.c` (one filter per channel),
//! weights `(C, 1, 3, 3)`. Strided/padded depthwise layers are lowered
//! by `nn` (host pad + output decimation) around this stride-1 core,
//! like every other kernel in this crate.

use anyhow::{ensure, Result};

use crate::cgra::{decode, decode_cached, Cgra, RunStats, DECODE_CACHE_CAPACITY};
use crate::conv::{ConvShape, TensorChw, Weights};
use crate::isa::Program;

use super::common::{ConvOutcome, LatencyBreakdown, Mapping, MemLayout};
use super::wp::{self, WpLaunch};

/// Word addresses of the depthwise memory image:
/// `[input (C·ih·iw) | weights (C·9) | output (C·Ox·Oy) | margin]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DwLayout {
    /// Input tensor base (CHW).
    pub input: usize,
    /// Weights base (`(C, 1, 3, 3)` flattened).
    pub weights: usize,
    /// Output tensor base (CHW).
    pub output: usize,
    /// Total words used (including the WP pipeline-overshoot margin).
    pub total_words: usize,
}

/// Words a depthwise layer requires: the three tensor regions plus the
/// same pipeline-overshoot margin the dense WP layout reserves (the
/// loaders read two rows past the last channel's input; with no
/// accumulation there is no prev-partial overshoot).
pub fn required_words(shape: &ConvShape) -> usize {
    shape.c * shape.ih() * shape.iw() + shape.c * 9 + shape.c * shape.ox * shape.oy
        + 2 * shape.iw()
        + 16
}

/// Depthwise memory usage in bytes (the paper's footprint metric):
/// input + one single-channel filter per channel + output.
pub fn footprint_bytes(shape: &ConvShape) -> usize {
    4 * (shape.c * shape.ih() * shape.iw() + shape.c * 9 + shape.c * shape.ox * shape.oy)
}

/// Validate the depthwise shape convention and build the layout under
/// the memory bound (same actionable error style as [`MemLayout::new`]).
pub fn layout(shape: &ConvShape, cfg: &crate::cgra::CgraConfig) -> Result<DwLayout> {
    shape.validate()?;
    ensure!(
        shape.k == shape.c,
        "depthwise convention: K must equal C (one filter per channel), got {shape}"
    );
    let total_words = required_words(shape);
    ensure!(
        total_words <= cfg.mem_words,
        "depthwise layer {shape} needs {total_words} words but the memory holds {} \
         (the paper bounds its sweep by the 512 KiB HEEPsilon RAM the same way)",
        cfg.mem_words
    );
    let input = 0;
    let weights = input + shape.c * shape.ih() * shape.iw();
    let output = weights + shape.c * 9;
    Ok(DwLayout { input, weights, output, total_words })
}

/// The per-launch `C = K = 1` view of the layer (what the WP generator
/// sees for one channel).
fn channel_shape(shape: &ConvShape) -> ConvShape {
    ConvShape::new3x3(1, 1, shape.ox, shape.oy)
}

/// Build channel `g`'s launch program: [`wp::build_program`] on the
/// single-channel shape, with the layout's bases shifted to channel
/// `g`'s slices. The WP generator reads only the `input`/`weights`/
/// `output` bases from the layout, so the shifted copy is a complete
/// description of the launch.
pub fn build_channel_program(shape: &ConvShape, lay: &DwLayout, g: usize) -> Program {
    let per_ch = MemLayout {
        input: lay.input + g * shape.ih() * shape.iw(),
        weights: lay.weights + g * 9,
        output: lay.output + g * shape.ox * shape.oy,
        im2col: lay.total_words,
        im2col_words: 0,
        scratch: lay.total_words,
        total_words: lay.total_words,
    };
    wp::build_program(&channel_shape(shape), &per_ch, WpLaunch { k: 0, ci: 0, acc: false })
}

/// Execute the full depthwise convolution with the Dw-WP mapping.
pub fn run(
    cgra: &Cgra,
    shape: &ConvShape,
    input: &TensorChw,
    weights: &Weights,
) -> Result<ConvOutcome> {
    let cfg = cgra.config();
    let lay = layout(shape, cfg)?;
    ensure!(
        weights.k == shape.c && weights.c == 1 && weights.fy == 3 && weights.fx == 3,
        "depthwise weights must be (C={}, 1, 3, 3), got ({}, {}, {}, {})",
        shape.c,
        weights.k,
        weights.c,
        weights.fy,
        weights.fx
    );
    ensure!(
        input.c == shape.c && input.h == shape.ih() && input.w == shape.iw(),
        "depthwise input must be ({}, {}, {}), got ({}, {}, {})",
        shape.c,
        shape.ih(),
        shape.iw(),
        input.c,
        input.h,
        input.w
    );
    let mut mem = crate::cgra::Memory::new(cfg.mem_words, cfg.n_banks);
    mem.poke_slice(lay.input, &input.data);
    mem.poke_slice(lay.weights, &weights.data);

    let mut stats = RunStats::new();
    stats.exited = true;
    let mut launches = 0u64;
    // Same memoization policy as dense WP: decode-cache the lowering
    // when the layer's launch set fits with headroom.
    let memoize = shape.c <= DECODE_CACHE_CAPACITY / 2;
    for g in 0..shape.c {
        let prog = build_channel_program(shape, &lay, g);
        let s = if memoize {
            cgra.run_decoded(&decode_cached(&prog), &mut mem)?
        } else {
            cgra.run_decoded(&decode(&prog), &mut mem)?
        };
        stats.merge(&s);
        launches += 1;
    }

    let output = TensorChw::from_vec(
        shape.k,
        shape.ox,
        shape.oy,
        mem.peek_slice(lay.output, shape.k * shape.ox * shape.oy).to_vec(),
    );
    let latency = LatencyBreakdown {
        cgra_cycles: stats.cycles,
        launch_cycles: launches * cfg.launch_overhead + cfg.instruction_load_overhead,
        launches,
        ..Default::default()
    };
    Ok(ConvOutcome {
        mapping: Mapping::DwWp,
        shape: *shape,
        output,
        latency,
        cgra_stats: stats,
        cpu_mem: Default::default(),
        footprint_bytes: footprint_bytes(shape),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::CgraConfig;
    use crate::conv::{depthwise2d, random_depthwise_weights, random_input};
    use crate::prop::Rng;

    fn check_shape(shape: ConvShape, seed: u64) {
        let mut rng = Rng::new(seed);
        let input = random_input(&shape, 50, &mut rng);
        let weights = random_depthwise_weights(&shape, 9, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let out = run(&cgra, &shape, &input, &weights).unwrap();
        let golden = depthwise2d(&shape, &input, &weights);
        assert_eq!(out.output.data, golden.data, "Dw-WP mismatch on {shape}");
        assert_eq!(out.latency.launches, shape.c as u64, "one launch per channel");
    }

    #[test]
    fn single_channel_is_plain_wp() {
        check_shape(ConvShape::new3x3(1, 1, 3, 4), 1);
    }

    #[test]
    fn multi_channel_depthwise_exact() {
        check_shape(ConvShape::new3x3(5, 5, 4, 6), 2);
        check_shape(ConvShape::new3x3(16, 16, 8, 8), 3);
    }

    #[test]
    fn rectangular_and_tiny_outputs() {
        check_shape(ConvShape::new3x3(3, 3, 1, 5), 4);
        check_shape(ConvShape::new3x3(2, 2, 5, 1), 5);
    }

    /// Dw-WP runs C launches where dense WP runs K·C, and does C× less
    /// multiply work on the same channel count.
    #[test]
    fn launch_count_is_linear_in_channels() {
        let shape = ConvShape::new3x3(8, 8, 6, 6);
        let mut rng = Rng::new(6);
        let input = random_input(&shape, 20, &mut rng);
        let dw_w = random_depthwise_weights(&shape, 9, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let dw = run(&cgra, &shape, &input, &dw_w).unwrap();
        assert_eq!(dw.latency.launches, 8);
        let dense_w = crate::conv::random_weights(&shape, 9, &mut rng);
        let dense = wp::run(&cgra, &shape, &input, &dense_w).unwrap();
        assert_eq!(dense.latency.launches, 64);
        assert!(dense.latency.total_cycles() > 7 * dw.latency.total_cycles());
    }

    #[test]
    fn rejects_non_depthwise_shapes_and_bad_weights() {
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let mut rng = Rng::new(7);
        // K != C.
        let bad = ConvShape::new3x3(4, 5, 4, 4);
        let input = random_input(&bad, 5, &mut rng);
        let w = random_depthwise_weights(&ConvShape::new3x3(5, 5, 4, 4), 5, &mut rng);
        let err = format!("{:#}", run(&cgra, &bad, &input, &w).unwrap_err());
        assert!(err.contains("K must equal C"), "{err}");
        // Dense weights on a depthwise run.
        let shape = ConvShape::new3x3(4, 4, 4, 4);
        let input = random_input(&shape, 5, &mut rng);
        let dense = crate::conv::random_weights(&shape, 5, &mut rng);
        let err = format!("{:#}", run(&cgra, &shape, &input, &dense).unwrap_err());
        assert!(err.contains("(C=4, 1, 3, 3)"), "{err}");
    }

    #[test]
    fn memory_bound_is_enforced_actionably() {
        let shape = ConvShape::new3x3(64, 64, 64, 64);
        let mut cfg = CgraConfig::default();
        cfg.mem_words = 2048;
        let err = format!("{:#}", layout(&shape, &cfg).unwrap_err());
        assert!(err.contains("words") && err.contains("2048"), "{err}");
    }
}
