//! **Compile-once / run-many kernel artifacts** — the build/run split of
//! the mapping kernels.
//!
//! Every `kernels::*::run` entry point interleaves three kinds of work:
//! *compile-side* work (building launch `Program`s, lowering them into
//! the µop IR, fixing the `MemLayout`, reordering weight images) and
//! *run-side* work (poking tensors, replaying launches, the modeled
//! per-inference host glue). For one-shot submissions that is fine; for
//! serving repeated inference traffic it re-lowers the same programs on
//! every call. [`CompiledKernel::build`] hoists all compile-side work
//! out once:
//!
//! - launch programs are built **and pre-decoded** into owned
//!   [`DecodedProgram`]s (`Arc`-shared so grouped layers and pool
//!   workers share one copy),
//! - the [`MemLayout`] / [`dw::DwLayout`] is frozen,
//! - weight-derived memory images (raw banks, the im2col weight matrix,
//!   IP's zero-padded lane image) are precomputed as pokeable blocks,
//!
//! so [`CompiledKernel::run_into`] only pokes tensors, replays the
//! decoded launches, and accounts — **zero program building, zero µop
//! decoding, zero heap allocation** (scratch lives in the caller's
//! [`KernelScratch`] arena, sized once via [`ScratchNeed`]).
//!
//! Replay is *bit-exact* with the legacy entry points by construction:
//! the same launch schedule in the same order against the same layout
//! produces the same `RunStats`, and the accounting formulas are the
//! ones the legacy drivers use (timing in this simulator is
//! data-independent, and every memory word a launch reads is freshly
//! written by the same run, so reusing an arena `Memory` across runs
//! and layers cannot change results — see DESIGN.md §8).
//!
//! The **modeled** cycles/energy are unchanged on purpose: the modeled
//! MCU still converts layouts and stages im2col patches per inference
//! (that work is data-dependent), so a `CompiledKernel` accelerates the
//! *simulator's* serving throughput (host wall-clock), not the modeled
//! hardware.

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::cgra::{
    decode, decode_cached, BatchMemory, Cgra, CgraConfig, DecodedProgram, Memory, MemStats,
    OpClass, ProgTable, RunStats, DECODE_CACHE_CAPACITY,
};
use crate::conv::{im2col_patch, patch_len, ConvShape, TensorChw, TensorHwc, Weights};
use crate::cpu_ref::CpuModel;
use crate::isa::N_PES;
use crate::obs::{profile, trace};
use crate::util::wire::{Reader, Writer};

use super::common::{ConvOutcome, HostCostModel, LatencyBreakdown, Mapping, MemLayout};
use super::{dw, ip, op_direct, op_im2col, wp};

/// One pokeable region of the kernel's initial memory image: everything
/// weight-derived, precomputed at build time and rewritten at the start
/// of every run (the arena `Memory` is shared across layers, so each
/// run re-establishes its own image; zero-padding blocks are explicit
/// instead of relying on a fresh zeroed memory).
#[derive(Clone, Debug)]
struct InitBlock {
    base: usize,
    data: Vec<i32>,
}

/// IP's zero-padded per-lane weight image: each output channel's bank
/// embedded at the head of a `patch_words`-wide row, padding lanes
/// explicitly zero. Shared by `build` and `with_weights` so sibling
/// kernels can never disagree with freshly built ones.
fn ip_padded_image(shape: &ConvShape, patch_words: usize, weights: &Weights) -> Vec<i32> {
    let mut image = vec![0i32; shape.k * patch_words];
    for k in 0..shape.k {
        image[k * patch_words..k * patch_words + shape.c * 9]
            .copy_from_slice(&weights.data[k * shape.c * 9..(k + 1) * shape.c * 9]);
    }
    image
}

/// The depthwise weight convention check shared by `build` and
/// `with_weights` (same message as the `dw` kernel's).
fn ensure_dw_weights(shape: &ConvShape, weights: &Weights) -> Result<()> {
    ensure!(
        weights.k == shape.c && weights.c == 1 && weights.fy == 3 && weights.fx == 3,
        "depthwise weights must be (C={}, 1, 3, 3), got ({}, {}, {}, {})",
        shape.c,
        weights.k,
        weights.c,
        weights.fy,
        weights.fx
    );
    Ok(())
}

/// Per-mapping frozen execution plan.
#[derive(Clone, Debug)]
enum Plan {
    /// WP: launches in (k, ci) order, `acc = ci > 0`.
    Wp { layout: MemLayout },
    /// Dw-WP: one launch per channel.
    Dw { lay: dw::DwLayout },
    /// Conv-OP: launches in (k_tile, fy, fx, y) order.
    OpDirect { layout: MemLayout },
    /// Im2col-OP: launches in (k_tile, pixel) order; the host stages one
    /// patch per (k_tile, pixel) into the ping-pong slot.
    OpIm2col { layout: MemLayout, pl: usize, w_prep_elems: u64 },
    /// Im2col-IP: launches in (pixel, k) order; channel-major patches
    /// padded to `cp` lanes.
    Ip { layout: MemLayout, cp: usize, w_prep_elems: u64 },
    /// CPU baseline: closed-form cycles, golden compute, no launches.
    Cpu,
}

/// What a [`CompiledKernel`] needs from the caller's scratch arena
/// (take the element-wise max over kernels sharing one arena).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScratchNeed {
    /// HWC staging elements (im2col mappings convert the input layout
    /// per run).
    pub hwc_elems: usize,
    /// Patch staging elements.
    pub patch_elems: usize,
}

impl ScratchNeed {
    /// Element-wise maximum of two needs.
    pub fn max(self, other: ScratchNeed) -> ScratchNeed {
        ScratchNeed {
            hwc_elems: self.hwc_elems.max(other.hwc_elems),
            patch_elems: self.patch_elems.max(other.patch_elems),
        }
    }
}

/// Reusable run-time scratch shared by every [`CompiledKernel`] of one
/// execution context: the CGRA memory image and the host staging
/// buffers. Allocated once (counted by [`super::common::arena_allocs`])
/// and reused for every layer of every inference.
pub struct KernelScratch {
    /// The CGRA memory image (one per context; layers overwrite each
    /// other's regions, each run re-pokes everything it reads).
    pub mem: Memory,
    hwc: TensorHwc,
    patch: Vec<i32>,
}

impl KernelScratch {
    /// Allocate scratch for a configuration and the max [`ScratchNeed`]
    /// over the kernels that will share it.
    pub fn new(cfg: &CgraConfig, need: ScratchNeed) -> KernelScratch {
        super::common::note_arena_alloc();
        KernelScratch {
            mem: Memory::new(cfg.mem_words, cfg.n_banks),
            hwc: TensorHwc { h: 0, w: 0, c: 0, data: Vec::with_capacity(need.hwc_elems) },
            patch: Vec::with_capacity(need.patch_elems),
        }
    }

    /// Reshape the HWC staging buffer (allocation-free while within the
    /// arena capacity; growth is counted as an arena allocation).
    fn hwc_for(&mut self, c: usize, h: usize, w: usize) {
        let elems = c * h * w;
        if elems > self.hwc.data.capacity() {
            super::common::note_arena_alloc();
        }
        self.hwc.data.resize(elems, 0);
        self.hwc.h = h;
        self.hwc.w = w;
        self.hwc.c = c;
    }

    /// Reshape the patch staging buffer.
    fn patch_for(&mut self, elems: usize) {
        if elems > self.patch.capacity() {
            super::common::note_arena_alloc();
        }
        self.patch.resize(elems, 0);
    }
}

/// The batched counterpart of [`KernelScratch`]: one structure-of-arrays
/// [`BatchMemory`] image plus per-lane HWC staging tensors, shared by
/// every [`CompiledKernel::run_batch_into`] replay of one execution
/// context. Allocated once per `(config, batch, need)` — counted by
/// [`super::common::arena_allocs`] — and reused across layers and
/// batches; runs may use any `1..=batch_capacity()` lanes.
pub struct BatchKernelScratch {
    /// The batched CGRA memory image (layers overwrite each other's
    /// regions; every run re-pokes everything it reads, per lane).
    pub mem: BatchMemory,
    hwc: Vec<TensorHwc>,
    patch: Vec<i32>,
}

impl BatchKernelScratch {
    /// Allocate scratch for `batch` lanes under a configuration and the
    /// max [`ScratchNeed`] over the kernels that will share it.
    pub fn new(cfg: &CgraConfig, need: ScratchNeed, batch: usize) -> BatchKernelScratch {
        assert!(batch >= 1);
        super::common::note_arena_alloc();
        BatchKernelScratch {
            mem: BatchMemory::new(cfg.mem_words, cfg.n_banks, batch),
            hwc: (0..batch)
                .map(|_| TensorHwc { h: 0, w: 0, c: 0, data: Vec::with_capacity(need.hwc_elems) })
                .collect(),
            patch: Vec::with_capacity(need.patch_elems),
        }
    }

    /// Number of lanes this scratch was allocated for.
    pub fn batch_capacity(&self) -> usize {
        self.mem.batch_capacity()
    }

    /// Reshape one lane's HWC staging tensor (allocation-free within
    /// the arena capacity; growth is counted as an arena allocation).
    fn hwc_for(&mut self, lane: usize, c: usize, h: usize, w: usize) {
        let t = &mut self.hwc[lane];
        let elems = c * h * w;
        if elems > t.data.capacity() {
            super::common::note_arena_alloc();
        }
        t.data.resize(elems, 0);
        t.h = h;
        t.w = w;
        t.c = c;
    }

    /// Reshape the (lane-shared) patch staging buffer.
    fn patch_for(&mut self, elems: usize) {
        if elems > self.patch.capacity() {
            super::common::note_arena_alloc();
        }
        self.patch.resize(elems, 0);
    }
}

/// A convolution compiled for one `(shape, mapping, weights, config)`
/// point: frozen layout, pre-decoded launch programs, precomputed
/// weight image. Build once with [`CompiledKernel::build`], replay any
/// number of times with [`CompiledKernel::run_into`].
///
/// `CompiledKernel` is immutable after build and `Send + Sync`: one
/// instance (inside an `Arc`-shared `CompiledNet`) serves every pool
/// worker concurrently, each worker replaying against its own
/// [`KernelScratch`].
#[derive(Clone, Debug)]
pub struct CompiledKernel {
    mapping: Mapping,
    shape: ConvShape,
    plan: Plan,
    /// Pre-decoded launch programs, in exact launch order.
    progs: Vec<Arc<DecodedProgram>>,
    /// Weight-derived memory blocks re-poked at the start of each run.
    init: Vec<InitBlock>,
    footprint_bytes: usize,
}

impl CompiledKernel {
    /// Compile one convolution: validate the shape/weights for the
    /// concrete `mapping` under `cfg`, freeze the memory layout, build
    /// and decode every launch program, and bake the weight image.
    /// Fails with the kernels' own actionable errors (memory bound,
    /// depthwise weight convention, …).
    pub fn build(
        cfg: &CgraConfig,
        shape: &ConvShape,
        mapping: Mapping,
        weights: &Weights,
    ) -> Result<CompiledKernel> {
        shape.validate()?;
        ensure!(!mapping.is_auto(), "compile needs a concrete mapping — resolve Auto first");
        let dense_elems = shape.weight_elems();
        match mapping {
            Mapping::DwWp => {}
            _ => ensure!(
                weights.data.len() == dense_elems,
                "weight tensor has {} elements, {} on shape {} needs {}",
                weights.data.len(),
                mapping,
                shape,
                dense_elems
            ),
        }
        match mapping {
            Mapping::Wp => {
                let layout = MemLayout::new(shape, 0, cfg)?;
                // Same memo policy as the legacy driver: route decodes
                // through the process-wide cache when the launch set
                // fits with headroom, so repeated compiles of one net
                // (the per-call `run_network` path) share `Arc`s
                // instead of re-lowering k·c programs every time.
                let memoize = shape.k * shape.c <= DECODE_CACHE_CAPACITY / 2;
                let mut progs = Vec::with_capacity(shape.k * shape.c);
                for k in 0..shape.k {
                    for ci in 0..shape.c {
                        let prog = wp::build_program(
                            shape,
                            &layout,
                            wp::WpLaunch { k, ci, acc: ci > 0 },
                        );
                        progs.push(if memoize {
                            decode_cached(&prog)
                        } else {
                            Arc::new(decode(&prog))
                        });
                    }
                }
                Ok(CompiledKernel {
                    mapping,
                    shape: *shape,
                    plan: Plan::Wp { layout },
                    progs,
                    init: vec![InitBlock { base: layout.weights, data: weights.data.clone() }],
                    footprint_bytes: shape.base_bytes(),
                })
            }
            Mapping::DwWp => {
                let lay = dw::layout(shape, cfg)?;
                ensure_dw_weights(shape, weights)?;
                let memoize = shape.c <= DECODE_CACHE_CAPACITY / 2;
                let progs = (0..shape.c)
                    .map(|g| {
                        let prog = dw::build_channel_program(shape, &lay, g);
                        if memoize {
                            decode_cached(&prog)
                        } else {
                            Arc::new(decode(&prog))
                        }
                    })
                    .collect();
                Ok(CompiledKernel {
                    mapping,
                    shape: *shape,
                    plan: Plan::Dw { lay },
                    progs,
                    init: vec![InitBlock { base: lay.weights, data: weights.data.clone() }],
                    footprint_bytes: dw::footprint_bytes(shape),
                })
            }
            Mapping::OpDirect => {
                let layout = MemLayout::new(shape, 0, cfg)?;
                let mut progs = Vec::new();
                for kt in 0..shape.k.div_ceil(N_PES) {
                    for fy in 0..3 {
                        for fx in 0..3 {
                            for y in 0..shape.ox {
                                let prog = op_direct::build_program(
                                    shape,
                                    &layout,
                                    op_direct::OpDirectLaunch { kt, fy, fx, y },
                                );
                                progs.push(Arc::new(decode(&prog)));
                            }
                        }
                    }
                }
                Ok(CompiledKernel {
                    mapping,
                    shape: *shape,
                    plan: Plan::OpDirect { layout },
                    progs,
                    init: vec![InitBlock { base: layout.weights, data: weights.data.clone() }],
                    footprint_bytes: shape.base_bytes(),
                })
            }
            Mapping::OpIm2col => {
                let pl = patch_len(shape);
                let layout = MemLayout::new(shape, 2 * pl, cfg)?;
                let w_matrix = weights.to_im2col_matrix();
                let w_prep_elems = w_matrix.len() as u64;
                let mut progs = Vec::new();
                for kt in 0..shape.k.div_ceil(N_PES) {
                    for y in 0..shape.ox {
                        for x in 0..shape.oy {
                            let pix = y * shape.oy + x;
                            let slot = layout.im2col + (pix % 2) * pl;
                            let prog = op_im2col::build_program(
                                shape,
                                slot as i32,
                                |l| {
                                    let kp = (kt * N_PES + l).min(shape.k - 1);
                                    (layout.weights + kp * pl) as i32
                                },
                                |l| {
                                    let kp = kt * N_PES + l;
                                    if kp < shape.k {
                                        (layout.output + kp * shape.ox * shape.oy + pix) as i32
                                    } else {
                                        (layout.scratch + l) as i32
                                    }
                                },
                            );
                            progs.push(Arc::new(decode(&prog)));
                        }
                    }
                }
                Ok(CompiledKernel {
                    mapping,
                    shape: *shape,
                    plan: Plan::OpIm2col { layout, pl, w_prep_elems },
                    progs,
                    init: vec![InitBlock { base: layout.weights, data: w_matrix }],
                    footprint_bytes: shape.base_bytes() + 4 * 2 * pl,
                })
            }
            Mapping::Ip => {
                let cp = ip::padded_c(shape);
                let patch_words = cp * 9;
                let padded_w = shape.c != cp;
                let aux_words = 2 * patch_words + if padded_w { shape.k * patch_words } else { 0 };
                let layout = MemLayout::new(shape, aux_words, cfg)?;
                // Weight image: raw bank at `layout.weights`; when C is
                // not a lane multiple, an explicit zero-padded per-lane
                // image replaces the fresh-memory zeros the legacy
                // driver relies on.
                let mut init =
                    vec![InitBlock { base: layout.weights, data: weights.data.clone() }];
                let w_prep_elems = if padded_w {
                    init.push(InitBlock {
                        base: layout.im2col + 2 * patch_words,
                        data: ip_padded_image(shape, patch_words, weights),
                    });
                    (shape.k * shape.c * 9) as u64
                } else {
                    0
                };
                let mut progs = Vec::new();
                let w_image_base =
                    if padded_w { layout.im2col + 2 * patch_words } else { layout.weights };
                for y in 0..shape.ox {
                    for x in 0..shape.oy {
                        let pix = y * shape.oy + x;
                        let slot = layout.im2col + (pix % 2) * patch_words;
                        for k in 0..shape.k {
                            let prog = ip::build_program(
                                shape,
                                slot as i32,
                                (w_image_base + k * patch_words) as i32,
                                (layout.output + k * shape.ox * shape.oy + pix) as i32,
                            );
                            progs.push(Arc::new(decode(&prog)));
                        }
                    }
                }
                Ok(CompiledKernel {
                    mapping,
                    shape: *shape,
                    plan: Plan::Ip { layout, cp, w_prep_elems },
                    progs,
                    init,
                    footprint_bytes: shape.base_bytes() + 4 * aux_words,
                })
            }
            Mapping::Cpu => {
                // The CPU shares the 512 KiB system RAM: same bound as
                // the dispatcher applies.
                MemLayout::new(shape, 0, cfg)?;
                Ok(CompiledKernel {
                    mapping,
                    shape: *shape,
                    plan: Plan::Cpu,
                    progs: Vec::new(),
                    init: vec![InitBlock { base: 0, data: weights.data.clone() }],
                    footprint_bytes: shape.base_bytes(),
                })
            }
            Mapping::Auto => unreachable!("rejected above"),
        }
    }

    /// A sibling kernel sharing this one's decoded programs and layout
    /// but carrying a different weight bank — the grouped-layer case,
    /// where every group runs identical programs over its own filter
    /// slice. Costs only the weight-image rebuild (the `Arc`d programs
    /// are reference-bumped, never re-decoded).
    pub fn with_weights(&self, weights: &Weights) -> Result<CompiledKernel> {
        let mut out = self.clone();
        match &self.plan {
            Plan::Wp { layout } | Plan::OpDirect { layout } => {
                ensure!(weights.data.len() == self.shape.weight_elems(), "weight size mismatch");
                out.init = vec![InitBlock { base: layout.weights, data: weights.data.clone() }];
            }
            Plan::Dw { lay } => {
                ensure_dw_weights(&self.shape, weights)?;
                out.init = vec![InitBlock { base: lay.weights, data: weights.data.clone() }];
            }
            Plan::OpIm2col { layout, .. } => {
                ensure!(weights.data.len() == self.shape.weight_elems(), "weight size mismatch");
                out.init =
                    vec![InitBlock { base: layout.weights, data: weights.to_im2col_matrix() }];
            }
            Plan::Ip { layout, cp, .. } => {
                ensure!(weights.data.len() == self.shape.weight_elems(), "weight size mismatch");
                let patch_words = cp * 9;
                let mut init =
                    vec![InitBlock { base: layout.weights, data: weights.data.clone() }];
                if self.shape.c != *cp {
                    init.push(InitBlock {
                        base: layout.im2col + 2 * patch_words,
                        data: ip_padded_image(&self.shape, patch_words, weights),
                    });
                }
                out.init = init;
            }
            Plan::Cpu => {
                out.init = vec![InitBlock { base: 0, data: weights.data.clone() }];
            }
        }
        Ok(out)
    }

    /// The concrete strategy this kernel replays.
    pub fn mapping(&self) -> Mapping {
        self.mapping
    }

    /// The frozen layer shape.
    pub fn shape(&self) -> &ConvShape {
        &self.shape
    }

    /// CGRA launches one run replays (0 for the CPU baseline).
    pub fn launches(&self) -> u64 {
        self.progs.len() as u64
    }

    /// Pre-decoded µops held by the artifact (compile-size metric).
    pub fn total_uops(&self) -> usize {
        self.progs.iter().map(|p| p.total_uops()).sum()
    }

    /// Memory footprint in bytes (the paper's metric, unchanged from the
    /// legacy driver).
    pub fn footprint_bytes(&self) -> usize {
        self.footprint_bytes
    }

    /// Intern this kernel's decoded programs into the artifact's shared
    /// program table (grouped layers holding the same `Arc`s intern to
    /// the same indices, so the on-disk form deduplicates exactly as
    /// the in-memory form shares).
    pub(crate) fn collect_progs(&self, table: &mut ProgTable) {
        for p in &self.progs {
            table.index_of(p);
        }
    }

    /// Serialize the kernel for the AOT artifact (DESIGN.md §13):
    /// mapping, frozen shape and plan, program-table indices in launch
    /// order, and the baked weight blocks.
    pub(crate) fn wire_encode(&self, w: &mut Writer, table: &mut ProgTable) {
        w.str(self.mapping.label());
        encode_shape(w, &self.shape);
        match &self.plan {
            Plan::Wp { layout } => {
                w.u8(0);
                encode_layout(w, layout);
            }
            Plan::Dw { lay } => {
                w.u8(1);
                w.usize(lay.input);
                w.usize(lay.weights);
                w.usize(lay.output);
                w.usize(lay.total_words);
            }
            Plan::OpDirect { layout } => {
                w.u8(2);
                encode_layout(w, layout);
            }
            Plan::OpIm2col { layout, pl, w_prep_elems } => {
                w.u8(3);
                encode_layout(w, layout);
                w.usize(*pl);
                w.u64(*w_prep_elems);
            }
            Plan::Ip { layout, cp, w_prep_elems } => {
                w.u8(4);
                encode_layout(w, layout);
                w.usize(*cp);
                w.u64(*w_prep_elems);
            }
            Plan::Cpu => w.u8(5),
        }
        w.u32(self.progs.len() as u32);
        for p in &self.progs {
            w.u32(table.index_of(p));
        }
        w.u32(self.init.len() as u32);
        for b in &self.init {
            w.usize(b.base);
            w.vec_i32(&b.data);
        }
        w.usize(self.footprint_bytes);
    }

    /// Reconstruct a kernel from its wire form, resolving launch
    /// programs by index into the artifact's shared table — **no
    /// program building, no µop decoding**. `mem_words` is the loading
    /// session's CGRA memory size; every frozen layout and baked block
    /// is re-validated against it so a corrupted-but-plausible artifact
    /// fails here instead of panicking inside a replay.
    pub(crate) fn wire_decode(
        r: &mut Reader,
        table: &[Arc<DecodedProgram>],
        mem_words: usize,
    ) -> Result<CompiledKernel> {
        let mapping = Mapping::parse(&r.str()?)?;
        let shape = decode_shape(r)?;
        let plan_tag = r.u8()?;
        let plan = match plan_tag {
            0 => Plan::Wp { layout: decode_layout(r, mem_words)? },
            1 => {
                let lay = dw::DwLayout {
                    input: r.usize()?,
                    weights: r.usize()?,
                    output: r.usize()?,
                    total_words: r.usize()?,
                };
                ensure!(
                    lay.total_words <= mem_words,
                    "artifact depthwise layout needs {} words but this session's memory \
                     holds {mem_words}",
                    lay.total_words
                );
                Plan::Dw { lay }
            }
            2 => Plan::OpDirect { layout: decode_layout(r, mem_words)? },
            3 => Plan::OpIm2col {
                layout: decode_layout(r, mem_words)?,
                pl: r.usize()?,
                w_prep_elems: r.u64()?,
            },
            4 => Plan::Ip {
                layout: decode_layout(r, mem_words)?,
                cp: r.usize()?,
                w_prep_elems: r.u64()?,
            },
            5 => Plan::Cpu,
            t => bail!("unknown kernel plan tag {t}"),
        };
        let n_progs = r.u32()? as usize;
        let mut progs = Vec::with_capacity(n_progs.min(table.len().max(1) * 64));
        for _ in 0..n_progs {
            let i = r.u32()? as usize;
            ensure!(
                i < table.len(),
                "kernel references program {i} but the artifact table holds {}",
                table.len()
            );
            progs.push(table[i].clone());
        }
        let n_init = r.u32()? as usize;
        let mut init = Vec::with_capacity(n_init);
        for _ in 0..n_init {
            let base = r.usize()?;
            let data = r.vec_i32()?;
            ensure!(
                plan_tag == 5 || base.saturating_add(data.len()) <= mem_words,
                "baked weight block [{base}..{}) overruns the {mem_words}-word memory",
                base.saturating_add(data.len())
            );
            init.push(InitBlock { base, data });
        }
        let footprint_bytes = r.usize()?;
        Ok(CompiledKernel { mapping, shape, plan, progs, init, footprint_bytes })
    }

    /// Scratch this kernel needs from a shared [`KernelScratch`].
    pub fn scratch_need(&self) -> ScratchNeed {
        match &self.plan {
            Plan::OpIm2col { pl, .. } => ScratchNeed {
                hwc_elems: self.shape.input_elems(),
                patch_elems: *pl,
            },
            Plan::Ip { cp, .. } => ScratchNeed {
                hwc_elems: self.shape.input_elems(),
                patch_elems: cp * 9,
            },
            _ => ScratchNeed::default(),
        }
    }

    /// Replay the convolution: poke `input` (CHW, `shape.input_elems()`
    /// long) and the baked weight image, run every pre-decoded launch in
    /// order, and write the output (CHW, `shape.output_elems()` long)
    /// into `out`. Returns the full [`ConvOutcome`] accounting with an
    /// **empty output tensor** (the data lives in `out`; the metrics
    /// side of `ConvOutcome` never reads it).
    ///
    /// Performs no program building, no decoding, no planner work and no
    /// heap allocation — the assertable warm-path contract
    /// (`tests/compiled_counters.rs`).
    pub fn run_into(
        &self,
        cgra: &Cgra,
        input: &[i32],
        scratch: &mut KernelScratch,
        out: &mut [i32],
    ) -> Result<ConvOutcome> {
        ensure!(
            input.len() == self.shape.input_elems(),
            "input has {} elements, shape {} needs {}",
            input.len(),
            self.shape,
            self.shape.input_elems()
        );
        ensure!(
            out.len() == self.shape.output_elems(),
            "output buffer has {} elements, shape {} needs {}",
            out.len(),
            self.shape,
            self.shape.output_elems()
        );
        let shape = &self.shape;
        let cfg = cgra.config();
        let host = HostCostModel::default();
        let mut ksp = trace::span_dyn("kernel", || format!("kernel:{}", self.mapping.label()));

        if let Plan::Cpu = self.plan {
            return self.run_cpu(input, out);
        }

        // Poke the weight image first, then the input (layout regions
        // are disjoint, order is irrelevant; every word any launch reads
        // is freshly written here or by the run itself).
        for block in &self.init {
            scratch.mem.poke_slice(block.base, &block.data);
        }

        let mut stats = RunStats::new();
        stats.exited = true;
        let mut launches = 0u64;
        let mut latency = LatencyBreakdown::default();
        let mut cpu_mem = MemStats::default();

        match &self.plan {
            Plan::Wp { layout } => {
                scratch.mem.poke_slice(layout.input, input);
                for dp in &self.progs {
                    let s = walk_decoded(cgra, self.mapping, launches, dp, &mut scratch.mem)?;
                    stats.merge(&s);
                    launches += 1;
                }
                copy_out(&scratch.mem, layout.output, out);
            }
            Plan::Dw { lay } => {
                scratch.mem.poke_slice(lay.input, input);
                for dp in &self.progs {
                    let s = walk_decoded(cgra, self.mapping, launches, dp, &mut scratch.mem)?;
                    stats.merge(&s);
                    launches += 1;
                }
                copy_out(&scratch.mem, lay.output, out);
            }
            Plan::OpDirect { layout } => {
                scratch.mem.poke_slice(layout.input, input);
                for dp in &self.progs {
                    let s = walk_decoded(cgra, self.mapping, launches, dp, &mut scratch.mem)?;
                    stats.merge(&s);
                    launches += 1;
                }
                copy_out(&scratch.mem, layout.output, out);
            }
            Plan::OpIm2col { layout, pl, w_prep_elems } => {
                scratch.hwc_for(shape.c, shape.ih(), shape.iw());
                to_hwc_into(shape, input, &mut scratch.hwc);
                scratch.mem.poke_slice(layout.input, &scratch.hwc.data);
                scratch.patch_for(*pl);
                let prep_elems = scratch.hwc.data.len() as u64 + w_prep_elems;
                let mut cpu_im2col = prep_elems * host.prep_cycles_per_elem;
                let mut cpu_hidden = 0u64;
                let mut cpu_copies = 0u64;
                let k_tiles = shape.k.div_ceil(N_PES);
                let mut idx = 0usize;
                for _kt in 0..k_tiles {
                    for y in 0..shape.ox {
                        for x in 0..shape.oy {
                            let pix = y * shape.oy + x;
                            let slot = layout.im2col + (pix % 2) * pl;
                            let copied =
                                im2col_patch(shape, &scratch.hwc, y, x, &mut scratch.patch)
                                    as u64;
                            scratch.mem.poke_slice(slot, &scratch.patch);
                            cpu_copies += copied;
                            cpu_im2col += copied * host.im2col_cycles_per_elem;
                            let s = walk_decoded(
                                cgra,
                                self.mapping,
                                launches,
                                &self.progs[idx],
                                &mut scratch.mem,
                            )?;
                            cpu_hidden += s.cycles.min(copied * host.im2col_cycles_per_elem);
                            stats.merge(&s);
                            launches += 1;
                            idx += 1;
                        }
                    }
                }
                latency.cpu_im2col_cycles = cpu_im2col;
                latency.cpu_hidden_cycles = cpu_hidden;
                cpu_mem = MemStats {
                    loads: cpu_copies + prep_elems,
                    stores: cpu_copies + prep_elems,
                };
                copy_out(&scratch.mem, layout.output, out);
            }
            Plan::Ip { layout, cp, w_prep_elems } => {
                let patch_words = cp * 9;
                scratch.hwc_for(shape.c, shape.ih(), shape.iw());
                to_hwc_into(shape, input, &mut scratch.hwc);
                scratch.mem.poke_slice(layout.input, &scratch.hwc.data);
                scratch.patch_for(patch_words);
                let prep_elems = scratch.hwc.data.len() as u64 + w_prep_elems;
                let mut cpu_im2col = prep_elems * host.prep_cycles_per_elem;
                let mut cpu_hidden = 0u64;
                let mut cpu_copies = 0u64;
                let mut idx = 0usize;
                for y in 0..shape.ox {
                    for x in 0..shape.oy {
                        let pix = y * shape.oy + x;
                        let slot = layout.im2col + (pix % 2) * patch_words;
                        ip::im2col_patch_cm(shape, &scratch.hwc, y, x, &mut scratch.patch);
                        scratch.mem.poke_slice(slot, &scratch.patch);
                        for _k in 0..shape.k {
                            cpu_copies += patch_words as u64;
                            cpu_im2col += patch_words as u64 * host.im2col_cycles_per_elem;
                            let s = walk_decoded(
                                cgra,
                                self.mapping,
                                launches,
                                &self.progs[idx],
                                &mut scratch.mem,
                            )?;
                            cpu_hidden +=
                                s.cycles.min(patch_words as u64 * host.im2col_cycles_per_elem);
                            stats.merge(&s);
                            launches += 1;
                            idx += 1;
                        }
                    }
                }
                latency.cpu_im2col_cycles = cpu_im2col;
                latency.cpu_hidden_cycles = cpu_hidden;
                cpu_mem = MemStats {
                    loads: cpu_copies + prep_elems,
                    stores: cpu_copies + prep_elems,
                };
                copy_out(&scratch.mem, layout.output, out);
            }
            Plan::Cpu => unreachable!("handled above"),
        }

        latency.cgra_cycles = stats.cycles;
        latency.launch_cycles = launches * cfg.launch_overhead + cfg.instruction_load_overhead;
        latency.launches = launches;
        ksp.arg("launches", launches);
        ksp.arg("cgra_cycles", stats.cycles);
        Ok(ConvOutcome {
            mapping: self.mapping,
            shape: *shape,
            output: TensorChw { c: 0, h: 0, w: 0, data: Vec::new() },
            latency,
            cgra_stats: stats,
            cpu_mem,
            footprint_bytes: self.footprint_bytes,
        })
    }

    /// Replay the convolution across `nb` independent inference lanes
    /// in **one shared µop walk per launch**
    /// ([`Cgra::run_decoded_batch`], DESIGN.md §9). Lane `l` reads its
    /// input at `inputs[l * in_stride ..][.. input_elems]` and writes
    /// its output at `outs[l * out_stride ..][.. output_elems]` —
    /// strided lane-major views, so grouped layers can hand whole
    /// activation buffers straight through without gather/scatter
    /// copies.
    ///
    /// The returned [`ConvOutcome`] is **per-inference** and bit-exact
    /// with a scalar [`CompiledKernel::run_into`] of any single lane:
    /// launches, `RunStats`, the latency decomposition and the host
    /// accounting are all lane-invariant (timing in this simulator is
    /// data-independent, and the im2col staging counts depend only on
    /// the shape). Like `run_into`, performs no program building, no
    /// µop decoding and no heap allocation.
    #[allow(clippy::too_many_arguments)]
    pub fn run_batch_into(
        &self,
        cgra: &Cgra,
        nb: usize,
        inputs: &[i32],
        in_stride: usize,
        scratch: &mut BatchKernelScratch,
        outs: &mut [i32],
        out_stride: usize,
    ) -> Result<ConvOutcome> {
        let in_elems = self.shape.input_elems();
        let out_elems = self.shape.output_elems();
        ensure!(
            nb >= 1 && nb <= scratch.batch_capacity(),
            "batch of {} lanes exceeds scratch capacity {}",
            nb,
            scratch.batch_capacity()
        );
        ensure!(
            in_stride >= in_elems && inputs.len() >= (nb - 1) * in_stride + in_elems,
            "batched input view too small: {} elements at stride {} for {} lanes of {} \
             (shape {})",
            inputs.len(),
            in_stride,
            nb,
            in_elems,
            self.shape
        );
        ensure!(
            out_stride >= out_elems && outs.len() >= (nb - 1) * out_stride + out_elems,
            "batched output view too small: {} elements at stride {} for {} lanes of {} \
             (shape {})",
            outs.len(),
            out_stride,
            nb,
            out_elems,
            self.shape
        );
        let shape = &self.shape;
        let cfg = cgra.config();
        let host = HostCostModel::default();
        let mut ksp = trace::span_dyn("kernel", || format!("kernel:{}", self.mapping.label()));
        ksp.arg("lanes", nb);

        if let Plan::Cpu = self.plan {
            let mut last = None;
            for l in 0..nb {
                last = Some(self.run_cpu(
                    &inputs[l * in_stride..l * in_stride + in_elems],
                    &mut outs[l * out_stride..l * out_stride + out_elems],
                )?);
            }
            return Ok(last.expect("nb >= 1"));
        }

        // Weight image: poked once, broadcast to every active lane.
        for block in &self.init {
            scratch.mem.poke_broadcast(block.base, &block.data, nb);
        }

        let mut stats = RunStats::new();
        stats.exited = true;
        let mut launches = 0u64;
        let mut latency = LatencyBreakdown::default();
        let mut cpu_mem = MemStats::default();

        match &self.plan {
            Plan::Wp { layout } | Plan::OpDirect { layout } => {
                for l in 0..nb {
                    scratch.mem.poke_slice_lane(
                        layout.input,
                        l,
                        &inputs[l * in_stride..l * in_stride + in_elems],
                    );
                }
                for dp in &self.progs {
                    let s =
                        walk_decoded_batch(cgra, self.mapping, launches, dp, &mut scratch.mem, nb)?;
                    stats.merge(&s);
                    launches += 1;
                }
                copy_out_lanes(&scratch.mem, layout.output, nb, outs, out_stride, out_elems);
            }
            Plan::Dw { lay } => {
                for l in 0..nb {
                    scratch.mem.poke_slice_lane(
                        lay.input,
                        l,
                        &inputs[l * in_stride..l * in_stride + in_elems],
                    );
                }
                for dp in &self.progs {
                    let s =
                        walk_decoded_batch(cgra, self.mapping, launches, dp, &mut scratch.mem, nb)?;
                    stats.merge(&s);
                    launches += 1;
                }
                copy_out_lanes(&scratch.mem, lay.output, nb, outs, out_stride, out_elems);
            }
            Plan::OpIm2col { layout, pl, w_prep_elems } => {
                for l in 0..nb {
                    scratch.hwc_for(l, shape.c, shape.ih(), shape.iw());
                    to_hwc_into(
                        shape,
                        &inputs[l * in_stride..l * in_stride + in_elems],
                        &mut scratch.hwc[l],
                    );
                    scratch.mem.poke_slice_lane(layout.input, l, &scratch.hwc[l].data);
                }
                scratch.patch_for(*pl);
                let prep_elems = scratch.hwc[0].data.len() as u64 + w_prep_elems;
                let mut cpu_im2col = prep_elems * host.prep_cycles_per_elem;
                let mut cpu_hidden = 0u64;
                let mut cpu_copies = 0u64;
                let k_tiles = shape.k.div_ceil(N_PES);
                let mut idx = 0usize;
                for _kt in 0..k_tiles {
                    for y in 0..shape.ox {
                        for x in 0..shape.oy {
                            let pix = y * shape.oy + x;
                            let slot = layout.im2col + (pix % 2) * pl;
                            // The staged element count depends only on
                            // the shape and pixel position — identical
                            // across lanes, charged once per inference.
                            let mut copied = 0u64;
                            for l in 0..nb {
                                copied = im2col_patch(
                                    shape,
                                    &scratch.hwc[l],
                                    y,
                                    x,
                                    &mut scratch.patch,
                                ) as u64;
                                scratch.mem.poke_slice_lane(slot, l, &scratch.patch);
                            }
                            cpu_copies += copied;
                            cpu_im2col += copied * host.im2col_cycles_per_elem;
                            let s = walk_decoded_batch(
                                cgra,
                                self.mapping,
                                launches,
                                &self.progs[idx],
                                &mut scratch.mem,
                                nb,
                            )?;
                            cpu_hidden += s.cycles.min(copied * host.im2col_cycles_per_elem);
                            stats.merge(&s);
                            launches += 1;
                            idx += 1;
                        }
                    }
                }
                latency.cpu_im2col_cycles = cpu_im2col;
                latency.cpu_hidden_cycles = cpu_hidden;
                cpu_mem = MemStats {
                    loads: cpu_copies + prep_elems,
                    stores: cpu_copies + prep_elems,
                };
                copy_out_lanes(&scratch.mem, layout.output, nb, outs, out_stride, out_elems);
            }
            Plan::Ip { layout, cp, w_prep_elems } => {
                let patch_words = cp * 9;
                for l in 0..nb {
                    scratch.hwc_for(l, shape.c, shape.ih(), shape.iw());
                    to_hwc_into(
                        shape,
                        &inputs[l * in_stride..l * in_stride + in_elems],
                        &mut scratch.hwc[l],
                    );
                    scratch.mem.poke_slice_lane(layout.input, l, &scratch.hwc[l].data);
                }
                scratch.patch_for(patch_words);
                let prep_elems = scratch.hwc[0].data.len() as u64 + w_prep_elems;
                let mut cpu_im2col = prep_elems * host.prep_cycles_per_elem;
                let mut cpu_hidden = 0u64;
                let mut cpu_copies = 0u64;
                let mut idx = 0usize;
                for y in 0..shape.ox {
                    for x in 0..shape.oy {
                        let pix = y * shape.oy + x;
                        let slot = layout.im2col + (pix % 2) * patch_words;
                        for l in 0..nb {
                            ip::im2col_patch_cm(shape, &scratch.hwc[l], y, x, &mut scratch.patch);
                            scratch.mem.poke_slice_lane(slot, l, &scratch.patch);
                        }
                        for _k in 0..shape.k {
                            cpu_copies += patch_words as u64;
                            cpu_im2col += patch_words as u64 * host.im2col_cycles_per_elem;
                            let s = walk_decoded_batch(
                                cgra,
                                self.mapping,
                                launches,
                                &self.progs[idx],
                                &mut scratch.mem,
                                nb,
                            )?;
                            cpu_hidden +=
                                s.cycles.min(patch_words as u64 * host.im2col_cycles_per_elem);
                            stats.merge(&s);
                            launches += 1;
                            idx += 1;
                        }
                    }
                }
                latency.cpu_im2col_cycles = cpu_im2col;
                latency.cpu_hidden_cycles = cpu_hidden;
                cpu_mem = MemStats {
                    loads: cpu_copies + prep_elems,
                    stores: cpu_copies + prep_elems,
                };
                copy_out_lanes(&scratch.mem, layout.output, nb, outs, out_stride, out_elems);
            }
            Plan::Cpu => unreachable!("handled above"),
        }

        latency.cgra_cycles = stats.cycles;
        latency.launch_cycles = launches * cfg.launch_overhead + cfg.instruction_load_overhead;
        latency.launches = launches;
        ksp.arg("launches", launches);
        ksp.arg("cgra_cycles", stats.cycles);
        Ok(ConvOutcome {
            mapping: self.mapping,
            shape: *shape,
            output: TensorChw { c: 0, h: 0, w: 0, data: Vec::new() },
            latency,
            cgra_stats: stats,
            cpu_mem,
            footprint_bytes: self.footprint_bytes,
        })
    }

    /// The CPU-baseline arm: closed-form cycles (the same [`CpuModel`]
    /// the dispatcher uses), golden compute written straight into `out`
    /// — the identical (k, y, x, c, fy, fx) wrapping loop nest as
    /// [`crate::conv::conv2d`], just allocation-free.
    fn run_cpu(&self, input: &[i32], out: &mut [i32]) -> Result<ConvOutcome> {
        let shape = &self.shape;
        let w = &self.init[0].data;
        let (ih, iw) = (shape.ih(), shape.iw());
        for k in 0..shape.k {
            for y in 0..shape.ox {
                for x in 0..shape.oy {
                    let mut acc: i32 = 0;
                    for c in 0..shape.c {
                        for fy in 0..3 {
                            for fx in 0..3 {
                                let iv = input[(c * ih + y + fy) * iw + x + fx];
                                let wv = w[((k * shape.c + c) * 3 + fy) * 3 + fx];
                                acc = acc.wrapping_add(iv.wrapping_mul(wv));
                            }
                        }
                    }
                    out[(k * shape.ox + y) * shape.oy + x] = acc;
                }
            }
        }
        let latency = LatencyBreakdown {
            cpu_compute_cycles: CpuModel::default().conv_cycles(shape),
            ..Default::default()
        };
        Ok(ConvOutcome {
            mapping: Mapping::Cpu,
            shape: *shape,
            output: TensorChw { c: 0, h: 0, w: 0, data: Vec::new() },
            latency,
            cgra_stats: RunStats::new(),
            cpu_mem: MemStats { loads: 2 * shape.macs(), stores: shape.output_elems() as u64 },
            footprint_bytes: self.footprint_bytes,
        })
    }
}

/// Attach the standard walk-span arguments: launch index, lane count,
/// walk cycles, and the op-class cycle attribution (DESIGN.md §11) —
/// "where did this launch's cycles go", in the paper's Fig. 3 classes.
fn annotate_walk(sp: &mut trace::Span, launch: u64, lanes: usize, s: &RunStats) {
    sp.arg("launch", launch);
    sp.arg("lanes", lanes);
    sp.arg("cycles", s.cycles);
    sp.arg("steps", s.steps);
    sp.arg("contention_cycles", s.contention_cycles);
    let cc = s.class_cycles();
    for c in OpClass::ALL {
        sp.arg(c.label(), cc[c.idx()]);
    }
}

/// One traced scalar simulator walk. When tracing is off this is
/// exactly `cgra.run_decoded` plus one relaxed atomic load.
fn walk_decoded(
    cgra: &Cgra,
    mapping: Mapping,
    launch: u64,
    dp: &DecodedProgram,
    mem: &mut Memory,
) -> Result<RunStats> {
    let mut sp = trace::span_dyn("walk", || format!("walk:{}", mapping.label()));
    let s = cgra.run_decoded(dp, mem)?;
    if sp.is_recording() {
        annotate_walk(&mut sp, launch, 1, &s);
    }
    annotate_profile(&mut sp, mapping);
    Ok(s)
}

/// Pick up the walk's bottleneck attribution left by the executor and
/// (a) attach it to the walk span, (b) fold it into the per-mapping
/// session aggregate (DESIGN.md §12). One relaxed atomic load when the
/// profiler is off.
fn annotate_profile(sp: &mut trace::Span, mapping: Mapping) {
    if !profile::enabled() {
        return;
    }
    if let Some(wp) = profile::take_last_walk() {
        if sp.is_recording() {
            for c in profile::BnClass::ALL {
                sp.arg(c.key(), wp.class_cycles[c.idx()]);
            }
            sp.arg("hi_water_words", wp.hi_water_words);
        }
        profile::record_walk(mapping.label(), &wp);
    }
}

/// One traced batched simulator walk (`nb` lanes per shared µop walk).
fn walk_decoded_batch(
    cgra: &Cgra,
    mapping: Mapping,
    launch: u64,
    dp: &DecodedProgram,
    mem: &mut BatchMemory,
    nb: usize,
) -> Result<RunStats> {
    let mut sp = trace::span_dyn("walk", || format!("walk:{}", mapping.label()));
    let s = cgra.run_decoded_batch(dp, mem, nb)?;
    if sp.is_recording() {
        annotate_walk(&mut sp, launch, nb, &s);
    }
    annotate_profile(&mut sp, mapping);
    Ok(s)
}

/// Copy a kernel's output region out of the memory image.
fn copy_out(mem: &Memory, base: usize, out: &mut [i32]) {
    out.copy_from_slice(mem.peek_slice(base, out.len()));
}

/// Copy each lane's output region out of the batched memory image into
/// its strided destination view.
fn copy_out_lanes(
    mem: &BatchMemory,
    base: usize,
    nb: usize,
    outs: &mut [i32],
    out_stride: usize,
    out_elems: usize,
) {
    for l in 0..nb {
        mem.peek_slice_lane(base, l, &mut outs[l * out_stride..l * out_stride + out_elems]);
    }
}

/// Serialize a frozen [`ConvShape`] (6 dims, DESIGN.md §13).
fn encode_shape(w: &mut Writer, s: &ConvShape) {
    w.usize(s.c);
    w.usize(s.k);
    w.usize(s.ox);
    w.usize(s.oy);
    w.usize(s.fx);
    w.usize(s.fy);
}

/// Deserialize and re-validate a frozen [`ConvShape`].
fn decode_shape(r: &mut Reader) -> Result<ConvShape> {
    let s = ConvShape {
        c: r.usize()?,
        k: r.usize()?,
        ox: r.usize()?,
        oy: r.usize()?,
        fx: r.usize()?,
        fy: r.usize()?,
    };
    s.validate()?;
    Ok(s)
}

/// Serialize a frozen [`MemLayout`] (7 word offsets/sizes).
fn encode_layout(w: &mut Writer, l: &MemLayout) {
    w.usize(l.input);
    w.usize(l.weights);
    w.usize(l.output);
    w.usize(l.im2col);
    w.usize(l.im2col_words);
    w.usize(l.scratch);
    w.usize(l.total_words);
}

/// Deserialize a frozen [`MemLayout`], re-checking the loading
/// session's memory bound (the layout was validated against the
/// *compiling* session's config; fingerprint matching makes them equal,
/// but the check keeps a hand-edited artifact from panicking a replay).
fn decode_layout(r: &mut Reader, mem_words: usize) -> Result<MemLayout> {
    let l = MemLayout {
        input: r.usize()?,
        weights: r.usize()?,
        output: r.usize()?,
        im2col: r.usize()?,
        im2col_words: r.usize()?,
        scratch: r.usize()?,
        total_words: r.usize()?,
    };
    ensure!(
        l.total_words <= mem_words,
        "artifact layout needs {} words but this session's memory holds {mem_words}",
        l.total_words
    );
    Ok(l)
}

/// CHW → HWC conversion into a preallocated staging tensor (the modeled
/// MCU does this per inference; the simulator just avoids allocating
/// for it).
fn to_hwc_into(shape: &ConvShape, input: &[i32], hwc: &mut TensorHwc) {
    let (c, h, w) = (shape.c, shape.ih(), shape.iw());
    for ci in 0..c {
        for y in 0..h {
            for x in 0..w {
                hwc.data[(y * w + x) * c + ci] = input[(ci * h + y) * w + x];
            }
        }
    }
}

/// Compile a kernel then immediately replay it once — the differential
/// harness the prebuilt tests use against the legacy `run` entry points.
#[cfg(test)]
fn build_and_run(
    cgra: &Cgra,
    shape: &ConvShape,
    mapping: Mapping,
    input: &TensorChw,
    weights: &Weights,
) -> Result<(ConvOutcome, Vec<i32>)> {
    let ck = CompiledKernel::build(cgra.config(), shape, mapping, weights)?;
    let mut scratch = KernelScratch::new(cgra.config(), ck.scratch_need());
    let mut out = vec![0i32; shape.output_elems()];
    let outcome = ck.run_into(cgra, &input.data, &mut scratch, &mut out)?;
    Ok((outcome, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{
        conv2d, depthwise2d, random_depthwise_weights, random_input, random_weights,
    };
    use crate::energy::EnergyModel;
    use crate::metrics::MappingReport;
    use crate::prop::Rng;

    fn legacy(
        cgra: &Cgra,
        mapping: Mapping,
        shape: &ConvShape,
        input: &TensorChw,
        weights: &Weights,
    ) -> ConvOutcome {
        super::super::dispatch(cgra, mapping, shape, input, weights).unwrap()
    }

    /// Every mapping's prebuilt replay is bit-exact with the legacy
    /// entry point: same output, same latency decomposition, same run
    /// statistics, bit-identical energy.
    #[test]
    fn prebuilt_replay_matches_legacy_for_every_mapping() {
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let model = EnergyModel::default();
        // A shape exercising padding lanes (C=5, K=17 spills tiles).
        let shape = ConvShape::new3x3(5, 17, 4, 3);
        let mut rng = Rng::new(33);
        let input = random_input(&shape, 60, &mut rng);
        let weights = random_weights(&shape, 11, &mut rng);
        for m in Mapping::ALL {
            let want = legacy(&cgra, m, &shape, &input, &weights);
            let (got, out) = build_and_run(&cgra, &shape, m, &input, &weights).unwrap();
            assert_eq!(out, want.output.data, "{m} output");
            assert_eq!(got.latency, want.latency, "{m} latency");
            assert_eq!(got.footprint_bytes, want.footprint_bytes, "{m} footprint");
            let (a, b) = (
                MappingReport::from_outcome(&got, &model),
                MappingReport::from_outcome(&want, &model),
            );
            assert_eq!(a.energy_uj.to_bits(), b.energy_uj.to_bits(), "{m} energy");
            assert_eq!(a.cgra_accesses, b.cgra_accesses, "{m} accesses");
            assert_eq!(a.utilization.to_bits(), b.utilization.to_bits(), "{m} utilization");
        }
    }

    /// Depthwise prebuilt replay matches the Dw-WP kernel.
    #[test]
    fn prebuilt_depthwise_matches_dw_kernel() {
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let shape = ConvShape::new3x3(5, 5, 4, 6);
        let mut rng = Rng::new(2);
        let input = random_input(&shape, 50, &mut rng);
        let weights = random_depthwise_weights(&shape, 9, &mut rng);
        let want = dw::run(&cgra, &shape, &input, &weights).unwrap();
        let (got, out) = build_and_run(&cgra, &shape, Mapping::DwWp, &input, &weights).unwrap();
        assert_eq!(out, want.output.data);
        assert_eq!(out, depthwise2d(&shape, &input, &weights).data);
        assert_eq!(got.latency, want.latency);
        assert_eq!(got.latency.launches, 5, "one launch per channel");
    }

    /// A warm artifact replays repeatedly with identical results — the
    /// shared arena memory carries no state between runs — and new
    /// inputs flow through without rebuilding anything.
    #[test]
    fn warm_replay_is_stateless_across_runs_and_inputs() {
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let shape = ConvShape::new3x3(3, 4, 5, 5);
        let mut rng = Rng::new(7);
        let weights = random_weights(&shape, 9, &mut rng);
        // Im2col-OP stresses the ping-pong patch slots and the weight
        // matrix image.
        let ck =
            CompiledKernel::build(&CgraConfig::default(), &shape, Mapping::OpIm2col, &weights)
                .unwrap();
        let mut scratch = KernelScratch::new(&CgraConfig::default(), ck.scratch_need());
        let mut out = vec![0i32; shape.output_elems()];
        for seed in [1u64, 2, 3, 1] {
            let input = random_input(&shape, 30, &mut Rng::new(seed));
            let a = ck.run_into(&cgra, &input.data, &mut scratch, &mut out).unwrap();
            assert_eq!(out, conv2d(&shape, &input, &weights).data, "seed {seed}");
            let b = ck.run_into(&cgra, &input.data, &mut scratch, &mut out).unwrap();
            assert_eq!(a.latency, b.latency, "replay must be deterministic");
        }
    }

    /// `with_weights` shares decoded programs and produces the sibling
    /// group's exact result.
    #[test]
    fn with_weights_shares_programs_and_is_exact() {
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let shape = ConvShape::new3x3(2, 4, 6, 6);
        let mut rng = Rng::new(9);
        let input = random_input(&shape, 30, &mut rng);
        let w0 = random_weights(&shape, 9, &mut rng);
        let w1 = random_weights(&shape, 9, &mut rng);
        let base = CompiledKernel::build(&CgraConfig::default(), &shape, Mapping::Wp, &w0).unwrap();
        let sibling = base.with_weights(&w1).unwrap();
        assert!(Arc::ptr_eq(&base.progs[0], &sibling.progs[0]), "programs must be shared");
        let mut scratch = KernelScratch::new(&CgraConfig::default(), base.scratch_need());
        let mut out = vec![0i32; shape.output_elems()];
        sibling.run_into(&cgra, &input.data, &mut scratch, &mut out).unwrap();
        assert_eq!(out, conv2d(&shape, &input, &w1).data);
        base.run_into(&cgra, &input.data, &mut scratch, &mut out).unwrap();
        assert_eq!(out, conv2d(&shape, &input, &w0).data);
    }

    /// `with_weights` applies the same validation as `build` — a
    /// wrong-tap depthwise bank is rejected, not poked over the frozen
    /// layout.
    #[test]
    fn with_weights_validates_like_build() {
        let cfg = CgraConfig::default();
        let shape = ConvShape::new3x3(4, 4, 4, 4);
        let mut rng = Rng::new(3);
        let dw = random_depthwise_weights(&shape, 5, &mut rng);
        let base = CompiledKernel::build(&cfg, &shape, Mapping::DwWp, &dw).unwrap();
        // Right channel count, wrong filter taps: (C, 1, 5, 5).
        let bad = Weights::zeros(4, 1, 5, 5);
        let err = format!("{:#}", base.with_weights(&bad).unwrap_err());
        assert!(err.contains("(C=4, 1, 3, 3)"), "{err}");
        // Dense kernels reject wrong-length banks too.
        let dense = random_weights(&shape, 5, &mut rng);
        let wp = CompiledKernel::build(&cfg, &shape, Mapping::Wp, &dense).unwrap();
        assert!(wp.with_weights(&Weights::zeros(2, 2, 3, 3)).is_err());
    }

    /// The batched replay is lane-for-lane bit-exact with scalar
    /// replays for **every** mapping: per-lane outputs, and a
    /// per-inference outcome (latency, run stats, host accounting,
    /// energy) identical to any single scalar run — at full capacity,
    /// at a ragged partial lane count, and at B = 1.
    #[test]
    fn batched_replay_matches_scalar_for_every_mapping() {
        let cfg = CgraConfig::default();
        let cgra = Cgra::new(cfg).unwrap();
        let model = EnergyModel::default();
        let shape = ConvShape::new3x3(5, 17, 4, 3);
        let mut rng = Rng::new(77);
        let weights = random_weights(&shape, 11, &mut rng);
        let inputs: Vec<TensorChw> =
            (0..3).map(|_| random_input(&shape, 60, &mut rng)).collect();
        for (m, shape) in Mapping::ALL
            .into_iter()
            .map(|m| (m, shape))
            .chain([(Mapping::DwWp, ConvShape::new3x3(5, 5, 4, 6))])
        {
            let w = if m == Mapping::DwWp {
                random_depthwise_weights(&shape, 11, &mut Rng::new(4))
            } else {
                weights.clone()
            };
            let inputs: Vec<TensorChw> = if m == Mapping::DwWp {
                let mut r = Rng::new(8);
                (0..3).map(|_| random_input(&shape, 60, &mut r)).collect()
            } else {
                inputs.clone()
            };
            let ck = CompiledKernel::build(cgra.config(), &shape, m, &w).unwrap();

            // Scalar reference: one run per lane.
            let mut scratch = KernelScratch::new(cgra.config(), ck.scratch_need());
            let mut want_out = vec![vec![0i32; shape.output_elems()]; inputs.len()];
            let mut want = None;
            for (l, input) in inputs.iter().enumerate() {
                let o = ck.run_into(&cgra, &input.data, &mut scratch, &mut want_out[l]).unwrap();
                want.get_or_insert(o);
            }
            let want = want.unwrap();

            for nb in [1usize, 2, 3] {
                let mut bscratch =
                    BatchKernelScratch::new(cgra.config(), ck.scratch_need(), 3);
                let in_stride = shape.input_elems() + 5; // strided views
                let out_stride = shape.output_elems() + 3;
                let mut flat_in = vec![0i32; 3 * in_stride];
                for l in 0..nb {
                    flat_in[l * in_stride..l * in_stride + shape.input_elems()]
                        .copy_from_slice(&inputs[l].data);
                }
                let mut flat_out = vec![0i32; 3 * out_stride];
                let got = ck
                    .run_batch_into(
                        &cgra,
                        nb,
                        &flat_in,
                        in_stride,
                        &mut bscratch,
                        &mut flat_out,
                        out_stride,
                    )
                    .unwrap();
                for l in 0..nb {
                    assert_eq!(
                        &flat_out[l * out_stride..l * out_stride + shape.output_elems()],
                        &want_out[l][..],
                        "{m} lane {l} of nb={nb} output"
                    );
                }
                assert_eq!(got.latency, want.latency, "{m} nb={nb} latency");
                assert_eq!(got.cgra_stats, want.cgra_stats, "{m} nb={nb} stats");
                assert_eq!(got.cpu_mem, want.cpu_mem, "{m} nb={nb} host mem");
                let (a, b) = (
                    MappingReport::from_outcome(&got, &model),
                    MappingReport::from_outcome(&want, &model),
                );
                assert_eq!(a.energy_uj.to_bits(), b.energy_uj.to_bits(), "{m} nb={nb} energy");
            }
        }
    }

    /// Batched lane/stride validation is actionable.
    #[test]
    fn batched_replay_validates_lanes_and_strides() {
        let cfg = CgraConfig::default();
        let cgra = Cgra::new(cfg).unwrap();
        let shape = ConvShape::new3x3(2, 3, 4, 4);
        let mut rng = Rng::new(5);
        let w = random_weights(&shape, 9, &mut rng);
        let ck = CompiledKernel::build(cgra.config(), &shape, Mapping::Wp, &w).unwrap();
        let mut scratch = BatchKernelScratch::new(cgra.config(), ck.scratch_need(), 2);
        let ie = shape.input_elems();
        let oe = shape.output_elems();
        let flat_in = vec![0i32; 2 * ie];
        let mut flat_out = vec![0i32; 2 * oe];
        // Too many lanes for the scratch.
        let err = ck
            .run_batch_into(&cgra, 3, &flat_in, ie, &mut scratch, &mut flat_out, oe)
            .unwrap_err();
        assert!(err.to_string().contains("exceeds scratch capacity"), "{err}");
        // Input view too small for the lane count.
        let err = ck
            .run_batch_into(&cgra, 2, &flat_in[..ie], ie, &mut scratch, &mut flat_out, oe)
            .unwrap_err();
        assert!(err.to_string().contains("batched input view too small"), "{err}");
        // Output view too small.
        let err = ck
            .run_batch_into(&cgra, 2, &flat_in, ie, &mut scratch, &mut flat_out[..oe], oe)
            .unwrap_err();
        assert!(err.to_string().contains("batched output view too small"), "{err}");
        // The happy path on the same scratch still works.
        ck.run_batch_into(&cgra, 2, &flat_in, ie, &mut scratch, &mut flat_out, oe).unwrap();
    }

    /// The wire codec round-trips every mapping's kernel bit-exactly —
    /// identical replay output and accounting — resolving shared
    /// programs through the artifact table **without a single µop
    /// decode**, and rejects dangling program references.
    #[test]
    fn wire_round_trip_replays_identically_without_decodes() {
        use crate::cgra::decode_count;
        use crate::util::wire::{Reader, Writer};
        let cfg = CgraConfig::default();
        let cgra = Cgra::new(cfg).unwrap();
        let shape = ConvShape::new3x3(3, 5, 4, 4);
        let mut rng = Rng::new(21);
        let input = random_input(&shape, 40, &mut rng);
        let weights = random_weights(&shape, 9, &mut rng);
        for m in Mapping::ALL {
            let ck = CompiledKernel::build(cgra.config(), &shape, m, &weights).unwrap();
            let mut table = ProgTable::new();
            ck.collect_progs(&mut table);
            let mut w = Writer::new();
            ck.wire_encode(&mut w, &mut table);
            let bytes = w.into_bytes();

            let before = decode_count();
            let mut r = Reader::new(&bytes);
            let loaded =
                CompiledKernel::wire_decode(&mut r, table.progs(), cgra.config().mem_words)
                    .unwrap();
            r.finish().unwrap();
            assert_eq!(decode_count(), before, "{m}: loading must not decode");
            assert_eq!(loaded.mapping(), ck.mapping(), "{m}");
            assert_eq!(loaded.launches(), ck.launches(), "{m}");
            assert_eq!(loaded.footprint_bytes(), ck.footprint_bytes(), "{m}");

            let mut scratch = KernelScratch::new(cgra.config(), ck.scratch_need());
            let mut out_a = vec![0i32; shape.output_elems()];
            let mut out_b = vec![0i32; shape.output_elems()];
            let a = ck.run_into(&cgra, &input.data, &mut scratch, &mut out_a).unwrap();
            let b = loaded.run_into(&cgra, &input.data, &mut scratch, &mut out_b).unwrap();
            assert_eq!(out_a, out_b, "{m} output");
            assert_eq!(a.latency, b.latency, "{m} latency");
            assert_eq!(a.cgra_stats, b.cgra_stats, "{m} stats");
            assert_eq!(a.cpu_mem, b.cpu_mem, "{m} host mem");

            // A dangling program reference is rejected, not indexed.
            if ck.launches() > 0 {
                let err = CompiledKernel::wire_decode(
                    &mut Reader::new(&bytes),
                    &table.progs()[..table.progs().len() - 1],
                    cgra.config().mem_words,
                )
                .unwrap_err();
                assert!(err.to_string().contains("artifact table"), "{m}: {err}");
            }
        }
    }

    /// Build-time validation mirrors the legacy drivers' diagnostics.
    #[test]
    fn build_rejects_bad_requests_actionably() {
        let cfg = CgraConfig::default();
        let shape = ConvShape::new3x3(4, 4, 4, 4);
        let mut rng = Rng::new(1);
        let dense = random_weights(&shape, 5, &mut rng);
        // Auto must be resolved by the caller.
        assert!(CompiledKernel::build(&cfg, &shape, Mapping::Auto, &dense).is_err());
        // Dense weights on a depthwise build.
        let err = format!(
            "{:#}",
            CompiledKernel::build(&cfg, &shape, Mapping::DwWp, &dense).unwrap_err()
        );
        assert!(err.contains("(C=4, 1, 3, 3)"), "{err}");
        // The memory bound is enforced at build time.
        let big = ConvShape::new3x3(144, 144, 64, 64);
        let bigw = Weights::zeros(144, 144, 3, 3);
        let err =
            format!("{:#}", CompiledKernel::build(&cfg, &big, Mapping::Wp, &bigw).unwrap_err());
        assert!(err.contains("512"), "{err}");
    }
}
