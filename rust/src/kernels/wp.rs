//! **WP — direct convolution with weight parallelism** (the paper's
//! winning mapping, Fig. 1).
//!
//! Nine compute PEs hold one 3×3 filter tap each (weight-stationary);
//! inputs stream through the array via torus shifts; partial products
//! flow east into an adder column; one PE stores the accumulated output.
//! The CPU relaunches the CGRA once per (output channel k, input channel
//! ci) pair with fresh weights, as in the paper ("this cycle is repeated
//! for the entire input spatial position before a new set of weights is
//! loaded").
//!
//! # Array roles (rows r, columns c)
//!
//! ```text
//!   c=0..2, r=0..2 : compute PE (fy=r, fx=c): R0 = W[k][ci][r][c]
//!   r=3, c=0..2    : loader c — streams input column x+c downward
//!   c=3, r=0..2    : adder chain (row sums -> running total)
//!   (3,3)          : accumulate with previous partial (ci>0) + store
//! ```
//!
//! # Schedule
//!
//! Output pixels of one output column x are produced down the column
//! (inner loop over y = 0..Ox-1); the paper sweeps along a row instead —
//! identical by the x/y symmetry of the 3×3 filter (DESIGN.md §3.3).
//!
//! The steady-state **main loop is 4 instructions** (matching the paper):
//!
//! ```text
//!   b0  compute: mov  r1+out <- s      ; vertical input shift
//!       loader:  lwinc out, #iw        ; stream next input row
//!       (3,3):   [iter m] nop
//!   b1  compute: mul  r2 <- r0, r1     ; the nine multiplications
//!       loader:  sub  r3 <- r3, #1     ; y counter
//!       (3,3):   add  r1 <- n, r2      ; total + previous partial
//!   b2  compute: add  out <- w, r2     ; eastward partial-sum chain
//!       (c=0):   mov  out <- r2
//!       col 3:   mov  .. <- w          ; capture row sums
//!       (3,3):   swinc r1, #Oy         ; store output pixel
//!   b3  compute: mov  out <- r1        ; re-expose input for the shift
//!       loader:  bne  r3, zero, body   ; column loop
//!       (3,3):   lwinc r2, #0          ; prefetch previous partial
//! ```
//!
//! Column `c`'s program is *rotated* by `c` slots (its blocks start `c`
//! steps later). This time-skew makes the eastward chain add products of
//! the **same** output pixel — the classic systolic alignment — without
//! address offsets.
//!
//! At each output-column change a **border block (6 instructions)**
//! refills the 3-deep input pipeline (3 loads per loader, two array
//! shifts) and resets addresses/counters — the paper's "border loop"
//! (5 instructions there; our extra slot is the y-counter reset, an
//! honest divergence reported by the Fig. 3 bench).

use anyhow::Result;

use crate::cgra::{decode, decode_cached, Cgra, RunStats, DECODE_CACHE_CAPACITY};
use crate::conv::{ConvShape, TensorChw, Weights};
use crate::isa::{Dir, Dst, Instr, Op, PeId, PeProgram, Program, Src};

use super::common::{ConvOutcome, LatencyBreakdown, Mapping, MemLayout};

const N: Src = Src::Neigh(Dir::North);
const S: Src = Src::Neigh(Dir::South);
const W: Src = Src::Neigh(Dir::West);

/// Per-launch parameters of the WP program generator.
#[derive(Clone, Copy, Debug)]
pub struct WpLaunch {
    /// Output channel.
    pub k: usize,
    /// Input channel.
    pub ci: usize,
    /// Accumulate with previously stored partials (true for ci > 0).
    pub acc: bool,
}

/// Build the 16 PE programs for one (k, ci) launch.
pub fn build_program(shape: &ConvShape, layout: &MemLayout, launch: WpLaunch) -> Program {
    super::common::note_program_build();
    let (ox, oy) = (shape.ox as i32, shape.oy as i32);
    let ih = shape.ih() as i32;
    let iw = shape.iw() as i32;
    let mut prog = Program::new(format!("wp-{}-k{}c{}", shape.id(), launch.k, launch.ci));

    let in_chan = layout.input as i32 + launch.ci as i32 * ih * iw;
    let out_chan = layout.output as i32 + (launch.k * shape.ox * shape.oy) as i32;
    let w_addr = |r: usize, c: usize| -> i32 {
        layout.weights as i32 + (((launch.k * shape.c + launch.ci) * 3 + r) * 3 + c) as i32
    };

    // ---- columns 0..2: compute rows 0..2 + loader row 3 ----
    for c in 0..3usize {
        let rot = c; // time-skew
        let border_start = rot + 2;
        let body_start = border_start + 6;

        for r in 0..3usize {
            let mut p = Vec::new();
            p.extend(std::iter::repeat(Instr::nop()).take(rot));
            // INIT: fetch the stationary weight.
            p.push(Instr::new(Op::Lw, Src::Imm(w_addr(r, c)), Src::Zero, Dst::Reg(0)));
            p.push(Instr::nop());
            // BORDER: pipeline refill (loader feeds at B3..B5; we shift
            // at B4, B5 so rows settle as I[1], I[0] above the loader).
            p.push(Instr::nop()); // B0
            p.push(Instr::nop()); // B1
            p.push(Instr::nop()); // B2
            p.push(Instr::nop()); // B3
            p.push(Instr::mov(Dst::Both(1), S)); // B4
            p.push(Instr::mov(Dst::Both(1), S)); // B5
            // BODY (4 instructions — the paper's main loop).
            debug_assert_eq!(p.len(), body_start);
            p.push(Instr::mov(Dst::Both(1), S)); // b0 shift
            p.push(Instr::new(Op::Mul, Src::Reg(0), Src::Reg(1), Dst::Reg(2))); // b1
            if c == 0 {
                p.push(Instr::mov(Dst::Out, Src::Reg(2))); // b2 head of chain
            } else {
                p.push(Instr::new(Op::Add, W, Src::Reg(2), Dst::Out)); // b2 chain
            }
            p.push(Instr::mov(Dst::Out, Src::Reg(1))); // b3 re-expose input
            // XCHECK: handled by the loader; compute PEs idle.
            p.push(Instr::nop());
            p.push(Instr::nop());
            prog.set_pe(PeId::new(r, c), PeProgram::from_instrs(p));
        }

        // Loader (3, c).
        let mut p = Vec::new();
        p.extend(std::iter::repeat(Instr::nop()).take(rot));
        // INIT: R2 = input column base tracker (pre-decremented), R0 = x
        // counter.
        p.push(Instr::mov(Dst::Reg(2), Src::Imm(in_chan + c as i32 - 1)));
        p.push(Instr::mov(Dst::Reg(0), Src::Imm(oy)));
        // BORDER.
        p.push(Instr::new(Op::Sub, Src::Reg(2), Src::Imm(-1), Dst::Reg(2))); // B0: col base += 1
        p.push(Instr::new(Op::SetAddr, Src::Reg(2), Src::Zero, Dst::None)); // B1
        p.push(Instr::mov(Dst::Reg(3), Src::Imm(ox + 1))); // B2: y counter
        p.push(Instr::new(Op::LwInc, Src::Imm(iw), Src::Zero, Dst::Out)); // B3: I[0]
        p.push(Instr::new(Op::LwInc, Src::Imm(iw), Src::Zero, Dst::Out)); // B4: I[1]
        p.push(Instr::new(Op::LwInc, Src::Imm(iw), Src::Zero, Dst::Out)); // B5: I[2]
        // BODY.
        debug_assert_eq!(p.len(), body_start);
        p.push(Instr::new(Op::LwInc, Src::Imm(iw), Src::Zero, Dst::Out)); // b0 stream
        p.push(Instr::new(Op::Sub, Src::Reg(3), Src::Imm(1), Dst::Reg(3))); // b1
        p.push(Instr::nop()); // b2
        p.push(Instr::branch(Op::Bne, Src::Reg(3), Src::Zero, body_start)); // b3
        // XCHECK.
        p.push(Instr::new(Op::Sub, Src::Reg(0), Src::Imm(1), Dst::Reg(0)));
        p.push(Instr::branch(Op::Bne, Src::Reg(0), Src::Zero, border_start));
        prog.set_pe(PeId::new(3, c), PeProgram::from_instrs(p));
    }

    // ---- column 3: adder chain + store PE ----
    {
        let rot = 3;
        let border_start = rot + 2;
        let fi_start = border_start + 6;
        let body_start = fi_start + 4;

        // PE(0,3): captures row-0 sums; owns the column's counters.
        let mut p = Vec::new();
        p.extend(std::iter::repeat(Instr::nop()).take(rot));
        p.push(Instr::mov(Dst::Reg(0), Src::Imm(oy))); // INIT: x counter
        p.push(Instr::nop());
        p.extend([Instr::nop(), Instr::nop()]); // B0, B1
        p.push(Instr::mov(Dst::Reg(3), Src::Imm(ox))); // B2: y counter (Ox trips)
        p.extend([Instr::nop(), Instr::nop(), Instr::nop()]); // B3..B5
        // FIRSTITER.
        debug_assert_eq!(p.len(), fi_start);
        p.extend([Instr::nop(), Instr::nop()]);
        p.push(Instr::mov(Dst::Out, W)); // capture row sum (pixel 0)
        p.push(Instr::nop());
        // BODY.
        debug_assert_eq!(p.len(), body_start);
        p.push(Instr::nop());
        p.push(Instr::new(Op::Sub, Src::Reg(3), Src::Imm(1), Dst::Reg(3)));
        p.push(Instr::mov(Dst::Out, W));
        p.push(Instr::branch(Op::Bne, Src::Reg(3), Src::Zero, body_start));
        // XCHECK.
        p.push(Instr::new(Op::Sub, Src::Reg(0), Src::Imm(1), Dst::Reg(0)));
        p.push(Instr::branch(Op::Bne, Src::Reg(0), Src::Zero, border_start));
        prog.set_pe(PeId::new(0, 3), PeProgram::from_instrs(p));

        // PE(1,3): rowsum0 + rowsum1.
        let mut p = Vec::new();
        p.extend(std::iter::repeat(Instr::nop()).take(rot + 2 + 6));
        for _ in 0..2 {
            // FIRSTITER and BODY share the same 4-slot pattern.
            p.push(Instr::nop());
            p.push(Instr::nop());
            p.push(Instr::mov(Dst::Reg(1), W)); // own row sum
            p.push(Instr::new(Op::Add, N, Src::Reg(1), Dst::Out)); // chain down
        }
        // Loop body is the second copy; PE(0,3) branches for the column.
        prog.set_pe(PeId::new(1, 3), PeProgram::from_instrs(p));

        // PE(2,3): (rowsum0+rowsum1) + rowsum2 -> running total.
        let mut p = Vec::new();
        p.extend(std::iter::repeat(Instr::nop()).take(rot + 2 + 6));
        for _ in 0..2 {
            p.push(Instr::new(Op::Add, N, Src::Reg(1), Dst::Out)); // total(prev pixel)
            p.push(Instr::nop());
            p.push(Instr::mov(Dst::Reg(1), W)); // own row sum
            p.push(Instr::nop());
        }
        prog.set_pe(PeId::new(2, 3), PeProgram::from_instrs(p));

        // PE(3,3): accumulate + store.
        let mut p = Vec::new();
        p.extend(std::iter::repeat(Instr::nop()).take(rot));
        p.push(Instr::mov(Dst::Reg(3), Src::Imm(out_chan - 1))); // INIT: out col base
        p.push(Instr::nop());
        p.push(Instr::new(Op::Sub, Src::Reg(3), Src::Imm(-1), Dst::Reg(3))); // B0
        p.push(Instr::new(Op::SetAddr, Src::Reg(3), Src::Zero, Dst::None)); // B1
        p.extend([Instr::nop(), Instr::nop(), Instr::nop(), Instr::nop()]); // B2..B5
        // FIRSTITER: prefetch previous partial of pixel 0; no store yet.
        debug_assert_eq!(p.len(), fi_start);
        p.extend([Instr::nop(), Instr::nop(), Instr::nop()]);
        if launch.acc {
            p.push(Instr::new(Op::LwInc, Src::Imm(0), Src::Zero, Dst::Reg(2)));
        } else {
            p.push(Instr::nop());
        }
        // BODY.
        debug_assert_eq!(p.len(), body_start);
        p.push(Instr::nop());
        if launch.acc {
            p.push(Instr::new(Op::Add, N, Src::Reg(2), Dst::Reg(1))); // total + prev
        } else {
            p.push(Instr::mov(Dst::Reg(1), N));
        }
        p.push(Instr::new(Op::SwInc, Src::Reg(1), Src::Imm(oy), Dst::None)); // store
        if launch.acc {
            p.push(Instr::new(Op::LwInc, Src::Imm(0), Src::Zero, Dst::Reg(2)));
        } else {
            p.push(Instr::nop());
        }
        // XCHECK (owned by PE(0,3)) then EXIT.
        p.extend([Instr::nop(), Instr::nop()]);
        p.push(Instr::exit());
        prog.set_pe(PeId::new(3, 3), PeProgram::from_instrs(p));
    }

    prog
}

/// Execute the full convolution with the WP mapping.
pub fn run(
    cgra: &Cgra,
    shape: &ConvShape,
    input: &TensorChw,
    weights: &Weights,
) -> Result<ConvOutcome> {
    shape.validate()?;
    let cfg = cgra.config();
    let layout = MemLayout::new(shape, 0, cfg)?;
    let mut mem = crate::cgra::Memory::new(cfg.mem_words, cfg.n_banks);
    mem.poke_slice(layout.input, &input.data);
    mem.poke_slice(layout.weights, &weights.data);

    let mut stats = RunStats::new();
    stats.exited = true;
    let mut launches = 0u64;
    // Memoize decodes only when the conv's k×c launch set fits the
    // bounded cache (with headroom): repeated convolutions of one shape
    // (figure drivers, benches) then re-use the lowering, while big
    // sweep points (e.g. C=144 → 2304 unique programs) decode directly
    // instead of churning every shard. Concurrent sweep workers can
    // still collectively exceed the bound; the cost is then the cheap
    // fingerprint + decode per launch (well under 1% of a launch's
    // simulation time), never a correctness or memory hazard.
    let memoize = shape.k * shape.c <= DECODE_CACHE_CAPACITY / 2;
    for k in 0..shape.k {
        for ci in 0..shape.c {
            let prog = build_program(shape, &layout, WpLaunch { k, ci, acc: ci > 0 });
            let s = if memoize {
                cgra.run_decoded(&decode_cached(&prog), &mut mem)?
            } else {
                cgra.run_decoded(&decode(&prog), &mut mem)?
            };
            stats.merge(&s);
            launches += 1;
        }
    }

    let output = TensorChw::from_vec(
        shape.k,
        shape.ox,
        shape.oy,
        mem.peek_slice(layout.output, shape.output_elems()).to_vec(),
    );
    let latency = LatencyBreakdown {
        cgra_cycles: stats.cycles,
        launch_cycles: launches * cfg.launch_overhead + cfg.instruction_load_overhead,
        launches,
        ..Default::default()
    };
    Ok(ConvOutcome {
        mapping: Mapping::Wp,
        shape: *shape,
        output,
        latency,
        cgra_stats: stats,
        cpu_mem: Default::default(),
        footprint_bytes: shape.base_bytes(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::{CgraConfig, OpClass};
    use crate::conv::{conv2d, random_input, random_weights};
    use crate::prop::Rng;

    fn check_shape(shape: ConvShape, seed: u64) {
        let mut rng = Rng::new(seed);
        let input = random_input(&shape, 50, &mut rng);
        let weights = random_weights(&shape, 9, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let out = run(&cgra, &shape, &input, &weights).unwrap();
        let golden = conv2d(&shape, &input, &weights);
        assert_eq!(out.output.data, golden.data, "WP mismatch on {shape}");
    }

    #[test]
    fn single_channel_tiny() {
        check_shape(ConvShape::new3x3(1, 1, 2, 2), 1);
    }

    #[test]
    fn single_channel_rect() {
        check_shape(ConvShape::new3x3(1, 1, 5, 3), 2);
    }

    #[test]
    fn multi_input_channels_accumulate() {
        check_shape(ConvShape::new3x3(3, 1, 4, 4), 3);
    }

    #[test]
    fn multi_output_channels() {
        check_shape(ConvShape::new3x3(2, 3, 3, 5), 4);
    }

    #[test]
    fn ox_equals_one() {
        check_shape(ConvShape::new3x3(2, 2, 1, 3), 5);
    }

    #[test]
    fn oy_equals_one() {
        check_shape(ConvShape::new3x3(2, 2, 3, 1), 6);
    }

    #[test]
    fn baseline_layer_exact_and_fast() {
        let shape = ConvShape::baseline();
        let mut rng = Rng::new(7);
        let input = random_input(&shape, 100, &mut rng);
        let weights = random_weights(&shape, 50, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let out = run(&cgra, &shape, &input, &weights).unwrap();
        let golden = conv2d(&shape, &input, &weights);
        assert_eq!(out.output.data, golden.data);
        // The paper reports ~0.6 MAC/cycle for WP on the baseline layer.
        let mpc = out.macs_per_cycle();
        assert!(
            (0.5..0.75).contains(&mpc),
            "baseline WP MAC/cycle {mpc:.3} out of the paper's ballpark"
        );
        // 256 launches: one per (k, ci).
        assert_eq!(out.latency.launches, 256);
    }

    #[test]
    fn main_loop_is_four_instructions() {
        // Static check on the generated program: the loader's branch at
        // body_start+3 targets body_start, i.e. a 4-slot loop.
        let shape = ConvShape::baseline();
        let layout = MemLayout::new(&shape, 0, &CgraConfig::default()).unwrap();
        let prog = build_program(&shape, &layout, WpLaunch { k: 0, ci: 0, acc: false });
        for c in 0..3 {
            let loader = prog.pe(PeId::new(3, c));
            let body_start = c + 2 + 6;
            let branch = loader.fetch(body_start + 3);
            assert_eq!(branch.op, Op::Bne);
            assert_eq!(branch.target as usize, body_start);
        }
    }

    #[test]
    fn programs_fit_32_words() {
        let shape = ConvShape::new3x3(144, 144, 64, 64);
        // Build with a relaxed config (footprint check is separate).
        let layout = MemLayout {
            input: 0,
            weights: 1,
            output: 2,
            im2col: 3,
            im2col_words: 0,
            scratch: 3,
            total_words: 4,
        };
        let prog = build_program(&shape, &layout, WpLaunch { k: 143, ci: 143, acc: true });
        assert!(prog.max_len() <= 32);
    }

    #[test]
    fn utilization_near_paper_value() {
        // Paper: WP main-loop utilization 78%. Whole-run utilization
        // (incl. borders and the idle aggregator slots) should land in
        // the same region.
        let shape = ConvShape::baseline();
        let mut rng = Rng::new(8);
        let input = random_input(&shape, 10, &mut rng);
        let weights = random_weights(&shape, 10, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let out = run(&cgra, &shape, &input, &weights).unwrap();
        let u = out.cgra_stats.utilization();
        assert!((0.55..0.90).contains(&u), "WP utilization {u:.3} unexpected");
        // Op-mix sanity: 9 muls per output pixel per (k, ci).
        let muls = out.cgra_stats.class_total(OpClass::Mul);
        let pixels = (shape.ox + 1) * shape.oy * shape.c * shape.k;
        assert_eq!(muls, 9 * pixels as u64);
    }

    #[test]
    fn memory_traffic_is_weight_stationary() {
        // WP's intrinsic load rate is one fresh input triplet per output
        // pixel = 3 loads / 9 MACs ≈ 0.33, plus border refills, weight
        // fetches and prev-partial reads — far below the 2 loads/MAC of
        // the other mappings (the paper's key claim).
        let shape = ConvShape::new3x3(2, 2, 16, 16);
        let mut rng = Rng::new(9);
        let input = random_input(&shape, 10, &mut rng);
        let weights = random_weights(&shape, 10, &mut rng);
        let cgra = Cgra::new(CgraConfig::default()).unwrap();
        let out = run(&cgra, &shape, &input, &weights).unwrap();
        let loads_per_mac = out.cgra_stats.mem.loads as f64 / shape.macs() as f64;
        assert!(loads_per_mac < 0.6, "loads/MAC {loads_per_mac:.3} too high for WP");
        let stores = out.cgra_stats.mem.stores;
        assert_eq!(stores, (shape.ox * shape.oy * shape.c * shape.k) as u64);
    }
}
