//! Span tracer with Chrome trace-event export.
//!
//! A process-global recorder collects **complete spans** (`ph: "X"`
//! events) from any thread. Instrumentation sites call [`span`] (static
//! name) or [`span_dyn`] (lazily built name) and hold the returned RAII
//! [`Span`] for the duration of the work; dropping it records the
//! event. The recorder is off by default and the disabled fast path is
//! a single relaxed atomic load returning an empty guard — no clock
//! read, no allocation, no lock (the overhead argument in DESIGN.md
//! §11, pinned by `tests/compiled_counters.rs`).
//!
//! Recording is enabled for the lifetime of a [`TraceSession`]
//! (see [`session`]); sessions serialize on a process-wide lock so
//! concurrent tests cannot interleave events. [`TraceSession::finish`]
//! returns the collected [`Trace`], exportable as Chrome trace-event
//! JSON ([`Trace::to_chrome_json`]) loadable in Perfetto or
//! `chrome://tracing`.

use std::borrow::Cow;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

use crate::util::json::Json;

/// Hard cap on buffered events per session; further spans are counted
/// in [`Trace::dropped`] instead of growing memory without bound.
pub const MAX_EVENTS: usize = 1 << 20;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SESSION_LOCK: Mutex<()> = Mutex::new(());
static RECORDER: Mutex<Recorder> =
    Mutex::new(Recorder { epoch: None, events: Vec::new(), dropped: 0 });

// Stable small thread ids for the `tid` field: std's ThreadId has no
// stable integer accessor, so threads draw sequential ids on first use.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

struct Recorder {
    /// Session time origin; `None` while no session is active.
    epoch: Option<Instant>,
    events: Vec<TraceEvent>,
    dropped: u64,
}

fn lock_recorder() -> MutexGuard<'static, Recorder> {
    RECORDER.lock().unwrap_or_else(|e| e.into_inner())
}

/// Whether a trace session is currently recording. Instrumentation
/// sites use this to skip building span *arguments* (the guard itself
/// is already free when disabled).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// One recorded complete span.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Span name (Perfetto slice title).
    pub name: String,
    /// Category — the instrumentation layer ("daemon", "queue",
    /// "layer", "walk", ...).
    pub cat: &'static str,
    /// Start, nanoseconds since the session epoch.
    pub ts_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
    /// Recording thread (sequential per-process id).
    pub tid: u64,
    /// Span arguments, shown in the Perfetto detail pane.
    pub args: Vec<(&'static str, Json)>,
}

struct SpanInner {
    name: Cow<'static, str>,
    cat: &'static str,
    start: Instant,
    args: Vec<(&'static str, Json)>,
}

/// RAII span guard: the span covers the guard's lifetime. When tracing
/// is disabled the guard is inert (`inner: None`) and costs nothing.
pub struct Span {
    inner: Option<SpanInner>,
}

impl Span {
    /// Attach a key/value argument (no-op when tracing is disabled).
    pub fn arg(&mut self, key: &'static str, value: impl Into<Json>) {
        if let Some(inner) = &mut self.inner {
            inner.args.push((key, value.into()));
        }
    }

    /// Whether this particular guard is recording (tracing was enabled
    /// when it was opened).
    pub fn is_recording(&self) -> bool {
        self.inner.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            record(inner);
        }
    }
}

/// Open a span with a static name. The disabled path is one relaxed
/// atomic load.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Span {
    if !enabled() {
        return Span { inner: None };
    }
    Span {
        inner: Some(SpanInner {
            name: Cow::Borrowed(name),
            cat,
            start: Instant::now(),
            args: Vec::new(),
        }),
    }
}

/// Open a span with a lazily built name; the closure only runs when
/// tracing is enabled, so dynamic names cost nothing when off.
#[inline]
pub fn span_dyn(cat: &'static str, name: impl FnOnce() -> String) -> Span {
    if !enabled() {
        return Span { inner: None };
    }
    Span {
        inner: Some(SpanInner {
            name: Cow::Owned(name()),
            cat,
            start: Instant::now(),
            args: Vec::new(),
        }),
    }
}

fn record(inner: SpanInner) {
    let end = Instant::now();
    let mut r = lock_recorder();
    // A span may outlive the session that opened it; without an epoch
    // there is nowhere consistent to anchor it, so drop it.
    let Some(epoch) = r.epoch else { return };
    if r.events.len() >= MAX_EVENTS {
        r.dropped += 1;
        return;
    }
    // Anchor both endpoints to the epoch *before* truncating to ns, so
    // "child ends no later than parent" survives integer conversion
    // exactly — the nesting invariant tested in tests/obs_trace.rs.
    let ts_ns = inner.start.saturating_duration_since(epoch).as_nanos() as u64;
    let end_ns = end.saturating_duration_since(epoch).as_nanos() as u64;
    let tid = TID.with(|t| *t);
    r.events.push(TraceEvent {
        name: inner.name.into_owned(),
        cat: inner.cat,
        ts_ns,
        dur_ns: end_ns.saturating_sub(ts_ns),
        tid,
        args: inner.args,
    });
}

/// A completed trace: every span recorded during one session.
#[derive(Clone, Debug)]
pub struct Trace {
    /// Recorded spans in completion order.
    pub events: Vec<TraceEvent>,
    /// Spans discarded after the [`MAX_EVENTS`] cap was hit.
    pub dropped: u64,
}

impl Trace {
    /// Render as a Chrome trace-event JSON document (the "JSON object
    /// format": `{"traceEvents": [...]}`), loadable in Perfetto.
    /// Timestamps and durations are microseconds with nanosecond
    /// fractions, per the format spec.
    pub fn to_chrome_json(&self) -> Json {
        let mut events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let args: std::collections::BTreeMap<String, Json> =
                    e.args.iter().map(|(k, v)| (k.to_string(), v.clone())).collect();
                Json::obj(vec![
                    ("name", e.name.as_str().into()),
                    ("cat", e.cat.into()),
                    ("ph", "X".into()),
                    ("ts", Json::Num(e.ts_ns as f64 / 1000.0)),
                    ("dur", Json::Num(e.dur_ns as f64 / 1000.0)),
                    ("pid", 1u64.into()),
                    ("tid", e.tid.into()),
                    ("args", Json::Obj(args)),
                ])
            })
            .collect();
        // Truncation must be visible *inside* the viewer, not only in
        // `otherData` (which Perfetto hides): emit a metadata event
        // naming the drop count so a capped trace is never mistaken
        // for a complete one.
        if self.dropped > 0 {
            events.push(Json::obj(vec![
                ("name", "trace_buffer_dropped".into()),
                ("cat", "__metadata".into()),
                ("ph", "M".into()),
                ("pid", 1u64.into()),
                ("tid", 0u64.into()),
                ("args", Json::obj(vec![("dropped_events", self.dropped.into())])),
            ]));
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", "ms".into()),
            ("otherData", Json::obj(vec![("dropped_events", self.dropped.into())])),
        ])
    }
}

/// RAII guard for one recording session. Created by [`session`];
/// holding it keeps the global recorder enabled. Sessions serialize on
/// a process-wide lock, so a second caller blocks until the first
/// session ends — concurrent tests cannot interleave events.
pub struct TraceSession {
    _lock: MutexGuard<'static, ()>,
}

/// Start a recording session: resets the recorder, sets the epoch and
/// enables span capture until the returned guard is finished/dropped.
pub fn session() -> TraceSession {
    let lock = SESSION_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    {
        let mut r = lock_recorder();
        r.epoch = Some(Instant::now());
        r.events.clear();
        r.dropped = 0;
    }
    ENABLED.store(true, Ordering::SeqCst);
    TraceSession { _lock: lock }
}

impl TraceSession {
    /// Stop recording and take the collected [`Trace`]. Spans still
    /// open on other threads when this is called are discarded (they
    /// have no session to anchor to).
    pub fn finish(self) -> Trace {
        ENABLED.store(false, Ordering::SeqCst);
        let (events, dropped) = {
            let mut r = lock_recorder();
            r.epoch = None;
            (std::mem::take(&mut r.events), r.dropped)
        };
        Trace { events, dropped }
    }
}

impl Drop for TraceSession {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::SeqCst);
        let mut r = lock_recorder();
        r.epoch = None;
        r.events.clear();
        r.dropped = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_are_inert() {
        // No session: guards are empty and dynamic names never build.
        let mut sp = span("t", "noop");
        assert!(!sp.is_recording());
        sp.arg("k", 1u64);
        drop(sp);
        let called = std::cell::Cell::new(false);
        let sp = span_dyn("t", || {
            called.set(true);
            "x".to_string()
        });
        drop(sp);
        assert!(!called.get(), "span_dyn must not build the name when disabled");
    }

    #[test]
    fn session_records_nested_spans() {
        let s = session();
        {
            let mut parent = span("t", "parent");
            parent.arg("n", 2u64);
            {
                let _child = span_dyn("t", || "child".to_string());
            }
        }
        let trace = s.finish();
        assert_eq!(trace.dropped, 0);
        assert_eq!(trace.events.len(), 2);
        // Completion order: child first.
        let child = &trace.events[0];
        let parent = &trace.events[1];
        assert_eq!(child.name, "child");
        assert_eq!(parent.name, "parent");
        assert_eq!(child.tid, parent.tid);
        assert!(child.ts_ns >= parent.ts_ns);
        assert!(child.ts_ns + child.dur_ns <= parent.ts_ns + parent.dur_ns);
        assert_eq!(parent.args.len(), 1);
        // A second session starts clean.
        let s2 = session();
        assert!(enabled());
        let t2 = s2.finish();
        assert!(t2.events.is_empty());
        assert!(!enabled());
    }

    #[test]
    fn chrome_export_shape() {
        let s = session();
        {
            let mut sp = span("cat", "work");
            sp.arg("cycles", 42u64);
        }
        let doc = s.finish().to_chrome_json();
        let text = doc.to_string_compact();
        let back = crate::util::json::parse(&text).expect("chrome JSON parses");
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        let e = &events[0];
        assert_eq!(e.req_str("name").unwrap(), "work");
        assert_eq!(e.req_str("ph").unwrap(), "X");
        assert_eq!(e.req_str("cat").unwrap(), "cat");
        assert_eq!(e.req_i64("pid").unwrap(), 1);
        assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(e.get("args").unwrap().get("cycles").unwrap().as_i64(), Some(42));
    }

    #[test]
    fn dropped_events_surface_as_metadata() {
        // A clean trace carries no metadata event.
        let clean = Trace { events: Vec::new(), dropped: 0 }.to_chrome_json();
        assert!(clean.get("traceEvents").unwrap().as_arr().unwrap().is_empty());

        // A truncated trace announces the drop count inside
        // traceEvents (ph:"M"), not only in otherData.
        let doc = Trace { events: Vec::new(), dropped: 7 }.to_chrome_json();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        let m = &events[0];
        assert_eq!(m.req_str("name").unwrap(), "trace_buffer_dropped");
        assert_eq!(m.req_str("ph").unwrap(), "M");
        assert_eq!(m.get("args").unwrap().req_i64("dropped_events").unwrap(), 7);
        assert_eq!(doc.get("otherData").unwrap().req_i64("dropped_events").unwrap(), 7);
    }
}
