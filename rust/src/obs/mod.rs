//! Observability: end-to-end tracing and metrics (DESIGN.md §11).
//!
//! Two halves, both zero-dependency and rendered through the crate's
//! hand-rolled [`crate::util::json`]:
//!
//! - [`trace`] — a span/event tracer with RAII guards and Chrome
//!   trace-event export. Instrumentation covers the full request path:
//!   TCP accept → admission pricing → queue wait → registry
//!   hit/miss/compile → batch gather → per-layer host glue →
//!   per-kernel launch → per-launch simulator walk with op-class cycle
//!   attribution. **Free when off**: the disabled fast path is one
//!   relaxed atomic load, pinned by the `RunCounters` assertions in
//!   `tests/compiled_counters.rs`.
//! - [`metrics`] — always-on counters/gauges/log2-bucket histograms
//!   plus a named [`metrics::Registry`]; the serving daemon's
//!   queue-wait/exec/end-to-end latency distributions and the
//!   p50/p95/p99 fields of the stats verb come from here.
//! - [`profile`] — the cycle-attribution profiler (DESIGN.md §12):
//!   attributes every simulated step's cycles to a bottleneck class
//!   (alu / dma-port / bank-conflict / control / floor) with per-PE
//!   occupancy, per-bank conflict histograms and memory watermarks,
//!   aggregated walk → layer → network → per-tenant daemon stats.
//!   Same free-when-off contract as [`trace`].
//!
//! Entry points: `cgra trace` / `cgra profile` (CLI) record one
//! session around a compiled-path run and write Chrome JSON resp. the
//! roofline-style report; servers record into histograms
//! unconditionally and surface summaries via `server::DaemonStats`.

pub mod metrics;
pub mod profile;
pub mod trace;

pub use metrics::{Counter, Gauge, Histogram, HistogramSummary, Registry};
pub use profile::{BnClass, Profile, ProfileDelta, ProfileSession};
pub use trace::{span, span_dyn, Span, Trace, TraceEvent, TraceSession};
