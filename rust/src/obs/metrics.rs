//! Counters, gauges and log2-bucket histograms with a named registry.
//!
//! Everything here is lock-free on the record path (relaxed atomics)
//! and cheap enough to stay enabled unconditionally — unlike spans,
//! metrics have no off switch. Histograms bucket by `log2(value)`
//! (65 buckets covering the full `u64` range) and additionally keep
//! exact min/max/sum, so summaries report exact extremes and mean with
//! bucket-resolution percentiles (p50/p95/p99) — the shape the daemon
//! stats verb exposes (DESIGN.md §11).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Number of histogram buckets: one for zero plus one per power of two.
pub const N_BUCKETS: usize = 65;

/// Bucket index for a value: 0 holds exactly zero, bucket `i ≥ 1`
/// holds `[2^(i-1), 2^i)`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        64 - v.leading_zeros() as usize
    }
}

/// Inclusive upper bound of a bucket (the value percentiles report).
#[inline]
pub fn bucket_upper(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// New counter at zero.
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// New gauge at zero.
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    /// Set the current value.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log2-bucket histogram over `u64` samples (latencies in µs, cycle
/// counts, ...): 65 buckets plus exact count/sum/min/max.
#[derive(Debug)]
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; N_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// New empty histogram.
    pub const fn new() -> Histogram {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: [Z; N_BUCKETS],
        }
    }

    /// Record one sample. Five relaxed atomic ops; safe on hot paths.
    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Bucket-resolution percentile: the inclusive upper bound of the
    /// bucket holding the sample of rank `ceil(q·count)` (`q` in
    /// `[0, 1]`). Returns 0 for an empty histogram. The reported value
    /// is an upper bound on the true percentile, at most 2× above it.
    pub fn percentile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let rank = ((q * count as f64).ceil() as u64).clamp(1, count);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= rank {
                return bucket_upper(i);
            }
        }
        self.max.load(Ordering::Relaxed)
    }

    /// Consistent point-in-time summary for reporting. Percentiles are
    /// clamped to the exact observed max so `min ≤ p50 ≤ p95 ≤ p99 ≤
    /// max` always holds in rendered output.
    pub fn summary(&self) -> HistogramSummary {
        let count = self.count();
        let max = self.max.load(Ordering::Relaxed);
        HistogramSummary {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min: if count == 0 { 0 } else { self.min.load(Ordering::Relaxed) },
            max,
            p50: self.percentile(0.50).min(max),
            p95: self.percentile(0.95).min(max),
            p99: self.percentile(0.99).min(max),
        }
    }
}

/// Snapshot of a [`Histogram`] — plain data, serializable.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistogramSummary {
    /// Samples recorded.
    pub count: u64,
    /// Exact sum of all samples.
    pub sum: u64,
    /// Exact smallest sample (0 when empty).
    pub min: u64,
    /// Exact largest sample.
    pub max: u64,
    /// Median (bucket upper bound, clamped to `max`).
    pub p50: u64,
    /// 95th percentile (bucket upper bound, clamped to `max`).
    pub p95: u64,
    /// 99th percentile (bucket upper bound, clamped to `max`).
    pub p99: u64,
}

impl HistogramSummary {
    /// Exact arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Render as `{count, min, mean, p50, p95, p99, max}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", self.count.into()),
            ("min", self.min.into()),
            ("mean", self.mean().into()),
            ("p50", self.p50.into()),
            ("p95", self.p95.into()),
            ("p99", self.p99.into()),
            ("max", self.max.into()),
        ])
    }

    /// One-line human rendering in a given unit, e.g.
    /// `min 12 µs, mean 31.5 µs, p99 64 µs (n=100)`.
    pub fn human(&self, unit: &str) -> String {
        format!(
            "min {} {unit}, mean {:.1} {unit}, p99 {} {unit} (n={})",
            self.min,
            self.mean(),
            self.p99,
            self.count
        )
    }
}

/// Named metrics registry: get-or-create handles by name, render all
/// at once. Handles are `Arc`s, so hot paths cache them and never take
/// the registry lock again.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    /// New empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or create a counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().unwrap_or_else(|e| e.into_inner());
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get or create a gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get or create a histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.histograms.lock().unwrap_or_else(|e| e.into_inner());
        m.entry(name.to_string()).or_default().clone()
    }

    /// Render every metric:
    /// `{counters: {..}, gauges: {..}, histograms: {name: summary}}`.
    pub fn to_json(&self) -> Json {
        let counters: BTreeMap<String, Json> = {
            let m = self.counters.lock().unwrap_or_else(|e| e.into_inner());
            m.iter().map(|(k, v)| (k.clone(), v.get().into())).collect()
        };
        let gauges: BTreeMap<String, Json> = {
            let m = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
            m.iter().map(|(k, v)| (k.clone(), v.get().into())).collect()
        };
        let histograms: BTreeMap<String, Json> = {
            let m = self.histograms.lock().unwrap_or_else(|e| e.into_inner());
            m.iter().map(|(k, v)| (k.clone(), v.summary().to_json())).collect()
        };
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(histograms)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(1), 1);
        assert_eq!(bucket_upper(2), 3);
        assert_eq!(bucket_upper(64), u64::MAX);
        // Every boundary value lands in a bucket whose bounds admit it.
        for i in 1..64usize {
            let lo = 1u64 << (i - 1);
            assert_eq!(bucket_index(lo), i);
            assert_eq!(bucket_index(bucket_upper(i)), i);
        }
    }

    #[test]
    fn histogram_summary_math() {
        let h = Histogram::new();
        assert_eq!(h.summary(), HistogramSummary::default());
        for v in [0u64, 1, 2, 3, 100, 1000] {
            h.record(v);
        }
        let s = h.summary();
        assert_eq!(s.count, 6);
        assert_eq!(s.sum, 1106);
        assert_eq!(s.min, 0);
        assert_eq!(s.max, 1000);
        assert!((s.mean() - 1106.0 / 6.0).abs() < 1e-9);
        // rank(0.5·6)=3 → third sample (2) → bucket [2,3] upper bound.
        assert_eq!(s.p50, 3);
        // p99 clamps to the exact max (bucket bound would be 1023).
        assert_eq!(h.percentile(0.99), 1023);
        assert_eq!(s.p99, 1000);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn registry_handles_and_render() {
        let r = Registry::new();
        let c = r.counter("served");
        c.inc();
        r.counter("served").add(2);
        assert_eq!(c.get(), 3, "same name must alias the same counter");
        r.gauge("depth").set(7);
        r.histogram("wait_us").record(5);
        let j = r.to_json();
        assert_eq!(j.get("counters").unwrap().get("served").unwrap().as_i64(), Some(3));
        assert_eq!(j.get("gauges").unwrap().get("depth").unwrap().as_i64(), Some(7));
        let h = j.get("histograms").unwrap().get("wait_us").unwrap();
        assert_eq!(h.req_i64("count").unwrap(), 1);
        assert_eq!(h.req_i64("p99").unwrap(), 5);
        // Round-trips through the crate's own parser.
        let text = j.to_string_compact();
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
    }

    #[test]
    fn percentile_edges() {
        // Empty: every quantile is 0 and the summary is all-zero.
        let h = Histogram::new();
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.percentile(q), 0);
        }
        assert_eq!(h.summary(), HistogramSummary::default());

        // Single sample: every quantile reports that sample's bucket,
        // clamped to the exact value in the summary — including q=0,
        // whose rank clamps up to 1.
        let h = Histogram::new();
        h.record(5);
        for q in [0.0, 0.25, 0.5, 1.0] {
            assert_eq!(h.percentile(q), 7, "bucket [4,7] upper bound");
        }
        let s = h.summary();
        assert_eq!((s.min, s.p50, s.p99, s.max), (5, 5, 5, 5));

        // All samples in one bucket: quantiles can't split the bucket,
        // so p50 == p99 == the bucket bound.
        let h = Histogram::new();
        for v in [8u64, 9, 12, 15] {
            h.record(v);
        }
        assert_eq!(h.percentile(0.5), 15);
        assert_eq!(h.percentile(0.99), 15);
        let s = h.summary();
        assert_eq!((s.min, s.p50, s.p99, s.max), (8, 15, 15, 15));

        // Saturating max: u64::MAX lands in the last bucket and the
        // upper bound saturates instead of overflowing.
        let h = Histogram::new();
        h.record(u64::MAX);
        h.record(0);
        assert_eq!(h.percentile(1.0), u64::MAX);
        assert_eq!(h.percentile(0.5), 0, "rank 1 is the zero bucket");
        let s = h.summary();
        assert_eq!(s.max, u64::MAX);
        assert_eq!(s.p99, u64::MAX);
        assert_eq!(s.min, 0);
        // The sum wraps silently only via the atomic add — document
        // the observed value: MAX + 0 = MAX.
        assert_eq!(s.sum, u64::MAX);

        // Out-of-range q is clamped by the rank computation, never a
        // panic or an out-of-range rank.
        let h = Histogram::new();
        h.record(3);
        h.record(4);
        assert_eq!(h.percentile(-1.0), 3, "rank clamps up to 1");
        assert_eq!(h.percentile(2.0), 7, "rank clamps down to count");
    }

    #[test]
    fn counter_gauge_basics() {
        let c = Counter::new();
        assert_eq!(c.get(), 0);
        c.inc();
        assert_eq!(c.get(), 1);
        let g = Gauge::new();
        g.set(9);
        assert_eq!(g.get(), 9);
        let s = Histogram::new();
        s.record(42);
        assert!(s.summary().human("µs").contains("n=1"));
    }
}
