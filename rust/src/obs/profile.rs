//! Cycle-attribution profiler: per-PE / per-bank bottleneck accounting.
//!
//! The executor computes a step-cost decomposition every step —
//! `alu_part` / `port_part` / `bank_part` in `cgra::exec::step_cost` —
//! and keeps only the max. This module, when a [`session`] is active,
//! attributes every `step_cycles` to a winning **bottleneck class**
//! ([`BnClass`]): the ALU critical path, DMA-port serialization,
//! memory-bank conflicts, control/bubble steps (the issue floor on
//! steps doing no data work), or the watchdog floor (the `.max(1)`
//! charge when every part is zero). Ties are split largest-remainder
//! style: the tied classes share the step's cycles equally and the
//! integer shortfall goes to the earlier classes in the fixed order
//! alu → dma-port → bank-conflict — deterministic, so the scalar and
//! batched executors (which share one walk) attribute identically.
//!
//! Alongside the class split the profiler accumulates per-PE busy/idle
//! occupancy (cycle-weighted, a PE is busy on a step when its issued
//! op is not a `nop`), per-PE × op-class issue counts, per-bank
//! conflict-degree histograms (how many same-bank accesses collided
//! per step), and the memory footprint watermark of each walk.
//!
//! # Free when off, observe-don't-perturb
//!
//! Same contract as [`super::trace`]: with no session active the entire
//! subsystem costs **one relaxed atomic load per simulator run** (not
//! per step — the executors latch [`enabled`] once at entry). The
//! profiler only ever *reads* executor state; it never feeds back into
//! timing, energy or architectural state, so a profiled run reports
//! bit-identical modeled numbers (pinned by `tests/profile.rs` and
//! `tests/compiled_counters.rs`).
//!
//! # Aggregation
//!
//! Walk deltas accumulate three ways at once:
//! - **per walk**: the executor finishes a walk → [`take_last_walk`]
//!   hands the delta to `kernels::prebuilt`, which attaches it to the
//!   PR-8 `walk:` span and files it under its mapping label;
//! - **per frame**: `engine::compiled` brackets layers and whole
//!   inferences in RAII [`Frame`]s; child frames fold into their parent
//!   on finish, so an `InferRun` carries its exact per-inference delta
//!   (batch walks are shared and counted once — lane-for-lane equal to
//!   a scalar run by construction);
//! - **globally**: every walk also folds into the session totals,
//!   grouped by mapping label and by layer, returned by
//!   [`ProfileSession::finish`] as a [`Profile`].

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::cgra::OpClass;
use crate::isa::N_PES;
use crate::util::json::Json;

/// Conflict-degree histogram cap: per-step same-bank access counts of
/// `MAX_CONFLICT_DEGREE` or more share the last bucket (16 PEs means
/// degrees above 16 are impossible on the paper's array anyway).
pub const MAX_CONFLICT_DEGREE: usize = 16;

/// The bottleneck classes a step's cycles are attributed to.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BnClass {
    /// ALU critical path won: the step was compute-limited.
    Alu,
    /// Per-column DMA-port serialization won.
    DmaPort,
    /// Memory-bank conflicts won.
    BankConflict,
    /// The ALU term won but no PE issued a load/mul/sum/store — the
    /// cycles are control flow, address setup or bubbles.
    Control,
    /// Every part was zero; the cycle is the executor's `.max(1)`
    /// issue floor.
    Floor,
}

impl BnClass {
    /// Number of classes (array sizing).
    pub const COUNT: usize = 5;

    /// All classes in report order.
    pub const ALL: [BnClass; 5] =
        [BnClass::Alu, BnClass::DmaPort, BnClass::BankConflict, BnClass::Control, BnClass::Floor];

    /// Index into `[u64; COUNT]` accumulators.
    pub fn idx(self) -> usize {
        match self {
            BnClass::Alu => 0,
            BnClass::DmaPort => 1,
            BnClass::BankConflict => 2,
            BnClass::Control => 3,
            BnClass::Floor => 4,
        }
    }

    /// Human-readable report label.
    pub fn label(self) -> &'static str {
        match self {
            BnClass::Alu => "alu",
            BnClass::DmaPort => "dma-port",
            BnClass::BankConflict => "bank-conflict",
            BnClass::Control => "control/bubble",
            BnClass::Floor => "watchdog-floor",
        }
    }

    /// Identifier-safe key for JSON objects and span args.
    pub fn key(self) -> &'static str {
        match self {
            BnClass::Alu => "alu",
            BnClass::DmaPort => "dma_port",
            BnClass::BankConflict => "bank_conflict",
            BnClass::Control => "control",
            BnClass::Floor => "floor",
        }
    }
}

/// One profiling accumulation — a single walk, a layer, an inference
/// or a whole session, depending on where it was collected.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ProfileDelta {
    /// Simulator walks folded into this delta.
    pub walks: u64,
    /// Issue steps observed.
    pub steps: u64,
    /// Modeled cycles observed (identical to the sum of the walks'
    /// `RunStats::cycles` — the profiler never re-models anything).
    pub cycles: u64,
    /// Bottleneck attribution, indexed by [`BnClass::idx`]. Sums to
    /// `cycles` exactly (the invariant `tests/profile.rs` enforces).
    pub class_cycles: [u64; BnClass::COUNT],
    /// Cycle-weighted busy occupancy per PE (issued op ≠ nop).
    pub busy: [u64; N_PES],
    /// Cycle-weighted idle occupancy per PE (`busy[i] + idle[i] ==
    /// cycles` for every PE).
    pub idle: [u64; N_PES],
    /// Issue-slot counts per PE × op class (`[pe][OpClass::idx()]`).
    pub pe_ops: [[u64; OpClass::COUNT]; N_PES],
    /// Per-bank conflict-degree histogram: `bank_conflicts[b][d]` =
    /// steps on which bank `b` took exactly `d` accesses (degree
    /// clamped to [`MAX_CONFLICT_DEGREE`]; degree ≥ 2 is a conflict).
    pub bank_conflicts: Vec<[u64; MAX_CONFLICT_DEGREE + 1]>,
    /// Highest memory word touched + 1 (footprint watermark; the max
    /// over folded walks).
    pub hi_water_words: usize,
}

impl ProfileDelta {
    /// Fold `other` into `self` (sums everywhere; watermark is a max).
    pub fn merge(&mut self, other: &ProfileDelta) {
        self.walks += other.walks;
        self.steps += other.steps;
        self.cycles += other.cycles;
        for k in 0..BnClass::COUNT {
            self.class_cycles[k] += other.class_cycles[k];
        }
        for i in 0..N_PES {
            self.busy[i] += other.busy[i];
            self.idle[i] += other.idle[i];
            for k in 0..OpClass::COUNT {
                self.pe_ops[i][k] += other.pe_ops[i][k];
            }
        }
        if self.bank_conflicts.len() < other.bank_conflicts.len() {
            self.bank_conflicts
                .resize(other.bank_conflicts.len(), [0; MAX_CONFLICT_DEGREE + 1]);
        }
        for (a, b) in self.bank_conflicts.iter_mut().zip(other.bank_conflicts.iter()) {
            for d in 0..=MAX_CONFLICT_DEGREE {
                a[d] += b[d];
            }
        }
        self.hi_water_words = self.hi_water_words.max(other.hi_water_words);
    }

    /// Scale every additive counter by `n` (a launch class observed via
    /// one probe stands for `n` structurally identical launches). The
    /// watermark is left alone — it is a max, not a sum.
    pub fn scale(&mut self, n: u64) {
        self.walks *= n;
        self.steps *= n;
        self.cycles *= n;
        for k in 0..BnClass::COUNT {
            self.class_cycles[k] *= n;
        }
        for i in 0..N_PES {
            self.busy[i] *= n;
            self.idle[i] *= n;
            for k in 0..OpClass::COUNT {
                self.pe_ops[i][k] *= n;
            }
        }
        for h in self.bank_conflicts.iter_mut() {
            for d in 0..=MAX_CONFLICT_DEGREE {
                h[d] *= n;
            }
        }
    }

    /// Bottleneck shares as fractions of `cycles` (zeros when empty).
    pub fn class_shares(&self) -> [f64; BnClass::COUNT] {
        let mut out = [0.0; BnClass::COUNT];
        if self.cycles == 0 {
            return out;
        }
        for k in 0..BnClass::COUNT {
            out[k] = self.class_cycles[k] as f64 / self.cycles as f64;
        }
        out
    }

    /// Cycles a bank spent conflicted (degree ≥ 2), summed over steps
    /// — a per-bank severity scalar for reports.
    pub fn bank_conflict_steps(&self, bank: usize) -> u64 {
        self.bank_conflicts
            .get(bank)
            .map(|h| h[2..].iter().sum())
            .unwrap_or(0)
    }

    /// JSON rendering (hand-rolled `util::json`, no serde — per ADR).
    pub fn to_json(&self) -> Json {
        let classes = Json::obj(
            BnClass::ALL
                .iter()
                .map(|c| (c.key(), Json::from(self.class_cycles[c.idx()])))
                .collect(),
        );
        let pes = Json::Arr(
            (0..N_PES)
                .map(|i| {
                    Json::obj(vec![
                        ("busy", self.busy[i].into()),
                        ("idle", self.idle[i].into()),
                        (
                            "ops",
                            Json::obj(
                                OpClass::ALL
                                    .iter()
                                    .map(|c| (c.label(), Json::from(self.pe_ops[i][c.idx()])))
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        let banks = Json::Arr(
            self.bank_conflicts
                .iter()
                .map(|h| Json::Arr(h.iter().map(|&n| n.into()).collect()))
                .collect(),
        );
        Json::obj(vec![
            ("walks", self.walks.into()),
            ("steps", self.steps.into()),
            ("cycles", self.cycles.into()),
            ("bottleneck_cycles", classes),
            ("pes", pes),
            ("bank_conflict_hist", banks),
            ("hi_water_words", (self.hi_water_words as u64).into()),
        ])
    }
}

/// A finished profiling session: totals plus per-mapping and per-layer
/// breakdowns (BTreeMaps — deterministic iteration order for reports).
#[derive(Clone, Debug, Default)]
pub struct Profile {
    /// Everything observed during the session.
    pub total: ProfileDelta,
    /// Walk deltas grouped by mapping label (`walk:<label>` spans).
    pub by_mapping: BTreeMap<String, ProfileDelta>,
    /// Frame deltas grouped by compiled-layer key (`L<idx>:<kind>`).
    pub by_layer: BTreeMap<String, ProfileDelta>,
}

impl Profile {
    /// JSON rendering of the whole session.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total", self.total.to_json()),
            (
                "by_mapping",
                Json::Obj(
                    self.by_mapping
                        .iter()
                        .map(|(k, v)| (k.clone(), v.to_json()))
                        .collect(),
                ),
            ),
            (
                "by_layer",
                Json::Obj(
                    self.by_layer
                        .iter()
                        .map(|(k, v)| (k.clone(), v.to_json()))
                        .collect(),
                ),
            ),
        ])
    }
}

// ---------------------------------------------------------------------
// Process-wide state
// ---------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is a profiling session active? One relaxed load — the executors
/// call this once per run and skip every hook when it is false.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

#[derive(Default)]
struct GlobalAgg {
    total: ProfileDelta,
    by_mapping: BTreeMap<String, ProfileDelta>,
    by_layer: BTreeMap<String, ProfileDelta>,
}

fn global() -> &'static Mutex<GlobalAgg> {
    static G: OnceLock<Mutex<GlobalAgg>> = OnceLock::new();
    G.get_or_init(|| Mutex::new(GlobalAgg::default()))
}

fn session_lock() -> &'static Mutex<()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(()))
}

struct Tls {
    walk: ProfileDelta,
    last_walk: Option<ProfileDelta>,
    frames: Vec<ProfileDelta>,
}

thread_local! {
    static TLS: RefCell<Tls> = const {
        RefCell::new(Tls { walk: new_delta(), last_walk: None, frames: Vec::new() })
    };
}

/// `ProfileDelta::default()` is not const-evaluable (Vec); spell out
/// the zero value for the thread-local initializer.
const fn new_delta() -> ProfileDelta {
    ProfileDelta {
        walks: 0,
        steps: 0,
        cycles: 0,
        class_cycles: [0; BnClass::COUNT],
        busy: [0; N_PES],
        idle: [0; N_PES],
        pe_ops: [[0; OpClass::COUNT]; N_PES],
        bank_conflicts: Vec::new(),
        hi_water_words: 0,
    }
}

// ---------------------------------------------------------------------
// Executor hooks (crate-internal)
// ---------------------------------------------------------------------

/// Start accumulating a walk on this thread. Called by the executors
/// only when [`enabled`] was true at run entry.
pub(crate) fn begin_walk() {
    TLS.with(|t| t.borrow_mut().walk = new_delta());
}

/// Attribute one executed step. `pe_class` is the [`OpClass::idx`] of
/// the op each PE issued this step; `bank_hits` is only meaningful
/// when `any_mem` (the executors skip clearing it otherwise).
#[allow(clippy::too_many_arguments)]
pub(crate) fn observe_step(
    alu_part: u64,
    port_part: u64,
    bank_part: u64,
    step_cycles: u64,
    any_mem: bool,
    bank_hits: &[u32],
    pe_class: &[usize; N_PES],
) {
    TLS.with(|t| {
        let mut t = t.borrow_mut();
        let d = &mut t.walk;
        d.steps += 1;
        d.cycles += step_cycles;
        let any_data_op = pe_class.iter().any(|&c| c <= OpClass::Store.idx());
        attribute(&mut d.class_cycles, alu_part, port_part, bank_part, step_cycles, any_data_op);
        for (i, &c) in pe_class.iter().enumerate() {
            if c == OpClass::Nop.idx() {
                d.idle[i] += step_cycles;
            } else {
                d.busy[i] += step_cycles;
            }
            d.pe_ops[i][c] += 1;
        }
        if any_mem {
            if d.bank_conflicts.len() < bank_hits.len() {
                d.bank_conflicts.resize(bank_hits.len(), [0; MAX_CONFLICT_DEGREE + 1]);
            }
            for (b, &n) in bank_hits.iter().enumerate() {
                if n > 0 {
                    d.bank_conflicts[b][(n as usize).min(MAX_CONFLICT_DEGREE)] += 1;
                }
            }
        }
    });
}

/// Split one step's cycles over the winning bottleneck classes.
///
/// The winner set is every part equal to the max; each gets an equal
/// `cycles / k` share and the integer shortfall goes one cycle apiece
/// to the earliest winners in fixed alu → dma-port → bank-conflict
/// order (the degenerate largest-remainder rule: equal shares mean
/// equal remainders, broken by class order — deterministic, so scalar
/// and batch attribution agree by construction). An alu-limited step
/// with no data op anywhere is `Control`; a step where every part is
/// zero is the executor's `.max(1)` `Floor`.
fn attribute(
    cc: &mut [u64; BnClass::COUNT],
    alu_part: u64,
    port_part: u64,
    bank_part: u64,
    cycles: u64,
    any_data_op: bool,
) {
    let m = alu_part.max(port_part).max(bank_part);
    if m == 0 {
        cc[BnClass::Floor.idx()] += cycles;
        return;
    }
    let alu_class = if any_data_op { BnClass::Alu } else { BnClass::Control };
    let mut winners = [BnClass::Alu; 3];
    let mut k = 0usize;
    if alu_part == m {
        winners[k] = alu_class;
        k += 1;
    }
    if port_part == m {
        winners[k] = BnClass::DmaPort;
        k += 1;
    }
    if bank_part == m {
        winners[k] = BnClass::BankConflict;
        k += 1;
    }
    let share = cycles / k as u64;
    let rem = (cycles % k as u64) as usize;
    for (j, w) in winners[..k].iter().enumerate() {
        cc[w.idx()] += share + u64::from(j < rem);
    }
}

/// Finish the walk started by [`begin_walk`]: stamp the memory
/// watermark, fold into the enclosing [`Frame`] (if any) and the
/// session totals, and stash the delta for [`take_last_walk`].
pub(crate) fn end_walk(hi_water_words: usize) {
    TLS.with(|t| {
        let mut t = t.borrow_mut();
        let mut d = std::mem::replace(&mut t.walk, new_delta());
        d.walks = 1;
        d.hi_water_words = hi_water_words;
        if let Some(top) = t.frames.last_mut() {
            top.merge(&d);
        }
        global().lock().unwrap_or_else(|e| e.into_inner()).total.merge(&d);
        t.last_walk = Some(d);
    });
}

/// Take the delta of the most recent finished walk on this thread
/// (None when no profiled walk has finished since the last take).
pub fn take_last_walk() -> Option<ProfileDelta> {
    TLS.with(|t| t.borrow_mut().last_walk.take())
}

/// File a walk delta under its mapping label in the session aggregate.
pub(crate) fn record_walk(label: &str, d: &ProfileDelta) {
    let mut g = global().lock().unwrap_or_else(|e| e.into_inner());
    g.by_mapping.entry(label.to_string()).or_default().merge(d);
}

/// File a frame delta under a compiled-layer key in the session
/// aggregate.
pub(crate) fn record_layer(key: String, d: &ProfileDelta) {
    let mut g = global().lock().unwrap_or_else(|e| e.into_inner());
    g.by_layer.entry(key).or_default().merge(d);
}

// ---------------------------------------------------------------------
// Frames (layer / inference aggregation)
// ---------------------------------------------------------------------

/// RAII aggregation scope: walks finishing on this thread fold into
/// the innermost open frame; a finished child folds into its parent.
/// Free when off — an inactive frame pushes nothing and returns None.
#[must_use]
pub struct Frame {
    pushed: bool,
}

/// Open a frame on this thread (no-op unless a session is active).
pub fn frame() -> Frame {
    let pushed = enabled();
    if pushed {
        TLS.with(|t| t.borrow_mut().frames.push(new_delta()));
    }
    Frame { pushed }
}

impl Frame {
    /// Close the frame and return everything it accumulated (also
    /// folded into the parent frame, if one is open).
    pub fn finish(mut self) -> Option<ProfileDelta> {
        self.pop()
    }

    fn pop(&mut self) -> Option<ProfileDelta> {
        if !self.pushed {
            return None;
        }
        self.pushed = false;
        TLS.with(|t| {
            let mut t = t.borrow_mut();
            let d = t.frames.pop()?;
            if let Some(parent) = t.frames.last_mut() {
                parent.merge(&d);
            }
            Some(d)
        })
    }
}

impl Drop for Frame {
    fn drop(&mut self) {
        // Keep the frame stack balanced even if a run errors out and
        // the frame is dropped without finish().
        let _ = self.pop();
    }
}

// ---------------------------------------------------------------------
// Sessions
// ---------------------------------------------------------------------

/// An active profiling session. Exactly one exists at a time
/// (process-global, serialized by a lock like trace sessions);
/// dropping it disables profiling.
pub struct ProfileSession {
    _guard: MutexGuard<'static, ()>,
    finished: bool,
}

/// Start a profiling session: resets the session aggregate and flips
/// [`enabled`] on. Blocks until any other session has finished.
pub fn session() -> ProfileSession {
    let guard = session_lock().lock().unwrap_or_else(|e| e.into_inner());
    *global().lock().unwrap_or_else(|e| e.into_inner()) = GlobalAgg::default();
    ENABLED.store(true, Ordering::SeqCst);
    ProfileSession { _guard: guard, finished: false }
}

impl ProfileSession {
    /// Stop profiling and return everything the session observed.
    pub fn finish(mut self) -> Profile {
        self.finished = true;
        ENABLED.store(false, Ordering::SeqCst);
        let g = std::mem::take(&mut *global().lock().unwrap_or_else(|e| e.into_inner()));
        Profile { total: g.total, by_mapping: g.by_mapping, by_layer: g.by_layer }
    }
}

impl Drop for ProfileSession {
    fn drop(&mut self) {
        if !self.finished {
            ENABLED.store(false, Ordering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cc(
        alu: u64,
        port: u64,
        bank: u64,
        cycles: u64,
        any_data: bool,
    ) -> [u64; BnClass::COUNT] {
        let mut out = [0; BnClass::COUNT];
        attribute(&mut out, alu, port, bank, cycles, any_data);
        out
    }

    #[test]
    fn attribution_sums_and_single_winners() {
        // Clear single winners take everything.
        assert_eq!(cc(5, 3, 2, 5, true)[BnClass::Alu.idx()], 5);
        assert_eq!(cc(1, 8, 4, 8, true)[BnClass::DmaPort.idx()], 8);
        assert_eq!(cc(1, 4, 9, 9, true)[BnClass::BankConflict.idx()], 9);
        // Control: alu-limited step with no data op anywhere.
        assert_eq!(cc(1, 0, 0, 1, false)[BnClass::Control.idx()], 1);
        // Floor: every part zero, the .max(1) charge.
        assert_eq!(cc(0, 0, 0, 1, true)[BnClass::Floor.idx()], 1);
    }

    #[test]
    fn tie_splitting_is_largest_remainder() {
        // Two-way tie over 9 cycles: 5/4, shortfall to the earlier
        // class (alu before dma-port).
        let out = cc(9, 9, 0, 9, true);
        assert_eq!(out[BnClass::Alu.idx()], 5);
        assert_eq!(out[BnClass::DmaPort.idx()], 4);
        // Three-way tie over 10: 4/3/3 in class order.
        let out = cc(10, 10, 10, 10, true);
        assert_eq!(out[BnClass::Alu.idx()], 4);
        assert_eq!(out[BnClass::DmaPort.idx()], 3);
        assert_eq!(out[BnClass::BankConflict.idx()], 3);
        // Adversarial sweep: the split always sums exactly.
        for a in 0..4u64 {
            for p in 0..4u64 {
                for b in 0..4u64 {
                    for cyc in 1..7u64 {
                        let out = cc(a, p, b, cyc, true);
                        assert_eq!(out.iter().sum::<u64>(), cyc, "a={a} p={p} b={b} c={cyc}");
                    }
                }
            }
        }
    }

    #[test]
    fn delta_merge_and_scale() {
        let mut a = new_delta();
        a.walks = 1;
        a.cycles = 10;
        a.class_cycles[0] = 10;
        a.busy[3] = 10;
        a.hi_water_words = 100;
        a.bank_conflicts = vec![[0; MAX_CONFLICT_DEGREE + 1]; 2];
        a.bank_conflicts[1][2] = 4;
        let mut b = new_delta();
        b.walks = 2;
        b.cycles = 5;
        b.class_cycles[1] = 5;
        b.hi_water_words = 60;
        b.bank_conflicts = vec![[0; MAX_CONFLICT_DEGREE + 1]; 4];
        b.bank_conflicts[3][16] = 1;
        a.merge(&b);
        assert_eq!(a.walks, 3);
        assert_eq!(a.cycles, 15);
        assert_eq!(a.class_cycles[0] + a.class_cycles[1], 15);
        assert_eq!(a.hi_water_words, 100, "watermark is a max, not a sum");
        assert_eq!(a.bank_conflicts.len(), 4);
        assert_eq!(a.bank_conflict_steps(1), 4);
        a.scale(3);
        assert_eq!(a.walks, 9);
        assert_eq!(a.cycles, 45);
        assert_eq!(a.bank_conflicts[1][2], 12);
        assert_eq!(a.hi_water_words, 100, "scale leaves the watermark alone");
    }

    #[test]
    fn delta_json_shape() {
        let mut d = new_delta();
        d.walks = 1;
        d.cycles = 7;
        d.class_cycles[BnClass::Alu.idx()] = 7;
        let s = d.to_json().to_string_compact();
        assert!(s.contains("\"bottleneck_cycles\""));
        assert!(s.contains("\"alu\":7"));
        assert!(s.contains("\"hi_water_words\":0"));
        assert!(s.contains("\"pes\""));
    }
}
