//! # openedge-cgra
//!
//! A full-system reproduction of *"Performance evaluation of acceleration
//! of convolutional layers on OpenEdgeCGRA"* (ACM Computing Frontiers 2024).
//!
//! **Start at [`engine`]** — the session-based front door. An
//! [`engine::Engine`] (built via [`engine::EngineBuilder`]) owns the
//! simulator config, energy model, worker pool and result caches, and
//! serves typed [`engine::ConvRequest`]s one at a time (`submit`), in
//! order-preserving batches over the pool (`submit_batch`), as chained
//! CNN inferences (`run_network`), or as whole figure sweeps (`sweep`,
//! `run_all_mappings`). `Mapping::Auto` lets the engine pick the
//! strategy per the paper's findings and records the decision in the
//! result. For repeated inference traffic, `Engine::compile` freezes a
//! network into a reusable `CompiledNet` artifact whose warm `run`
//! does zero compile-side work (`cgra compile` / `cgra serve`).
//!
//! The crate contains, from the bottom up:
//!
//! - [`isa`] / [`asm`] — the OpenEdgeCGRA instruction set (32-bit integer
//!   ALU, auto-increment loads/stores, branches, **no MAC**) and a text
//!   assembler for it.
//! - [`cgra`] — a cycle-level simulator of the 4×4 PE array: torus
//!   interconnect, per-column program counters and DMA ports, a contended
//!   memory subsystem, and per-PE statistics. Execution is a two-stage
//!   decode/execute engine with a process-wide decoded-program memo
//!   (DESIGN.md §3.4); the pre-refactor interpreter survives as the
//!   differential baseline `Cgra::run_reference`.
//! - [`conv`] — the convolution substrate: int32 tensors, CHW/HWC layouts,
//!   a golden direct convolution and the Im2col transformation.
//! - [`kernels`] — the paper's four mapping strategies as *program
//!   generators*: `WP` (direct conv, weight parallelism), `IP` (im2col,
//!   input-channel parallelism), `OP-im2col` and `OP-direct`
//!   (output-channel parallelism).
//! - [`cpu_ref`] — the CPU-only baseline (functional + cycle cost model).
//! - [`energy`] / [`metrics`] — the paper's evaluation metrics: latency,
//!   energy (CGRA + CPU + memory blocks), memory footprint, MAC/cycle.
//! - [`coordinator`] — a multi-threaded sweep/aggregation layer that
//!   regenerates the paper's figures — work sharded over a pool with a
//!   cross-driver sweep-point cache — plus a layer-wise network runner.
//! - [`engine`] — the session front door: `Engine` / `EngineBuilder`,
//!   typed `ConvRequest` → `ConvResult` submission (single, batched,
//!   network, sweep), `Mapping::Auto` strategy selection, and the
//!   compile-once / run-many `CompiledNet` artifact (`engine::compiled`,
//!   DESIGN.md §8).
//! - [`planner`] — the analytical cost model: closed-form launch
//!   decomposition + micro-probe calibration predicts latency/energy
//!   per `(shape, mapping)` without simulating (`Engine::plan`,
//!   `submit_planned`, `plan_network`), validated against the decoded
//!   simulator by `cgra plan --validate`.
//! - [`nn`] — the layer-graph subsystem: generalized convolutions
//!   (stride / padding / groups), depthwise (`Dw-WP`) and pointwise
//!   layers, pooling, named presets, and a graph executor + planner
//!   that lower MobileNet-style networks end to end onto the engine
//!   (`cgra net --preset <name>`).
//! - [`server`] — the persistent serving subsystem (`cgra daemon`): a
//!   bounded multi-tenant artifact registry over `CompiledNet`,
//!   planner-priced admission control with per-request deadlines and a
//!   degradation ladder, a batching worker pool, and a stats surface —
//!   in-process ([`server::Daemon`]) or NDJSON over TCP
//!   ([`server::tcp`]).
//! - [`obs`] — observability: a free-when-off span tracer covering the
//!   whole request path (daemon accept → admission → queue → layers →
//!   µop walks) with Chrome trace-event export (`cgra trace`),
//!   always-on counters/gauges/log2 histograms behind the daemon's
//!   p50/p95/p99 stats fields, and a cycle-attribution profiler that
//!   accounts every modeled cycle to a bottleneck class — ALU,
//!   DMA port, bank conflict, control, watchdog floor — per PE and
//!   per bank (`cgra profile`, DESIGN.md §12).
//! - [`runtime`] — the PJRT bridge: loads AOT-compiled JAX/Pallas HLO
//!   artifacts and verifies the simulator element-exactly against them.
//! - [`report`] — figure/table regeneration (Fig. 3, Fig. 4, Fig. 5),
//!   driven through an [`engine::Engine`].
//! - [`util`], [`prop`], [`benchkit`] — offline-friendly infrastructure:
//!   CLI parsing, JSON, deterministic property testing and benchmarking.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod asm;
pub mod benchkit;
pub mod cgra;
pub mod conv;
pub mod coordinator;
pub mod cpu_ref;
pub mod energy;
pub mod engine;
pub mod isa;
pub mod kernels;
pub mod metrics;
pub mod nn;
pub mod obs;
pub mod planner;
pub mod prop;
pub mod report;
pub mod runtime;
pub mod server;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
