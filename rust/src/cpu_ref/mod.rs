//! CPU-only baseline: the plain nested-loop convolution the paper
//! compares every CGRA mapping against (the "CPU" point in Figure 4).
//!
//! Functionally it is the golden direct convolution; the cycle cost comes
//! from an instruction-level model of an in-order, single-issue RV32IM
//! microcontroller core (X-HEEP's CPU class) executing the naive loop
//! nest. The per-MAC budget is documented field by field in
//! [`CpuModel`]; with the defaults it lands at 17.5 cycles/MAC ≈ 0.057
//! MAC/cycle, which reproduces the paper's 9.9× WP-vs-CPU latency ratio
//! against WP's ≈0.6 MAC/cycle.

use anyhow::Result;

use crate::cgra::{MemStats, RunStats};
use crate::conv::{conv2d, ConvShape, TensorChw, Weights};
use crate::kernels::{ConvOutcome, LatencyBreakdown, Mapping};

/// Cycle cost model of the scalar core.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CpuModel {
    /// Cycles per data load (shared memory subsystem, no D-cache).
    pub load_latency: f64,
    /// Cycles for the 32-bit multiply.
    pub mul_latency: f64,
    /// Cycles per simple ALU op.
    pub alu_latency: f64,
    /// Address-computation ALU ops per MAC for the naive CHW loop nest
    /// (two 3-level index calculations amortized by strength reduction).
    pub addr_ops_per_mac: f64,
    /// Amortized loop-control cycles per MAC (compare + branch of the
    /// inner loop, partially amortized outer levels).
    pub loop_overhead_per_mac: f64,
    /// Cycles per output-element store (amortized over C·9 MACs each).
    pub store_latency: f64,
}

impl Default for CpuModel {
    fn default() -> Self {
        CpuModel {
            load_latency: 4.0,
            mul_latency: 1.0,
            alu_latency: 1.0,
            addr_ops_per_mac: 6.0,
            loop_overhead_per_mac: 1.5,
            store_latency: 4.0,
        }
    }
}

impl CpuModel {
    /// Cycles per MAC: 2 loads + mul + accumulate-add + addressing +
    /// loop control.
    pub fn cycles_per_mac(&self) -> f64 {
        2.0 * self.load_latency
            + self.mul_latency
            + self.alu_latency
            + self.addr_ops_per_mac * self.alu_latency
            + self.loop_overhead_per_mac
    }

    /// Total cycles for a layer.
    pub fn conv_cycles(&self, shape: &ConvShape) -> u64 {
        let macs = shape.macs() as f64;
        let stores = shape.output_elems() as f64;
        (macs * self.cycles_per_mac() + stores * self.store_latency).round() as u64
    }
}

/// Execute the CPU baseline: golden convolution + cycle/energy accounting.
pub fn run(
    model: &CpuModel,
    shape: &ConvShape,
    input: &TensorChw,
    weights: &Weights,
) -> Result<ConvOutcome> {
    shape.validate()?;
    let output = conv2d(shape, input, weights);
    let latency = LatencyBreakdown {
        cpu_compute_cycles: model.conv_cycles(shape),
        ..Default::default()
    };
    Ok(ConvOutcome {
        mapping: Mapping::Cpu,
        shape: *shape,
        output,
        latency,
        cgra_stats: RunStats::new(),
        cpu_mem: MemStats { loads: 2 * shape.macs(), stores: shape.output_elems() as u64 },
        footprint_bytes: shape.base_bytes(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{random_input, random_weights};
    use crate::prop::Rng;

    #[test]
    fn default_model_matches_paper_ratio_anchor() {
        let m = CpuModel::default();
        // 2*4 + 1 + 1 + 6 + 1.5 = 17.5 cycles/MAC.
        assert!((m.cycles_per_mac() - 17.5).abs() < 1e-9);
        let mac_per_cycle = 1.0 / m.cycles_per_mac();
        assert!((0.050..0.068).contains(&mac_per_cycle));
    }

    #[test]
    fn functional_output_is_golden() {
        let shape = ConvShape::new3x3(3, 4, 5, 6);
        let mut rng = Rng::new(1);
        let input = random_input(&shape, 40, &mut rng);
        let weights = random_weights(&shape, 9, &mut rng);
        let out = run(&CpuModel::default(), &shape, &input, &weights).unwrap();
        assert_eq!(out.output.data, conv2d(&shape, &input, &weights).data);
        assert_eq!(out.latency.cgra_cycles, 0);
        assert!(out.latency.cpu_compute_cycles > 0);
    }

    #[test]
    fn cycles_scale_with_macs() {
        let m = CpuModel::default();
        let small = m.conv_cycles(&ConvShape::new3x3(8, 8, 8, 8));
        let big = m.conv_cycles(&ConvShape::new3x3(16, 8, 8, 8));
        assert!(big > 19 * small / 10, "doubling C should ~double cycles");
    }

    #[test]
    fn mem_traffic_two_loads_per_mac() {
        let shape = ConvShape::baseline();
        let mut rng = Rng::new(2);
        let input = random_input(&shape, 10, &mut rng);
        let weights = random_weights(&shape, 10, &mut rng);
        let out = run(&CpuModel::default(), &shape, &input, &weights).unwrap();
        assert_eq!(out.cpu_mem.loads, 2 * shape.macs());
        assert_eq!(out.cpu_mem.stores, 16 * 16 * 16);
    }
}
