//! Calibration of the energy/timing constants against the paper.
//!
//! We do not have the authors' TSMC-65nm post-synthesis power traces, so
//! every constant below is *anchored* to a number the paper reports and
//! the rest follows from the system structure. The integration test
//! `rust/tests/integration.rs::calibration_anchors` re-checks the anchors
//! end-to-end on every run.
//!
//! | constant | value | paper anchor |
//! |----------|-------|--------------|
//! | `CgraConfig::mem_latency = 4` | DMA port round-trip | WP baseline lands at ≈0.6 MAC/cycle (abstract: "overall average performance of 0.6 MAC/cycle") |
//! | `CgraConfig::mul_latency = 1` | single-cycle PE multiply | WP peak ≈0.665 MAC/cycle at C=K=16, Ox=Oy=64 (§3.2) |
//! | `CgraConfig::launch_overhead = 24` | CPU writes CGRA config regs | Im2col-IP's per-position launches visibly hurt latency (§3.1) |
//! | `CpuModel` = 17.5 cycles/MAC | naive RV32 loop nest | WP vs CPU latency ratio 9.9× (abstract) |
//! | `p_pe_active_mw = 0.115` | per-PE dynamic power | WP system power ≈2.5 mW, "the highest among the CGRA-approaches" (§3.1) |
//! | `p_cpu_active_mw = 0.50`, `p_mem_static_mw = 0.20`, `e_mem_access_pj = 15` | CPU-only avg power ≈0.86 mW | energy 3.4× at latency 9.9× ⇒ P(CPU) ≈ 0.34 × P(WP) |
//! | `e_mem_access_pj = 15` | 65nm SRAM access | memory dynamic energy is "the largest energy-wise discriminative factor" (§3.1): Im2col-OP's 2 loads/MAC dwarf WP's ≈0.45 |
//! | `clock_hz = 100 MHz` | HEEPsilon-class SoC clock | absolute times only; all paper comparisons are ratios |
//!
//! The *shape* of Figure 4 (who wins, roughly by how much) is what these
//! anchors pin down; absolute µJ/ms values are simulator-native.

use super::EnergyModel;

/// The calibrated model (see module docs for the anchor table).
pub const CALIBRATED: EnergyModel = EnergyModel {
    clock_hz: 100.0e6,
    p_cgra_leak_mw: 0.05,
    p_pe_active_mw: 0.115,
    p_cpu_active_mw: 0.50,
    p_cpu_idle_mw: 0.20,
    p_mem_static_mw: 0.20,
    e_mem_access_pj: 15.0,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_is_default() {
        assert_eq!(EnergyModel::default(), CALIBRATED);
    }

    #[test]
    fn constants_are_physically_sane() {
        let m = CALIBRATED;
        assert!(m.clock_hz > 1e6);
        assert!(m.p_cgra_leak_mw > 0.0 && m.p_cgra_leak_mw < 1.0);
        // Full-tilt CGRA should sit in the paper's "< 2.5 mW" class.
        let p_full = m.p_cgra_leak_mw + 16.0 * m.p_pe_active_mw;
        assert!((1.0..3.0).contains(&p_full), "CGRA full power {p_full} mW");
        assert!(m.e_mem_access_pj > 1.0 && m.e_mem_access_pj < 100.0);
    }
}
