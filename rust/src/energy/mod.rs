//! Energy/power model of the minimal HEEPsilon system: CGRA + CPU +
//! memory (paper §2.3: "we consider the power consumption of a complete
//! minimal system, including CGRA, CPU and memory subsystems").
//!
//! Block powers are constants calibrated against the paper's anchors
//! (see [`calibration`]); energies integrate those powers over the
//! latency decomposition of a [`ConvOutcome`], plus a per-access dynamic
//! energy for the memory — the quantity the paper singles out as "the
//! largest energy-wise discriminative factor between methods".

pub mod calibration;

use crate::kernels::{ConvOutcome, Mapping};

/// System-level power/energy constants. Defaults come from
/// [`calibration`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyModel {
    /// System clock (HEEPsilon FPGA/ASIC class runs ~100 MHz).
    pub clock_hz: f64,
    /// CGRA leakage + clock-tree power, mW (always on while the CGRA has
    /// been configured; the CPU-only baseline clock-gates it).
    pub p_cgra_leak_mw: f64,
    /// Dynamic power of one *active* PE slot, mW (scaled by measured
    /// utilization).
    pub p_pe_active_mw: f64,
    /// CPU active power (computing / building im2col), mW.
    pub p_cpu_active_mw: f64,
    /// CPU busy-wait power (polling the CGRA interrupt), mW.
    pub p_cpu_idle_mw: f64,
    /// Memory static power, mW.
    pub p_mem_static_mw: f64,
    /// Dynamic energy per 32-bit memory access, pJ.
    pub e_mem_access_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        calibration::CALIBRATED
    }
}

/// Energy decomposition of one convolution execution (µJ).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// CGRA block energy.
    pub cgra_uj: f64,
    /// CPU block energy (active + busy-wait).
    pub cpu_uj: f64,
    /// Memory static energy.
    pub mem_static_uj: f64,
    /// Memory dynamic (per-access) energy.
    pub mem_dynamic_uj: f64,
    /// Wall-clock of the execution, ms.
    pub latency_ms: f64,
}

impl EnergyBreakdown {
    /// Total energy, µJ.
    pub fn total_uj(&self) -> f64 {
        self.cgra_uj + self.cpu_uj + self.mem_static_uj + self.mem_dynamic_uj
    }

    /// Average system power, mW.
    pub fn avg_power_mw(&self) -> f64 {
        if self.latency_ms <= 0.0 {
            0.0
        } else {
            self.total_uj() / self.latency_ms
        }
    }
}

impl EnergyModel {
    /// Integrate the model over one execution.
    pub fn evaluate(&self, out: &ConvOutcome) -> EnergyBreakdown {
        let total_cycles = out.latency.total_cycles() as f64;
        let t_total_s = total_cycles / self.clock_hz;
        let t_cgra_s = out.latency.cgra_cycles as f64 / self.clock_hz;
        let t_cpu_active_s =
            (out.latency.cpu_active_cycles() as f64 / self.clock_hz).min(t_total_s);

        // CGRA: leakage whenever present + per-PE activity. The CPU-only
        // baseline power-gates the accelerator.
        let cgra_uj = if out.mapping == Mapping::Cpu {
            0.0
        } else {
            let active_mw = self.p_cgra_leak_mw
                + self.p_pe_active_mw
                    * crate::isa::N_PES as f64
                    * out.cgra_stats.utilization();
            active_mw * t_cgra_s * 1e3
                + self.p_cgra_leak_mw * (t_total_s - t_cgra_s).max(0.0) * 1e3
        };

        let cpu_uj = (self.p_cpu_active_mw * t_cpu_active_s
            + self.p_cpu_idle_mw * (t_total_s - t_cpu_active_s).max(0.0))
            * 1e3;

        let mem_static_uj = self.p_mem_static_mw * t_total_s * 1e3;
        let accesses = (out.cgra_stats.mem.total() + out.cpu_mem.total()) as f64;
        let mem_dynamic_uj = accesses * self.e_mem_access_pj * 1e-6;

        EnergyBreakdown {
            cgra_uj,
            cpu_uj,
            mem_static_uj,
            mem_dynamic_uj,
            latency_ms: t_total_s * 1e3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::RunStats;
    use crate::conv::{ConvShape, TensorChw};
    use crate::kernels::LatencyBreakdown;

    fn fake_outcome(mapping: Mapping, cycles: u64, accesses: u64) -> ConvOutcome {
        let shape = ConvShape::baseline();
        let mut stats = RunStats::new();
        stats.cycles = cycles;
        stats.mem.loads = accesses;
        ConvOutcome {
            mapping,
            shape,
            output: TensorChw::zeros(1, 1, 1),
            latency: LatencyBreakdown {
                cgra_cycles: if mapping == Mapping::Cpu { 0 } else { cycles },
                cpu_compute_cycles: if mapping == Mapping::Cpu { cycles } else { 0 },
                ..Default::default()
            },
            cgra_stats: stats,
            cpu_mem: Default::default(),
            footprint_bytes: 0,
        }
    }

    #[test]
    fn more_accesses_cost_more_energy() {
        let m = EnergyModel::default();
        let lo = m.evaluate(&fake_outcome(Mapping::Wp, 1000, 10));
        let hi = m.evaluate(&fake_outcome(Mapping::Wp, 1000, 10_000));
        assert!(hi.total_uj() > lo.total_uj());
        assert_eq!(hi.mem_static_uj, lo.mem_static_uj);
    }

    #[test]
    fn cpu_mapping_has_no_cgra_energy() {
        let m = EnergyModel::default();
        let e = m.evaluate(&fake_outcome(Mapping::Cpu, 1000, 0));
        assert_eq!(e.cgra_uj, 0.0);
        assert!(e.cpu_uj > 0.0);
    }

    #[test]
    fn avg_power_is_energy_over_time() {
        let m = EnergyModel::default();
        let e = m.evaluate(&fake_outcome(Mapping::Wp, 123_456, 999));
        assert!((e.avg_power_mw() - e.total_uj() / e.latency_ms).abs() < 1e-12);
        assert!(e.avg_power_mw() > 0.0);
    }
}
