//! Regeneration of the paper's figures as text tables + CSV + ASCII
//! charts. Each `figN` function drives a shared [`Engine`] session and
//! returns the rendered report and the raw rows; the benches and the
//! `cgra report` subcommand print/save them.

use anyhow::Result;

use crate::cgra::OpClass;
use crate::conv::ConvShape;
use crate::coordinator::{SweepRow, SweepSpec};
use crate::engine::Engine;
use crate::kernels::Mapping;
use crate::util::fmt::{bar_chart, kib, Table};

/// A rendered report: human text + CSV + the metric rows.
#[derive(Clone, Debug)]
pub struct Figure {
    /// Figure id, e.g. `fig4`.
    pub id: String,
    /// Rendered text (tables + charts + findings).
    pub text: String,
    /// CSV of the underlying data.
    pub csv: String,
}

impl Figure {
    /// Write `<id>.txt` and `<id>.csv` into `dir`.
    pub fn save(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}.txt", self.id)), &self.text)?;
        std::fs::write(dir.join(format!("{}.csv", self.id)), &self.csv)?;
        Ok(())
    }
}

/// **Figure 3** — operation distribution of the mapping strategies'
/// executed slots, plus PE utilization.
pub fn fig3(engine: &Engine) -> Result<Figure> {
    let shape = ConvShape::baseline();
    let rows = engine.run_all_mappings(&shape, 3)?;
    let mut table = Table::new(&[
        "mapping", "load", "mul", "sum", "store", "other", "nop", "utilization",
    ]);
    let mut text = String::from(
        "Figure 3 — operation distribution over executed PE slots\n\
         (baseline layer C=K=Ox=Oy=16, 3x3; whole-run measurement incl. borders)\n\n",
    );
    for r in rows.iter().filter(|r| r.mapping != Mapping::Cpu) {
        let mut cells = vec![r.mapping.label().to_string()];
        for c in OpClass::ALL {
            cells.push(format!("{:.3}", r.op_mix[c.idx()]));
        }
        cells.push(format!("{:.1}%", r.utilization * 100.0));
        table.row(cells);
    }
    text.push_str(&table.render());
    text.push_str(
        "\npaper anchors: WP main-loop utilization 78%, the three other\n\
         mappings share one 8-instruction loop at 69% (most PEs nop in the\n\
         tail slots). Expect WP's mix to be mul/sum-heavy and the others\n\
         load-dominated.\n",
    );
    Ok(Figure { id: "fig3".into(), text, csv: table.to_csv() })
}

/// **Figure 4** — energy vs latency of every strategy on the baseline
/// layer, with the paper's headline ratios.
pub fn fig4(engine: &Engine) -> Result<Figure> {
    let shape = ConvShape::baseline();
    let rows = engine.run_all_mappings(&shape, 4)?;
    let mut table = Table::new(&[
        "mapping",
        "latency_ms",
        "energy_uJ",
        "power_mW",
        "MAC/cycle",
        "mem_dyn_uJ",
        "launches",
    ]);
    for r in &rows {
        table.row(vec![
            r.mapping.label().into(),
            format!("{:.3}", r.latency_ms),
            format!("{:.2}", r.energy_uj),
            format!("{:.2}", r.avg_power_mw),
            format!("{:.3}", r.mac_per_cycle),
            format!("{:.2}", r.energy.mem_dynamic_uj),
            r.launches.to_string(),
        ]);
    }
    let wp = rows.iter().find(|r| r.mapping == Mapping::Wp).unwrap();
    let cpu = rows.iter().find(|r| r.mapping == Mapping::Cpu).unwrap();
    let lat_ratio = cpu.latency_cycles as f64 / wp.latency_cycles as f64;
    let e_ratio = cpu.energy_uj / wp.energy_uj;

    let mut text = String::from(
        "Figure 4 — energy vs latency, baseline layer (C=K=Ox=Oy=16, 3x3)\n\n",
    );
    text.push_str(&table.render());
    text.push_str("\nlatency (normalized to WP):\n");
    text.push_str(&bar_chart(
        &rows
            .iter()
            .map(|r| {
                (r.mapping.label().to_string(), r.latency_cycles as f64 / wp.latency_cycles as f64)
            })
            .collect::<Vec<_>>(),
        40,
    ));
    text.push_str("\nenergy (normalized to WP):\n");
    text.push_str(&bar_chart(
        &rows
            .iter()
            .map(|r| (r.mapping.label().to_string(), r.energy_uj / wp.energy_uj))
            .collect::<Vec<_>>(),
        40,
    ));
    text.push_str(&format!(
        "\nheadline (paper: latency 9.9x, energy 3.4x, WP ~0.6 MAC/cycle, ~2.5 mW):\n\
         measured: CPU/WP latency {lat_ratio:.2}x | CPU/WP energy {e_ratio:.2}x | \
         WP {:.3} MAC/cycle | WP {:.2} mW\n",
        wp.mac_per_cycle, wp.avg_power_mw
    ));
    Ok(Figure { id: "fig4".into(), text, csv: table.to_csv() })
}

/// **Figure 5** — hyper-parameter sweep: MAC/cycle and memory footprint
/// per mapping along the C / K / Ox=Oy axes.
pub fn fig5(engine: &Engine, spec: &SweepSpec) -> Result<Figure> {
    let rows = engine.sweep(spec)?;
    let mut table =
        Table::new(&["axis", "value", "mapping", "MAC/cycle", "memory", "skipped"]);
    for r in &rows {
        table.row(vec![
            r.point.axis.label().into(),
            r.point.value.to_string(),
            r.point.mapping.label().into(),
            r.report.as_ref().map(|m| format!("{:.3}", m.mac_per_cycle)).unwrap_or_default(),
            r.report.as_ref().map(|m| kib(m.footprint_bytes)).unwrap_or_default(),
            r.skipped.as_deref().map(|_| "mem-bound".to_string()).unwrap_or_default(),
        ]);
    }
    let mut text = String::from("Figure 5 — hyper-parameter robustness sweep\n\n");
    text.push_str(&table.render());
    text.push_str(&findings(&rows));
    Ok(Figure { id: "fig5".into(), text, csv: table.to_csv() })
}

/// **Planner validation** — predicted-vs-simulated comparison of the
/// analytical cost model over a sweep grid **plus the nn extension
/// points** (one depthwise and one strided layer — see
/// [`crate::planner::validate_extended`]), packaged as a persistable
/// [`Figure`] (id `planner`) alongside the raw
/// [`crate::planner::ValidationReport`]. `cgra plan --validate` prints
/// and saves it; CI gates on the report's mean absolute latency error.
pub fn planner_fig(
    engine: &Engine,
    spec: &SweepSpec,
) -> Result<(Figure, crate::planner::ValidationReport)> {
    let report = crate::planner::validate_extended(engine, spec)?;
    let figure = Figure {
        id: "planner".into(),
        text: report.render(),
        csv: report.table().to_csv(),
    };
    Ok((figure, report))
}

/// Render an executed network report (`cgra net`) as a persistable
/// [`Figure`] (id `net-<name>`): per-layer rows — cycles, energy,
/// chosen mapping, CPU-baseline speedup — plus network totals.
pub fn net_fig(report: &crate::nn::NetworkReport) -> Figure {
    let mut table = Table::new(&[
        "layer", "kind", "shape", "mapping", "cycles", "conv_cycles", "host_cycles",
        "energy_uJ", "MAC/cycle", "cpu_speedup", "exact",
    ]);
    for l in &report.layers {
        table.row(vec![
            l.index.to_string(),
            l.kind.into(),
            l.desc.clone(),
            l.mapping.map(|m| m.label().to_string()).unwrap_or_else(|| "host".into()),
            l.cycles.to_string(),
            l.conv_cycles.to_string(),
            l.host_cycles.to_string(),
            format!("{:.2}", l.energy_uj),
            format!("{:.3}", l.macs as f64 / l.cycles.max(1) as f64),
            l.speedup().map(|s| format!("{s:.2}x")).unwrap_or_default(),
            if l.exact { "yes".into() } else { "NO".into() },
        ]);
    }
    let mut text = format!(
        "Network '{}' on the simulated CGRA — per-layer planner-chosen mappings\n\n",
        report.name
    );
    text.push_str(&table.render());
    text.push_str(&format!(
        "\ntotal: {} cycles, {:.2} uJ, {:.3} MAC/cycle, {:.2}x vs scalar CPU, \
         output exact vs generalized golden: {}\n",
        report.total_cycles,
        report.total_energy_uj,
        report.mac_per_cycle(),
        report.speedup(),
        report.exact,
    ));
    Figure { id: format!("net-{}", report.name), text, csv: table.to_csv() }
}

/// Render a plan-only network report (`cgra net --plan-only`) as a
/// persistable [`Figure`] (id `net-<name>-plan`). No layer was
/// simulated; every number is the cost model's prediction.
pub fn net_plan_fig(plan: &crate::nn::NetPlan) -> Figure {
    let mut table = Table::new(&[
        "layer", "kind", "shape", "mapping", "pred_cycles", "pred_conv", "pred_host",
        "pred_uJ", "cpu_cycles",
    ]);
    for l in &plan.layers {
        table.row(vec![
            l.index.to_string(),
            l.kind.into(),
            l.desc.clone(),
            l.mapping.map(|m| m.label().to_string()).unwrap_or_else(|| "host".into()),
            l.cycles.to_string(),
            l.conv_cycles.to_string(),
            l.host_cycles.to_string(),
            format!("{:.2}", l.energy_uj),
            l.cpu_cycles.to_string(),
        ]);
    }
    let mut text = format!(
        "Network '{}' — planned per layer (objective: {}), no layer simulated\n\n",
        plan.name,
        plan.objective.label()
    );
    text.push_str(&table.render());
    text.push_str(&format!(
        "\npredicted total: {} cycles, {:.2} uJ\n",
        plan.total_cycles, plan.total_energy_uj
    ));
    Figure { id: format!("net-{}-plan", plan.name), text, csv: table.to_csv() }
}

/// Summarize the paper's §3.2 claims against the sweep rows.
fn findings(rows: &[SweepRow]) -> String {
    let mut out = String::from("\nfindings vs paper §3.2:\n");
    // (1) WP best everywhere.
    let mut wp_dominates = true;
    let mut keyed: std::collections::BTreeMap<(String, usize), Vec<&SweepRow>> =
        Default::default();
    for r in rows {
        keyed.entry((r.point.axis.label().to_string(), r.point.value)).or_default().push(r);
    }
    for group in keyed.values() {
        let best = group
            .iter()
            .filter_map(|r| r.report.as_ref().map(|m| (r.point.mapping, m.mac_per_cycle)))
            .max_by(|a, b| a.1.total_cmp(&b.1));
        if let Some((m, _)) = best {
            if m != Mapping::Wp {
                wp_dominates = false;
            }
        }
    }
    out.push_str(&format!(
        "  [{}] WP is the best mapping at every point (paper: \"WP remains the best \
         approach for any hyperparameter combination\")\n",
        if wp_dominates { "ok" } else { "MISS" }
    ));
    // (2) peak WP MAC/cycle (paper: 0.665 at C=K=16, Ox=Oy=64).
    let peak = rows
        .iter()
        .filter(|r| r.point.mapping == Mapping::Wp)
        .filter_map(|r| r.report.as_ref().map(|m| (r.point.value, m.mac_per_cycle)))
        .max_by(|a, b| a.1.total_cmp(&b.1));
    if let Some((v, p)) = peak {
        out.push_str(&format!(
            "  peak WP performance {p:.3} MAC/cycle at axis value {v} (paper: 0.665 at 64)\n"
        ));
    }
    // (3) the =17 collapse for the parallelized dimension.
    for (axis, mapping) in [("K", Mapping::OpIm2col), ("K", Mapping::OpDirect), ("C", Mapping::Ip)]
    {
        let at = |val: usize| {
            rows.iter()
                .find(|r| {
                    r.point.axis.label() == axis
                        && r.point.value == val
                        && r.point.mapping == mapping
                })
                .and_then(|r| r.report.as_ref().map(|m| m.mac_per_cycle))
        };
        if let (Some(a16), Some(a17)) = (at(16), at(17)) {
            out.push_str(&format!(
                "  {} at {axis}=17 drops to {:.2}x of its {axis}=16 performance \
                 (paper: sharp dip when dim % 16 == 1)\n",
                mapping.label(),
                a17 / a16
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineBuilder;

    fn quick_engine() -> Engine {
        EngineBuilder::new().workers(4).build().unwrap()
    }

    #[test]
    fn fig3_renders_mappings() {
        let f = fig3(&quick_engine()).unwrap();
        assert!(f.text.contains("Conv-WP"));
        assert!(f.text.contains("Im2col-IP"));
        assert!(f.csv.lines().count() >= 5);
        assert!(!f.text.contains("CPU,")); // fig3 is CGRA-only
    }

    #[test]
    fn fig4_headline_ratios_in_band() {
        let f = fig4(&quick_engine()).unwrap();
        assert!(f.text.contains("headline"));
        // Extract the measured ratios from the text.
        let line = f.text.lines().find(|l| l.contains("CPU/WP latency")).unwrap();
        let lat: f64 = line
            .split("latency ")
            .nth(1)
            .unwrap()
            .split('x')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(
            (7.0..13.0).contains(&lat),
            "latency ratio {lat} far from the paper's 9.9x"
        );
    }

    #[test]
    fn fig5_quick_sweep_has_findings() {
        let spec = SweepSpec {
            c_values: vec![16, 17],
            k_values: vec![16, 17],
            spatial_values: vec![16],
            mappings: Mapping::ALL.to_vec(),
            mag: 10,
            seed: 9,
        };
        let f = fig5(&quick_engine(), &spec).unwrap();
        assert!(f.text.contains("findings"));
        assert!(f.text.contains("WP is the best mapping"));
        assert!(f.text.contains("=17"));
    }

    #[test]
    fn planner_fig_renders_and_reports() {
        let spec = SweepSpec {
            c_values: vec![2],
            k_values: vec![],
            spatial_values: vec![],
            mappings: vec![Mapping::Wp, Mapping::Cpu],
            mag: 8,
            seed: 3,
        };
        let engine = EngineBuilder::new().workers(2).private_cache().build().unwrap();
        let (fig, report) = planner_fig(&engine, &spec).unwrap();
        assert_eq!(fig.id, "planner");
        assert!(fig.text.contains("mean |err|"));
        assert!(fig.csv.contains("pred_cycles"));
        // 2 grid rows + the DW and stride extension rows.
        assert_eq!(report.rows.len(), 4);
        assert!(report.rows.iter().any(|r| r.axis == "DW"));
        assert!(report.rows.iter().any(|r| r.axis == "stride"));
        assert_eq!(report.bound_mismatches, 0);
    }

    #[test]
    fn net_figs_render_executed_and_planned_networks() {
        let engine = EngineBuilder::new().workers(2).private_cache().build().unwrap();
        let net = crate::nn::build_preset("vgg-mini", 4).unwrap();
        let input = net.random_input(8, 4);
        let report = crate::nn::run_network(&engine, &net, &input).unwrap();
        let fig = net_fig(&report);
        assert_eq!(fig.id, "net-vgg-mini");
        assert!(fig.text.contains("maxpool") && fig.text.contains("host"));
        assert!(fig.text.contains("exact vs generalized golden: true"));
        assert!(fig.csv.contains("cpu_speedup"));
        let plan = crate::nn::plan_network(
            engine.planner(),
            &net,
            crate::planner::PlanObjective::Latency,
        )
        .unwrap();
        let pfig = net_plan_fig(&plan);
        assert_eq!(pfig.id, "net-vgg-mini-plan");
        assert!(pfig.text.contains("no layer simulated"));
    }

    #[test]
    fn figure_save_writes_files() {
        let f = Figure { id: "t".into(), text: "x".into(), csv: "a\n1\n".into() };
        let dir = std::env::temp_dir().join(format!("cgra-fig-test-{}", std::process::id()));
        f.save(&dir).unwrap();
        assert!(dir.join("t.txt").exists());
        assert!(dir.join("t.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
