//! Cross-language verification: CGRA simulator ⇔ Rust golden model ⇔
//! AOT-compiled JAX/Pallas artifact, all bit-exact on int32.

use anyhow::{Context, Result};

use crate::conv::{conv2d, random_input, random_weights};
use crate::coordinator::{golden_network, ConvNet};
use crate::engine::{ConvRequest, Engine, EngineBuilder};
use crate::kernels::Mapping;
use crate::prop::Rng;

use super::artifact::{ArtifactKind, ArtifactSpec, Manifest};
use super::Runtime;

/// Result of verifying one artifact.
#[derive(Clone, Debug)]
pub struct VerifyRow {
    /// Artifact name.
    pub name: String,
    /// Elements compared.
    pub elements: usize,
    /// Whether artifact == golden == CGRA simulator.
    pub passed: bool,
    /// Mismatch description (empty when passed).
    pub detail: String,
}

/// Aggregate verification report.
#[derive(Clone, Debug, Default)]
pub struct VerifySummary {
    /// Per-artifact rows.
    pub rows: Vec<VerifyRow>,
}

impl VerifySummary {
    /// True if every artifact verified.
    pub fn all_passed(&self) -> bool {
        !self.rows.is_empty() && self.rows.iter().all(|r| r.passed)
    }
}

impl std::fmt::Display for VerifySummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "verification: CGRA simulator vs Rust golden vs XLA artifact (bit-exact int32)"
        )?;
        for r in &self.rows {
            writeln!(
                f,
                "  [{}] {:<32} {} elements{}",
                if r.passed { "ok" } else { "FAIL" },
                r.name,
                r.elements,
                if r.detail.is_empty() { String::new() } else { format!(" — {}", r.detail) }
            )?;
        }
        write!(
            f,
            "{}/{} artifacts verified",
            self.rows.iter().filter(|r| r.passed).count(),
            self.rows.len()
        )
    }
}

fn seed_for(name: &str) -> u64 {
    // FNV-1a over the artifact name: deterministic per artifact.
    name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
    })
}

/// Verify one artifact against the engine's simulator session (see
/// module docs).
pub fn verify_artifact(
    engine: &Engine,
    rt: &Runtime,
    dir: &std::path::Path,
    spec: &ArtifactSpec,
) -> Result<VerifyRow> {
    let loaded = rt.load(dir, spec)?;
    let mut rng = Rng::new(seed_for(&spec.name));

    let (xla_out, golden, sim, n) = match spec.kind {
        ArtifactKind::Conv => {
            let shape = spec.conv_shape();
            let input = random_input(&shape, 40, &mut rng);
            let weights = random_weights(&shape, 9, &mut rng);
            let xla_out = loaded.execute_conv(&input, &weights)?;
            let golden = conv2d(&shape, &input, &weights).data;
            // Exercise the mapping matching the artifact's kernel kind.
            let mapping =
                if spec.kernel == "im2col" { Mapping::OpIm2col } else { Mapping::Wp };
            let sim = engine
                .submit(&ConvRequest::with_data(shape, mapping, input, weights))?
                .output
                .data;
            let n = golden.len();
            (xla_out, golden, sim, n)
        }
        ArtifactKind::Cnn => {
            let net = ConvNet::random(spec.depth, spec.c, spec.k, spec.h, spec.w, 1234);
            let input = random_input(&net.layers[0].shape, 8, &mut rng);
            let ws: Vec<&crate::conv::Weights> =
                net.layers.iter().map(|l| &l.weights).collect();
            let xla_out = loaded.execute_cnn(&input, &ws)?;
            let golden = golden_network(&net, &input)?.data;
            let sim = engine.run_network(&net, &input)?.output.data;
            let n = golden.len();
            (xla_out, golden, sim, n)
        }
    };

    let detail = if xla_out.len() != n {
        format!("artifact returned {} elements, expected {n}", xla_out.len())
    } else if let Some(i) = (0..n).find(|&i| xla_out[i] != golden[i]) {
        format!("artifact[{i}]={} != golden[{i}]={}", xla_out[i], golden[i])
    } else if let Some(i) = (0..n).find(|&i| sim[i] != golden[i]) {
        format!("simulator[{i}]={} != golden[{i}]={}", sim[i], golden[i])
    } else {
        String::new()
    };
    Ok(VerifyRow { name: spec.name.clone(), elements: n, passed: detail.is_empty(), detail })
}

/// Verify every artifact in the manifest through one engine session.
pub fn verify_all(dir: &std::path::Path) -> Result<VerifySummary> {
    let manifest = Manifest::load(dir)?;
    let rt = Runtime::cpu().context("PJRT client")?;
    let engine = EngineBuilder::new().build()?;
    let mut summary = VerifySummary::default();
    for spec in &manifest.artifacts {
        let row = verify_artifact(&engine, &rt, dir, spec)
            .with_context(|| format!("verifying artifact '{}'", spec.name))?;
        summary.rows.push(row);
    }
    Ok(summary)
}
