//! PJRT runtime: load the AOT-compiled JAX/Pallas HLO artifacts and
//! execute them from Rust — Python is never on this path.
//!
//! `artifacts/manifest.json` (written by `python -m compile.aot`) lists
//! the available computations; [`Runtime`] compiles them on the PJRT CPU
//! client; [`verify_all`] replays each against the CGRA simulator (WP
//! mapping) *and* the pure-Rust golden model with deterministic data and
//! demands bit-exact int32 agreement — the cross-language correctness
//! gate of the whole reproduction.
//!
//! # Feature gating (DESIGN.md "Dependency reality")
//!
//! The PJRT/XLA path needs the `xla` crate and its native XLA libraries,
//! which the offline CI image does not ship. It is therefore gated
//! behind the **`pjrt`** cargo feature: without it, [`Runtime`] is a
//! stub whose constructor returns an actionable error, so the crate —
//! and every test that *skips* when `artifacts/` is absent — builds and
//! runs everywhere. Enabling `pjrt` requires adding the `xla` dependency
//! on a machine that has the toolchain (see `rust/Cargo.toml`).

mod artifact;
mod verify;

pub use artifact::{ArtifactKind, ArtifactSpec, Manifest};
pub use verify::{verify_all, verify_artifact, VerifyRow, VerifySummary};

use crate::conv::{TensorChw, Weights};

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use anyhow::{Context, Result};

    use super::{ArtifactSpec, TensorChw, Weights};

    /// A compiled artifact ready to execute.
    pub struct LoadedArtifact {
        /// Manifest entry.
        pub spec: ArtifactSpec,
        exe: xla::PjRtLoadedExecutable,
    }

    /// PJRT CPU client + artifact loader.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// Create the PJRT CPU client.
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client })
        }

        /// Backend platform name (e.g. `cpu`).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile one artifact from HLO text.
        pub fn load(&self, dir: &std::path::Path, spec: &ArtifactSpec) -> Result<LoadedArtifact> {
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{}'", spec.name))?;
            Ok(LoadedArtifact { spec: spec.clone(), exe })
        }
    }

    /// Build an int32 literal with the given dimensions.
    fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "literal dims {dims:?} != len {}", data.len());
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }

    impl LoadedArtifact {
        /// Execute with raw int32 literals; unwraps the 1-tuple result.
        pub fn execute_raw(&self, args: &[xla::Literal]) -> Result<Vec<i32>> {
            let result = self.exe.execute::<xla::Literal>(args)?[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
            Ok(out.to_vec::<i32>()?)
        }

        /// Execute a `conv` artifact: input CHW + weights KCFF → output KHW.
        pub fn execute_conv(&self, input: &TensorChw, weights: &Weights) -> Result<Vec<i32>> {
            let x =
                literal_i32(&input.data, &[input.c as i64, input.h as i64, input.w as i64])?;
            let w = literal_i32(&weights.data, &[weights.k as i64, weights.c as i64, 3, 3])?;
            self.execute_raw(&[x, w])
        }

        /// Execute a `cnn` artifact: input + one weight tensor per layer.
        pub fn execute_cnn(
            &self,
            input: &TensorChw,
            layer_weights: &[&Weights],
        ) -> Result<Vec<i32>> {
            let mut args = vec![literal_i32(
                &input.data,
                &[input.c as i64, input.h as i64, input.w as i64],
            )?];
            for w in layer_weights {
                args.push(literal_i32(&w.data, &[w.k as i64, w.c as i64, 3, 3])?);
            }
            self.execute_raw(&args)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{LoadedArtifact, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use anyhow::{bail, Result};

    use super::{ArtifactSpec, TensorChw, Weights};

    const UNAVAILABLE: &str = "PJRT runtime unavailable: this build was compiled without the \
         `pjrt` feature (the offline image ships no `xla` crate). Rebuild with \
         `--features pjrt` on a machine with the XLA toolchain, or run the \
         pure-Rust verification paths instead";

    /// Stub standing in for the PJRT client when `pjrt` is disabled.
    /// Construction always fails with an actionable message; callers that
    /// skip on missing artifacts never reach it.
    pub struct Runtime {
        _private: (),
    }

    /// Stub counterpart of the compiled artifact.
    pub struct LoadedArtifact {
        /// Manifest entry.
        pub spec: ArtifactSpec,
    }

    impl Runtime {
        /// Always fails in stub builds.
        pub fn cpu() -> Result<Runtime> {
            bail!(UNAVAILABLE)
        }

        /// Stub platform name.
        pub fn platform(&self) -> String {
            "unavailable (pjrt feature disabled)".to_string()
        }

        /// Always fails in stub builds.
        pub fn load(
            &self,
            _dir: &std::path::Path,
            _spec: &ArtifactSpec,
        ) -> Result<LoadedArtifact> {
            bail!(UNAVAILABLE)
        }
    }

    impl LoadedArtifact {
        /// Always fails in stub builds.
        pub fn execute_conv(&self, _input: &TensorChw, _weights: &Weights) -> Result<Vec<i32>> {
            bail!(UNAVAILABLE)
        }

        /// Always fails in stub builds.
        pub fn execute_cnn(
            &self,
            _input: &TensorChw,
            _layer_weights: &[&Weights],
        ) -> Result<Vec<i32>> {
            bail!(UNAVAILABLE)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{LoadedArtifact, Runtime};

#[cfg(test)]
mod tests {
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_fails_actionably() {
        let err = super::Runtime::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
