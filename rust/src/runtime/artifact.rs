//! Artifact manifest parsing (`artifacts/manifest.json`).

use anyhow::{bail, Context, Result};

use crate::util::json::{parse, Json};

/// What a lowered computation is.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ArtifactKind {
    /// Single conv layer: args (input, weights) → output.
    Conv,
    /// CNN forward: args (input, w0, …, w_{depth-1}) → output.
    Cnn,
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Unique name, e.g. `conv_direct_c16k16o16x16`.
    pub name: String,
    /// File name (HLO text) relative to the artifact dir.
    pub file: String,
    /// Conv or CNN.
    pub kind: ArtifactKind,
    /// Which Layer-1 kernel was lowered (`direct` or `im2col`).
    pub kernel: String,
    /// Conv: (C, K, Ox, Oy). CNN: C = c0, K = per-layer k.
    pub c: usize,
    /// Output channels / per-layer channels.
    pub k: usize,
    /// Conv: output rows. CNN: unused (0).
    pub ox: usize,
    /// Conv: output cols. CNN: unused (0).
    pub oy: usize,
    /// CNN: input height/width and depth (0 for conv).
    pub h: usize,
    /// CNN input width.
    pub w: usize,
    /// CNN depth.
    pub depth: usize,
}

impl ArtifactSpec {
    /// Conv shape of a `Conv` artifact.
    pub fn conv_shape(&self) -> crate::conv::ConvShape {
        crate::conv::ConvShape::new3x3(self.c, self.k, self.ox, self.oy)
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// All artifacts, in manifest order.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `manifest.json` from the artifact directory.
    pub fn load(dir: &std::path::Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` to build the AOT artifacts first",
                path.display()
            )
        })?;
        Self::parse_text(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse_text(text: &str) -> Result<Manifest> {
        let v = parse(text).context("parsing manifest.json")?;
        let fmt = v.req_i64("format")?;
        if fmt != 1 {
            bail!("unsupported manifest format {fmt}");
        }
        let arr = v
            .req("artifacts")?
            .as_arr()
            .context("'artifacts' is not an array")?;
        let mut artifacts = Vec::new();
        for (i, a) in arr.iter().enumerate() {
            artifacts.push(
                Self::parse_entry(a).with_context(|| format!("artifact entry {i}"))?,
            );
        }
        Ok(Manifest { artifacts })
    }

    fn parse_entry(a: &Json) -> Result<ArtifactSpec> {
        let kind = match a.req_str("kind")? {
            "conv" => ArtifactKind::Conv,
            "cnn" => ArtifactKind::Cnn,
            other => bail!("unknown artifact kind '{other}'"),
        };
        let get = |k: &str| a.get(k).and_then(|v| v.as_i64()).unwrap_or(0) as usize;
        Ok(ArtifactSpec {
            name: a.req_str("name")?.to_string(),
            file: a.req_str("file")?.to_string(),
            kind,
            kernel: a.req_str("kernel")?.to_string(),
            c: if kind == ArtifactKind::Conv { a.req_i64("c")? as usize } else { get("c0") },
            k: a.req_i64("k")? as usize,
            ox: get("ox"),
            oy: get("oy"),
            h: get("h"),
            w: get("w"),
            depth: get("depth"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "artifacts": [
        {"name": "conv_direct_c2k3o4x5", "file": "conv.hlo.txt", "kind": "conv",
         "kernel": "direct", "c": 2, "k": 3, "ox": 4, "oy": 5},
        {"name": "cnn_direct", "file": "cnn.hlo.txt", "kind": "cnn",
         "kernel": "direct", "c0": 3, "k": 8, "h": 12, "w": 12, "depth": 3}
      ]
    }"#;

    #[test]
    fn parses_both_kinds() {
        let m = Manifest::parse_text(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let conv = &m.artifacts[0];
        assert_eq!(conv.kind, ArtifactKind::Conv);
        assert_eq!(conv.conv_shape().id(), "c2k3o4x5");
        let cnn = &m.artifacts[1];
        assert_eq!(cnn.kind, ArtifactKind::Cnn);
        assert_eq!((cnn.c, cnn.k, cnn.h, cnn.w, cnn.depth), (3, 8, 12, 12, 3));
    }

    #[test]
    fn rejects_bad_format_or_kind() {
        assert!(Manifest::parse_text(r#"{"format": 2, "artifacts": []}"#).is_err());
        let bad = r#"{"format": 1, "artifacts": [{"name":"x","file":"f","kind":"zap","kernel":"d"}]}"#;
        assert!(Manifest::parse_text(bad).is_err());
    }

    #[test]
    fn load_errors_mention_make_artifacts() {
        let e = Manifest::load(std::path::Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(format!("{e:#}").contains("make artifacts"));
    }
}
